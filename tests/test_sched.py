"""Cluster-dispatcher integration tests: straggler avoidance, elastic
events, and ESDP vs greedy on the roofline-grounded instance."""
import numpy as np
import pytest

from repro.sched import ClusterSim, JobType, Slice, build_instance, rate_matrix


@pytest.fixture(scope="module")
def cluster():
    slices = [
        Slice("pod-a", "v5e", 256, 32, 4),
        Slice("pod-b", "v5e", 256, 32, 4),
        Slice("pod-c", "v5e", 256, 32, 4),
        Slice("pod-d", "v5p", 256, 32, 4),
    ]
    jobs = [
        JobType("qwen-train", "qwen2.5-32b", "train_4k", ("v5e", "v5p"),
                256, 32, 4, value_rate=1.0),
        JobType("mamba-train", "mamba2-2.7b", "train_4k", ("v5e",),
                256, 32, 4, value_rate=0.6),
        JobType("ds-decode", "deepseek-v3-671b", "decode_32k", ("v5e", "v5p"),
                256, 32, 4, value_rate=1.4),
        JobType("whisper", "whisper-medium", "train_4k", ("v5p",),
                256, 32, 4, value_rate=0.5),
    ]
    rates = rate_matrix(jobs, slices,
                        slice_speed={"pod-b": 0.55})  # chronic straggler
    inst, edge_rate = build_instance(slices, jobs, rates, seed=0)
    return slices, jobs, inst


def test_instance_construction(cluster):
    slices, jobs, inst = cluster
    assert inst.n_ports == len(jobs)
    assert inst.n_servers == len(slices)
    # service locality respected: whisper (v5p-only) has no v5e edges
    wl = [e for e in inst.edges if e[0] == 3]
    assert all(slices[e[1]].accel == "v5p" for e in wl)
    assert np.all(inst.A <= inst.c[:, None])


def test_esdp_beats_greedy_on_cluster(cluster):
    _, _, inst = cluster
    T = 600
    esdp = ClusterSim(inst, T, seed=3).run("esdp")
    for pol in ("hswf", "lcf", "lwtf"):
        base = ClusterSim(inst, T, seed=3).run(pol, tiebreak=0.0)
        assert esdp.asw > base.asw, pol


def test_straggler_avoidance(cluster):
    """A slice that degrades mid-run loses dispatch share under ESDP."""
    slices, jobs, inst = cluster
    T = 800
    R = inst.n_servers

    def speed_fn(t):
        s = np.ones(R, np.float32)
        if t > T // 3:
            s[0] = 0.3  # pod-a brownout after t=T/3
        return s

    out = ClusterSim(inst, T, speed_fn=speed_fn, seed=1).run("esdp")
    early = out.dispatch_share[:T // 3, 0].mean()
    late = out.dispatch_share[-T // 4:, 0].mean()
    assert late < early * 0.6, (early, late)


def test_elastic_slice_loss(cluster):
    """A dead slice receives ZERO dispatches while dead, and traffic
    resumes after it rejoins (elastic scale-down/up)."""
    _, _, inst = cluster
    T = 300
    R = inst.n_servers
    dead = (100, 200)

    def alive_fn(t):
        a = np.ones(R, bool)
        if dead[0] <= t < dead[1]:
            a[1] = False
        return a

    out = ClusterSim(inst, T, alive_fn=alive_fn, seed=2).run("esdp")
    assert out.dispatch_share[dead[0]:dead[1], 1].sum() == 0.0
    assert out.dispatch_share[dead[1]:, 1].sum() > 0.0


def test_regret_sublinear_on_cluster(cluster):
    _, _, inst = cluster
    T = 900
    out = ClusterSim(inst, T, seed=5).run("esdp")
    cr = out.cum_regret
    first, second = cr[T // 2 - 1], cr[-1] - cr[T // 2 - 1]
    assert second < first

"""Fast dry-run path smoke: one reduced-depth cell lowered + compiled on the
512-device production mesh in a subprocess (the full 40-cell × 2-mesh sweep
runs via `python -m repro.launch.dryrun --all`; its results land in
results/dryrun and EXPERIMENTS.md §Dry-run)."""
import json
import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import json
    from repro.launch.dryrun import lower_cell

    rec = lower_cell("gemma-7b", "decode_32k", multi_pod=True,
                     config_overrides={"n_layers": 4})
    out = {
        "ok": "roofline" in rec,
        "n_devices": rec.get("n_devices"),
        "bottleneck": rec.get("roofline", {}).get("bottleneck"),
        "flops": rec.get("roofline", {}).get("flops_per_device", 0) > 0,
        "wire": rec.get("roofline", {}).get("wire_bytes_per_device", 0) >= 0,
        "mem": rec.get("memory", {}).get("peak_est_bytes", 0) > 0,
    }
    print(json.dumps(out))
""")


def test_dryrun_cell_multi_pod_reduced_depth():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1500,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["n_devices"] == 512
    assert res["flops"] and res["wire"] and res["mem"]

"""Per-arch smoke tests (reduced configs, CPU) + full-config spec sanity.

The consistency test is the strong one: decode-with-cache after a prefill of
S tokens must reproduce the last-position logits of a prefill over S+1
tokens (catches cache layout, masking, rope-position and state-handoff bugs
in every family).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.models import build_model

B, S = 2, 32


def make_batch(cfg, key, s=S):
    tokens = jax.random.randint(key, (B, s + 1), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        batch["patch_embeds"] = jax.random.normal(key, (B, nv, cfg.d_model))
        stot = nv + s
        pos = jnp.broadcast_to(jnp.arange(stot)[None], (B, stot))
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, stot))
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.enc_len, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}
    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, reduced=True)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(built, arch):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(lambda p, b: model.loss(p, b))(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat)
    # gradients reach every parameter except declared-frozen none
    nonzero = sum(float(jnp.abs(g).sum()) > 0 for g in flat)
    assert nonzero / len(flat) > 0.9, f"{arch}: too many zero grads"


def _pad_cache(tree, axes_tree, s_from, s_to):
    """Pad every cache leaf along its 'cache_seq' axis."""
    def pad(leaf, axes):
        if axes is None or "cache_seq" not in axes:
            return leaf
        ax = axes.index("cache_seq")
        pads = [(0, 0)] * leaf.ndim
        pads[ax] = (0, s_to - s_from)
        return jnp.pad(leaf, pads)
    return jax.tree.map(pad, tree, axes_tree,
                        is_leaf=lambda x: x is None or isinstance(x, jnp.ndarray))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(built, arch):
    cfg, model, params = built(arch)
    if cfg.n_experts > 0:
        # capacity-based MoE drops are context-dependent, so decode-vs-
        # prefill equality only holds in no-drop mode (cf = E/k), the
        # standard serving configuration.
        cfg = cfg.replace(capacity_factor=cfg.n_experts / cfg.top_k)
        from repro.models import build_model as _bm
        model = _bm(cfg)
    key = jax.random.PRNGKey(2)
    batch_full = make_batch(cfg, key, s=S)  # tokens (B, S+1)
    tokens = batch_full["tokens"]

    # reference: prefill over all S+1 tokens
    pre_full = dict(batch_full)
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        stot = nv + S + 1
        pos = jnp.broadcast_to(jnp.arange(stot)[None], (B, stot))
        pre_full["positions"] = jnp.broadcast_to(pos[None], (3, B, stot))
    ref_logits, _ = jax.jit(lambda p, b: model.prefill(p, b))(params, pre_full)

    # candidate: prefill over S tokens, then decode token S
    pre = dict(batch_full)
    pre["tokens"] = tokens[:, :S]
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        stot = nv + S
        pos = jnp.broadcast_to(jnp.arange(stot)[None], (B, stot))
        pre["positions"] = jnp.broadcast_to(pos[None], (3, B, stot))
    _, cache = jax.jit(lambda p, b: model.prefill(p, b))(params, pre)

    s_from = S + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    s_max = s_from + 1
    _, axes = model.cache_spec(B, s_max)
    cache = _pad_cache(cache, axes, s_from, s_max)

    dec_batch = {"token": tokens[:, S:S + 1],
                 "pos": jnp.full((B,), s_from, jnp.int32),
                 "cache": cache}
    if cfg.family == "vlm":
        p3 = jnp.full((3, B, 1), s_from, jnp.int32)
        dec_batch["positions"] = p3
    got_logits, _ = jax.jit(lambda p, b: model.decode(p, b))(params, dec_batch)

    np.testing.assert_allclose(
        np.asarray(got_logits), np.asarray(ref_logits), rtol=2e-2, atol=2e-2)


EXPECTED_PARAMS_B = {
    "qwen2.5-32b": 32.8, "gemma3-27b": 27.0, "gemma-7b": 8.5,
    "qwen1.5-32b": 35.2, "zamba2-7b": 5.7, "dbrx-132b": 131.6,
    "deepseek-v3-671b": 671.7, "whisper-medium": 0.79,
    "mamba2-2.7b": 2.8, "qwen2-vl-72b": 72.7,
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """FULL configs instantiate abstractly (no allocation) at the right size."""
    model = build_model(get_config(arch))
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(model.abstract()))
    assert n / 1e9 == pytest.approx(EXPECTED_PARAMS_B[arch], rel=0.02)


def test_shape_skip_policy():
    long = SHAPES["long_500k"]
    runs = {a for a in ARCHS if shape_applicable(get_config(a), long)[0]}
    assert runs == {"zamba2-7b", "mamba2-2.7b"}
    for a in ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), SHAPES[s])[0]

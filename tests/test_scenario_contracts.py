"""Scenario-contract suite: metamorphic invariants over EVERY registered
fluctuation regime, plus golden-trace regression pins and property tests.

A regime added to ``experiments.scenarios`` is *automatically* covered
here — the parametrizations iterate ``scenario_names()`` — so the
contract the rest of the stack relies on cannot silently erode:

  * declared bounds — realized speeds stay inside the regime's
    ``Scenario.speed_bounds``, arrival scales are non-negative, alive
    masks are boolean;
  * bit-identical replay — the same seed unrolls and simulates to the
    same trace, twice;
  * batch faithfulness — ``simulate_batch`` row i equals
    ``simulate(seed=seeds[i])`` slice for slice (decisions exactly,
    welfare to 1 float32 ulp — the documented vmap reduction caveat);
  * stream ≡ lockstep — the streaming engine's single ``lax.scan`` path
    and the host-driven path agree bit for bit under every regime, and
    both conserve the arrival/units ledgers;
  * ledger conservation — wherever a ledger exists (the PR 8 failure
    ledger, the malleable work-units ledger) the books balance exactly;
  * golden traces — per-regime × per-policy mean utility on a small
    fixed grid is pinned to ``tests/goldens/scenario_goldens.json``
    (regenerate deliberately via ``tools/regen_goldens.py``);
  * boundary errors — unknown regime/policy names raise ``ValueError``
    naming the registry at every public entry point.

Property tests use ``hypothesis`` when available (CI installs it) and
fall back to deterministic sweeps when not — the invariants are always
exercised, the randomized search is a bonus.
"""
import json
import pathlib

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import build_tables, generate_instance, simulate, \
    simulate_batch
from repro.core.baselines import msr_greedy_factory, msr_index_factory
from repro.experiments import (SweepSpec, get_scenario, run_spec,
                               scenario_names, unroll_scenario)
from repro.experiments.scenarios import power_allocation
from repro.experiments.sweep import default_policies
from repro.sched import (ClusterSim, DispatchEngine, FailureModel, JobType,
                         MalleableModel, Slice, build_instance, rate_matrix)
from repro.sched.engine import LOCKSTEP_POLICIES

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # the container may not ship hypothesis; CI does
    HAS_HYPOTHESIS = False

REGIMES = tuple(scenario_names())

ENGINE_FIELDS = ("sw", "regret", "dispatch_share", "n", "sumz", "queue_len")

GOLDEN_PATH = pathlib.Path(__file__).parent / "goldens" \
    / "scenario_goldens.json"


@pytest.fixture(scope="module")
def small():
    inst = generate_instance(seed=3, n_ports=4, n_servers=10, edge_prob=0.3)
    return inst, build_tables(inst.A, inst.c)


@pytest.fixture(scope="module")
def golden_grid():
    goldens = json.loads(GOLDEN_PATH.read_text())
    grid = goldens["grid"]
    inst = generate_instance(**grid["instance_kwargs"])
    return goldens, grid, inst, build_tables(inst.A, inst.c)


def _malleable_cluster():
    slices = [Slice("pod-a", "v5e", 256, 32, 4),
              Slice("pod-b", "v5e", 256, 32, 4),
              Slice("pod-c", "v5p", 256, 32, 4)]
    jobs = [JobType("train", "qwen2.5-32b", "train_4k", ("v5e", "v5p"),
                    256, 32, 4, value_rate=1.0, malleable=True,
                    min_chips=128, min_hosts=16, min_ici_domains=2),
            JobType("decode", "deepseek-v3-671b", "decode_32k", ("v5e",),
                    256, 32, 4, value_rate=1.2, malleable=True,
                    min_chips=64, min_hosts=8, min_ici_domains=1)]
    rates = rate_matrix(jobs, slices)
    inst, _ = build_instance(slices, jobs, rates, seed=0)
    return inst


# ---------------------------------------------------------------------------
# declared bounds: speed_bounds is a contract, not a hint
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("regime", REGIMES)
def test_speeds_within_declared_bounds(regime):
    scn = get_scenario(regime)
    lo, hi = scn.speed_bounds
    assert 0.0 <= lo <= hi
    for seed in (0, 7):
        arr, speed, alive = unroll_scenario(scn, 200, 12, seed=seed,
                                            n_ports=4)
        assert np.isfinite(speed).all(), regime
        assert (speed >= lo - 1e-6).all(), (regime, float(speed.min()))
        assert (speed <= hi + 1e-6).all(), (regime, float(speed.max()))
        assert (arr >= 0).all(), regime
        assert alive.dtype == bool and alive.shape == speed.shape


@pytest.mark.parametrize("regime", REGIMES)
def test_unroll_replay_bit_identical(regime):
    scn = get_scenario(regime)
    a = unroll_scenario(scn, 150, 9, seed=4, n_ports=3)
    b = unroll_scenario(scn, 150, 9, seed=4, n_ports=3)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), regime)


@pytest.mark.parametrize("regime", REGIMES)
def test_simulate_replay_bit_identical(small, regime):
    inst, tables = small
    policy = default_policies(names=("hswf",))["hswf"](inst, 80, tables)
    scn = get_scenario(regime)
    a = simulate(inst, policy, 80, seed=5, tables=tables, scenario=scn)
    b = simulate(inst, policy, 80, seed=5, tables=tables, scenario=scn)
    np.testing.assert_array_equal(a.sw, b.sw, regime)
    np.testing.assert_array_equal(a.n_dispatched, b.n_dispatched, regime)


# ---------------------------------------------------------------------------
# simulate ≡ simulate_batch, slice for slice, per regime
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("regime", REGIMES)
def test_batch_matches_per_seed_per_regime(small, regime):
    inst, tables = small
    T, seeds = 90, (2, 3)
    policy = default_policies(names=("esdp",))["esdp"](inst, T, tables)
    scn = get_scenario(regime)
    batch = simulate_batch(inst, policy, T, seeds, tables=tables,
                           scenario=scn)
    for i, s in enumerate(seeds):
        one = simulate(inst, policy, T, seed=s, tables=tables, scenario=scn)
        np.testing.assert_array_equal(batch.n_dispatched[i],
                                      one.n_dispatched, regime)
        np.testing.assert_array_equal(batch.regret[i], one.regret, regime)
        np.testing.assert_allclose(batch.sw[i], one.sw, rtol=1e-6,
                                   atol=1e-6, err_msg=regime)


# ---------------------------------------------------------------------------
# streaming engine: stream ≡ lockstep bit for bit, under every regime
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("regime", REGIMES)
def test_engine_stream_matches_lockstep_per_regime(small, regime):
    inst, _ = small
    scn = get_scenario(regime)
    eng = DispatchEngine(inst, 70, seed=6, scenario=scn)
    o_s, o_l = eng.run(mode="stream"), eng.run(mode="lockstep")
    for f in ENGINE_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(o_s, f)),
                                      np.asarray(getattr(o_l, f)),
                                      err_msg=f"{regime}: {f}")
    for out in (o_s, o_l):
        led = out.ledger
        assert led["total_arrivals"] == (led["total_rejected"]
                                         + led["total_blocked"]
                                         + led["total_admitted"]), regime
        assert led["total_admitted"] == (led["total_dispatched"]
                                         + led["total_dropped"]
                                         + led["total_shed"]
                                         + led["final_queue"]), regime


# ---------------------------------------------------------------------------
# ledger conservation wherever a ledger exists
# ---------------------------------------------------------------------------

def test_failure_ledger_conserves_under_scenario():
    inst = _malleable_cluster()
    fm = FailureModel(p_crash=0.08, checkpoints=1)
    scn = get_scenario("server_failures", p_crash=0.05)
    out = ClusterSim(inst, 100, scenario=scn, seed=1, failures=fm).run("esdp")
    led = out.failures
    np.testing.assert_allclose(
        led["total_dispatched"],
        led["total_completed"] + led["total_salvaged"] + led["total_lost"],
        rtol=1e-6)
    assert led["total_dispatched"] > 0


@pytest.mark.parametrize("preempt", [False, True])
def test_malleable_units_ledger_conserves(preempt):
    inst = _malleable_cluster()
    mm = MalleableModel(duration=4, preempt=preempt)
    out = ClusterSim(inst, 150, seed=2, malleable=mm).run("esdp")
    mal = out.malleable
    assert mal is not None
    lhs = mal["total_dispatched"]
    rhs = mal["total_done"] + mal["total_lost"] + mal["residual_units"]
    assert lhs == pytest.approx(rhs, abs=1e-9)
    assert lhs > 0
    # shrink/grow never violates residual capacity: Ax ≤ c every slot
    c = np.asarray(inst.c)
    assert (mal["occupancy"] <= c[None, :]).all()
    # reconfiguration cost is charged exactly once per transition
    assert mal["total_reconfig_cost"] == pytest.approx(
        mal["transitions"] * mm.reconfig_cost, rel=1e-6)
    assert mal["shutdowns"].sum() == (0 if not preempt
                                      else mal["shutdowns"].sum())
    if preempt:
        assert mal["total_shutdown_cost"] == pytest.approx(
            mal["shutdowns"].sum() * mm.shutdown_cost, rel=1e-6)
    else:
        assert mal["total_lost"] == 0.0 and mal["shutdowns"].sum() == 0


def test_malleable_duration_one_reduces_to_rigid():
    """On a family-free instance, duration=1 malleable is the rigid loop."""
    inst = generate_instance(seed=0, n_ports=6, n_servers=12, edge_prob=0.25)
    rigid = ClusterSim(inst, 80, seed=3).run("esdp")
    mall = ClusterSim(inst, 80, seed=3,
                      malleable=MalleableModel(duration=1)).run("esdp")
    np.testing.assert_array_equal(rigid.sw, mall.sw)
    np.testing.assert_array_equal(rigid.regret, mall.regret)


# ---------------------------------------------------------------------------
# golden traces: per-regime × per-policy mean utility on the fixed grid
# ---------------------------------------------------------------------------

def test_goldens_cover_every_regime_and_policy(golden_grid):
    goldens, grid, _, _ = golden_grid
    for regime in scenario_names():
        for pname in grid["policies"]:
            assert f"{regime}/{pname}" in goldens["values"], \
                f"{regime}/{pname} missing — run tools/regen_goldens.py"


@pytest.mark.parametrize("regime", REGIMES)
def test_golden_traces(golden_grid, regime):
    """Mean utility per (regime, policy) matches the committed golden.

    Tolerance 2e-3 relative: loose enough to survive jax-version float
    reassociation across the CI matrix, tight enough that any behavioral
    change to a regime or policy trips it."""
    goldens, grid, inst, tables = golden_grid
    T, seeds = grid["T"], tuple(grid["seeds"])
    scn = get_scenario(regime)
    for pname, factory in default_policies(
            names=tuple(grid["policies"])).items():
        policy = factory(inst, T, tables)
        res = simulate_batch(inst, policy, T, seeds, tables=tables,
                             scenario=scn)
        want = goldens["values"][f"{regime}/{pname}"]
        got_asw = float(res.asw[:, -1].mean())
        got_reg = float(res.regret[:, -1].mean())
        assert got_asw == pytest.approx(want["asw_final_mean"],
                                        rel=2e-3, abs=1e-4), \
            (regime, pname, "asw")
        assert got_reg == pytest.approx(want["regret_final_mean"],
                                        rel=2e-3, abs=1e-4), \
            (regime, pname, "regret")


# ---------------------------------------------------------------------------
# property tests: power allocation + malleable invariants
# (hypothesis-driven when installed, deterministic sweeps otherwise)
# ---------------------------------------------------------------------------

def _check_power_allocation(demand, budget):
    p = np.asarray(power_allocation(jnp.asarray(demand), budget))
    assert (p >= -1e-6).all()
    assert (p <= np.asarray(demand) + 1e-6).all()
    assert p.sum() <= budget + 1e-4 * max(budget, 1.0)


def _check_power_monotone(demand, b_lo, b_hi):
    p_lo = np.asarray(power_allocation(jnp.asarray(demand), b_lo))
    p_hi = np.asarray(power_allocation(jnp.asarray(demand), b_hi))
    assert (p_hi >= p_lo - 1e-5).all()


if HAS_HYPOTHESIS:

    @settings(deadline=None, max_examples=50)
    @given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=16),
           st.floats(0.0, 50.0))
    def test_power_allocation_respects_budget(demand, budget):
        _check_power_allocation(demand, budget)

    @settings(deadline=None, max_examples=50)
    @given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=16),
           st.floats(0.0, 30.0), st.floats(0.0, 30.0))
    def test_power_allocation_monotone_in_budget(demand, b1, b2):
        _check_power_monotone(demand, min(b1, b2), max(b1, b2))

else:

    def test_power_allocation_respects_budget():
        rng = np.random.default_rng(0)
        for _ in range(50):
            d = rng.uniform(0.0, 10.0, rng.integers(1, 17))
            _check_power_allocation(d, float(rng.uniform(0.0, 50.0)))

    def test_power_allocation_monotone_in_budget():
        rng = np.random.default_rng(1)
        for _ in range(50):
            d = rng.uniform(0.0, 10.0, rng.integers(1, 17))
            b = sorted(rng.uniform(0.0, 30.0, 2))
            _check_power_monotone(d, float(b[0]), float(b[1]))


def _check_malleable_run(duration, seed, preempt):
    inst = _malleable_cluster()
    mm = MalleableModel(duration=duration, preempt=preempt)
    out = ClusterSim(inst, 60, seed=seed, malleable=mm).run("esdp")
    mal = out.malleable
    c = np.asarray(inst.c)
    assert (mal["occupancy"] <= c[None, :]).all()
    assert mal["total_dispatched"] == pytest.approx(
        mal["total_done"] + mal["total_lost"] + mal["residual_units"],
        abs=1e-9)
    assert mal["total_reconfig_cost"] == pytest.approx(
        mal["transitions"] * mm.reconfig_cost, rel=1e-6)


if HAS_HYPOTHESIS:

    @settings(deadline=None, max_examples=12)
    @given(st.integers(1, 6), st.integers(0, 100), st.booleans())
    def test_malleable_invariants_property(duration, seed, preempt):
        _check_malleable_run(duration, seed, preempt)

else:

    @pytest.mark.parametrize("duration,seed,preempt",
                             [(1, 0, False), (3, 1, False), (4, 2, True),
                              (6, 3, True), (2, 4, False), (5, 5, True)])
    def test_malleable_invariants_property(duration, seed, preempt):
        _check_malleable_run(duration, seed, preempt)


# ---------------------------------------------------------------------------
# boundary errors: unknown names raise ValueError naming the registry
# ---------------------------------------------------------------------------

def test_unknown_scenario_raises_value_error():
    with pytest.raises(ValueError, match="power_coupled"):
        get_scenario("not_a_regime")


def test_unknown_policy_raises_value_error():
    with pytest.raises(ValueError, match="msr_greedy"):
        default_policies(names=("esdp", "not_a_policy"))


def test_sweep_spec_unknown_scenario_raises(small):
    inst, _ = small
    spec = SweepSpec(name="bad", T=10, seeds=(0,),
                     policies=default_policies(names=("hswf",)),
                     scenario="not_a_regime",
                     instance_kwargs={"seed": 3, "n_ports": 4,
                                      "n_servers": 10, "edge_prob": 0.3})
    with pytest.raises(ValueError, match="registered scenarios"):
        run_spec(spec)


def test_cluster_sim_unknown_policy_raises():
    inst = _malleable_cluster()
    with pytest.raises(ValueError, match="esdp"):
        ClusterSim(inst, 10).run("not_a_policy")
    assert set(LOCKSTEP_POLICIES) == {"esdp", "hswf", "lcf", "lwtf"}


def test_cluster_sim_malleable_excludes_failures():
    inst = _malleable_cluster()
    with pytest.raises(ValueError):
        ClusterSim(inst, 10, malleable=MalleableModel(),
                   failures=FailureModel(p_crash=0.1))


def test_run_batch_rejects_malleable():
    inst = _malleable_cluster()
    sim = ClusterSim(inst, 10, malleable=MalleableModel())
    with pytest.raises(NotImplementedError):
        sim.run_batch([0, 1])


# ---------------------------------------------------------------------------
# MSR baselines behave like policies (finite, registered, distinct)
# ---------------------------------------------------------------------------

def test_msr_policies_run_and_differ(small):
    inst, tables = small
    T = 100
    outs = {}
    for factory in (msr_greedy_factory(), msr_index_factory()):
        policy = factory(inst, T, tables)
        res = simulate(inst, policy, T, seed=0, tables=tables,
                       scenario=get_scenario("markov_dvfs"))
        assert np.isfinite(res.sw).all() and np.isfinite(res.regret).all()
        outs[factory.policy_name] = np.asarray(res.sw)
    # the UCB exploration bonus must actually change behaviour
    assert not np.array_equal(outs["msr_greedy"], outs["msr_index"])

"""Property tests for the evolving statistics (paper eqs. 7–15)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional [test] extra — property tests skip cleanly without it
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core.stats import (DELTA_VARIANTS, G_VARIANTS, horizon_for_s_cap,
                              s_cap_for_horizon, scale_statistics, xi_of)


if HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 100_000), st.integers(1, 64))
    def test_xi_monotone_and_scale(t, m):
        """ξ(t) = ⌈m/δ(t)⌉ is ≥ m and non-decreasing in t (δ decreasing)."""
        x1 = int(xi_of(jnp.float32(t), m))
        x2 = int(xi_of(jnp.float32(t + 50), m))
        assert x1 >= m
        assert x2 >= x1

    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 10_000), st.integers(1, 40),
           st.integers(0, 2**31 - 1))
    def test_scaled_statistics_int32_bounds(t, m, seed):
        """Υ̂, Σ̂² and the DP-sum bound stay far inside int32 (stats.py claim)."""
        rng = np.random.default_rng(seed)
        E = int(rng.integers(1, 64))
        vhat = jnp.asarray(rng.uniform(0, 1, E), jnp.float32)
        n = jnp.asarray(rng.integers(0, 1000, E), jnp.int32)
        ups, sig, xi, s_limit = scale_statistics(vhat, n, jnp.float32(t), m)
        ups, sig = np.asarray(ups), np.asarray(sig, np.int64)
        assert np.all(ups >= 0) and np.all(ups <= int(xi))
        assert np.all(sig > 0)
        # the dominance invariant: one unexplored beats any m explored channels
        explored = sig[np.asarray(n) > 0]
        unexplored = sig[np.asarray(n) == 0]
        if explored.size and unexplored.size:
            assert unexplored.min() > m * explored.max() * 0.99
        # DP sums of ≤ m+1 values stay in int32
        assert (m + 1) * int(sig.max()) < 2**31
else:
    def test_hypothesis_extra_missing():
        pytest.importorskip(
            "hypothesis",
            reason="property tests need the [test] extra (pip install .[test])")


def test_s_cap_covers_horizon():
    for name, d in DELTA_VARIANTS.items():
        cap = s_cap_for_horizon(2000, 16, d)
        for t in (1, 500, 2000):
            assert int(xi_of(jnp.float32(t), 16, d)) * 16 <= cap, (name, t)


def test_g_variants_ordering():
    """default g dominates ln-t g for m > 1 (the over-exploration source)."""
    t = jnp.float32(1000.0)
    assert float(G_VARIANTS["default"](t, 16)) > float(
        G_VARIANTS["logt_only"](t, 16))


def test_horizon_for_s_cap_inverts_s_cap_for_horizon():
    """The inverse sizing helper: when a horizon within t_max reaches the
    requested budget axis, the returned T does so minimally (T−1 does
    not); unreachable combinations — ξ grows only logarithmically, so
    s_cap ≫ m² needs astronomic horizons under the slow δ schedules —
    yield None instead of overflowing.  This is what ties the long-S
    benchmark configs (S = 4096/8192) back to concrete sampling
    horizons (large-m instances)."""
    for name, d in DELTA_VARIANTS.items():
        for m in (8, 16, 36):
            for s_cap in (64, 1024, 4096):
                T = horizon_for_s_cap(s_cap, m, d)
                if T is None:
                    # genuinely unreachable within t_max
                    assert s_cap_for_horizon(10 ** 12, m, d) < s_cap, \
                        (name, m, s_cap)
                    continue
                assert s_cap_for_horizon(T, m, d) >= s_cap, (name, m, s_cap)
                if T > 1:
                    assert s_cap_for_horizon(T - 1, m, d) < s_cap, \
                        (name, m, s_cap)
    # the S = 4096 benchmark regime is reachable for paper-scale m
    assert horizon_for_s_cap(4096, 36) is not None


def test_horizon_for_s_cap_exact_above_f32_range():
    """Regression (f32 precision): ``_xi_at_horizon`` used to evaluate
    ``delta_fn(jnp.float32(T))`` — exact only for T < 2²⁴.  Above that the
    float32 grid quantizes T (spacing 512 near 3·10⁹, ≈2¹⁷ near 10¹²), so
    ``horizon_for_s_cap`` landed on a float32 grid edge instead of the true
    integer threshold (≈2·10⁴ slots off at the horizon pinned here).  The
    pure-``math`` float64 oracle below reproduces the sizing map
    independently and pins the exact minimal horizon."""
    import math
    m = 16

    def delta_host(t):  # the paper default, float64
        return 1.0 / (math.log(math.log(t + 1.0) + 1.0) + 1.0)

    def cap(T):
        return math.ceil(m / delta_host(float(T))) * m

    s_cap = cap(10 ** 10)
    lo, hi = 1, 10 ** 12
    assert cap(lo) < s_cap <= cap(hi)
    while lo + 1 < hi:  # exact bisection, pure math
        mid = (lo + hi) // 2
        if cap(mid) < s_cap:
            lo = mid
        else:
            hi = mid
    t_star = hi
    assert t_star > 2 ** 24  # the regime f32 mangled
    assert horizon_for_s_cap(s_cap, m) == t_star
    assert s_cap_for_horizon(t_star, m) >= s_cap
    assert s_cap_for_horizon(t_star - 1, m) < s_cap


def test_horizon_for_s_cap_t_max_window():
    """Regression: thresholds between the last power-of-two probe and
    t_max must still be found (the doubling loop clamps its final probe
    to t_max instead of bailing past it)."""
    def delta(t):
        return 1.0 / jnp.sqrt(t)  # s_cap grows fast enough

    m, s_cap = 4, 72
    T = horizon_for_s_cap(s_cap, m, delta)  # unbounded-ish search
    assert T is not None and s_cap_for_horizon(T, m, delta) >= s_cap
    # t_max sits between 2^k and the threshold: must still resolve
    got = horizon_for_s_cap(s_cap, m, delta, t_max=T + 1)
    assert got == T
    # and a t_max just below the threshold is genuinely unreachable
    assert horizon_for_s_cap(s_cap, m, delta, t_max=T - 1) is None

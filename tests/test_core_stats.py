"""Property tests for the evolving statistics (paper eqs. 7–15)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:        # optional [test] extra — property tests skip cleanly without it
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core.stats import (DELTA_VARIANTS, G_VARIANTS, s_cap_for_horizon,
                              scale_statistics, xi_of)


if HAS_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 100_000), st.integers(1, 64))
    def test_xi_monotone_and_scale(t, m):
        """ξ(t) = ⌈m/δ(t)⌉ is ≥ m and non-decreasing in t (δ decreasing)."""
        x1 = int(xi_of(jnp.float32(t), m))
        x2 = int(xi_of(jnp.float32(t + 50), m))
        assert x1 >= m
        assert x2 >= x1

    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 10_000), st.integers(1, 40),
           st.integers(0, 2**31 - 1))
    def test_scaled_statistics_int32_bounds(t, m, seed):
        """Υ̂, Σ̂² and the DP-sum bound stay far inside int32 (stats.py claim)."""
        rng = np.random.default_rng(seed)
        E = int(rng.integers(1, 64))
        vhat = jnp.asarray(rng.uniform(0, 1, E), jnp.float32)
        n = jnp.asarray(rng.integers(0, 1000, E), jnp.int32)
        ups, sig, xi, s_limit = scale_statistics(vhat, n, jnp.float32(t), m)
        ups, sig = np.asarray(ups), np.asarray(sig, np.int64)
        assert np.all(ups >= 0) and np.all(ups <= int(xi))
        assert np.all(sig > 0)
        # the dominance invariant: one unexplored beats any m explored channels
        explored = sig[np.asarray(n) > 0]
        unexplored = sig[np.asarray(n) == 0]
        if explored.size and unexplored.size:
            assert unexplored.min() > m * explored.max() * 0.99
        # DP sums of ≤ m+1 values stay in int32
        assert (m + 1) * int(sig.max()) < 2**31
else:
    def test_hypothesis_extra_missing():
        pytest.importorskip(
            "hypothesis",
            reason="property tests need the [test] extra (pip install .[test])")


def test_s_cap_covers_horizon():
    for name, d in DELTA_VARIANTS.items():
        cap = s_cap_for_horizon(2000, 16, d)
        for t in (1, 500, 2000):
            assert int(xi_of(jnp.float32(t), 16, d)) * 16 <= cap, (name, t)


def test_g_variants_ordering():
    """default g dominates ln-t g for m > 1 (the over-exploration source)."""
    t = jnp.float32(1000.0)
    assert float(G_VARIANTS["default"](t, 16)) > float(
        G_VARIANTS["logt_only"](t, 16))

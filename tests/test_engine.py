"""Streaming dispatch engine tests (``sched.engine``).

The load-bearing invariants:

  * adapter faithfulness — ``ClusterSim.run`` (now a thin adapter over
    ``engine.lockstep_run``) stays trace-equivalent to a compact
    reimplementation of the pre-engine loop on all six registered
    fluctuation regimes;
  * stream/lockstep bit-identity — the jitted ``lax.scan`` path and the
    host-driven path compose the same slot functions, so fault-free they
    agree bit for bit;
  * ledger conservation — ``arrivals = rejected + blocked + admitted``
    and ``admitted = dispatched + dropped + shed + final_queue``, under
    every backpressure policy;
  * dead-letter isolation — rejected arrivals never consume capacity and
    never enter the bandit statistics;
  * deterministic A/B routing — same seed ⇒ same variant assignment,
    split ≈ weights, different salt ⇒ different assignment;
  * one-launch scaling — the stream jaxpr contains a single scan and its
    equation count does not grow with the horizon.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import stats as stats_mod
from repro.core.baselines import greedy_pack
from repro.core.dp import oracle_knapsack
from repro.core.graph import generate_instance
from repro.experiments import get_scenario, scenario_names
from repro.sched import (BACKPRESSURE_POLICIES, ClusterSim, DispatchEngine,
                         EngineConfig, FailureModel, JobType, Slice,
                         VariantSpec, feasible_ports, validate_jobs)

REGIMES = ("iid", "markov_dvfs", "mmpp_arrivals", "chronic_straggler",
           "transient_brownout", "elastic_outage", "power_coupled")

AB = EngineConfig(variants=(VariantSpec("esdp", weight=0.9),
                            VariantSpec("challenger", kind="hswf",
                                        weight=0.1)))

ENGINE_FIELDS = ("sw", "regret", "dispatch_share", "sw_variant",
                 "regret_variant", "dispatched_variant", "routed_variant",
                 "n", "sumz", "queue_len")


@pytest.fixture(scope="module")
def inst():
    return generate_instance(seed=0)


def assert_conserves(out):
    led = out.ledger
    assert led["total_arrivals"] == (led["total_rejected"]
                                     + led["total_blocked"]
                                     + led["total_admitted"])
    assert led["total_admitted"] == (led["total_dispatched"]
                                     + led["total_dropped"]
                                     + led["total_shed"]
                                     + led["final_queue"])


# ---------------------------------------------------------------------------
# adapter faithfulness: ClusterSim.run == the pre-engine loop, bit for bit
# ---------------------------------------------------------------------------

def _reference_run(sim, policy="esdp", tiebreak=1e-4):
    """Compact reimplementation of the pre-engine ``ClusterSim.run`` loop
    (plain backend, no failure runtime) — the trace ``lockstep_run`` must
    keep reproducing exactly."""
    inst, tables = sim.inst, sim.tables
    E = inst.n_edges
    port = inst.port_of_edge
    server = inst.edges[:, 1]
    arrivals, noise = sim._streams()
    rng = np.random.default_rng(sim.seed + 1)
    n = np.zeros(E, np.int64)
    sumz = np.zeros(E, np.float64)
    waiting = np.zeros(inst.n_ports, np.int64)
    sw = np.zeros(sim.T, np.float32)
    regret = np.zeros(sim.T, np.float32)
    share = np.zeros((sim.T, inst.n_servers), np.float32)
    jit_dp = jax.jit(lambda u, s, lim, al: sim.solver(
        u, s, tables, sim.s_cap, lim, allowed=al, u_max=sim.u_max)[0])
    jit_oracle = jax.jit(lambda v, al: oracle_knapsack(v, tables, al)[0])
    jit_greedy = jax.jit(lambda sc, el: greedy_pack(
        sc, el, jnp.asarray(inst.A), jnp.asarray(inst.c)))
    for t0 in range(sim.T):
        alive_srv = np.asarray(sim.alive_fn(t0), bool)
        allowed = arrivals[t0][port] & alive_srv[server]
        vhat = np.where(n > 0, sumz / np.maximum(n, 1), 0.0).astype(
            np.float32)
        if policy == "esdp":
            ups, sig, _, s_lim = stats_mod.scale_statistics(
                jnp.asarray(vhat), jnp.asarray(n.astype(np.int32)),
                jnp.float32(t0 + 1), sim.m, g_fn=sim.g_fn)
            x = np.asarray(jit_dp(ups, sig, s_lim, jnp.asarray(allowed)))
        else:
            tb = rng.random(E).astype(np.float32) * tiebreak
            score = {"hswf": vhat + tb, "lcf": -inst.cost + tb,
                     "lwtf": waiting[port] * 1e3 + vhat + tb}[policy]
            x = np.asarray(jit_greedy(jnp.asarray(score),
                                      jnp.asarray(allowed)))
        x = x * allowed
        z = sim._z(t0, noise[t0])
        sw[t0] = float((x * z).sum())
        v_true = sim._v_true(t0)
        x_star = np.asarray(jit_oracle(jnp.asarray(v_true),
                                       jnp.asarray(allowed)))
        regret[t0] = float((v_true * x_star).sum() - (v_true * x).sum())
        n += x
        sumz += x * z
        served = np.zeros(inst.n_ports, bool)
        np.maximum.at(served, port, x > 0)
        waiting = np.where(served, 0, waiting + arrivals[t0])
        if x.sum() > 0:
            np.add.at(share[t0], server, x / x.sum())
    return sw, regret, share


@pytest.mark.parametrize("scenario", REGIMES)
def test_adapter_trace_equivalent_on_regimes(inst, scenario):
    assert scenario in scenario_names()
    sim = ClusterSim(inst, 48, scenario=get_scenario(scenario), seed=11)
    out = sim.run("esdp")
    sw, regret, share = _reference_run(sim, "esdp")
    np.testing.assert_array_equal(out.sw, sw)
    np.testing.assert_array_equal(out.regret, regret)
    np.testing.assert_array_equal(out.dispatch_share, share)


@pytest.mark.parametrize("policy", ["hswf", "lcf", "lwtf"])
def test_adapter_trace_equivalent_greedy_policies(inst, policy):
    sim = ClusterSim(inst, 48, scenario=get_scenario("markov_dvfs"), seed=11)
    out = sim.run(policy)
    sw, regret, share = _reference_run(sim, policy)
    np.testing.assert_array_equal(out.sw, sw)
    np.testing.assert_array_equal(out.regret, regret)
    np.testing.assert_array_equal(out.dispatch_share, share)


# ---------------------------------------------------------------------------
# stream/lockstep bit-identity + ledger conservation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("config", [None, AB], ids=["single", "ab"])
def test_stream_matches_lockstep_bitwise(inst, config):
    eng = DispatchEngine(inst, 80, config, seed=3)
    o_s, o_l = eng.run(mode="stream"), eng.run(mode="lockstep")
    assert o_s.mode == "stream" and o_l.mode == "lockstep"
    for f in ENGINE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(o_s, f)), np.asarray(getattr(o_l, f)),
            err_msg=f)
    assert_conserves(o_s)
    assert_conserves(o_l)
    assert o_s.ledger["total_dispatched"] > 0


def test_stream_replay_deterministic(inst):
    a = DispatchEngine(inst, 60, AB, seed=5).run(mode="stream")
    b = DispatchEngine(inst, 60, AB, seed=5).run(mode="stream")
    for f in ENGINE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f)


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", BACKPRESSURE_POLICIES)
def test_backpressure_policy_table(inst, policy):
    """Under pressure, exactly the configured overflow channel fires —
    and the ledger still balances."""
    cfg = EngineConfig(queue_capacity=1, backpressure=policy)
    out = DispatchEngine(inst, 80, cfg, arr_scale=3.0,
                         seed=5).run(mode="stream")
    led = out.ledger
    active = {"drop_oldest": "dropped", "block": "blocked",
              "shed_by_utility": "shed"}[policy]
    assert led[f"total_{active}"] > 0
    for ch in ("dropped", "blocked", "shed"):
        if ch != active:
            assert led[f"total_{ch}"] == 0
    assert_conserves(out)


def test_engine_config_validates(inst):
    with pytest.raises(ValueError, match="backpressure"):
        EngineConfig(backpressure="bogus")
    with pytest.raises(ValueError, match="unique"):
        EngineConfig(variants=(VariantSpec("a"), VariantSpec("a")))
    with pytest.raises(ValueError, match="kind"):
        VariantSpec("x", kind="bogus")
    with pytest.raises(ValueError):
        DispatchEngine(inst, 10).run(mode="bogus")


# ---------------------------------------------------------------------------
# admission: dead-letter isolation
# ---------------------------------------------------------------------------

def test_dead_letter_never_consumes(inst):
    """Arrivals on a never-feasible port are rejected at admission: no
    capacity use, no bandit observations, and the feasible ports dispatch
    exactly as if the dead port's traffic never existed."""
    A2 = inst.A.copy()
    A2[:, inst.port_of_edge == 0] = int(inst.c.max()) + 5
    bad = dataclasses.replace(inst, A=A2)
    ok = feasible_ports(bad)
    assert not ok[0] and ok[1:].all()

    out = DispatchEngine(bad, 80, seed=3).run(mode="stream")
    assert out.ledger["total_rejected"] > 0
    bad_edges = ~ok[bad.port_of_edge]
    assert np.asarray(out.n)[:, bad_edges].sum() == 0
    assert np.asarray(out.sumz)[:, bad_edges].sum() == 0
    assert_conserves(out)


def test_validate_jobs_preflight():
    slices = [Slice("pod-a", "v5e", 256, 32, 4)]
    jobs = [JobType("ok", "m", "s", ("v5e",), 256, 32, 4, value_rate=1.0),
            JobType("wrong-accel", "m", "s", ("trn2",), 8, 1, 1,
                    value_rate=1.0),
            JobType("too-big", "m", "s", ("v5e",), 512, 64, 8,
                    value_rate=1.0)]
    reasons = validate_jobs(slices, jobs)
    assert set(reasons) == {"wrong-accel", "too-big"}
    assert "accelerator" in reasons["wrong-accel"]
    assert "exceeds" in reasons["too-big"]


# ---------------------------------------------------------------------------
# A/B routing
# ---------------------------------------------------------------------------

def test_ab_split_deterministic_and_weighted(inst):
    a = DispatchEngine(inst, 400, AB, seed=7).run(mode="stream")
    b = DispatchEngine(inst, 400, AB, seed=7).run(mode="stream")
    np.testing.assert_array_equal(a.routed_variant, b.routed_variant)
    assert a.variants == ("esdp", "challenger")
    tot = np.asarray(a.routed_variant).sum(axis=0).astype(float)
    assert tot.sum() > 0
    frac = tot / tot.sum()
    assert abs(frac[0] - 0.9) < 0.05, frac
    # per-variant accounting decomposes the totals
    np.testing.assert_allclose(
        np.asarray(a.sw_variant).sum(axis=1), np.asarray(a.sw),
        rtol=1e-5, atol=1e-5)
    assert np.asarray(a.dispatched_variant).sum() \
        == a.ledger["total_dispatched"]


def test_route_salt_changes_assignment(inst):
    base = DispatchEngine(inst, 400, AB, seed=7).run(mode="stream")
    salted_cfg = EngineConfig(variants=AB.variants, route_salt=0xBEEF)
    salted = DispatchEngine(inst, 400, salted_cfg, seed=7).run(mode="stream")
    assert not np.array_equal(base.routed_variant, salted.routed_variant)


def test_single_variant_routes_everything(inst):
    out = DispatchEngine(inst, 60, seed=1).run(mode="stream")
    routed = np.asarray(out.routed_variant)
    assert routed.shape[1] == 1
    assert routed.sum() == out.ledger["total_arrivals"] \
        - out.ledger["total_rejected"]


# ---------------------------------------------------------------------------
# scaling: one jitted call per trace, batch == per-seed
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", [None, "power_coupled"])
def test_jaxpr_single_scan_horizon_independent(inst, scenario):
    """The stream path stays ONE jitted lax.scan with a horizon-independent
    jaxpr — including under the coupled-speed regime, whose schedule enters
    as precomputed scan inputs rather than extra equations."""
    scn = get_scenario(scenario) if scenario else None
    eng = DispatchEngine(inst, 1000, scenario=scn)
    j1 = eng.make_stream_jaxpr(1_000)
    j2 = eng.make_stream_jaxpr(1_000_000)
    scans = [e for e in j1.jaxpr.eqns if e.primitive.name == "scan"]
    assert len(scans) == 1
    assert len(j1.jaxpr.eqns) == len(j2.jaxpr.eqns)


def test_run_batch_matches_per_seed(inst):
    outs = DispatchEngine(inst, 60, AB, seed=0).run_batch([11, 12, 13])
    for s, ob in zip([11, 12, 13], outs):
        one = DispatchEngine(inst, 60, AB, seed=s).run(mode="stream")
        for f in ENGINE_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(one, f)), np.asarray(getattr(ob, f)),
                err_msg=f"seed {s}: {f}")


# ---------------------------------------------------------------------------
# failure runtime integration (lockstep)
# ---------------------------------------------------------------------------

def test_failure_lockstep_per_variant_ledgers(inst):
    fm = FailureModel(p_crash=0.1, redundancy=2)
    out = DispatchEngine(inst, 60, AB, seed=3, failures=fm).run(mode="auto")
    assert out.mode == "lockstep"  # auto routes failure runs host-side
    fv = out.failures["per_variant"]
    assert set(fv) == set(out.variants)
    for name in out.variants:
        led = fv[name]
        np.testing.assert_allclose(
            np.asarray(led["dispatched"]),
            np.asarray(led["completed"]) + np.asarray(led["lost"])
            + np.asarray(led["salvaged"]), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(out.failures["dispatched"]),
        sum(np.asarray(fv[n]["dispatched"]) for n in out.variants),
        rtol=1e-6, atol=1e-6)
    assert_conserves(out)

"""Substrate tests: data pipeline, optimizer, compression, checkpointing,
fault-tolerant restart, serving."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:  # optional [test] extra — property tests skip cleanly without it
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLM, make_batch_iterator
from repro.models import build_model
from repro.optim import AdamW, linear_warmup_cosine, topk_compress_with_feedback
from repro.runtime import (greedy_generate, init_train_state, make_train_step)
from repro.runtime.fault import (CrashRateTracker, FailureInjector,
                                 StragglerTracker, TrainSupervisor)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_restart_exact():
    ds = SyntheticLM(vocab=512, seq_len=32, global_batch=8, seed=3)
    a = [b for _, b in zip(range(5), make_batch_iterator(ds, 0))]
    b = [b for _, b in zip(range(3), make_batch_iterator(ds, 2))]
    np.testing.assert_array_equal(a[2][1]["tokens"], b[0][1]["tokens"])
    np.testing.assert_array_equal(a[4][1]["tokens"], b[2][1]["tokens"])


def test_data_host_sharding():
    ds = SyntheticLM(vocab=512, seq_len=16, global_batch=8, seed=1)
    full = ds.batch(7)["tokens"]
    lo = ds.batch(7, host_slice=slice(0, 4))["tokens"]
    hi = ds.batch(7, host_slice=slice(4, 8))["tokens"]
    np.testing.assert_array_equal(np.concatenate([lo, hi]), full)


def test_data_has_learnable_signal():
    """A bigram table predicts the stream better than chance."""
    ds = SyntheticLM(vocab=128, seq_len=256, global_batch=4, seed=0)
    toks = ds.batch(0)["tokens"]
    # simple structure check: consecutive-difference entropy is low
    diffs = np.diff(toks, axis=1) % 128
    _, counts = np.unique(diffs, return_counts=True)
    p = counts / counts.sum()
    ent = -(p * np.log(p)).sum()
    assert ent < 0.9 * np.log(128)


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_warmup_cosine_shape():
    lr = linear_warmup_cosine(1e-3, warmup=10, total_steps=100)
    assert float(lr(jnp.int32(0))) < 1e-4
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=0.05)
    assert float(lr(jnp.int32(100))) < 5e-4


if HAS_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.01, 0.5))
    def test_compression_error_feedback_conserves_mass(seed, ratio):
        """compressed + error == original (+ previous error): nothing is lost."""
        rng = np.random.default_rng(seed)
        g = {"a": jnp.asarray(rng.normal(size=(37,)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(8, 9)), jnp.float32)}
        comp, err = topk_compress_with_feedback(g, None, ratio)
        for k in g:
            np.testing.assert_allclose(np.asarray(comp[k]) + np.asarray(err[k]),
                                       np.asarray(g[k]), rtol=1e-5, atol=1e-6)
        # second round carries the error forward
        comp2, err2 = topk_compress_with_feedback(g, err, ratio)
        for k in g:
            np.testing.assert_allclose(
                np.asarray(comp2[k]) + np.asarray(err2[k]),
                np.asarray(g[k]) + np.asarray(err[k]), rtol=1e-5, atol=1e-6)
else:
    def test_hypothesis_extra_missing():
        pytest.importorskip(
            "hypothesis",
            reason="property tests need the [test] extra (pip install .[test])")


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path, keep_n=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.int32(5)}
    cm.save(5, state)
    cm.save(10, state, async_=True)
    cm.wait()
    restored, step = cm.restore(like=state)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_retention_and_latest(tmp_path):
    cm = CheckpointManager(tmp_path, keep_n=2)
    s = {"x": jnp.zeros(3)}
    for step in (1, 2, 3, 4):
        cm.save(step, s)
    assert cm.all_steps() == [3, 4]
    assert cm.latest_step() == 4


# ---------------------------------------------------------------------------
# fault-tolerant training loop (tiny model, real steps)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("qwen2.5-32b", reduced=True)
    model = build_model(cfg)
    opt = AdamW(lr=3e-3)
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    ds = SyntheticLM(vocab=cfg.vocab, seq_len=48, global_batch=4, seed=0)
    return cfg, model, opt, step_fn, ds


def test_training_reduces_loss(tiny_setup):
    """Loss trends down on the synthetic stream (the end-to-end ~100M-param
    demo in examples/train_tiny_lm.py asserts a much larger drop over 300
    steps; this is the fast CI version)."""
    cfg, model, opt, step_fn, ds = tiny_setup
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    losses = []
    for step, batch in make_batch_iterator(ds, 0):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if step >= 90:
            break
    assert np.mean(losses[-5:]) < losses[0] * 0.95


def test_supervisor_restart_exact(tiny_setup, tmp_path):
    """A failure mid-run restores the checkpoint and replays the stream —
    final state must equal the no-failure run's state."""
    cfg, model, opt, step_fn, ds = tiny_setup

    def run(fail_at):
        cm = CheckpointManager(tmp_path / f"ck{bool(fail_at)}", keep_n=3)
        sup = TrainSupervisor(step_fn, cm,
                              FailureInjector(scheduled=fail_at),
                              save_every=10, async_save=False)
        state = init_train_state(model, jax.random.PRNGKey(1), opt)
        state, final = sup.run(
            state, lambda s: make_batch_iterator(ds, start_step=s),
            total_steps=30)
        return state, sup

    clean, _ = run(())
    failed, sup = run((17,))
    assert sup.restarts == 1 and sup.lost_steps == 7  # 17 -> restored 10
    for a, b in zip(jax.tree.leaves(clean.params),
                    jax.tree.leaves(failed.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_straggler_tracker():
    st_ = StragglerTracker(alpha=0.5, k=2.0)
    assert not st_.observe(1.0)
    assert not st_.observe(1.1)
    assert st_.observe(5.0)  # 5x slower than EMA
    assert st_.slow_steps == 1


def test_injector_replay_deterministic():
    """The Bernoulli failure stream is counter-based: step t's outcome is a
    pure function of (seed, t), never of prior call history — so a
    restore-replay through already-visited steps sees the identical stream."""
    fresh = FailureInjector(p_fail=0.3, seed=7)
    stream = [fresh.check(t) for t in range(40)]
    assert any(stream) and not all(stream)  # p=0.3 actually draws both ways

    replayed = FailureInjector(p_fail=0.3, seed=7)
    # burn extra out-of-order checks first — a stateful generator would
    # advance and desynchronize; a counter-based one cannot
    for t in (13, 13, 2, 39, 5):
        replayed.check(t)
    assert [replayed.check(t) for t in range(40)] == stream

    # draw() is pure in (seed, step, salt); distinct salts are independent
    inj = FailureInjector(seed=7)
    assert inj.draw(5) == inj.draw(5) == fresh.draw(5)
    assert inj.draw(5, salt=1) != inj.draw(5, salt=2)
    # different seeds give different streams
    other = FailureInjector(p_fail=0.3, seed=8)
    assert [other.check(t) for t in range(40)] != stream


def test_injector_scheduled_fires_once():
    inj = FailureInjector(scheduled=(3,))
    assert not inj.check(2)
    assert inj.check(3)
    assert not inj.check(3)  # replay through step 3 must not re-kill


def test_straggler_rate_estimate():
    st_ = StragglerTracker(alpha=0.5, k=2.0)
    assert st_.rate_estimate == 0.0  # no observation yet: unknown, not inf
    st_.observe(0.5)
    assert st_.rate_estimate == pytest.approx(2.0)
    before = st_.rate_estimate
    assert st_.observe(5.0)  # flagged slow step still updates the EMA
    assert 0.0 < st_.rate_estimate < before


def test_crash_rate_tracker_probation():
    tr = CrashRateTracker(alpha=0.2, threshold=0.1)
    assert not tr.suspicious  # clean history: eligible
    assert tr.observe(True)  # one crash at defaults exceeds the threshold
    assert tr.suspicious and tr.crashes == 1
    # probation: ~4 clean slots at the defaults before eligibility returns
    clean = 0
    while tr.suspicious:
        tr.observe(False)
        clean += 1
    assert 3 <= clean <= 5
    assert tr.crashes == 1


class _SlowWriteManager(CheckpointManager):
    """Async writes linger long enough to still be in flight next step."""

    def _write(self, step, flat, meta):
        import time as _time
        _time.sleep(0.5)
        super()._write(step, flat, meta)


def test_supervisor_async_save_gap(tiny_setup, tmp_path):
    """A failure landing while an async save is still in flight must join
    the writer BEFORE reading latest_step(): otherwise the supervisor
    restores the previous checkpoint and replays 10 extra steps."""
    cfg, model, opt, step_fn, ds = tiny_setup
    cm = _SlowWriteManager(tmp_path / "slow", keep_n=3)
    sup = TrainSupervisor(step_fn, cm, FailureInjector(scheduled=(21,)),
                          save_every=10, async_save=True)
    state = init_train_state(model, jax.random.PRNGKey(1), opt)
    _, final = sup.run(
        state, lambda s: make_batch_iterator(ds, start_step=s),
        total_steps=25)
    assert final == 25
    # the step-20 save was mid-write when step 21 failed; wait-then-restore
    # loses exactly one step (21 -> 20), not eleven (21 -> 10)
    assert sup.restarts == 1 and sup.lost_steps == 1
    assert cm.latest_step() == 20


def test_compressed_training_still_learns(tiny_setup):
    cfg, model, opt, _, ds = tiny_setup
    step_fn = jax.jit(make_train_step(model, opt, compress_ratio=0.05),
                      donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(0), opt,
                             compress=True)
    losses = []
    for step, batch in make_batch_iterator(ds, 0):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if step >= 50:
            break
    # 5%-topk compression slows early progress; require a clear loss drop
    # without demanding the uncompressed rate (~4% observed in 50 steps)
    assert np.mean(losses[-5:]) < losses[0] * 0.97


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_greedy_generate_deterministic(tiny_setup):
    cfg, model, opt, _, _ = tiny_setup
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(24).reshape(2, 12) % cfg.vocab}
    out1 = greedy_generate(model, params, batch, steps=6, s_max=20)
    out2 = greedy_generate(model, params, batch, steps=6, s_max=20)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 6)

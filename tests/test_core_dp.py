"""Correctness of the budgeted DP (Algorithm 2) against brute force."""
import itertools

import numpy as np
import pytest

try:  # optional [test] extra — property tests skip cleanly without it
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core.dp import NEG, build_tables, oracle_knapsack, solve_budgeted_dp

import jax.numpy as jnp


def brute_force_p4(upsilon, sigma2, A, c, s, allowed=None):
    """max Σ̂²ᵀx  s.t. Ax ≤ c, Υ̂ᵀx ≥ s over all x ∈ {0,1}^E."""
    E = len(upsilon)
    best = None
    for bits in itertools.product([0, 1], repeat=E):
        x = np.array(bits)
        if allowed is not None and np.any(x > allowed):
            continue
        if np.any(A @ x > c):
            continue
        if upsilon @ x < s:
            continue
        val = int(sigma2 @ x)
        if best is None or val > best:
            best = val
    return best


def brute_force_eq17(upsilon, sigma2, A, c, s_limit, allowed=None):
    """The full Alg.-2 objective: max over s of s + sqrt(P4(s))."""
    best_score, best_s = -1.0, None
    for s in range(s_limit + 1):
        v = brute_force_p4(upsilon, sigma2, A, c, s, allowed)
        if v is None:
            continue
        score = s + np.sqrt(v)
        if score > best_score:
            best_score, best_s = score, s
    return best_score, best_s


def _rand_problem(rng, E=6, K=2, cmax=3, umax=5, smax=50):
    A = rng.integers(1, 3, size=(K, E))
    c = rng.integers(1, cmax + 1, size=K)
    A = np.minimum(A, c[:, None])
    upsilon = rng.integers(0, umax + 1, size=E)
    sigma2 = rng.integers(1, smax + 1, size=E)
    return A, c, upsilon, sigma2


@pytest.mark.parametrize("seed", range(8))
def test_dp_matches_bruteforce_eq17(seed):
    rng = np.random.default_rng(seed)
    A, c, upsilon, sigma2 = _rand_problem(rng)
    tables = build_tables(A, c)
    s_limit = int(upsilon.sum())
    s_cap = s_limit
    x, info = solve_budgeted_dp(
        jnp.asarray(upsilon, jnp.int32), jnp.asarray(sigma2, jnp.int32),
        tables, s_cap, jnp.int32(s_limit))
    x = np.asarray(x)
    # solution must be feasible
    assert np.all(A @ x <= c)
    # and achieve the brute-force-optimal eq.-17 score
    bf_score, _ = brute_force_eq17(upsilon, sigma2, A, c, s_limit)
    assert upsilon @ x >= int(info["s_star"])
    got_score = float(info["s_star"]) + np.sqrt(float(sigma2 @ x))
    assert got_score == pytest.approx(bf_score, rel=1e-6)


@pytest.mark.parametrize("seed", range(4))
def test_dp_with_allowed_mask(seed):
    rng = np.random.default_rng(100 + seed)
    A, c, upsilon, sigma2 = _rand_problem(rng)
    allowed = rng.integers(0, 2, size=len(upsilon)).astype(bool)
    tables = build_tables(A, c)
    s_limit = int(upsilon[allowed].sum())
    x, info = solve_budgeted_dp(
        jnp.asarray(upsilon, jnp.int32), jnp.asarray(sigma2, jnp.int32),
        tables, s_limit, jnp.int32(s_limit), allowed=jnp.asarray(allowed))
    x = np.asarray(x)
    assert np.all(x <= allowed.astype(int))
    assert np.all(A @ x <= c)
    bf_score, _ = brute_force_eq17(upsilon, sigma2, A, c, s_limit,
                                   allowed.astype(int))
    got_score = float(info["s_star"]) + np.sqrt(float(sigma2 @ x))
    assert got_score == pytest.approx(bf_score, rel=1e-6)


@pytest.mark.parametrize("seed", range(6))
def test_oracle_knapsack_matches_bruteforce(seed):
    rng = np.random.default_rng(200 + seed)
    A, c, _, _ = _rand_problem(rng)
    E = A.shape[1]
    values = rng.uniform(0.0, 1.0, size=E).astype(np.float32)
    allowed = rng.integers(0, 2, size=E).astype(bool)
    tables = build_tables(A, c)
    x, v = oracle_knapsack(jnp.asarray(values), tables, jnp.asarray(allowed))
    x = np.asarray(x)
    assert np.all(A @ x <= c)
    assert np.all(x <= allowed.astype(int))
    best = -1.0
    for bits in itertools.product([0, 1], repeat=E):
        xx = np.array(bits)
        if np.any(xx > allowed.astype(int)) or np.any(A @ xx > c):
            continue
        best = max(best, float(values @ xx))
    assert float(v) == pytest.approx(best, rel=1e-5)
    assert float(values @ x) == pytest.approx(best, rel=1e-5)


# ---------------------------------------------------------------------------
# hypothesis property tests: DP invariants on random problems
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_dp_solution_always_feasible(seed):
        rng = np.random.default_rng(seed)
        E = int(rng.integers(2, 9))
        K = int(rng.integers(1, 4))
        A, c, upsilon, sigma2 = _rand_problem(rng, E=E, K=K)
        tables = build_tables(A, c)
        s_limit = int(upsilon.sum())
        x, info = solve_budgeted_dp(
            jnp.asarray(upsilon, jnp.int32), jnp.asarray(sigma2, jnp.int32),
            tables, s_limit, jnp.int32(s_limit))
        x = np.asarray(x)
        assert set(np.unique(x)).issubset({0, 1})
        assert np.all(A @ x <= c)  # capacity (1)
        assert upsilon @ x >= int(info["s_star"])  # budget (16)
        row = np.asarray(info["value_row"])
        assert row[int(info["s_star"])] == sigma2 @ x  # value consistency

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_dp_value_row_monotone(seed):
        """V(s) is non-increasing in s (larger budget ⇒ smaller feasible set)."""
        rng = np.random.default_rng(seed)
        A, c, upsilon, sigma2 = _rand_problem(rng)
        tables = build_tables(A, c)
        s_limit = int(upsilon.sum())
        _, info = solve_budgeted_dp(
            jnp.asarray(upsilon, jnp.int32), jnp.asarray(sigma2, jnp.int32),
            tables, s_limit, jnp.int32(s_limit))
        row = np.asarray(info["value_row"], dtype=np.int64)
        ok = row > int(NEG) // 2
        vals = row[ok]
        assert np.all(np.diff(vals) <= 0)
else:
    def test_hypothesis_extra_missing():
        pytest.importorskip(
            "hypothesis",
            reason="property tests need the [test] extra (pip install .[test])")

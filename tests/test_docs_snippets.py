"""Executable documentation: every fenced ```python snippet in README.md
and docs/*.md runs as a test, so example code can never rot silently.

Rules (kept deliberately simple so docs stay honest):
  * snippets must be SELF-CONTAINED — they build their own instances and
    import what they use, exactly as a reader would paste them;
  * snippets run with cwd set to a temp dir, so examples may write
    relative paths (``results/my_sweep.csv``) without dirtying the repo;
  * a ``<!-- doc-snippet: compile-only -->`` comment right before a fence
    downgrades it to a syntax check (for templates with ``...`` bodies
    that must not execute, e.g. the add-a-regime skeleton — executing it
    would register a scenario that cannot simulate and leak it into the
    process-wide registry other tests iterate);
  * snippets are sized for CI (small instances, short horizons) — the
    docs say so where it matters.

The CI lint job runs exactly this file (see .github/workflows/ci.yml), and
it is part of tier-1.
"""
from __future__ import annotations

import os
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = sorted(
    [REPO / "README.md"] + list((REPO / "docs").glob("*.md")),
    key=lambda p: p.name)

COMPILE_ONLY = "compile-only"
_FENCE = re.compile(
    r"(?P<mark><!-- doc-snippet: (?P<mode>[a-z-]+) -->\s*\n)?"
    r"^```python[ \t]*\n(?P<body>.*?)^```[ \t]*$",
    re.DOTALL | re.MULTILINE)


def extract_snippets(path: pathlib.Path):
    """(relative file name, index, mode, source) for every python fence."""
    out = []
    for i, m in enumerate(_FENCE.finditer(path.read_text())):
        mode = m.group("mode") or "exec"
        out.append((path.name, i, mode, m.group("body")))
    return out


SNIPPETS = [s for f in DOC_FILES for s in extract_snippets(f)]


def test_docs_actually_contain_snippets():
    """The extractor must keep finding the documented examples — an empty
    sweep would turn this whole harness into a silent no-op."""
    files = {name for name, *_ in SNIPPETS}
    assert {"README.md", "solvers.md", "scenarios.md", "api.md"} <= files
    assert len(SNIPPETS) >= 5
    assert any(mode == COMPILE_ONLY for _, _, mode, _ in SNIPPETS)


@pytest.mark.parametrize(
    "name,idx,mode,src",
    SNIPPETS, ids=[f"{n}:{i}" for n, i, _, _ in SNIPPETS])
def test_doc_snippet(name, idx, mode, src, tmp_path, monkeypatch):
    code = compile(src, f"{name}:snippet{idx}", "exec")
    if mode == COMPILE_ONLY:
        return  # template: syntax-checked, not run
    assert mode == "exec", f"unknown doc-snippet mode {mode!r}"
    monkeypatch.chdir(tmp_path)  # relative writes land in the temp dir
    exec(code, {"__name__": f"doc_snippet_{name}_{idx}"})
    assert os.getcwd() == str(tmp_path)

"""Tests for tools/format.py — the stdlib machine-format normalizer.

The tree-wide check mirrors the blocking CI format gate: if a change
re-introduces aligned trailing comments or aligned-under-paren def
signatures, tier-1 fails locally before CI does.
"""

import ast
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from format import _split_top_level, format_source, main  # noqa: E402


def test_inline_comment_respaced():
    src = "x = 1          # aligned far right\ny = 2  # already fine\n"
    out, skipped = format_source(src)
    assert out == "x = 1  # aligned far right\ny = 2  # already fine\n"
    assert skipped == []


def test_standalone_comment_untouched():
    src = "    # a standalone comment keeps its indent\nx = 1\n"
    out, _ = format_source(src)
    assert out == src


def test_hash_inside_string_not_a_comment():
    src = 'x = "#  not a comment"     # real one\n'
    out, _ = format_source(src)
    assert out == 'x = "#  not a comment"  # real one\n'


def test_signature_joined_when_it_fits():
    src = "def f(a, b,\n      c):\n    return a + b + c\n"
    out, _ = format_source(src)
    assert out.startswith("def f(a, b, c):\n")


def test_signature_hug_form():
    long_names = ", ".join(f"argument_number_{i}" for i in range(3))
    src = f"def quite_a_long_function_name({long_names},\n        tail=None) -> dict:\n    pass\n"
    assert len(src.splitlines()[0]) + len("tail=None) -> dict:") > 88  # one line won't fit
    out, _ = format_source(src)
    lines = out.splitlines()
    assert lines[0] == "def quite_a_long_function_name("
    assert lines[1] == f"    {long_names}, tail=None"
    assert lines[2] == ") -> dict:"


def test_magic_trailing_comma_forces_explode():
    src = "def f(a, b,\n      c,):\n    pass\n"
    out, _ = format_source(src)
    assert out.splitlines()[:5] == ["def f(", "    a,", "    b,", "    c,", "):"]


def test_default_with_commas_and_strings_survives():
    src = 'def f(a=(1, 2), b="x,  y",\n      c=None) -> int:\n    return a[0]\n'
    out, _ = format_source(src)
    assert 'b="x,  y"' in out  # string interior untouched by whitespace collapse
    assert ast.dump(ast.parse(out)) == ast.dump(ast.parse(src))


def test_split_top_level_respects_nesting():
    assert _split_top_level('a=(1, 2), b="q,r", *args') == ["a=(1, 2)", ' b="q,r"', " *args"]


def test_signature_with_comment_is_skipped():
    src = "def f(a,  # why\n      b):\n    return a\n"
    out, skipped = format_source(src)
    assert "def f(a,  # why" in out  # body left alone
    assert any("def f" in s for s in skipped)


def test_idempotent_and_ast_preserving_on_this_repo():
    targets = [REPO / "src", REPO / "tests", REPO / "benchmarks", REPO / "tools"]
    for path in targets:
        for f in sorted(path.rglob("*.py")):
            src = f.read_text()
            out, _ = format_source(src)  # raises if AST changes
            assert out == src, f"{f} is not machine-formatted — run python tools/format.py"


def test_check_mode_exit_codes(tmp_path):
    good = tmp_path / "good.py"
    good.write_text("x = 1  # fine\n")
    assert main(["--check", str(good)]) == 0
    bad = tmp_path / "bad.py"
    bad.write_text("x = 1     # aligned\n")
    assert main(["--check", str(bad)]) == 1
    assert bad.read_text() == "x = 1     # aligned\n"  # check mode never writes
    assert main([str(bad)]) == 0
    assert bad.read_text() == "x = 1  # aligned\n"


@pytest.mark.parametrize("snippet", ["def f(:\n", "x = (\n"])
def test_broken_source_reports_error(tmp_path, snippet):
    f = tmp_path / "broken.py"
    f.write_text(snippet)
    assert main(["--check", str(f)]) == 2

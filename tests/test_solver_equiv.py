"""Differential-testing harness for the pluggable Algorithm-2 backends.

The only trustworthy spec for a hand-written kernel against an exact-integer
DP is agreement with an oracle: brute-force enumeration over all 2^E subsets
(the ground truth for P4/eq. 17) and the pure-JAX reference DP.  Property
tests (hypothesis, optional [test] extra) generate random small instances
(E ≤ 12, K ≤ 3) and require *bit-exact* agreement on x, s*, and the value
row across backends, random ``allowed`` masks, ``u_max`` edge cases, and
``s_limit < s_cap`` — plus end-to-end trace invariance through ``simulate``,
``simulate_batch``, and a fig6-style ``SweepSpec``.

The fleet-batched section extends the same contract to B solves per launch:
``solve_budgeted_dp_batched`` and ``jax.vmap`` of the pallas backend (which
dispatches through the custom batching rule) must match a per-instance loop
over the reference backend bit for bit — heterogeneous Υ̂/Σ̂²/allowed/s_limit
across the fleet, ragged batches, random (block_b, block_e, block_s,
block_c) tilings, and the degenerate B=1 fleet against the single-instance
kernel.
"""
import dataclasses
import itertools

import numpy as np
import pytest

try:  # optional [test] extra — property tests skip cleanly without it
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro.core import (build_tables, generate_instance, make_esdp_policy,
                        simulate, simulate_batch)
from repro.core import stats as stats_mod
from repro.core.baselines import hswf_factory
from repro.core.dp import NEG, oracle_knapsack, solve_budgeted_dp
from repro.core.esdp import esdp_factory
from repro.core.solvers import (SOLVER_ENV_VAR, get_solver, resolve_solver)
from repro.experiments import GridPoint, SweepSpec, get_scenario, run_spec
from repro.kernels.budgeted_dp.kernel import resolve_interpret
from repro.kernels.budgeted_dp.ops import (VALUE_BOUND, max_achievable_value,
                                           prepare_tables,
                                           solve_budgeted_dp_batched,
                                           solve_budgeted_dp_pallas)

REF = get_solver("reference")
PAL = get_solver("pallas_interpret")


# ---------------------------------------------------------------------------
# oracles
# ---------------------------------------------------------------------------

def enumerate_value_row(upsilon, sigma2, A, c, s_cap, allowed=None):
    """Ground-truth {P4(s)}_s: exhaustive max Σ̂²ᵀx over all 2^E subsets with
    Ax ≤ c and Υ̂ᵀx ≥ s, for every s — NEG where no subset reaches budget s."""
    E = len(upsilon)
    bits = ((np.arange(2 ** E)[:, None] >> np.arange(E)[None, :]) & 1
            ).astype(np.int64)
    if allowed is not None:
        bits = bits[(bits <= np.asarray(allowed, np.int64)).all(axis=1)]
    bits = bits[(bits @ np.asarray(A, np.int64).T <=
                 np.asarray(c, np.int64)).all(axis=1)]
    u = bits @ np.asarray(upsilon, np.int64)
    v = bits @ np.asarray(sigma2, np.int64)
    row = np.full(s_cap + 1, int(NEG), np.int64)
    for uu, vv in zip(u, v):  # subset covers every s ≤ Υ̂ᵀx
        hi = min(int(uu), s_cap)
        row[:hi + 1] = np.maximum(row[:hi + 1], vv)
    return row.astype(np.int32)


def eq17_star(row, s_limit):
    """The eq.-17 selection on a value row: argmax_s s + sqrt(P4(s))."""
    s_vals = np.arange(row.shape[0])
    score = s_vals + np.sqrt(np.maximum(row, 0).astype(np.float64))
    score = np.where((row >= 0) & (s_vals <= s_limit), score, -np.inf)
    return int(np.argmax(score))


def _rand_problem(rng, E, K, c_hi=3, u_hi=5, sig_hi=5000):
    A = rng.integers(1, 3, size=(K, E))
    c = rng.integers(1, c_hi + 1, size=K)
    A = np.minimum(A, c[:, None])
    upsilon = rng.integers(0, u_hi + 1, size=E).astype(np.int32)
    sigma2 = rng.integers(1, sig_hi + 1, size=E).astype(np.int32)
    return A, c, upsilon, sigma2


def _solve_with(solver, upsilon, sigma2, tables, s_cap, s_limit, allowed=None):
    x, info = solver(jnp.asarray(upsilon, jnp.int32),
                     jnp.asarray(sigma2, jnp.int32), tables, s_cap,
                     jnp.int32(s_limit),
                     None if allowed is None else jnp.asarray(allowed))
    return (np.asarray(x), int(info["s_star"]),
            np.asarray(info["value_row"]))


# ---------------------------------------------------------------------------
# (a) reference DP vs brute-force enumeration, for every s
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_reference_value_row_matches_bruteforce(seed):
        rng = np.random.default_rng(seed)
        E, K = int(rng.integers(4, 13)), int(rng.integers(1, 4))
        A, c, ups, sig = _rand_problem(rng, E, K)
        allowed = (rng.integers(0, 2, E).astype(bool)
                   if rng.integers(0, 2) else None)
        tables = build_tables(A, c)
        s_cap = int(ups.sum())
        x, s_star, row = _solve_with(REF, ups, sig, tables, s_cap, s_cap,
                                     allowed)
        bf_row = enumerate_value_row(ups, sig, A, c, s_cap, allowed)
        np.testing.assert_array_equal(row, bf_row)
        assert s_star == eq17_star(bf_row, s_cap)
        # the returned x realizes the row entry at s*
        assert np.all(A @ x <= c)
        assert int(ups @ x) >= s_star
        assert int(sig @ x) == bf_row[s_star]

    # -----------------------------------------------------------------------
    # (b) reference vs Pallas: bit-exact on x, s*, and the value row.
    # Shapes are drawn from a small pool so the kernel compiles a handful of
    # tiny programs instead of one per example.
    # -----------------------------------------------------------------------

    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_reference_vs_pallas_bitexact(seed):
        rng = np.random.default_rng(seed)
        E = int(rng.choice([6, 10]))
        K = int(rng.integers(1, 3))
        A, c, ups, sig = _rand_problem(rng, E, K, c_hi=2, u_hi=4,
                                       sig_hi=10**4)
        allowed = (rng.integers(0, 2, E).astype(bool)
                   if rng.integers(0, 2) else None)
        tables = build_tables(A, c)
        s_cap = 4 * E  # static per E: few jit keys
        s_limit = int(rng.integers(0, s_cap + 1))  # exercises s_limit < s_cap
        got_ref = _solve_with(REF, ups, sig, tables, s_cap, s_limit, allowed)
        got_pal = _solve_with(PAL, ups, sig, tables, s_cap, s_limit, allowed)
        np.testing.assert_array_equal(got_ref[0], got_pal[0])  # x
        assert got_ref[1] == got_pal[1]  # s_star
        np.testing.assert_array_equal(got_ref[2], got_pal[2])  # value_row

    # -----------------------------------------------------------------------
    # (c) oracle_knapsack vs exhaustive search
    # -----------------------------------------------------------------------

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_oracle_knapsack_matches_exhaustive(seed):
        rng = np.random.default_rng(seed)
        E, K = int(rng.integers(4, 11)), int(rng.integers(1, 4))
        A, c, _, _ = _rand_problem(rng, E, K)
        values = rng.uniform(0.0, 1.0, E).astype(np.float32)
        allowed = rng.integers(0, 2, E).astype(bool)
        tables = build_tables(A, c)
        x, v = oracle_knapsack(jnp.asarray(values), tables,
                               jnp.asarray(allowed))
        x = np.asarray(x)
        best = 0.0
        for bits in itertools.product([0, 1], repeat=E):
            xx = np.array(bits)
            if np.any(xx > allowed.astype(int)) or np.any(A @ xx > c):
                continue
            best = max(best, float(values @ xx))
        assert np.all(A @ x <= c) and np.all(x <= allowed.astype(int))
        assert float(v) == pytest.approx(best, rel=1e-5)
else:
    def test_hypothesis_extra_missing():
        pytest.importorskip(
            "hypothesis",
            reason="property tests need the [test] extra (pip install .[test])")


# ---------------------------------------------------------------------------
# u_max edge cases (deterministic — these pin the shift-padding contract)
# ---------------------------------------------------------------------------

def test_pallas_u_max_one_all_zero_upsilon():
    """u_max=1 is legal only when every Υ̂ is 0 (shift never exceeds padding)."""
    rng = np.random.default_rng(5)
    E, K = 8, 2
    A, c, _, sig = _rand_problem(rng, E, K)
    ups = np.zeros(E, np.int32)
    tables = build_tables(A, c)
    s_cap = 6
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups), jnp.asarray(sig), tables,
                               s_cap, jnp.int32(s_cap))
    x2, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                      u_max=1, interpret=True)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert int(i1["s_star"]) == int(i2["s_star"]) == 0


@pytest.mark.parametrize("u_max_kind", ["tight", "s_cap_plus_one"])
def test_pallas_u_max_padding_invariance(u_max_kind):
    """The result must not depend on the padding amount (≥ max Υ̂ + 1)."""
    rng = np.random.default_rng(6)
    E, K = 9, 2
    A, c, ups, sig = _rand_problem(rng, E, K, u_hi=4)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    u_max = int(ups.max() + 1) if u_max_kind == "tight" else s_cap + 1
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups), jnp.asarray(sig), tables,
                               s_cap, jnp.int32(s_cap))
    x2, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                      u_max=u_max, interpret=True)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert int(i1["s_star"]) == int(i2["s_star"])


def test_s_limit_below_cap_matches_bruteforce():
    rng = np.random.default_rng(7)
    A, c, ups, sig = _rand_problem(rng, 8, 2)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    s_limit = s_cap // 2
    bf_row = enumerate_value_row(ups, sig, A, c, s_cap)
    for solver in (REF, PAL):
        x, s_star, row = _solve_with(solver, ups, sig, tables, s_cap,
                                     s_limit)
        assert s_star == eq17_star(bf_row, s_limit)
        assert s_star <= s_limit
        np.testing.assert_array_equal(row, bf_row)


# ---------------------------------------------------------------------------
# offset-encoded transitions (the E·C² → E operand contract)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_offset_identity_on_feasible_pairs(seed):
        """DPTables.offsets is the whole transition table: next_state[c, e]
        == c − offsets[e] for EVERY feasible (e, c), and offsets[e] ==
        Σ_k A[k,e]·strides[k]."""
        rng = np.random.default_rng(seed)
        E, K = int(rng.integers(2, 16)), int(rng.integers(1, 5))
        A, c, _, _ = _rand_problem(rng, E, K)
        tables = build_tables(A, c)
        np.testing.assert_array_equal(
            tables.offsets, (A.T * tables.strides[None, :]).sum(axis=1))
        states, edges = np.nonzero(tables.feasible)
        np.testing.assert_array_equal(
            tables.next_state[states, edges],
            states - tables.offsets[edges])


if HAS_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_s_tiled_solver_bitexact_random_tilings(seed):
        """The 2-D (S-tile × C-tile) pipeline under RANDOM legal tilings —
        tight (block = halo floor), padded (dividing neither plane
        extent), and everything between, with u_max at or above the exact
        Υ̂ maximum, optional allowed masks, AND a random edge-fusion chunk
        block_e ∈ {None (per-edge scan), 1 … 32} (dividing E or not) —
        yields bit-identical x / s* / value_row vs the reference backend."""
        rng = np.random.default_rng(seed)
        E = int(rng.choice([6, 10]))
        K = int(rng.integers(1, 3))
        A, c, ups, sig = _rand_problem(rng, E, K, c_hi=2, u_hi=4,
                                       sig_hi=10**4)
        allowed = (rng.integers(0, 2, E).astype(bool)
                   if rng.integers(0, 2) else None)
        tables = build_tables(A, c)
        s_cap = 4 * E  # static per E: few jit keys
        S, C = s_cap + 1, tables.n_states
        off_max = int(tables.offsets.max())
        # u_max halo edge cases: the exact bound, +1 margin, or generous
        u_max = int(ups.max()) + int(rng.integers(0, 3))
        u_max = max(u_max, 1)
        block_s = int(rng.integers(max(u_max, 2), S + 3))
        block_c = int(rng.integers(max(off_max, 1), C + 3))
        block_e = (None if rng.integers(0, 4) == 0
                   else int(rng.integers(1, 33)))
        s_limit = int(rng.integers(0, s_cap + 1))
        got_ref = _solve_with(REF, ups, sig, tables, s_cap, s_limit, allowed)
        x, info = solve_budgeted_dp_pallas(
            ups, sig, tables, s_cap, s_limit, u_max=u_max,
            allowed=None if allowed is None else jnp.asarray(allowed),
            interpret=True, block_c=block_c, block_s=block_s,
            block_e=block_e)
        np.testing.assert_array_equal(got_ref[0], np.asarray(x))
        assert got_ref[1] == int(info["s_star"])
        row_ref = got_ref[2].astype(np.int64)
        row = np.asarray(info["value_row"])
        np.testing.assert_array_equal(row_ref >= 0, row >= 0)
        np.testing.assert_array_equal(row_ref[row_ref >= 0],
                                      row[row >= 0].astype(np.int64))


def test_prepare_tables_offsets_track_tables():
    """Kernel operands are pure derivations of DPTables fields — a replaced
    tables object can never serve stale operands (the old side-channel
    cache), and never-feasible edges get offset 0 (keeps the pad tight)."""
    A = np.array([[1, 2, 3]])  # edge 2 needs 3 > c=2: never feasible
    c = np.array([2])
    tables = build_tables(A, c)
    feas, offs = prepare_tables(tables)
    np.testing.assert_array_equal(offs, [1, 2, 0])
    np.testing.assert_array_equal(feas, np.asarray(tables.feasible,
                                                   np.float32).T)
    swapped = dataclasses.replace(
        tables, feasible=np.zeros_like(tables.feasible))
    feas2, _ = prepare_tables(swapped)
    assert not feas2.any()  # derived from the NEW fields


def test_large_c_blocked_grid_bitexact_vs_reference():
    """C = 512 (radices 8·8·8) — a capacity space whose one-hot operand
    (4·E·C² = 16 MB at E=16) could never fit VMEM — through the blocked
    grid path (forced small tiles), bit-exact vs the int32 reference on
    x / s* / value_row, with an allowed mask."""
    rng = np.random.default_rng(21)
    E, K = 16, 3
    A = rng.integers(0, 2, (K, E))  # 0/1 demands keep off_max ≤ 128
    A[:, A.sum(axis=0) == 0] = 1  # no all-zero demand columns
    c = np.array([7, 7, 7])
    ups = rng.integers(0, 4, E).astype(np.int32)
    sig = rng.integers(1, 5000, E).astype(np.int32)
    allowed = rng.integers(0, 2, E).astype(bool)
    allowed[:2] = True
    tables = build_tables(A, c)
    assert tables.n_states == 512
    s_cap = int(ups.sum())
    got_ref = _solve_with(REF, ups, sig, tables, s_cap, s_cap, allowed)
    x, info = solve_budgeted_dp_pallas(
        ups, sig, tables, s_cap, s_cap, allowed=allowed, interpret=True,
        block_c=128)
    assert int(tables.offsets.max()) <= 128  # halo contract holds
    np.testing.assert_array_equal(got_ref[0], np.asarray(x))
    assert got_ref[1] == int(info["s_star"])
    row = np.asarray(info["value_row"])
    ref_row = got_ref[2]
    np.testing.assert_array_equal(ref_row >= 0, row >= 0)
    np.testing.assert_array_equal(ref_row[ref_row >= 0],
                                  row[row >= 0].astype(np.int64))


def test_undersized_u_max_raises_instead_of_clamping():
    """The kernel clamps shifts at u_max for memory safety; the wrapper must
    refuse a concrete contract breach rather than return silently-wrong
    values."""
    rng = np.random.default_rng(22)
    A, c, ups, sig = _rand_problem(rng, 8, 2, u_hi=5)
    ups[0] = 5
    tables = build_tables(A, c)
    with pytest.raises(ValueError, match="u_max"):
        solve_budgeted_dp_pallas(ups, sig, tables, int(ups.sum()),
                                 int(ups.sum()), u_max=3, interpret=True)


def test_u_max_for_horizon_bounds_upsilon():
    """The tight static shift bound: ξ(T)+1 dominates every Υ̂ the schedules
    can emit (v̂ ≤ 1), and is m× smaller than the always-safe s_cap+1."""
    inst = generate_instance(seed=0)
    m = inst.m
    for T in (150, 1500, 10**5):
        u_max = stats_mod.u_max_for_horizon(T, m)
        s_cap = stats_mod.s_cap_for_horizon(T, m)
        assert u_max == s_cap // m + 1
        for t in (1.0, float(T) / 2, float(T)):
            ups, _, _, _ = stats_mod.scale_statistics(
                jnp.ones(inst.n_edges, jnp.float32),
                jnp.ones(inst.n_edges, jnp.int32), jnp.float32(t), m)
            assert int(jnp.max(ups)) < u_max


# ---------------------------------------------------------------------------
# fleet-batched solves: B instances, ONE launch (batched differential
# harness)
# ---------------------------------------------------------------------------

def _ref_loop(ups, sig, tables, s_cap, slim, alw):
    """Per-instance loop over the reference backend — the batched oracle."""
    return [_solve_with(REF, ups[b], sig[b], tables, s_cap, int(slim[b]),
                        None if alw is None else alw[b])
            for b in range(ups.shape[0])]


def _assert_batched_matches(x, info, want):
    for b, (x_r, s_r, row_r) in enumerate(want):
        np.testing.assert_array_equal(np.asarray(x[b]), x_r)
        assert int(info["s_star"][b]) == s_r
        row = np.asarray(info["value_row"][b])
        np.testing.assert_array_equal(row >= 0, row_r >= 0)
        np.testing.assert_array_equal(row[row >= 0].astype(np.int64),
                                      row_r[row_r >= 0].astype(np.int64))


def _rand_fleet(rng, B, E, s_cap, u_hi=4, sig_hi=10**4):
    """Heterogeneous per-instance statistics: every row its own problem."""
    ups = rng.integers(0, u_hi + 1, (B, E)).astype(np.int32)
    sig = rng.integers(1, sig_hi + 1, (B, E)).astype(np.int32)
    alw = rng.integers(0, 2, (B, E)).astype(np.int32)
    slim = rng.integers(0, s_cap + 1, B).astype(np.int32)
    return ups, sig, alw, slim


if HAS_HYPOTHESIS:
    # budget the heaviest sweep in the suite: a hypothesis shrink search
    # over B=32 interpret-mode fleets can otherwise eat the CI job's whole
    # timeout-minutes allowance (enforced only where pytest-timeout is
    # installed — the [test] extra)
    @pytest.mark.timeout(300)
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_batched_solve_bitexact_vs_instance_loop(seed):
        """Both batched routes — the explicit ``solve_budgeted_dp_batched``
        entry AND ``jax.vmap`` of the pallas backend (the custom batching
        rule) — are bit-exact vs a per-instance loop over the reference
        backend, with heterogeneous Υ̂/Σ̂²/allowed/s_limit across the fleet
        and B spanning 1 (degenerate), non-dividing (7) and wide (32)."""
        rng = np.random.default_rng(seed)
        E = int(rng.choice([6, 10]))
        K = int(rng.integers(1, 3))
        B = int(rng.choice([1, 2, 7, 32]))
        A, c, _, _ = _rand_problem(rng, E, K, c_hi=2)
        tables = build_tables(A, c)
        s_cap = 4 * E  # static per E: few jit keys
        u_max = 5  # static bound over u_hi=4
        ups, sig, alw, slim = _rand_fleet(rng, B, E, s_cap)
        want = _ref_loop(ups, sig, tables, s_cap, slim, alw)

        xb, info = solve_budgeted_dp_batched(
            ups, sig, tables, s_cap, slim, u_max=u_max, allowed=alw,
            interpret=True)
        _assert_batched_matches(xb, info, want)

        vm = jax.vmap(lambda u, s, l, a: PAL(u, s, tables, s_cap, l,
                                             allowed=a, u_max=u_max))
        xv, info_v = vm(jnp.asarray(ups), jnp.asarray(sig),
                        jnp.asarray(slim), jnp.asarray(alw))
        _assert_batched_matches(xv, info_v, want)
        for b, (_, _, row_r) in enumerate(want):
            # the Solver wrapper restores the exact int32 row incl. NEG
            np.testing.assert_array_equal(np.asarray(info_v["value_row"][b]),
                                          row_r)


if HAS_HYPOTHESIS:
    # same 5-minute budget as the fleet sweep above: random tilings multiply
    # the per-example kernel launches
    @pytest.mark.timeout(300)
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_batched_solver_random_tilings_bitexact(seed):
        """Random legal 4-tuple (block_b, block_e, block_s, block_c)
        tilings: the whole-plane kernel under every ``block_b`` ∈ [1, B]
        (ragged batches pad with inert instances), and the edge-fused
        pipeline with the batch as the outermost grid dimension under
        random block_e / block_s / block_c — all bit-exact vs the
        per-instance reference loop."""
        rng = np.random.default_rng(seed)
        E = int(rng.choice([6, 10]))
        K = int(rng.integers(1, 3))
        B = int(rng.choice([2, 7]))
        A, c, _, _ = _rand_problem(rng, E, K, c_hi=2)
        tables = build_tables(A, c)
        s_cap = 4 * E
        S, C = s_cap + 1, tables.n_states
        off_max = int(tables.offsets.max())
        ups, sig, alw, slim = _rand_fleet(rng, B, E, s_cap)
        u_max = int(ups.max()) + int(rng.integers(1, 3))
        if rng.integers(0, 2):  # whole-plane, batch-tiled grid
            kw = dict(block_b=int(rng.integers(1, B + 1)), block_c=None)
        else:  # edge-fused, batch-outermost grid
            kw = dict(block_c=int(rng.integers(max(off_max, 1), C + 3)),
                      block_e=int(rng.integers(1, 33)),
                      block_s=(None if rng.integers(0, 2) else
                               int(rng.integers(max(u_max, 2), S + 3))))
        x, info = solve_budgeted_dp_batched(
            ups, sig, tables, s_cap, slim, u_max=u_max, allowed=alw,
            interpret=True, **kw)
        _assert_batched_matches(
            x, info, _ref_loop(ups, sig, tables, s_cap, slim, alw))


def test_batched_b1_degenerates_to_single_instance():
    """A fleet of one reproduces the single-instance kernel exactly —
    including the raw f32 value row (same sentinel, same bits) — and a
    scalar s_limit broadcasts across the batch."""
    rng = np.random.default_rng(33)
    A, c, ups, sig = _rand_problem(rng, 10, 2, u_hi=4)
    alw = rng.integers(0, 2, 10).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    x1, i1 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap // 2,
                                      u_max=5, allowed=alw, interpret=True)
    xb, ib = solve_budgeted_dp_batched(ups[None], sig[None], tables, s_cap,
                                       np.int32(s_cap // 2), u_max=5,
                                       allowed=alw[None], interpret=True)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(xb[0]))
    assert int(i1["s_star"]) == int(ib["s_star"][0])
    np.testing.assert_array_equal(np.asarray(i1["value_row"]),
                                  np.asarray(ib["value_row"][0]))


def test_cluster_run_batch_reproduces_per_seed_runs(small):
    """``run_batch`` fleet-batches the per-slot solves (ONE launch per
    slot through the batch-aware backend) yet reproduces per-seed
    ``run()`` bit for bit — sw, regret, dispatch_share, asw — for both
    the batch-aware pallas backend and the conventionally-vmapped
    reference, on a DP policy and a greedy one."""
    from repro.sched import ClusterSim
    inst, _ = small
    T, seeds = 40, [4, 9]
    for name, policy in (("pallas_interpret", "esdp"),
                         ("reference", "hswf")):
        outs = ClusterSim(inst, T, seed=0, solver=name).run_batch(
            seeds, policy)
        assert len(outs) == len(seeds)
        for s, ob in zip(seeds, outs):
            o1 = ClusterSim(inst, T, seed=s, solver=name).run(policy)
            np.testing.assert_array_equal(ob.sw, o1.sw)
            np.testing.assert_array_equal(ob.regret, o1.regret)
            np.testing.assert_array_equal(ob.dispatch_share,
                                          o1.dispatch_share)
            assert ob.asw == o1.asw


def test_prepare_tables_cached_per_tables_identity():
    """The host-side operand derivation runs ONCE per DPTables object —
    every per-slot solve of a simulation hits the lru_cache — while a
    ``dataclasses.replace``d tables object is a fresh key (so the cache
    can never serve stale operands; see
    test_prepare_tables_offsets_track_tables)."""
    tables = build_tables(np.array([[1, 1, 2]]), np.array([3]))
    before = prepare_tables.cache_info()
    f1, o1 = prepare_tables(tables)
    mid = prepare_tables.cache_info()
    assert mid.misses == before.misses + 1
    f2, o2 = prepare_tables(tables)
    after = prepare_tables.cache_info()
    assert after.hits == mid.hits + 1 and after.misses == mid.misses
    assert f1 is f2 and o1 is o2  # same host arrays, not copies
    swapped = dataclasses.replace(tables,
                                  feasible=np.zeros_like(tables.feasible))
    prepare_tables(swapped)
    assert prepare_tables.cache_info().misses == after.misses + 1


# ---------------------------------------------------------------------------
# backend resolution logic (the silent-interpret fix)
# ---------------------------------------------------------------------------

def test_backend_resolution_table():
    for platform in ("cpu", "gpu", "tpu"):
        # kernel level: never silently interpreted on TPU
        assert resolve_interpret(None, platform) is (platform != "tpu")
        assert resolve_interpret(True, platform) is True
        assert resolve_interpret(False, platform) is False
        # registry level: auto = compiled pallas on TPU, reference elsewhere
        expect = "pallas" if platform == "tpu" else "reference"
        assert resolve_solver("auto", platform) == expect
        for name in ("reference", "pallas", "pallas_interpret"):
            assert resolve_solver(name, platform) == name
    with pytest.raises(ValueError):
        resolve_solver("bogus")


def test_env_var_overrides_auto_but_not_explicit(monkeypatch):
    monkeypatch.setenv(SOLVER_ENV_VAR, "pallas_interpret")
    assert resolve_solver(None, "tpu") == "pallas_interpret"
    assert resolve_solver("auto", "cpu") == "pallas_interpret"
    assert get_solver(None, "cpu").name == "pallas_interpret"
    assert resolve_solver("reference", "tpu") == "reference"
    monkeypatch.setenv(SOLVER_ENV_VAR, "")
    assert resolve_solver(None, "cpu") == "reference"


def test_invalid_env_var_warns_and_falls_back_to_auto(monkeypatch):
    """A stale/typo'd $REPRO_DP_SOLVER must not hard-crash callers that
    never asked for a concrete backend: env-sourced invalid names WARN and
    fall back to the auto resolution — while an invalid name passed in
    code still raises (the caller asked for something that doesn't
    exist)."""
    monkeypatch.setenv(SOLVER_ENV_VAR, "bogus")
    for requested in (None, "auto"):
        for platform, expect in (("cpu", "reference"), ("gpu", "reference"),
                                 ("tpu", "pallas")):
            with pytest.warns(RuntimeWarning, match="REPRO_DP_SOLVER"):
                assert resolve_solver(requested, platform) == expect
    # explicit names win before the env var is even consulted — no warning
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        assert resolve_solver("reference", "tpu") == "reference"
    # names passed IN CODE keep raising, env var irrelevant
    with pytest.raises(ValueError, match="bogus"):
        resolve_solver("bogus", "cpu")


def test_get_solver_caches_identity():
    assert get_solver("reference") is get_solver("reference")
    assert get_solver(PAL) is PAL


# ---------------------------------------------------------------------------
# VALUE_BOUND contract (f32 exactness < 2^24)
# ---------------------------------------------------------------------------

def test_value_bound_overflow_raises():
    rng = np.random.default_rng(8)
    A, c, ups, sig = _rand_problem(rng, 6, 2)
    sig = sig.astype(np.int32)
    sig[0] = VALUE_BOUND  # a single value at the bound
    tables = build_tables(A, c)
    with pytest.raises(ValueError, match="2\\^24"):
        solve_budgeted_dp_pallas(ups, sig, tables, int(ups.sum()),
                                 int(ups.sum()), interpret=True)


def test_max_achievable_value_topk():
    # K=1, c=2, A=1 per edge: at most 2 edges fit → top-2 sum of Σ̂²
    E = 5
    A = np.ones((1, E), np.int64)
    c = np.array([2], np.int64)
    sig = np.array([10, 50, 20, 40, 30], np.int64)
    tables = build_tables(A, c)
    assert max_achievable_value(sig, tables) == 90


def test_default_schedules_stay_under_value_bound():
    """Pins the stats.scale_statistics outputs under 2^24 at the default
    horizons (T=1500 benchmarks, T=10^5 stress), so the traced hot path —
    where the runtime check cannot see concrete values — is safe."""
    inst = generate_instance(seed=0)  # paper Table-2 defaults
    tables = build_tables(inst.A, inst.c)
    m = inst.m
    E = inst.n_edges
    for T in (1500, 10**5):
        # worst explored statistics: n = 1 for every channel at t = T
        _, sig, _, _ = stats_mod.scale_statistics(
            jnp.ones(E, jnp.float32), jnp.ones(E, jnp.int32),
            jnp.float32(T), m)
        assert max_achievable_value(np.asarray(sig), tables) < VALUE_BOUND
    # all channels unexplored (the finite dominance bonus) at t = 1
    _, sig0, _, _ = stats_mod.scale_statistics(
        jnp.zeros(E, jnp.float32), jnp.zeros(E, jnp.int32),
        jnp.float32(1.0), m)
    assert max_achievable_value(np.asarray(sig0), tables) < VALUE_BOUND


# ---------------------------------------------------------------------------
# end-to-end backend invariance (ESDP through the simulator and sweeps)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    inst = generate_instance(seed=3, n_ports=4, n_servers=10, edge_prob=0.3)
    tables = build_tables(inst.A, inst.c)
    return inst, tables


@pytest.mark.parametrize("scenario", [None, "markov_dvfs"])
def test_esdp_trace_invariance_end_to_end(small, scenario):
    """simulate(instance, esdp, T=200) produces identical SimResult traces
    (decisions, sw, regret) under both backends."""
    inst, tables = small
    T = 200
    scn = None if scenario is None else get_scenario(scenario)
    results = {}
    for name in ("reference", "pallas_interpret"):
        policy = make_esdp_policy(inst, T, tables=tables, solver=name)
        results[name] = simulate(inst, policy, T, seed=1, tables=tables,
                                 scenario=scn)
    ref, pal = results["reference"], results["pallas_interpret"]
    np.testing.assert_array_equal(ref.n_dispatched, pal.n_dispatched)
    np.testing.assert_array_equal(ref.sw, pal.sw)
    np.testing.assert_array_equal(ref.sw_oracle, pal.sw_oracle)
    np.testing.assert_array_equal(ref.regret, pal.regret)


def test_pallas_vmaps_through_simulate_batch(small):
    """The Pallas path is vmap-safe: a seed batch through simulate_batch is
    bit-identical to the reference backend's batch."""
    inst, tables = small
    T, seeds = 80, (0, 1, 2)
    res = {}
    for name in ("reference", "pallas"):  # public name; interpret on CPU
        policy = make_esdp_policy(inst, T, tables=tables, solver=name)
        res[name] = simulate_batch(inst, policy, T, seeds, tables=tables)
    np.testing.assert_array_equal(res["reference"].n_dispatched,
                                  res["pallas"].n_dispatched)
    np.testing.assert_array_equal(res["reference"].sw, res["pallas"].sw)
    np.testing.assert_array_equal(res["reference"].regret,
                                  res["pallas"].regret)


# Mirrors benchmarks.sensitivity.FIG6_SPEC.smoke() (defined locally so the
# test suite never depends on the benchmarks/ namespace package being on
# sys.path).  hswf rides along to cover run_spec's non-solver-aware branch.
FIG6_SMOKE = SweepSpec(
    name="fig6", T=120, seeds=(0,),
    policies={"esdp": esdp_factory(), "hswf": hswf_factory()},
    grid=tuple(GridPoint(f"c_hi{c}",
                         instance_kwargs={"seed": 2, "c_lo": 1, "c_hi": c})
               for c in (1, 2, 4, 6)),
)


def test_cluster_dispatcher_backend_invariance(small):
    """ClusterSim threads solver= into its jitted per-slot DP call."""
    from repro.sched import ClusterSim
    inst, _ = small
    outs = {name: ClusterSim(inst, 60, seed=4, solver=name).run("esdp")
            for name in ("reference", "pallas_interpret")}
    np.testing.assert_array_equal(outs["reference"].sw,
                                  outs["pallas_interpret"].sw)
    np.testing.assert_array_equal(outs["reference"].regret,
                                  outs["pallas_interpret"].regret)
    assert outs["reference"].asw == outs["pallas_interpret"].asw


def test_pallas_through_sweepspec_fig6_smoke():
    """SweepSpec.solver threads the backend through run_spec; the fig6 smoke
    sweep is bit-identical between backends (full per-seed traces, not just
    means)."""
    rows = {}
    for name in ("reference", "pallas"):
        rows[name] = run_spec(dataclasses.replace(FIG6_SMOKE, solver=name))
    assert len(rows["reference"]) == 8  # 4 grid points × 2 policies
    for r_ref, r_pal in zip(rows["reference"], rows["pallas"]):
        assert (r_ref.point, r_ref.policy) == (r_pal.point, r_pal.policy)
        assert r_pal.solver == "pallas"
        np.testing.assert_array_equal(r_ref.result.sw, r_pal.result.sw)
        np.testing.assert_array_equal(r_ref.result.regret,
                                      r_pal.result.regret)
        np.testing.assert_array_equal(r_ref.result.n_dispatched,
                                      r_pal.result.n_dispatched)
        assert r_ref.asw_mean == r_pal.asw_mean


# ---------------------------------------------------------------------------
# (i) incremental legs: the warm-started and cached re-solve layers must be
# bit-exact against cold solves over random DRIFT SEQUENCES — localized
# statistic drifts, eligibility flips, s_limit-only changes, and verbatim
# repeats (core.incremental / kernels.budgeted_dp.ops.WarmPallasSolver)
# ---------------------------------------------------------------------------

def _incremental_legs_body(seed):
    from repro.core.incremental import (solve_budgeted_dp_warm,
                                        warm_carry_init)
    from repro.core.solvers import CachedSolver
    from repro.kernels.budgeted_dp.ops import WarmPallasSolver

    rng = np.random.default_rng(seed)
    E = int(rng.choice([6, 10]))
    K = int(rng.integers(1, 3))
    A, c, ups, sig = _rand_problem(rng, E, K, c_hi=2, u_hi=4, sig_hi=10**4)
    tables = build_tables(A, c)
    s_cap = 4 * E  # static per E: few jit keys
    k = int(rng.choice([2, 4]))

    cached = CachedSolver(REF)
    warm_pal = WarmPallasSolver(tables, s_cap, checkpoint_every=k,
                                interpret=True)
    carry = warm_carry_init(E, s_cap, tables.n_states, k)

    @jax.jit
    def warm_ref(u, s, lim, a, cr):
        return solve_budgeted_dp_warm(u, s, tables, s_cap, lim, cr,
                                      allowed=a, checkpoint_every=k)

    alw = np.ones(E, bool)
    s_limit = s_cap
    for slot in range(6):
        kind = ("cold", "suffix", "slim", "repeat", "alw", "suffix")[slot]
        if kind == "suffix":  # edge 0 folds LAST: long prefix
            e = int(rng.integers(0, max(1, E // 3)))
            ups[e] = rng.integers(0, 5)
            sig[e] = rng.integers(1, 10**4)
        elif kind == "slim":
            s_limit = int(rng.integers(0, s_cap + 1))
        elif kind == "alw":
            e = int(rng.integers(0, E))
            alw[e] = ~alw[e]

        want = _solve_with(REF, ups, sig, tables, s_cap, s_limit, alw)
        got = {}
        got["cached"] = cached(ups, sig, tables, s_cap, s_limit, allowed=alw)
        got["warm_pal"] = warm_pal(ups, sig, tables, s_cap, s_limit,
                                   allowed=alw)
        xw, iw, carry = warm_ref(jnp.asarray(ups, jnp.int32),
                                 jnp.asarray(sig, jnp.int32),
                                 jnp.int32(s_limit), jnp.asarray(alw), carry)
        got["warm_ref"] = (xw, iw)
        for leg, (x, info) in got.items():
            np.testing.assert_array_equal(np.asarray(x), want[0], err_msg=leg)
            assert int(info["s_star"]) == want[1], leg
            np.testing.assert_array_equal(np.asarray(info["value_row"]),
                                          want[2], err_msg=leg)
    # the layers actually skipped work on this trace
    assert cached.stats.hits >= 1  # the "repeat" slot
    assert warm_pal.stats["edges_skipped"] > 0


if HAS_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_incremental_legs_bitexact_over_drift(seed):
        _incremental_legs_body(seed)
else:
    @pytest.mark.parametrize("seed", [0, 42, 20260808])
    def test_incremental_legs_bitexact_over_drift(seed):
        _incremental_legs_body(seed)

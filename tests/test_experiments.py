"""Tests for the batched scenario-sweep engine (repro.experiments)."""
import numpy as np
import pytest

from repro.core import (build_tables, generate_instance, make_esdp_policy,
                        make_hswf_policy, simulate, simulate_batch)
from repro.core.baselines import hswf_factory
from repro.core.esdp import esdp_factory
from repro.experiments import (GridPoint, SweepSpec, get_scenario, run_spec,
                               scenario_names, sweep_scenario_param,
                               unroll_scenario, write_csv, write_json)
from repro.sched import ClusterSim, JobType, Slice, build_instance, rate_matrix


@pytest.fixture(scope="module")
def small():
    inst = generate_instance(seed=3, n_ports=4, n_servers=10, edge_prob=0.3)
    tables = build_tables(inst.A, inst.c)
    return inst, tables


# ---------------------------------------------------------------------------
# vmapped batch ≡ per-seed loop (the acceptance bar for replacing the loops)
# ---------------------------------------------------------------------------

def test_batch_matches_per_seed_loop(small):
    """simulate_batch row i reproduces simulate(seed=seeds[i]): decisions,
    oracle, and regret bit-for-bit; realized welfare to 1 float32 ulp (XLA
    reorders the Σ_e reduction under vmap)."""
    inst, tables = small
    T, seeds = 150, (11, 12, 13)
    for factory in (esdp_factory(), hswf_factory()):
        policy = factory(inst, T, tables)
        batch = simulate_batch(inst, policy, T, seeds, tables=tables)
        assert batch.sw.shape == (len(seeds), T)
        for i, s in enumerate(seeds):
            one = simulate(inst, policy, T, seed=s, tables=tables)
            np.testing.assert_array_equal(batch.n_dispatched[i],
                                          one.n_dispatched)
            np.testing.assert_array_equal(batch.sw_oracle[i], one.sw_oracle)
            np.testing.assert_array_equal(batch.regret[i], one.regret)
            np.testing.assert_allclose(batch.sw[i], one.sw,
                                       rtol=1e-6, atol=1e-6)


def test_sweep_reproduces_per_seed_means():
    """A fig6-style sweep spec gives the same per-seed means the old Python
    loop over `simulate` produced (same instance seeds, same run seeds)."""
    T, seeds = 120, (11, 12)
    spec = SweepSpec(
        name="fig6_mini", T=T, seeds=seeds,
        policies={"esdp": esdp_factory(), "hswf": hswf_factory()},
        grid=tuple(GridPoint(f"c_hi{c}",
                             instance_kwargs={"seed": 2, "c_lo": 1, "c_hi": c})
                   for c in (1, 2)),
    )
    rows = {(r.point, r.policy): r for r in run_spec(spec)}
    for c in (1, 2):
        inst = generate_instance(seed=2, c_lo=1, c_hi=c)
        tables = build_tables(inst.A, inst.c)
        for pname, policy in (("esdp", make_esdp_policy(inst, T, tables=tables)),
                              ("hswf", make_hswf_policy(inst))):
            loop_mean = float(np.mean(
                [simulate(inst, policy, T, seed=s, tables=tables).asw[-1]
                 for s in seeds]))
            got = rows[(f"c_hi{c}", pname)].asw_mean
            assert got == pytest.approx(loop_mean, rel=1e-5), (c, pname)


# ---------------------------------------------------------------------------
# scenario registry round-trip
# ---------------------------------------------------------------------------

def test_registry_has_named_regimes():
    names = scenario_names()
    assert len(names) >= 4
    for required in ("iid", "markov_dvfs", "chronic_straggler",
                     "transient_brownout"):
        assert required in names


def test_registry_roundtrip_simulates(small):
    """Every registered scenario builds, simulates T=50 slots, and produces
    finite welfare/regret."""
    inst, tables = small
    T = 50
    policy = hswf_factory()(inst, T, tables)
    for name in scenario_names():
        scn = get_scenario(name)
        assert scn.name == name
        res = simulate_batch(inst, policy, T, (0, 1), tables=tables,
                             scenario=scn)
        assert res.sw.shape == (2, T)
        for field in (res.sw, res.sw_oracle, res.regret):
            assert np.isfinite(field).all(), name
        assert np.all(res.sw >= 0), name


def test_default_scenario_matches_no_scenario(small):
    """scenario='iid' is the identity regime: bit-identical to scenario=None."""
    inst, tables = small
    policy = hswf_factory()(inst, 80, tables)
    a = simulate_batch(inst, policy, 80, (3,), tables=tables)
    b = simulate_batch(inst, policy, 80, (3,), tables=tables,
                       scenario=get_scenario("iid"))
    np.testing.assert_array_equal(a.sw, b.sw)
    np.testing.assert_array_equal(a.regret, b.regret)


def test_get_scenario_overrides_and_unknown():
    scn = get_scenario("chronic_straggler", straggler_speed=0.1)
    assert scn.params["straggler_speed"] == 0.1
    # unknown regimes raise ValueError and name the registry, so a typo'd
    # SweepSpec fails with the valid choices instead of a raw KeyError
    with pytest.raises(ValueError, match="chronic_straggler"):
        get_scenario("no_such_regime")


def test_degraded_speeds_lower_oracle_welfare(small):
    """Fluctuated speeds reduce the omniscient-oracle welfare — the regimes
    actually bite."""
    inst, tables = small
    T = 200
    policy = hswf_factory()(inst, T, tables)
    base = simulate_batch(inst, policy, T, (0, 1), tables=tables)
    brown = simulate_batch(
        inst, policy, T, (0, 1), tables=tables,
        scenario=get_scenario("transient_brownout", t_start=1.0,
                              t_end=float(T + 1), brownout_speed=0.3))
    assert (brown.sw_oracle.sum() < base.sw_oracle.sum())
    assert (brown.asw[:, -1].mean() < base.asw[:, -1].mean())


# ---------------------------------------------------------------------------
# lax.map scenario-parameter grids
# ---------------------------------------------------------------------------

def test_scenario_param_grid_matches_pointwise(small):
    """One lax.map×vmap call over a severity grid equals building each
    scenario separately (decision-level: dispatches and regret)."""
    inst, tables = small
    T, seeds = 60, (0, 1)
    values = (0.3, 0.7, 1.0)
    grid = sweep_scenario_param(inst, hswf_factory(), T, seeds,
                                "chronic_straggler", "straggler_speed",
                                values, tables=tables)
    assert grid.sw.shape == (len(values), len(seeds), T)
    policy = hswf_factory()(inst, T, tables)
    for gi, v in enumerate(values):
        scn = get_scenario("chronic_straggler", straggler_speed=v)
        point = simulate_batch(inst, policy, T, seeds, tables=tables,
                               scenario=scn)
        np.testing.assert_array_equal(grid.n_dispatched[gi],
                                      point.n_dispatched)
        np.testing.assert_allclose(grid.regret[gi], point.regret,
                                   rtol=1e-5, atol=1e-5)


def test_scenario_param_grid_unknown_param(small):
    inst, tables = small
    with pytest.raises(KeyError):
        sweep_scenario_param(inst, hswf_factory(), 10, (0,),
                             "chronic_straggler", "bogus", (1.0,),
                             tables=tables)


# ---------------------------------------------------------------------------
# result sinks
# ---------------------------------------------------------------------------

def test_csv_json_sinks(tmp_path, small):
    inst, tables = small
    spec = SweepSpec(
        name="sink", T=30, seeds=(0, 1),
        policies={"hswf": hswf_factory()},
        instance_kwargs={"seed": 3, "n_ports": 4, "n_servers": 10,
                         "edge_prob": 0.3},
    )
    rows = run_spec(spec)
    csv_path = write_csv(rows, tmp_path / "out.csv")
    json_path = write_json(rows, tmp_path / "out.json")
    text = csv_path.read_text()
    assert "asw_mean" in text and "hswf" in text
    import json
    recs = json.loads(json_path.read_text())
    assert len(recs) == 1 and recs[0]["policy"] == "hswf"
    assert recs[0]["seeds"] == "0;1"


# ---------------------------------------------------------------------------
# shared scenario interface with the cluster dispatcher
# ---------------------------------------------------------------------------

def _tiny_cluster():
    slices = [Slice("pod-a", "v5e", 256, 32, 4),
              Slice("pod-b", "v5e", 256, 32, 4),
              Slice("pod-c", "v5p", 256, 32, 4)]
    jobs = [JobType("train", "qwen2.5-32b", "train_4k", ("v5e", "v5p"),
                    256, 32, 4, value_rate=1.0),
            JobType("decode", "deepseek-v3-671b", "decode_32k", ("v5e",),
                    256, 32, 4, value_rate=1.2)]
    rates = rate_matrix(jobs, slices)
    inst, _ = build_instance(slices, jobs, rates, seed=0)
    return inst


def test_cluster_sim_accepts_scenario():
    """ClusterSim consumes a registry scenario through the same interface as
    the jitted env: dead servers get zero dispatch share while down."""
    inst = _tiny_cluster()
    T = 120
    scn = get_scenario("elastic_outage", frac=0.34, t_down=40.0, t_up=80.0)
    _, _, alive = unroll_scenario(scn, T, inst.n_servers, seed=2)
    dead_servers = np.nonzero(~alive.all(axis=0))[0]
    assert dead_servers.size > 0  # the outage actually fired
    out = ClusterSim(inst, T, scenario=scn, seed=2).run("esdp")
    assert out.dispatch_share[39:79, dead_servers].sum() == 0.0


def test_unroll_supports_per_port_arr_scale():
    """The Scenario contract allows scalar or (L,) arr_scale; the host-side
    unroll normalizes both to (T, n_ports)."""
    import jax.numpy as jnp
    from repro.core.env import Scenario

    def step(params, state, t, n_servers):
        return (state, jnp.asarray([1.0, 0.5, 0.0]),
                jnp.ones(n_servers, jnp.float32),
                jnp.ones(n_servers, dtype=bool))

    scn = Scenario(name="per_port", init=lambda p, k, r: (), step=step)
    arr, speed, alive = unroll_scenario(scn, 5, 4, n_ports=3)
    assert arr.shape == (5, 3) and speed.shape == (5, 4)
    np.testing.assert_allclose(arr[0], [1.0, 0.5, 0.0])
    # scalar scales broadcast across ports
    arr2, _, _ = unroll_scenario(get_scenario("mmpp_arrivals"), 5, 4,
                                 n_ports=3)
    assert arr2.shape == (5, 3)
    assert (arr2 == arr2[:, :1]).all()


def test_cluster_sim_rejects_conflicting_schedules():
    inst = _tiny_cluster()
    with pytest.raises(ValueError):
        ClusterSim(inst, 10, speed_fn=lambda t: np.ones(inst.n_servers),
                   scenario=get_scenario("iid"))

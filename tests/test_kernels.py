"""Per-kernel allclose sweeps against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd_op
from repro.kernels.ssd.ref import ssd_ref
from repro.core.dp import build_tables, solve_budgeted_dp
from repro.kernels.budgeted_dp.kernel import NEG, dp_forward_pallas
from repro.kernels.budgeted_dp.ops import prepare_tables, solve_budgeted_dp_pallas
from repro.kernels.budgeted_dp.ref import dp_forward_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KH,hd,causal,window", [
    (2, 256, 4, 4, 64, True, 0),
    (1, 256, 8, 2, 64, True, 0),       # GQA g=4
    (2, 128, 4, 1, 32, True, 0),       # MQA
    (1, 512, 2, 2, 128, True, 128),    # sliding window
    (2, 256, 4, 4, 64, False, 0),      # bidirectional (whisper encoder)
])
def test_flash_attention_matches_ref(B, S, H, KH, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, hd), dtype)
    scale = 1.0 / np.sqrt(hd)
    got = flash_attention_op(q, k, v, scale=scale, causal=causal,
                             window=window, blk_q=64, blk_k=128)
    want = attention_ref(q, k, v, scale=scale, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_cross_lengths():
    """Sq < Sk (query block at the end of a longer KV) — prefill tail."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 512, 4, 64))
    v = jax.random.normal(ks[2], (1, 512, 4, 64))
    got = flash_attention_op(q, k, v, scale=0.125, blk_q=64, blk_k=128)
    want = attention_ref(q, k, v, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 128, 2, 32, 16, 32),
    (1, 96, 4, 64, 32, 32),      # S not multiple of Q after pad? 96%32=0
    (2, 80, 2, 32, 16, 32),      # padding path (80 % 32 != 0)
    (1, 256, 2, 64, 64, 64),
])
def test_ssd_matches_ref(B, S, H, P, N, Q, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[0], (B, S, N), dtype)
    y_got, st_got = ssd_op(x, dt, A, Bm, Cm, chunk=Q)
    y_want, st_want = ssd_ref(x, dt, A, Bm, Cm, chunk=Q)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(st_got), np.asarray(st_want),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# budgeted_dp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_budgeted_dp_matches_core(seed):
    rng = np.random.default_rng(seed)
    E, K = int(rng.integers(4, 14)), int(rng.integers(1, 4))
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 9, E)
    sig = rng.integers(1, 5000, E)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups, jnp.int32),
                               jnp.asarray(sig, jnp.int32), tables, s_cap,
                               jnp.int32(s_cap))
    x2, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                      u_max=int(ups.max() + 1))
    assert int(i1["s_star"]) == int(i2["s_star"])
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


@pytest.mark.parametrize("E", [7, 32, 40])   # 1 word, exact fit, 2 words
def test_budgeted_dp_kernel_packed_decisions_match_ref(E):
    """The kernel's bit-packed (⌈E/32⌉, S, C) i32 decision words equal the
    pure-jnp oracle's, including across the word boundary (bit 31 → sign)."""
    rng = np.random.default_rng(11)
    K = 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 3, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 5, E).astype(np.int32)
    sig = rng.integers(1, 3000, E).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    V_k, dec_k = dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas,
                                   offs, v0, n_edges=E,
                                   u_max=int(ups.max() + 1),
                                   off_max=int(offs.max()), interpret=True)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    assert dec_k.shape == ((E + 31) // 32, s_cap + 1, tables.n_states)
    assert dec_k.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(V_k), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_k), np.asarray(dec_r))


@pytest.mark.parametrize("tile", ["tight", "padded"])
def test_budgeted_dp_blocked_grid_matches_ref(tile):
    """The C-blocked pipeline (scan over edges × capacity-tile grid, haloed
    left-neighbor loads, C padded to a tile multiple) is bit-exact vs the
    oracle — values and packed decision words.  ``tight`` runs the minimum
    legal tile (= off_max, maximum tile count); ``padded`` a tile width that
    does not divide C, exercising the pad-state masking."""
    rng = np.random.default_rng(13)
    E, K = 14, 3
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 5, E).astype(np.int32)
    sig = rng.integers(1, 3000, E).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    block_c = off_max if tile == "tight" else off_max + 3
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    V_b, dec_b = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=E,
        u_max=int(ups.max() + 1), off_max=off_max, interpret=True,
        block_c=block_c)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_b), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_b), np.asarray(dec_r))


def test_budgeted_dp_value_rows_share_feasibility_contract():
    """Normalized value rows agree across backends: same feasibility mask
    (value ≥ 0) and identical values on it, despite different NEG sentinels."""
    rng = np.random.default_rng(12)
    E, K = 12, 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 6, E)
    sig = rng.integers(1, 5000, E)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    _, i1 = solve_budgeted_dp(jnp.asarray(ups, jnp.int32),
                              jnp.asarray(sig, jnp.int32), tables, s_cap,
                              jnp.int32(s_cap))
    _, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                     interpret=True)
    r1 = np.asarray(i1["value_row"]).astype(np.int64)
    r2 = np.asarray(i2["value_row"]).astype(np.int64)
    np.testing.assert_array_equal(r1 >= 0, r2 >= 0)
    np.testing.assert_array_equal(r1[r1 >= 0], r2[r2 >= 0])


def test_budgeted_dp_with_arrival_mask():
    rng = np.random.default_rng(7)
    E, K = 10, 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(2, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 6, E)
    sig = rng.integers(1, 900, E)
    allowed = rng.integers(0, 2, E).astype(bool)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups, jnp.int32),
                               jnp.asarray(sig, jnp.int32), tables, s_cap,
                               jnp.int32(s_cap), allowed=jnp.asarray(allowed))
    x2, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                      u_max=int(ups.max() + 1),
                                      allowed=allowed)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert np.all(np.asarray(x2) <= allowed.astype(int))

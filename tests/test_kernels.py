"""Per-kernel allclose sweeps against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd_op
from repro.kernels.ssd.ref import ssd_ref
from repro.core.dp import build_tables, solve_budgeted_dp
from repro.kernels.budgeted_dp.kernel import (
    MAX_BLOCK_E, NEG, VMEM_BUDGET_BYTES, c_blocked_tile_vmem_bytes,
    choose_tiling, dp_forward_pallas, fused_tile_vmem_bytes,
    modeled_hbm_bytes, tiled_vmem_bytes, unblocked_vmem_bytes)
from repro.kernels.budgeted_dp.ops import prepare_tables, solve_budgeted_dp_pallas
from repro.kernels.budgeted_dp.ref import dp_forward_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KH,hd,causal,window", [
    (2, 256, 4, 4, 64, True, 0),
    (1, 256, 8, 2, 64, True, 0),       # GQA g=4
    (2, 128, 4, 1, 32, True, 0),       # MQA
    (1, 512, 2, 2, 128, True, 128),    # sliding window
    (2, 256, 4, 4, 64, False, 0),      # bidirectional (whisper encoder)
])
def test_flash_attention_matches_ref(B, S, H, KH, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, hd), dtype)
    scale = 1.0 / np.sqrt(hd)
    got = flash_attention_op(q, k, v, scale=scale, causal=causal,
                             window=window, blk_q=64, blk_k=128)
    want = attention_ref(q, k, v, scale=scale, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_cross_lengths():
    """Sq < Sk (query block at the end of a longer KV) — prefill tail."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 512, 4, 64))
    v = jax.random.normal(ks[2], (1, 512, 4, 64))
    got = flash_attention_op(q, k, v, scale=0.125, blk_q=64, blk_k=128)
    want = attention_ref(q, k, v, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 128, 2, 32, 16, 32),
    (1, 96, 4, 64, 32, 32),      # S not multiple of Q after pad? 96%32=0
    (2, 80, 2, 32, 16, 32),      # padding path (80 % 32 != 0)
    (1, 256, 2, 64, 64, 64),
])
def test_ssd_matches_ref(B, S, H, P, N, Q, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[0], (B, S, N), dtype)
    y_got, st_got = ssd_op(x, dt, A, Bm, Cm, chunk=Q)
    y_want, st_want = ssd_ref(x, dt, A, Bm, Cm, chunk=Q)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(st_got), np.asarray(st_want),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# budgeted_dp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_budgeted_dp_matches_core(seed):
    rng = np.random.default_rng(seed)
    E, K = int(rng.integers(4, 14)), int(rng.integers(1, 4))
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 9, E)
    sig = rng.integers(1, 5000, E)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups, jnp.int32),
                               jnp.asarray(sig, jnp.int32), tables, s_cap,
                               jnp.int32(s_cap))
    x2, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                      u_max=int(ups.max() + 1))
    assert int(i1["s_star"]) == int(i2["s_star"])
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


@pytest.mark.parametrize("E", [7, 32, 40])   # 1 word, exact fit, 2 words
def test_budgeted_dp_kernel_packed_decisions_match_ref(E):
    """The kernel's bit-packed (⌈E/32⌉, S, C) i32 decision words equal the
    pure-jnp oracle's, including across the word boundary (bit 31 → sign)."""
    rng = np.random.default_rng(11)
    K = 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 3, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 5, E).astype(np.int32)
    sig = rng.integers(1, 3000, E).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    V_k, dec_k = dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas,
                                   offs, v0, n_edges=E,
                                   u_max=int(ups.max() + 1),
                                   off_max=int(offs.max()), interpret=True)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    assert dec_k.shape == ((E + 31) // 32, s_cap + 1, tables.n_states)
    assert dec_k.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(V_k), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_k), np.asarray(dec_r))


@pytest.mark.parametrize("tile", ["tight", "padded"])
def test_budgeted_dp_blocked_grid_matches_ref(tile):
    """The C-blocked pipeline (scan over edges × capacity-tile grid, haloed
    left-neighbor loads, C padded to a tile multiple) is bit-exact vs the
    oracle — values and packed decision words.  ``tight`` runs the minimum
    legal tile (= off_max, maximum tile count); ``padded`` a tile width that
    does not divide C, exercising the pad-state masking."""
    rng = np.random.default_rng(13)
    E, K = 14, 3
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 5, E).astype(np.int32)
    sig = rng.integers(1, 3000, E).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    block_c = off_max if tile == "tight" else off_max + 3
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    V_b, dec_b = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=E,
        u_max=int(ups.max() + 1), off_max=off_max, interpret=True,
        block_c=block_c)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_b), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_b), np.asarray(dec_r))


def _tiling_problem(seed=13, E=14, K=3):
    rng = np.random.default_rng(seed)
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 5, E).astype(np.int32)
    sig = rng.integers(1, 3000, E).astype(np.int32)
    return A, c, ups, sig


@pytest.mark.parametrize("tile", ["tight", "padded", "full_c", "single_s"])
def test_budgeted_dp_s_tiled_grid_matches_ref(tile):
    """The 2-D (S-tile × C-tile) pipeline is bit-exact vs the oracle —
    values and packed decision words — across tile geometries: ``tight``
    runs the minimum legal pair (block_s = u_max, block_c = off_max:
    maximum tile counts, every read crosses a halo); ``padded`` tile
    widths that divide neither S nor C (pad-row/pad-state masking);
    ``full_c`` a single full-width capacity tile (S-only tiling);
    ``single_s`` one S tile spanning the padded plane (the 2-D kernel's
    clamp-row branch on every tile)."""
    A, c, ups, sig = _tiling_problem()
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    S, C = s_cap + 1, tables.n_states
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups.max() + 1)
    block_s, block_c = {
        "tight": (u_max, off_max),
        "padded": (u_max + 2, off_max + 3),
        "full_c": (u_max + 1, C),
        "single_s": (S + 3, off_max),
    }[tile]
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)
    V_t, dec_t = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=len(ups),
        u_max=u_max, off_max=off_max, interpret=True,
        block_c=block_c, block_s=block_s)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_t), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_t), np.asarray(dec_r))


def test_budgeted_dp_s_tiled_u_max_halo_edge():
    """u_max == max Υ̂ exactly (the legal minimum): the deepest s-shift
    reads the FIRST halo row of each tile, and block_s == u_max makes the
    halo as tall as the tile itself."""
    A, c, ups, sig = _tiling_problem(seed=17)
    ups[0] = max(int(ups.max()), 1)          # ensure the max is taken
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    S, C = s_cap + 1, tables.n_states
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    u_max = int(ups.max())                   # no +1 margin
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)
    V_t, dec_t = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=len(ups),
        u_max=u_max, off_max=int(offs.max()), interpret=True,
        block_c=int(offs.max()), block_s=u_max)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_t), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_t), np.asarray(dec_r))


def test_budgeted_dp_s_tiled_solver_with_allowed_mask():
    """Solver-level S-tiled path: x / s* / value_row match the reference
    backend under an eligibility mask."""
    A, c, ups, sig = _tiling_problem(seed=19)
    rng = np.random.default_rng(19)
    allowed = rng.integers(0, 2, len(ups)).astype(bool)
    allowed[:2] = True
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    u_max = int(ups.max() + 1)
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups), jnp.asarray(sig), tables,
                               s_cap, jnp.int32(s_cap),
                               allowed=jnp.asarray(allowed))
    x2, i2 = solve_budgeted_dp_pallas(
        ups, sig, tables, s_cap, s_cap, u_max=u_max, allowed=allowed,
        interpret=True, block_c=int(tables.offsets.max()) + 1,
        block_s=u_max + 1)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert int(i1["s_star"]) == int(i2["s_star"])
    r1 = np.asarray(i1["value_row"]).astype(np.int64)
    r2 = np.asarray(i2["value_row"])
    np.testing.assert_array_equal(r1 >= 0, r2 >= 0)
    np.testing.assert_array_equal(r1[r1 >= 0], r2[r2 >= 0].astype(np.int64))


def test_budgeted_dp_s_tiled_halo_contract_errors():
    """Tiles thinner than the halos are rejected, and block_s without a
    concrete block_c is a usage error — never a silent wrong answer."""
    A, c, ups, sig = _tiling_problem(seed=23)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups.max() + 1)
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    kwargs = dict(n_edges=len(ups), u_max=u_max, off_max=off_max,
                  interpret=True)
    with pytest.raises(ValueError, match="block_s"):
        dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas, offs,
                          v0, block_c=off_max, block_s=u_max - 1, **kwargs)
    with pytest.raises(ValueError, match="block_c"):
        dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas, offs,
                          v0, block_c=None, block_s=u_max, **kwargs)
    # a forced block_s must never be silently overwritten by auto tiling
    with pytest.raises(ValueError, match="auto"):
        solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                 u_max=u_max, interpret=True,
                                 block_s=u_max)


def test_choose_tiling_decision_table():
    """The tiling chooser: whole-plane when it fits, full-height C blocks
    when they fit, 2-D tiles for long horizons — every returned tiling
    respects the halo floors and the VMEM budget, and every blocked tiling
    carries the largest edge-fused chunk that fits."""
    # paper-default sizes: trivially VMEM-resident (nothing to fuse — the
    # whole-plane kernel already walks edges inside one pallas_call)
    assert choose_tiling(110, 27, 40, 9, 13) == (None, None, None)
    # large C, short S: full-height C-blocking suffices — and because the
    # single-S-row grid keeps no rowh history, the whole edge set fuses
    # even at this plane width
    be, bs, bc = choose_tiling(64, 1 << 16, 16, 8, 100)
    assert bs is None and bc is not None
    assert bc >= 100 and c_blocked_tile_vmem_bytes(64, bc, 8) <= \
        VMEM_BUDGET_BYTES
    assert be == min(16, MAX_BLOCK_E)
    assert fused_tile_vmem_bytes(be, 64, bc, 8, 100, 64, 1 << 16) <= \
        VMEM_BUDGET_BYTES
    # long S with large C: the whole plane and every full-height block
    # are impossible — the 2-D grid is chosen, fused over every edge
    S, C, E, u_max, off_max = 4096, 512, 16, 4, 73
    assert unblocked_vmem_bytes(S, C, E, u_max, off_max) > VMEM_BUDGET_BYTES
    be, bs, bc = choose_tiling(S, C, E, u_max, off_max)
    assert bs is not None and bs >= u_max and bc >= off_max
    assert tiled_vmem_bytes(bs, bc, u_max) <= VMEM_BUDGET_BYTES
    assert be == min(E, MAX_BLOCK_E)      # small histories: whole E fuses
    assert fused_tile_vmem_bytes(be, bs, bc, u_max, off_max, S, C) <= \
        VMEM_BUDGET_BYTES
    # a tighter budget still yields a legal (if smaller) pair
    be2, bs2, bc2 = choose_tiling(S, C, E, u_max, off_max, budget=2 ** 20)
    assert bs2 >= u_max and bc2 >= off_max
    assert bs2 * bc2 <= bs * bc
    assert be2 is None or be2 <= be


def test_fused_hbm_model_cuts_traffic_blockwise():
    """The modeled HBM traffic of the fused pipeline drops ~block_e-fold vs
    the per-edge scan on the same plane tiling — the quantity dp_bench
    records as ``hbm_bytes_streamed`` and the point of the fusion."""
    S, C, E, u_max, off_max = 4096, 512, 16, 4, 73
    be, bs, bc = choose_tiling(S, C, E, u_max, off_max)
    scan = modeled_hbm_bytes(S, C, E, u_max, off_max, None, bs, bc)
    fused = modeled_hbm_bytes(S, C, E, u_max, off_max, be, bs, bc)
    assert fused * 4 <= scan              # the PR-5 acceptance bound
    # whole-plane streams everything exactly once and is the floor
    whole = modeled_hbm_bytes(S, C, E, u_max, off_max, None, None, None)
    assert whole < fused < scan


@pytest.mark.parametrize("block_e", [1, 3, 14, 32])
@pytest.mark.parametrize("tile", ["tight", "padded", "full_c", "single_s"])
def test_budgeted_dp_fused_grid_matches_ref(tile, block_e):
    """The edge-fused pipeline — chunks of block_e consecutive edges per
    pallas_call, tiles resident across the chunk, halos refreshed from the
    persistent history scratches — is bit-exact vs the oracle on values AND
    packed decision words, across every tile geometry of the unfused sweep
    and block_e ∈ {1 (scan-equivalent), 3 (does not divide E=14 — ragged
    inert-padded last chunk), 14 (one single chunk), 32 (the in-word
    packing cap, > E)}."""
    A, c, ups, sig = _tiling_problem()
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    S, C = s_cap + 1, tables.n_states
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups.max() + 1)
    block_s, block_c = {
        "tight": (u_max, off_max),
        "padded": (u_max + 2, off_max + 3),
        "full_c": (u_max + 1, C),
        "single_s": (None, off_max),
    }[tile]
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)
    V_f, dec_f = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=len(ups),
        u_max=u_max, off_max=off_max, interpret=True,
        block_c=block_c, block_s=block_s, block_e=block_e)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_f), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_f), np.asarray(dec_r))


@pytest.mark.parametrize("E", [33, 40])
def test_budgeted_dp_fused_chunks_straddle_word_boundary(E):
    """block_e=5 never divides 32, so with E > 32 some chunk's edges span
    BOTH int32 decision words — the per-chunk word masks must route each
    bit into the right packed word (including bit 31 → the sign bit)."""
    rng = np.random.default_rng(29)
    K = 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 3, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 4, E).astype(np.int32)
    sig = rng.integers(1, 3000, E).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups.max() + 1)
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    V_f, dec_f = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=E,
        u_max=u_max, off_max=off_max, interpret=True,
        block_c=off_max + 1, block_s=u_max + 2, block_e=5)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    assert dec_f.shape[0] == (E + 31) // 32 >= 2
    np.testing.assert_array_equal(np.asarray(V_f), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_f), np.asarray(dec_r))


def test_budgeted_dp_fused_whole_chunk_masked():
    """An ``allowed`` mask can zero EVERY edge of a fused chunk: the chunk
    must be a no-op (the inert-edge argument the ragged pad also relies
    on) and the solver must still match the reference bit for bit."""
    A, c, ups, sig = _tiling_problem(seed=31, E=12)
    allowed = np.ones(12, bool)
    allowed[4:8] = False                 # chunk [4, 8) fully masked
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    u_max = int(ups.max() + 1)
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups), jnp.asarray(sig), tables,
                               s_cap, jnp.int32(s_cap),
                               allowed=jnp.asarray(allowed))
    x2, i2 = solve_budgeted_dp_pallas(
        ups, sig, tables, s_cap, s_cap, u_max=u_max, allowed=allowed,
        interpret=True, block_c=int(tables.offsets.max()),
        block_s=u_max, block_e=4)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert int(i1["s_star"]) == int(i2["s_star"])
    assert not np.asarray(x2)[4:8].any()


def test_budgeted_dp_fused_u_max_halo_tracks_in_chunk_updates():
    """The up-neighbor halo must be the neighbor's value at each
    INTERMEDIATE edge of the chunk, not its final value: with every Υ̂ > 0
    and block_s = u_max every edge's s-shift crosses the tile boundary
    into rows the upstream tile updated EARLIER IN THE SAME CHUNK, so a
    stale (initial or final) halo would corrupt values.  Exact-bound
    u_max (no +1 margin) makes the deepest shift read the first history
    row."""
    rng = np.random.default_rng(37)
    E, K = 10, 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(2, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(1, 4, E).astype(np.int32)     # strictly positive
    sig = rng.integers(1, 3000, E).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    u_max = int(ups.max())               # exact bound, no margin
    off_max = int(offs.max())
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    V_f, dec_f = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=E,
        u_max=u_max, off_max=off_max, interpret=True,
        block_c=off_max, block_s=u_max, block_e=E)   # one chunk, all edges
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_f), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_f), np.asarray(dec_r))


def test_budgeted_dp_fused_contract_errors():
    """block_e outside [1, 32] and block_e without a concrete block_c are
    usage errors — never a silent wrong answer."""
    A, c, ups, sig = _tiling_problem(seed=23)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups.max() + 1)
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    kwargs = dict(n_edges=len(ups), u_max=u_max, off_max=off_max,
                  interpret=True)
    with pytest.raises(ValueError, match="block_e"):
        dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas, offs,
                          v0, block_c=off_max, block_e=MAX_BLOCK_E + 1,
                          **kwargs)
    with pytest.raises(ValueError, match="block_e"):
        dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas, offs,
                          v0, block_c=off_max, block_e=0, **kwargs)
    with pytest.raises(ValueError, match="block_e"):
        dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas, offs,
                          v0, block_c=None, block_e=4, **kwargs)
    # a forced block_e must never be silently overwritten by auto tiling
    with pytest.raises(ValueError, match="auto"):
        solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                 u_max=u_max, interpret=True, block_e=4)


def test_budgeted_dp_value_rows_share_feasibility_contract():
    """Normalized value rows agree across backends: same feasibility mask
    (value ≥ 0) and identical values on it, despite different NEG sentinels."""
    rng = np.random.default_rng(12)
    E, K = 12, 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 6, E)
    sig = rng.integers(1, 5000, E)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    _, i1 = solve_budgeted_dp(jnp.asarray(ups, jnp.int32),
                              jnp.asarray(sig, jnp.int32), tables, s_cap,
                              jnp.int32(s_cap))
    _, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                     interpret=True)
    r1 = np.asarray(i1["value_row"]).astype(np.int64)
    r2 = np.asarray(i2["value_row"]).astype(np.int64)
    np.testing.assert_array_equal(r1 >= 0, r2 >= 0)
    np.testing.assert_array_equal(r1[r1 >= 0], r2[r2 >= 0])


def test_budgeted_dp_with_arrival_mask():
    rng = np.random.default_rng(7)
    E, K = 10, 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(2, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 6, E)
    sig = rng.integers(1, 900, E)
    allowed = rng.integers(0, 2, E).astype(bool)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups, jnp.int32),
                               jnp.asarray(sig, jnp.int32), tables, s_cap,
                               jnp.int32(s_cap), allowed=jnp.asarray(allowed))
    x2, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                      u_max=int(ups.max() + 1),
                                      allowed=allowed)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert np.all(np.asarray(x2) <= allowed.astype(int))

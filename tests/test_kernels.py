"""Per-kernel allclose sweeps against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd_op
from repro.kernels.ssd.ref import ssd_ref
from repro.core.dp import build_tables, solve_budgeted_dp
from repro.kernels.budgeted_dp.kernel import (
    MAX_BLOCK_E, NEG, VMEM_BUDGET_BYTES, batched_fused_tile_vmem_bytes,
    batched_modeled_hbm_bytes, batched_vmem_bytes,
    c_blocked_tile_vmem_bytes, choose_tiling, dp_forward_pallas,
    dp_forward_pallas_batched, fused_tile_vmem_bytes, modeled_hbm_bytes,
    tiled_vmem_bytes, unblocked_vmem_bytes)
from repro.kernels.budgeted_dp.ops import (prepare_tables,
                                           solve_budgeted_dp_batched,
                                           solve_budgeted_dp_pallas)
from repro.kernels.budgeted_dp.ref import dp_forward_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KH,hd,causal,window", [
    (2, 256, 4, 4, 64, True, 0),
    (1, 256, 8, 2, 64, True, 0),  # GQA g=4
    (2, 128, 4, 1, 32, True, 0),  # MQA
    (1, 512, 2, 2, 128, True, 128),  # sliding window
    (2, 256, 4, 4, 64, False, 0),  # bidirectional (whisper encoder)
])
def test_flash_attention_matches_ref(B, S, H, KH, hd, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, hd), dtype)
    scale = 1.0 / np.sqrt(hd)
    got = flash_attention_op(q, k, v, scale=scale, causal=causal,
                             window=window, blk_q=64, blk_k=128)
    want = attention_ref(q, k, v, scale=scale, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_cross_lengths():
    """Sq < Sk (query block at the end of a longer KV) — prefill tail."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 512, 4, 64))
    v = jax.random.normal(ks[2], (1, 512, 4, 64))
    got = flash_attention_op(q, k, v, scale=0.125, blk_q=64, blk_k=128)
    want = attention_ref(q, k, v, scale=0.125)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 128, 2, 32, 16, 32),
    (1, 96, 4, 64, 32, 32),  # S not multiple of Q after pad? 96%32=0
    (2, 80, 2, 32, 16, 32),  # padding path (80 % 32 != 0)
    (1, 256, 2, 64, 64, 64),
])
def test_ssd_matches_ref(B, S, H, P, N, Q, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[0], (B, S, N), dtype)
    y_got, st_got = ssd_op(x, dt, A, Bm, Cm, chunk=Q)
    y_want, st_want = ssd_ref(x, dt, A, Bm, Cm, chunk=Q)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(st_got), np.asarray(st_want),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# budgeted_dp
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(6))
def test_budgeted_dp_matches_core(seed):
    rng = np.random.default_rng(seed)
    E, K = int(rng.integers(4, 14)), int(rng.integers(1, 4))
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 9, E)
    sig = rng.integers(1, 5000, E)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups, jnp.int32),
                               jnp.asarray(sig, jnp.int32), tables, s_cap,
                               jnp.int32(s_cap))
    x2, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                      u_max=int(ups.max() + 1))
    assert int(i1["s_star"]) == int(i2["s_star"])
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))


@pytest.mark.parametrize("E", [7, 32, 40])  # 1 word, exact fit, 2 words
def test_budgeted_dp_kernel_packed_decisions_match_ref(E):
    """The kernel's bit-packed (⌈E/32⌉, S, C) i32 decision words equal the
    pure-jnp oracle's, including across the word boundary (bit 31 → sign)."""
    rng = np.random.default_rng(11)
    K = 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 3, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 5, E).astype(np.int32)
    sig = rng.integers(1, 3000, E).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    V_k, dec_k = dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas,
                                   offs, v0, n_edges=E,
                                   u_max=int(ups.max() + 1),
                                   off_max=int(offs.max()), interpret=True)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    assert dec_k.shape == ((E + 31) // 32, s_cap + 1, tables.n_states)
    assert dec_k.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(V_k), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_k), np.asarray(dec_r))


@pytest.mark.parametrize("tile", ["tight", "padded"])
def test_budgeted_dp_blocked_grid_matches_ref(tile):
    """The C-blocked pipeline (scan over edges × capacity-tile grid, haloed
    left-neighbor loads, C padded to a tile multiple) is bit-exact vs the
    oracle — values and packed decision words.  ``tight`` runs the minimum
    legal tile (= off_max, maximum tile count); ``padded`` a tile width that
    does not divide C, exercising the pad-state masking."""
    rng = np.random.default_rng(13)
    E, K = 14, 3
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 5, E).astype(np.int32)
    sig = rng.integers(1, 3000, E).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    block_c = off_max if tile == "tight" else off_max + 3
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    V_b, dec_b = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=E,
        u_max=int(ups.max() + 1), off_max=off_max, interpret=True,
        block_c=block_c)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_b), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_b), np.asarray(dec_r))


def _tiling_problem(seed=13, E=14, K=3):
    rng = np.random.default_rng(seed)
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 5, E).astype(np.int32)
    sig = rng.integers(1, 3000, E).astype(np.int32)
    return A, c, ups, sig


@pytest.mark.parametrize("tile", ["tight", "padded", "full_c", "single_s"])
def test_budgeted_dp_s_tiled_grid_matches_ref(tile):
    """The 2-D (S-tile × C-tile) pipeline is bit-exact vs the oracle —
    values and packed decision words — across tile geometries: ``tight``
    runs the minimum legal pair (block_s = u_max, block_c = off_max:
    maximum tile counts, every read crosses a halo); ``padded`` tile
    widths that divide neither S nor C (pad-row/pad-state masking);
    ``full_c`` a single full-width capacity tile (S-only tiling);
    ``single_s`` one S tile spanning the padded plane (the 2-D kernel's
    clamp-row branch on every tile)."""
    A, c, ups, sig = _tiling_problem()
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    S, C = s_cap + 1, tables.n_states
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups.max() + 1)
    block_s, block_c = {
        "tight": (u_max, off_max),
        "padded": (u_max + 2, off_max + 3),
        "full_c": (u_max + 1, C),
        "single_s": (S + 3, off_max),
    }[tile]
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)
    V_t, dec_t = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=len(ups),
        u_max=u_max, off_max=off_max, interpret=True,
        block_c=block_c, block_s=block_s)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_t), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_t), np.asarray(dec_r))


def test_budgeted_dp_s_tiled_u_max_halo_edge():
    """u_max == max Υ̂ exactly (the legal minimum): the deepest s-shift
    reads the FIRST halo row of each tile, and block_s == u_max makes the
    halo as tall as the tile itself."""
    A, c, ups, sig = _tiling_problem(seed=17)
    ups[0] = max(int(ups.max()), 1)  # ensure the max is taken
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    S, C = s_cap + 1, tables.n_states
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    u_max = int(ups.max())  # no +1 margin
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)
    V_t, dec_t = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=len(ups),
        u_max=u_max, off_max=int(offs.max()), interpret=True,
        block_c=int(offs.max()), block_s=u_max)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_t), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_t), np.asarray(dec_r))


def test_budgeted_dp_s_tiled_solver_with_allowed_mask():
    """Solver-level S-tiled path: x / s* / value_row match the reference
    backend under an eligibility mask."""
    A, c, ups, sig = _tiling_problem(seed=19)
    rng = np.random.default_rng(19)
    allowed = rng.integers(0, 2, len(ups)).astype(bool)
    allowed[:2] = True
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    u_max = int(ups.max() + 1)
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups), jnp.asarray(sig), tables,
                               s_cap, jnp.int32(s_cap),
                               allowed=jnp.asarray(allowed))
    x2, i2 = solve_budgeted_dp_pallas(
        ups, sig, tables, s_cap, s_cap, u_max=u_max, allowed=allowed,
        interpret=True, block_c=int(tables.offsets.max()) + 1,
        block_s=u_max + 1)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert int(i1["s_star"]) == int(i2["s_star"])
    r1 = np.asarray(i1["value_row"]).astype(np.int64)
    r2 = np.asarray(i2["value_row"])
    np.testing.assert_array_equal(r1 >= 0, r2 >= 0)
    np.testing.assert_array_equal(r1[r1 >= 0], r2[r2 >= 0].astype(np.int64))


def test_budgeted_dp_s_tiled_halo_contract_errors():
    """Tiles thinner than the halos are rejected, and block_s without a
    concrete block_c is a usage error — never a silent wrong answer."""
    A, c, ups, sig = _tiling_problem(seed=23)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups.max() + 1)
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    kwargs = dict(n_edges=len(ups), u_max=u_max, off_max=off_max,
                  interpret=True)
    with pytest.raises(ValueError, match="block_s"):
        dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas, offs,
                          v0, block_c=off_max, block_s=u_max - 1, **kwargs)
    with pytest.raises(ValueError, match="block_c"):
        dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas, offs,
                          v0, block_c=None, block_s=u_max, **kwargs)
    # a forced block_s must never be silently overwritten by auto tiling
    with pytest.raises(ValueError, match="auto"):
        solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                 u_max=u_max, interpret=True,
                                 block_s=u_max)


def test_choose_tiling_decision_table():
    """The tiling chooser: whole-plane when it fits, full-height C blocks
    when they fit, 2-D tiles for long horizons — every returned tiling
    respects the halo floors and the VMEM budget, and every blocked tiling
    carries the largest edge-fused chunk that fits."""
    # paper-default sizes: trivially VMEM-resident (nothing to fuse — the
    # whole-plane kernel already walks edges inside one pallas_call)
    assert choose_tiling(110, 27, 40, 9, 13) == (None, None, None)
    # large C, short S: full-height C-blocking suffices — and because the
    # single-S-row grid keeps no rowh history, the whole edge set fuses
    # even at this plane width
    be, bs, bc = choose_tiling(64, 1 << 16, 16, 8, 100)
    assert bs is None and bc is not None
    assert bc >= 100 and c_blocked_tile_vmem_bytes(64, bc, 8) <= \
        VMEM_BUDGET_BYTES
    assert be == min(16, MAX_BLOCK_E)
    assert fused_tile_vmem_bytes(be, 64, bc, 8, 100, 64, 1 << 16) <= \
        VMEM_BUDGET_BYTES
    # long S with large C: the whole plane and every full-height block
    # are impossible — the 2-D grid is chosen, fused over every edge
    S, C, E, u_max, off_max = 4096, 512, 16, 4, 73
    assert unblocked_vmem_bytes(S, C, E, u_max, off_max) > VMEM_BUDGET_BYTES
    be, bs, bc = choose_tiling(S, C, E, u_max, off_max)
    assert bs is not None and bs >= u_max and bc >= off_max
    assert tiled_vmem_bytes(bs, bc, u_max) <= VMEM_BUDGET_BYTES
    assert be == min(E, MAX_BLOCK_E)  # small histories: whole E fuses
    assert fused_tile_vmem_bytes(be, bs, bc, u_max, off_max, S, C) <= \
        VMEM_BUDGET_BYTES
    # a tighter budget still yields a legal (if smaller) pair
    be2, bs2, bc2 = choose_tiling(S, C, E, u_max, off_max, budget=2 ** 20)
    assert bs2 >= u_max and bc2 >= off_max
    assert bs2 * bc2 <= bs * bc
    assert be2 is None or be2 <= be


def test_fused_hbm_model_cuts_traffic_blockwise():
    """The modeled HBM traffic of the fused pipeline drops ~block_e-fold vs
    the per-edge scan on the same plane tiling — the quantity dp_bench
    records as ``hbm_bytes_streamed`` and the point of the fusion."""
    S, C, E, u_max, off_max = 4096, 512, 16, 4, 73
    be, bs, bc = choose_tiling(S, C, E, u_max, off_max)
    scan = modeled_hbm_bytes(S, C, E, u_max, off_max, None, bs, bc)
    fused = modeled_hbm_bytes(S, C, E, u_max, off_max, be, bs, bc)
    assert fused * 4 <= scan  # the PR-5 acceptance bound
    # whole-plane streams everything exactly once and is the floor
    whole = modeled_hbm_bytes(S, C, E, u_max, off_max, None, None, None)
    assert whole < fused < scan


@pytest.mark.parametrize("block_e", [1, 3, 14, 32])
@pytest.mark.parametrize("tile", ["tight", "padded", "full_c", "single_s"])
def test_budgeted_dp_fused_grid_matches_ref(tile, block_e):
    """The edge-fused pipeline — chunks of block_e consecutive edges per
    pallas_call, tiles resident across the chunk, halos refreshed from the
    persistent history scratches — is bit-exact vs the oracle on values AND
    packed decision words, across every tile geometry of the unfused sweep
    and block_e ∈ {1 (scan-equivalent), 3 (does not divide E=14 — ragged
    inert-padded last chunk), 14 (one single chunk), 32 (the in-word
    packing cap, > E)}."""
    A, c, ups, sig = _tiling_problem()
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    S, C = s_cap + 1, tables.n_states
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups.max() + 1)
    block_s, block_c = {
        "tight": (u_max, off_max),
        "padded": (u_max + 2, off_max + 3),
        "full_c": (u_max + 1, C),
        "single_s": (None, off_max),
    }[tile]
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)
    V_f, dec_f = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=len(ups),
        u_max=u_max, off_max=off_max, interpret=True,
        block_c=block_c, block_s=block_s, block_e=block_e)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_f), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_f), np.asarray(dec_r))


@pytest.mark.parametrize("E", [33, 40])
def test_budgeted_dp_fused_chunks_straddle_word_boundary(E):
    """block_e=5 never divides 32, so with E > 32 some chunk's edges span
    BOTH int32 decision words — the per-chunk word masks must route each
    bit into the right packed word (including bit 31 → the sign bit)."""
    rng = np.random.default_rng(29)
    K = 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 3, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 4, E).astype(np.int32)
    sig = rng.integers(1, 3000, E).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups.max() + 1)
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    V_f, dec_f = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=E,
        u_max=u_max, off_max=off_max, interpret=True,
        block_c=off_max + 1, block_s=u_max + 2, block_e=5)
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    assert dec_f.shape[0] == (E + 31) // 32 >= 2
    np.testing.assert_array_equal(np.asarray(V_f), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_f), np.asarray(dec_r))


def test_budgeted_dp_fused_whole_chunk_masked():
    """An ``allowed`` mask can zero EVERY edge of a fused chunk: the chunk
    must be a no-op (the inert-edge argument the ragged pad also relies
    on) and the solver must still match the reference bit for bit."""
    A, c, ups, sig = _tiling_problem(seed=31, E=12)
    allowed = np.ones(12, bool)
    allowed[4:8] = False  # chunk [4, 8) fully masked
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    u_max = int(ups.max() + 1)
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups), jnp.asarray(sig), tables,
                               s_cap, jnp.int32(s_cap),
                               allowed=jnp.asarray(allowed))
    x2, i2 = solve_budgeted_dp_pallas(
        ups, sig, tables, s_cap, s_cap, u_max=u_max, allowed=allowed,
        interpret=True, block_c=int(tables.offsets.max()),
        block_s=u_max, block_e=4)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert int(i1["s_star"]) == int(i2["s_star"])
    assert not np.asarray(x2)[4:8].any()


def test_budgeted_dp_fused_u_max_halo_tracks_in_chunk_updates():
    """The up-neighbor halo must be the neighbor's value at each
    INTERMEDIATE edge of the chunk, not its final value: with every Υ̂ > 0
    and block_s = u_max every edge's s-shift crosses the tile boundary
    into rows the upstream tile updated EARLIER IN THE SAME CHUNK, so a
    stale (initial or final) halo would corrupt values.  Exact-bound
    u_max (no +1 margin) makes the deepest shift read the first history
    row."""
    rng = np.random.default_rng(37)
    E, K = 10, 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(2, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(1, 4, E).astype(np.int32)  # strictly positive
    sig = rng.integers(1, 3000, E).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    u_max = int(ups.max())  # exact bound, no margin
    off_max = int(offs.max())
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    V_f, dec_f = dp_forward_pallas(
        jnp.asarray(ups), jnp.asarray(sig), feas, offs, v0, n_edges=E,
        u_max=u_max, off_max=off_max, interpret=True,
        block_c=off_max, block_s=u_max, block_e=E)  # one chunk, all edges
    V_r, dec_r = dp_forward_ref(jnp.asarray(ups), jnp.asarray(sig), feas,
                                offs, v0)
    np.testing.assert_array_equal(np.asarray(V_f), np.asarray(V_r))
    np.testing.assert_array_equal(np.asarray(dec_f), np.asarray(dec_r))


def test_budgeted_dp_fused_contract_errors():
    """block_e outside [1, 32] and block_e without a concrete block_c are
    usage errors — never a silent wrong answer."""
    A, c, ups, sig = _tiling_problem(seed=23)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups.max() + 1)
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    kwargs = dict(n_edges=len(ups), u_max=u_max, off_max=off_max,
                  interpret=True)
    with pytest.raises(ValueError, match="block_e"):
        dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas, offs,
                          v0, block_c=off_max, block_e=MAX_BLOCK_E + 1,
                          **kwargs)
    with pytest.raises(ValueError, match="block_e"):
        dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas, offs,
                          v0, block_c=off_max, block_e=0, **kwargs)
    with pytest.raises(ValueError, match="block_e"):
        dp_forward_pallas(jnp.asarray(ups), jnp.asarray(sig), feas, offs,
                          v0, block_c=None, block_e=4, **kwargs)
    # a forced block_e must never be silently overwritten by auto tiling
    with pytest.raises(ValueError, match="auto"):
        solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                 u_max=u_max, interpret=True, block_e=4)


# ---------------------------------------------------------------------------
# fleet-batched budgeted_dp (B solves per launch)
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    """Walk every equation of a jaxpr, descending into nested call/scan/
    cond jaxprs wherever they hide in the params."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for v in vals:
                if isinstance(v, jax.core.ClosedJaxpr):
                    yield from _iter_eqns(v.jaxpr)
                elif isinstance(v, jax.core.Jaxpr):
                    yield from _iter_eqns(v)


def _pallas_calls(jaxpr):
    return [e for e in _iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


def test_batched_vmap_emits_single_launch_with_shared_tables():
    """jax.vmap of the pallas solve at B=32 lowers to EXACTLY ONE
    pallas_call, and that launch's operands carry the (E, C) feasibility
    plane UNBATCHED — never a replicated (B, E, C) copy.  This is the
    launch-count contract of the fleet-batched megakernel: sharing the
    tables, not stacking the launches."""
    A, c, ups1, sig1 = _tiling_problem()
    E = len(ups1)
    tables = build_tables(A, c)
    C = tables.n_states
    B, s_cap, u_max = 32, int(ups1.sum()), int(ups1.max() + 1)
    rng = np.random.default_rng(41)
    ups = np.broadcast_to(ups1, (B, E)) + 0
    sig = rng.integers(1, 3000, (B, E)).astype(np.int32)
    alw = rng.integers(0, 2, (B, E)).astype(np.int32)
    slim = rng.integers(0, s_cap + 1, B).astype(np.int32)

    def one(u, s, l, a):
        return solve_budgeted_dp_pallas(u, s, tables, s_cap, l, u_max=u_max,
                                        allowed=a, interpret=True)[0]

    jaxpr = jax.make_jaxpr(jax.vmap(one))(
        jnp.asarray(ups), jnp.asarray(sig), jnp.asarray(slim),
        jnp.asarray(alw))
    calls = _pallas_calls(jaxpr.jaxpr)
    assert len(calls) == 1
    shapes = [tuple(v.aval.shape) for v in calls[0].invars]
    assert (E, C) in shapes  # feasibility plane, shared
    assert (B, E, C) not in shapes  # never replicated per seed
    assert (B, E) in shapes  # per-instance statistics


def test_simulate_batch_one_launch_per_slot():
    """The whole batched simulation — vmapped horizon scan over a seed
    batch — contains exactly ONE pallas_call in its jaxpr: the scan body
    solves every seed's slot in one fleet-batched launch (a conventional
    vmap of the kernel would still show one call; a per-seed unroll or a
    replicated-operand lowering would show more, or batched tables)."""
    from repro.core import env as env_mod
    from repro.core import generate_instance, make_esdp_policy

    inst = generate_instance(seed=3, n_ports=4, n_servers=10, edge_prob=0.3)
    tables = build_tables(inst.A, inst.c)
    T, B = 12, 32
    policy = make_esdp_policy(inst, T, tables=tables,
                              solver="pallas_interpret")
    tables_, scenario, params = env_mod._scenario_args(inst, tables, None)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
    jaxpr = jax.make_jaxpr(
        lambda arrays, ks, ps: env_mod._run_batch(
            policy, T, tables_, scenario, inst.n_servers, arrays, ks, ps))(
        env_mod._instance_arrays(inst), keys, params)
    calls = _pallas_calls(jaxpr.jaxpr)
    assert len(calls) == 1
    E, C = inst.n_edges, tables.n_states
    shapes = [tuple(v.aval.shape) for v in calls[0].invars]
    assert (E, C) in shapes and (B, E, C) not in shapes


def test_choose_tiling_batched_decision_table():
    """The 4-tuple chooser: the BATCH axis shrinks before the plane ever
    tiles — full fleet per step when it fits, the largest power-of-two
    sub-fleet when it doesn't, and only when even one instance's plane
    overflows does the tiling fall back to the 3-tuple rule with block_b
    pinned to 1 (batch as the fused pipeline's outermost grid dim)."""
    # paper-default sizes: the whole 32-fleet fits in one grid step
    assert choose_tiling(110, 27, 40, 9, 13, batch=32) == \
        (32, None, None, None)
    assert batched_vmem_bytes(110, 27, 40, 9, 13, 32) <= VMEM_BUDGET_BYTES
    # a degenerate fleet of one stays on the whole-plane kernel
    assert choose_tiling(110, 27, 40, 9, 13, batch=1) == \
        (1, None, None, None)
    # taller planes: the fleet splits (4, then 2, then 1 per step) while
    # every instance's plane stays whole — batch shrinks FIRST
    for S, bb_want in ((256, 4), (512, 2), (1024, 1)):
        bb, be, bs, bc = choose_tiling(S, 512, 16, 4, 73, batch=32)
        assert (bb, be, bs, bc) == (bb_want, None, None, None)
        assert batched_vmem_bytes(S, 512, 16, 4, 73, bb) <= \
            VMEM_BUDGET_BYTES
        if bb < 32:  # the next-larger fleet is what overflowed
            assert batched_vmem_bytes(S, 512, 16, 4, 73, 2 * bb) > \
                VMEM_BUDGET_BYTES
    # long horizon: even block_b=1 overflows whole-plane → the plane
    # tiles exactly as the single-instance rule says, block_b pinned to 1
    S, C, E, u_max, off_max = 4096, 512, 16, 4, 73
    assert batched_vmem_bytes(S, C, E, u_max, off_max, 1) > \
        VMEM_BUDGET_BYTES
    four = choose_tiling(S, C, E, u_max, off_max, batch=32)
    assert four == (1,) + choose_tiling(S, C, E, u_max, off_max)
    _, be, bs, bc = four
    assert batched_fused_tile_vmem_bytes(be, bs, bc, u_max, off_max, S, C,
                                         1) <= VMEM_BUDGET_BYTES
    with pytest.raises(ValueError, match="batch"):
        choose_tiling(110, 27, 40, 9, 13, batch=0)


def test_batched_modeled_hbm_shares_tables_once():
    """The batched traffic model: shared operands stream once, so B
    batched solves always model strictly under B× the single-solve
    traffic, and the saving is exactly the (B−1)-fold shared-operand
    re-stream a vmapped-single-launch lowering would pay."""
    for (S, C, E, u_max, off_max), (be, bs, bc) in (
            ((110, 27, 40, 9, 13), (None, None, None)),
            ((4096, 512, 16, 4, 73), choose_tiling(4096, 512, 16, 4, 73))):
        one = modeled_hbm_bytes(S, C, E, u_max, off_max, be, bs, bc)
        for B in (8, 64):
            batched = batched_modeled_hbm_bytes(S, C, E, u_max, off_max, B,
                                                be, bs, bc)
            vmapped = B * one
            assert batched < vmapped
            shared = vmapped - batched
            assert shared % (B - 1) == 0  # (B−1) shared re-streams saved
        assert batched_modeled_hbm_bytes(S, C, E, u_max, off_max, 1,
                                         be, bs, bc) == one


def test_batched_contract_errors():
    """Every illegal batched configuration is a loud ValueError — block_b
    outside [1, B], a forced block under auto tiling, the fused pipeline
    with block_b ≠ 1, and the per-edge-scan tilings that gain nothing
    from sharing a launch — never a silent wrong answer."""
    A, c, ups1, sig1 = _tiling_problem(seed=23)
    E = len(ups1)
    tables = build_tables(A, c)
    s_cap = int(ups1.sum())
    feas, offs = prepare_tables(tables)
    feas, offs = jnp.asarray(feas), jnp.asarray(offs)
    off_max = int(offs.max())
    u_max = int(ups1.max() + 1)
    B = 4
    ups = jnp.broadcast_to(jnp.asarray(ups1), (B, E))
    sig = jnp.broadcast_to(jnp.asarray(sig1), (B, E))
    alw = jnp.ones((B, E), jnp.int32)
    v0 = jnp.full((s_cap + 1, tables.n_states), NEG,
                  jnp.float32).at[0, :].set(0.0)
    kwargs = dict(n_edges=E, u_max=u_max, off_max=off_max, interpret=True)
    for bad_bb in (0, B + 1):
        with pytest.raises(ValueError, match="block_b"):
            dp_forward_pallas_batched(ups, sig, alw, feas, offs, v0,
                                      block_b=bad_bb, **kwargs)
    # fused pipeline: batch is the outermost grid dim, one instance/step
    with pytest.raises(ValueError, match="block_b"):
        dp_forward_pallas_batched(ups, sig, alw, feas, offs, v0, block_b=2,
                                  block_c=off_max, block_e=4, **kwargs)
    # per-edge-scan tilings don't share anything worth batching
    with pytest.raises(ValueError, match="block_e"):
        dp_forward_pallas_batched(ups, sig, alw, feas, offs, v0,
                                  block_c=off_max, **kwargs)
    with pytest.raises(ValueError, match="block_c"):
        dp_forward_pallas_batched(ups, sig, alw, feas, offs, v0,
                                  block_s=u_max, **kwargs)
    # a forced block must never be silently overwritten by auto tiling
    with pytest.raises(ValueError, match="auto"):
        solve_budgeted_dp_batched(ups, sig, tables, s_cap, s_cap,
                                  u_max=u_max, interpret=True, block_b=2)
    with pytest.raises(ValueError, match="block_b"):
        solve_budgeted_dp_batched(ups, sig, tables, s_cap, s_cap,
                                  u_max=u_max, interpret=True,
                                  block_b=B + 1, block_c=None)


def test_batched_ragged_pad_instances_inert():
    """B=5 under block_b=2 pads the grid to 6 instances: the pad rides
    ``allowed ≡ 0`` and must be INERT — and the same argument makes a
    real all-masked instance return the untouched v0 plane and zero
    decision words, which we check directly."""
    A, c, ups1, sig1 = _tiling_problem(seed=43, E=10)
    E = len(ups1)
    tables = build_tables(A, c)
    s_cap = int(ups1.sum())
    S, C = s_cap + 1, tables.n_states
    u_max = int(ups1.max() + 1)
    rng = np.random.default_rng(43)
    B = 5
    ups = rng.integers(0, u_max, (B, E)).astype(np.int32)
    sig = rng.integers(1, 3000, (B, E)).astype(np.int32)
    alw = rng.integers(0, 2, (B, E)).astype(np.int32)
    alw[3] = 0  # a real all-masked instance
    slim = rng.integers(0, s_cap + 1, B).astype(np.int32)
    x, info = solve_budgeted_dp_batched(ups, sig, tables, s_cap, slim,
                                        u_max=u_max, allowed=alw,
                                        interpret=True, block_b=2,
                                        block_c=None)
    assert x.shape == (B, E)  # pad instances dropped
    for b in range(B):
        xr, ir = solve_budgeted_dp(
            jnp.asarray(ups[b]), jnp.asarray(sig[b]), tables, s_cap,
            int(slim[b]), allowed=jnp.asarray(alw[b]))
        np.testing.assert_array_equal(np.asarray(x[b]), np.asarray(xr))
        assert int(info["s_star"][b]) == int(ir["s_star"])
    assert not np.asarray(x[3]).any()
    # the all-masked instance's forward plane is v0, untouched
    feas, offs = prepare_tables(tables)
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)
    V, dec = dp_forward_pallas_batched(
        jnp.asarray(ups), jnp.asarray(sig), jnp.asarray(alw),
        jnp.asarray(feas), jnp.asarray(offs), v0, n_edges=E, u_max=u_max,
        off_max=int(offs.max()), interpret=True, block_b=2)
    np.testing.assert_array_equal(np.asarray(V[3]), np.asarray(v0))
    assert not np.asarray(dec[3]).any()


def test_budgeted_dp_value_rows_share_feasibility_contract():
    """Normalized value rows agree across backends: same feasibility mask
    (value ≥ 0) and identical values on it, despite different NEG sentinels."""
    rng = np.random.default_rng(12)
    E, K = 12, 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 6, E)
    sig = rng.integers(1, 5000, E)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    _, i1 = solve_budgeted_dp(jnp.asarray(ups, jnp.int32),
                              jnp.asarray(sig, jnp.int32), tables, s_cap,
                              jnp.int32(s_cap))
    _, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                     interpret=True)
    r1 = np.asarray(i1["value_row"]).astype(np.int64)
    r2 = np.asarray(i2["value_row"]).astype(np.int64)
    np.testing.assert_array_equal(r1 >= 0, r2 >= 0)
    np.testing.assert_array_equal(r1[r1 >= 0], r2[r2 >= 0])


def test_budgeted_dp_with_arrival_mask():
    rng = np.random.default_rng(7)
    E, K = 10, 2
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(2, 4, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, 6, E)
    sig = rng.integers(1, 900, E)
    allowed = rng.integers(0, 2, E).astype(bool)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    x1, i1 = solve_budgeted_dp(jnp.asarray(ups, jnp.int32),
                               jnp.asarray(sig, jnp.int32), tables, s_cap,
                               jnp.int32(s_cap), allowed=jnp.asarray(allowed))
    x2, i2 = solve_budgeted_dp_pallas(ups, sig, tables, s_cap, s_cap,
                                      u_max=int(ups.max() + 1),
                                      allowed=allowed)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    assert np.all(np.asarray(x2) <= allowed.astype(int))

"""Tests for the cross-slot incremental re-solve layer (core.incremental).

Three contracts are enforced here:

* **Exactness** — the exact-key solve cache (``SolveCache`` quanta = 1,
  ``CachedSolver``), the warm-started reference path
  (``solve_budgeted_dp_warm``) and the segmented Pallas driver
  (``WarmPallasSolver``) must be BIT-identical to cold solves over drift
  sequences: fold-suffix statistic drifts, ``s_limit``-only changes, and
  eligibility flips.
* **No key aliasing** — batched ``(B, E)`` solves through ``CachedSolver``
  key every row independently; rows engineered to collide under naive key
  packing (same bytes, different fields) must not alias, for B ∈ {1, 2, 7}.
* **Determinism** — LRU eviction and the hit/miss trace replay identically
  for an identical call sequence (hypothesis-driven when the [test] extra
  is present, seeded otherwise), so cached runs are reproducible.

Plus the policy layer: ``cache="memo"`` / ``cache="warm"`` ESDP policies
are trace-invariant vs ``cache=None`` through ``simulate`` AND
``simulate_batch``, and their ``finalize`` counters are sane.
"""
import numpy as np
import pytest

try:  # optional [test] extra — property tests skip cleanly without it
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from repro.core import (build_tables, generate_instance, make_esdp_policy,
                        simulate, simulate_batch)
from repro.core.incremental import (CacheStats, SolveCache, WarmCarry,
                                    changed_edge_mask, n_checkpoints,
                                    solve_budgeted_dp_warm, solve_key,
                                    unchanged_fold_prefix, warm_carry_init)
from repro.core.solvers import CachedSolver, get_solver
from repro.kernels.budgeted_dp.ops import WarmPallasSolver

REF = get_solver("reference")
PAL = get_solver("pallas_interpret")


# ---------------------------------------------------------------------------
# shared problem + drift-sequence machinery
# ---------------------------------------------------------------------------

def _problem(seed=0, E=10, K=2, c_hi=3, u_hi=5, sig_hi=5000):
    rng = np.random.default_rng(seed)
    A = rng.integers(1, 3, size=(K, E))
    c = rng.integers(1, c_hi + 1, size=K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, u_hi + 1, size=E).astype(np.int32)
    sig = rng.integers(1, sig_hi + 1, size=E).astype(np.int32)
    return build_tables(A, c), ups, sig


def _drift_seq(rng, ups, sig, s_cap, n_steps, u_hi=5, sig_hi=5000):
    """A seeded slot sequence exercising every delta-mask regime.

    Yields (ups, sig, alw, s_limit) tuples.  "suffix" steps mutate LOW
    edge indices — late FOLD steps (edge e folds at step E-1-e), so warm
    paths get a long unchanged prefix; "head" steps mutate edge E-1 (fold
    step 0 — full refold); "slim" steps change only the budget mask;
    "alw" flips one eligibility bit; "repeat" replays the previous slot
    verbatim (the exact-cache hit case).
    """
    E = len(ups)
    ups, sig = ups.copy(), sig.copy()
    alw = np.ones(E, bool)
    s_limit = s_cap
    kinds = ["head", "suffix", "slim", "repeat", "suffix", "alw",
             "repeat", "slim", "suffix", "head"]
    out = [(ups.copy(), sig.copy(), alw.copy(), s_limit)]
    for i in range(n_steps - 1):
        kind = kinds[i % len(kinds)]
        if kind == "suffix":
            e = int(rng.integers(0, max(1, E // 4)))
            ups[e] = rng.integers(0, u_hi + 1)
            sig[e] = rng.integers(1, sig_hi + 1)
        elif kind == "head":
            sig[E - 1] = rng.integers(1, sig_hi + 1)
        elif kind == "alw":
            e = int(rng.integers(0, E))
            alw[e] = ~alw[e]
        elif kind == "slim":
            s_limit = int(rng.integers(0, s_cap + 1))
        # "repeat": no mutation
        out.append((ups.copy(), sig.copy(), alw.copy(), s_limit))
    return out


def _cold(solver, ups, sig, tables, s_cap, s_limit, alw):
    x, info = solver(jnp.asarray(ups, jnp.int32), jnp.asarray(sig, jnp.int32),
                     tables, s_cap, jnp.int32(s_limit),
                     None if alw is None else jnp.asarray(alw))
    return (np.asarray(x), int(info["s_star"]), np.asarray(info["value_row"]))


# ---------------------------------------------------------------------------
# solve_key / SolveCache units
# ---------------------------------------------------------------------------

def test_solve_key_fields_do_not_alias():
    """Fixed field order + fixed widths: moving the same bytes between
    fields (Υ̂↔Σ̂², Υ̂↔s_limit) must change the key; allowed=None equals
    the explicit all-True mask."""
    ups = np.array([2, 0, 0, 0], np.int32)
    sig = np.array([1, 1, 1, 1], np.int32)
    k0 = solve_key(ups, sig, None, 5)
    assert k0 == solve_key(ups, sig, np.ones(4, bool), 5)
    assert k0 != solve_key(sig, ups, None, 5)  # Υ̂ ↔ Σ̂² swap
    assert k0 != solve_key(np.array([5, 0, 0, 0], np.int32), sig, None, 2)
    assert k0 != solve_key(ups, sig, None, 2)  # s_limit exact
    assert k0 != solve_key(ups, sig, np.array([1, 1, 1, 0], bool), 5)


def test_solve_key_quantization_buckets():
    ups = np.array([10, 20], np.int32)
    sig = np.array([100, 200], np.int32)
    # same bucket under q=8: 10//8 == 15//8
    assert (solve_key(ups, sig, None, 3, q_ups=8)
            == solve_key(np.array([15, 23], np.int32), sig, None, 3, q_ups=8))
    # different bucket
    assert (solve_key(ups, sig, None, 3, q_ups=8)
            != solve_key(np.array([16, 20], np.int32), sig, None, 3, q_ups=8))
    # eligibility is never quantized
    assert (solve_key(ups, sig, np.array([1, 0], bool), 3, q_ups=8)
            != solve_key(ups, sig, None, 3, q_ups=8))


def test_solve_cache_exact_flag_and_validation():
    assert SolveCache().exact
    assert not SolveCache(q_ups=4).exact
    assert not SolveCache(q_sig=16).exact
    with pytest.raises(ValueError):
        SolveCache(capacity=0)
    with pytest.raises(ValueError):
        SolveCache(q_ups=0)


def _cache_trace(ops, capacity):
    """Replay a sequence of (key, value) ops; return the observable trace."""
    cache = SolveCache(capacity=capacity)
    trace = []
    for key, val in ops:
        hit = cache.get(key)
        if hit is None:
            cache.put(key, val)
        trace.append((hit, cache.stats.hits, cache.stats.misses,
                      cache.stats.evictions, len(cache)))
    return trace


def _eviction_determinism_body(seed, capacity):
    rng = np.random.default_rng(seed)
    ops = [(bytes([rng.integers(0, 6)]), int(rng.integers(0, 100)))
           for _ in range(40)]
    t1 = _cache_trace(ops, capacity)
    t2 = _cache_trace(ops, capacity)
    assert t1 == t2
    # LRU, not FIFO: a hit refreshes recency.  With capacity 2 the
    # sequence a,b,a,c must evict b (a was refreshed), keeping a.
    c = SolveCache(capacity=2)
    for k in (b"a", b"b"):
        c.put(k, k)
    assert c.get(b"a") == b"a"
    c.put(b"c", b"c")
    assert c.get(b"b") is None and c.get(b"a") == b"a"


if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 4))
    def test_cache_eviction_deterministic(seed, capacity):
        _eviction_determinism_body(seed, capacity)
else:
    def test_cache_eviction_deterministic():
        for seed in (0, 7, 1234):
            for capacity in (1, 2, 3):
                _eviction_determinism_body(seed, capacity)


def test_solve_cache_max_stale_refuses_and_refreshes():
    cache = SolveCache(q_ups=8, max_stale=2)
    cache.put(b"k", "v0")
    cache.tick()
    cache.tick()
    assert cache.get(b"k") == "v0"  # age 2 == max_stale: still valid
    cache.tick()
    assert cache.get(b"k") is None  # age 3 > max_stale: refused
    assert cache.stats.stale_rejects == 1
    cache.put(b"k", "v1")  # refreshed entry restarts clock
    assert cache.get(b"k") == "v1"


def test_cache_stats_dict_shape():
    d = CacheStats(hits=3, misses=1).as_dict()
    assert d["cache_hit_rate"] == pytest.approx(0.75)
    assert set(d) == {"hits", "misses", "evictions", "stale_rejects",
                      "bypasses", "launches_saved", "cache_hit_rate"}


# ---------------------------------------------------------------------------
# CachedSolver: exact-key bit-identity, batching, no aliasing, bypass
# ---------------------------------------------------------------------------

def test_cached_solver_exact_hits_bit_identical():
    tables, ups, sig = _problem(seed=1)
    s_cap = int(ups.sum())
    cached = CachedSolver(REF)
    assert cached.exact and cached.name == "cached:reference"
    rng = np.random.default_rng(2)
    seq = _drift_seq(rng, ups, sig, s_cap, 12)
    for u, s, a, lim in seq + seq:  # second pass: all exact hits
        want = _cold(REF, u, s, tables, s_cap, lim, a)
        x, info = cached(u, s, tables, s_cap, lim, allowed=a)
        np.testing.assert_array_equal(x, want[0])
        assert int(info["s_star"]) == want[1]
        np.testing.assert_array_equal(info["value_row"], want[2])
    st = cached.stats
    assert st.hits >= len(seq)  # full replay + "repeat" slots
    assert st.launches_saved == st.hits
    assert st.bypasses == 0


@pytest.mark.parametrize("B", [1, 2, 7])
def test_cached_solver_batched_no_aliasing(B):
    """(B, E) solves: per-row keys, per-row bit-identity vs a reference
    loop, and a full-hit replay skips the launch.  Rows 0/1 are engineered
    near-collisions (Υ̂ of one equals Σ̂² of the other, s_limit swapped
    with a Υ̂ entry) — aliasing would serve row 0's solution to row 1."""
    tables, ups, sig = _problem(seed=3, E=8)
    E, s_cap = len(ups), int(ups.sum())
    rng = np.random.default_rng(4)
    ups_b = np.stack([ups] * B).astype(np.int32)
    sig_b = np.stack([sig] * B).astype(np.int32)
    alw_b = np.ones((B, E), bool)
    lim_b = np.full(B, s_cap, np.int64)
    if B >= 2:  # the near-collision pair
        ups_b[1], sig_b[1] = sig_b[0] % (s_cap + 1), ups_b[0] + 1
        lim_b[1] = int(ups_b[0][0])
        ups_b[0][0] = lim_b[0] % 6
    for b in range(2, B):  # remaining rows: random drift
        ups_b[b] = rng.integers(0, 6, E)
        alw_b[b] = rng.integers(0, 2, E).astype(bool)
        lim_b[b] = int(rng.integers(0, s_cap + 1))
    keys = [solve_key(ups_b[b], sig_b[b], alw_b[b], lim_b[b])
            for b in range(B)]
    assert len(set(keys)) == B  # no aliasing at the key level

    cached = CachedSolver(REF)
    x, info = cached(ups_b, sig_b, tables, s_cap, lim_b, allowed=alw_b)
    for b in range(B):
        want = _cold(REF, ups_b[b], sig_b[b], tables, s_cap,
                     int(lim_b[b]), alw_b[b])
        np.testing.assert_array_equal(x[b], want[0])
        assert int(info["s_star"][b]) == want[1]
        np.testing.assert_array_equal(info["value_row"][b], want[2])

    saved0 = cached.stats.launches_saved
    x2, info2 = cached(ups_b, sig_b, tables, s_cap, lim_b, allowed=alw_b)
    assert cached.stats.launches_saved == saved0 + 1  # full-hit replay
    np.testing.assert_array_equal(x2, x)
    np.testing.assert_array_equal(info2["value_row"], info["value_row"])


def test_cached_solver_partial_batch_miss_launches_once():
    """One changed row forces ONE batched launch; every row refreshes."""
    tables, ups, sig = _problem(seed=5, E=6)
    s_cap = int(ups.sum())
    cached = CachedSolver(REF)
    ups_b = np.stack([ups, ups]).astype(np.int32)
    sig_b = np.stack([sig, sig]).astype(np.int32)
    cached(ups_b, sig_b, tables, s_cap, np.array([s_cap, s_cap]))
    ups_b2 = ups_b.copy()
    ups_b2[1, 0] = (ups_b2[1, 0] + 1) % 6
    saved = cached.stats.launches_saved
    x, info = cached(ups_b2, sig_b, tables, s_cap, np.array([s_cap, s_cap]))
    assert cached.stats.launches_saved == saved  # row 1 missed
    want = _cold(REF, ups_b2[1], sig_b[1], tables, s_cap, s_cap, None)
    np.testing.assert_array_equal(x[1], want[0])
    np.testing.assert_array_equal(info["value_row"][1], want[2])


def test_cached_solver_traced_inputs_bypass():
    tables, ups, sig = _problem(seed=6, E=6)
    s_cap = int(ups.sum())
    cached = CachedSolver(REF)

    @jax.jit
    def run(u, s):
        x, _ = cached(u, s, tables, s_cap, jnp.int32(s_cap))
        return x

    x = run(jnp.asarray(ups), jnp.asarray(sig))
    want = _cold(REF, ups, sig, tables, s_cap, s_cap, None)
    np.testing.assert_array_equal(np.asarray(x), want[0])
    assert cached.stats.bypasses == 1
    assert cached.stats.hits == 0 and cached.stats.misses == 0


def test_cached_solver_quantized_mode_reports_inexact():
    """Approximate mode must (a) say so via ``exact``; (b) serve feasible
    solutions: capacity feasibility never depends on the statistics."""
    tables, ups, sig = _problem(seed=7, E=8)
    s_cap = int(ups.sum())
    cached = CachedSolver(REF, q_sig=64)
    assert not cached.exact
    x0, _ = cached(ups, sig, tables, s_cap, s_cap)
    sig2 = sig + np.arange(len(sig)) % 3  # same q_sig=64 bucket... maybe
    x1, _ = cached(ups, sig2, tables, s_cap, s_cap)
    A = np.asarray(tables.A) if hasattr(tables, "A") else None
    for x in (x0, x1):
        assert set(np.unique(x)) <= {0, 1}


# ---------------------------------------------------------------------------
# warm-started reference path: bit-identity + fold accounting
# ---------------------------------------------------------------------------

def _make_warm_fn(tables, s_cap, k):
    @jax.jit
    def warm(u, s, lim, a, carry):
        return solve_budgeted_dp_warm(u, s, tables, s_cap, lim, carry,
                                      allowed=a, checkpoint_every=k)
    return warm


@pytest.mark.parametrize("k", [1, 4, 8])
def test_warm_reference_bit_identical_over_drift(k):
    tables, ups, sig = _problem(seed=8)
    E, s_cap = len(ups), int(ups.sum())
    rng = np.random.default_rng(9)
    seq = _drift_seq(rng, ups, sig, s_cap, 14)
    warm = _make_warm_fn(tables, s_cap, k)
    carry = warm_carry_init(E, s_cap, tables.n_states, k)
    folded = []
    for u, s, a, lim in seq:
        want = _cold(REF, u, s, tables, s_cap, lim, a)
        x, info, carry = warm(jnp.asarray(u), jnp.asarray(s),
                              jnp.int32(lim), jnp.asarray(a), carry)
        np.testing.assert_array_equal(np.asarray(x), want[0])
        assert int(info["s_star"]) == want[1]
        np.testing.assert_array_equal(np.asarray(info["value_row"]), want[2])
        folded.append(int(info["edges_folded"]))
    assert folded[0] == E  # invalid carry: full cold fold
    assert all(0 <= f <= E for f in folded)
    assert sum(folded) < len(seq) * E  # the drift structure saves work


def test_warm_reference_s_limit_only_folds_zero():
    tables, ups, sig = _problem(seed=10)
    E, s_cap = len(ups), int(ups.sum())
    warm = _make_warm_fn(tables, s_cap, 4)
    carry = warm_carry_init(E, s_cap, tables.n_states, 4)
    a = np.ones(E, bool)
    _, info, carry = warm(jnp.asarray(ups), jnp.asarray(sig),
                          jnp.int32(s_cap), jnp.asarray(a), carry)
    assert int(info["edges_folded"]) == E
    for lim in (0, s_cap // 2, s_cap):  # budget-only changes: free
        want = _cold(REF, ups, sig, tables, s_cap, lim, a)
        x, info, carry = warm(jnp.asarray(ups), jnp.asarray(sig),
                              jnp.int32(lim), jnp.asarray(a), carry)
        assert int(info["edges_folded"]) == 0
        np.testing.assert_array_equal(np.asarray(x), want[0])
        assert int(info["s_star"]) == want[1]


def test_warm_reference_inside_lax_scan():
    """The warm path is scan-carriable: a lax.scan over a stacked slot
    sequence matches the per-slot cold loop bit for bit."""
    tables, ups, sig = _problem(seed=11, E=8)
    E, s_cap = len(ups), int(ups.sum())
    rng = np.random.default_rng(12)
    seq = _drift_seq(rng, ups, sig, s_cap, 10)
    U = jnp.asarray(np.stack([q[0] for q in seq]))
    S = jnp.asarray(np.stack([q[1] for q in seq]))
    A = jnp.asarray(np.stack([q[2] for q in seq]))
    L = jnp.asarray(np.array([q[3] for q in seq], np.int32))

    def step(carry, slot):
        u, s, a, lim = slot
        x, info, carry = solve_budgeted_dp_warm(
            u, s, tables, s_cap, lim, carry, allowed=a, checkpoint_every=4)
        return carry, (x, info["s_star"], info["edges_folded"])

    carry0 = warm_carry_init(E, s_cap, tables.n_states, 4)
    _, (xs, stars, folded) = jax.lax.scan(step, carry0, (U, S, A, L))
    for i, (u, s, a, lim) in enumerate(seq):
        want = _cold(REF, u, s, tables, s_cap, lim, a)
        np.testing.assert_array_equal(np.asarray(xs[i]), want[0])
        assert int(stars[i]) == want[1]
    assert int(folded[0]) == E and int(folded.sum()) < len(seq) * E


def test_delta_mask_and_prefix_helpers():
    tables, ups, sig = _problem(seed=13, E=6)
    E, s_cap = len(ups), int(ups.sum())
    carry = warm_carry_init(E, s_cap, tables.n_states, 4)
    # invalid carry: everything changed
    m = changed_edge_mask(carry, jnp.asarray(ups), jnp.asarray(sig), None)
    assert bool(m.all()) and int(unchanged_fold_prefix(m)) == 0
    # a valid carry of these exact inputs: nothing changed, prefix == E
    carry = WarmCarry(ups_f=jnp.asarray(ups[::-1]),
                      sig_f=jnp.asarray(sig[::-1]),
                      alw_f=jnp.ones(E, bool), ckpts=carry.ckpts,
                      v_final=carry.v_final, decisions=carry.decisions,
                      valid=jnp.asarray(True))
    m = changed_edge_mask(carry, jnp.asarray(ups), jnp.asarray(sig), None)
    assert not bool(m.any()) and int(unchanged_fold_prefix(m)) == E
    # edge 0 folds LAST: changing it leaves an E-1 unchanged prefix
    u2 = ups.copy()
    u2[0] += 1
    m = changed_edge_mask(carry, jnp.asarray(u2), jnp.asarray(sig), None)
    assert int(unchanged_fold_prefix(m)) == E - 1
    assert n_checkpoints(E, 4) == 2


# ---------------------------------------------------------------------------
# WarmPallasSolver: segmented carried-plane path vs cold pallas backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [3, 8])
def test_warm_pallas_bit_identical_over_drift(k):
    tables, ups, sig = _problem(seed=14)
    E, s_cap = len(ups), int(ups.sum())
    warm = WarmPallasSolver(tables, s_cap, checkpoint_every=k,
                            interpret=True)
    assert warm.name == "warm:pallas_interpret"
    rng = np.random.default_rng(15)
    seq = _drift_seq(rng, ups, sig, s_cap, 12)
    for u, s, a, lim in seq:
        want = _cold(PAL, u, s, tables, s_cap, lim, a)
        x, info = warm(u, s, tables, s_cap, lim, allowed=a)
        np.testing.assert_array_equal(np.asarray(x), want[0])
        assert int(info["s_star"]) == want[1]
        np.testing.assert_array_equal(np.asarray(info["value_row"]), want[2])
    assert warm.stats["solves"] == len(seq)
    assert warm.stats["full_hits"] >= 2  # "repeat" and "slim" slots
    assert 0.0 < warm.skip_rate < 1.0


def test_warm_pallas_s_limit_only_zero_launches():
    tables, ups, sig = _problem(seed=16)
    E, s_cap = len(ups), int(ups.sum())
    warm = WarmPallasSolver(tables, s_cap, checkpoint_every=4,
                            interpret=True)
    warm(ups, sig, tables, s_cap, s_cap)
    launched = warm.stats["segments_launched"]
    for lim in (0, s_cap // 3, s_cap):
        want = _cold(PAL, ups, sig, tables, s_cap, lim, None)
        x, info = warm(ups, sig, tables, s_cap, lim)
        assert int(info["edges_folded"]) == 0
        np.testing.assert_array_equal(np.asarray(x), want[0])
    assert warm.stats["segments_launched"] == launched
    assert warm.stats["full_hits"] == 3


def test_warm_pallas_reset_and_binding_guards():
    tables, ups, sig = _problem(seed=17, E=6)
    s_cap = int(ups.sum())
    warm = WarmPallasSolver(tables, s_cap, interpret=True)
    warm(ups, sig, tables, s_cap, s_cap)
    warm.reset()
    want = _cold(PAL, ups, sig, tables, s_cap, s_cap, None)
    x, info = warm(ups, sig, tables, s_cap, s_cap)
    assert int(info["edges_folded"]) == len(ups)  # reset forces cold fold
    np.testing.assert_array_equal(np.asarray(x), want[0])
    other_tables = build_tables(np.ones((1, 6), np.int64),
                                np.array([2], np.int64))
    with pytest.raises(ValueError, match="bound to one"):
        warm(ups, sig, other_tables, s_cap, s_cap)
    with pytest.raises(TypeError, match="concrete"):
        jax.jit(lambda u: warm(u, sig, tables, s_cap, s_cap)[0])(
            jnp.asarray(ups))


# ---------------------------------------------------------------------------
# policy layer: cache modes are trace-invariant through simulate
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small():
    inst = generate_instance(seed=3, n_ports=4, n_servers=10, edge_prob=0.3)
    return inst, build_tables(inst.A, inst.c)


@pytest.mark.parametrize("mode", ["memo", "warm"])
def test_esdp_cache_modes_trace_invariant_simulate(small, mode):
    inst, tables = small
    T = 100
    base = make_esdp_policy(inst, T, tables=tables, solver="reference")
    res0 = simulate(inst, base, T, seed=1, tables=tables)
    policy = make_esdp_policy(inst, T, tables=tables, solver="reference",
                              cache=mode)
    res1 = simulate(inst, policy, T, seed=1, tables=tables)
    np.testing.assert_array_equal(res0.n_dispatched, res1.n_dispatched)
    np.testing.assert_array_equal(res0.sw, res1.sw)
    np.testing.assert_array_equal(res0.regret, res1.regret)
    stats = policy.finalize(res1.policy_final)
    assert stats["cache_solves"] == T
    if mode == "memo":
        assert 0.0 <= stats["cache_hit_rate"] <= 1.0
    else:
        assert 0.0 <= stats["edge_skip_rate"] <= 1.0


@pytest.mark.parametrize("mode", ["memo", "warm"])
def test_esdp_cache_modes_trace_invariant_simulate_batch(small, mode):
    """vmap safety: per-instance cache state must not alias across the
    seed batch — every seed's trace matches its cache-less counterpart."""
    inst, tables = small
    T, seeds = 60, (0, 1, 2)
    base = make_esdp_policy(inst, T, tables=tables, solver="reference")
    res0 = simulate_batch(inst, base, T, seeds, tables=tables)
    policy = make_esdp_policy(inst, T, tables=tables, solver="reference",
                              cache=mode)
    res1 = simulate_batch(inst, policy, T, seeds, tables=tables)
    np.testing.assert_array_equal(res0.n_dispatched, res1.n_dispatched)
    np.testing.assert_array_equal(res0.sw, res1.sw)
    np.testing.assert_array_equal(res0.regret, res1.regret)
    # per-seed finalize: counters are seed-local, not pooled
    for i in range(len(seeds)):
        row = jax.tree.map(lambda a: np.asarray(a)[i], res1.policy_final)
        stats = policy.finalize(row)
        assert stats["cache_solves"] == T


def test_esdp_cache_mode_validation(small):
    inst, tables = small
    with pytest.raises(ValueError, match="cache mode"):
        make_esdp_policy(inst, 50, tables=tables, cache="bogus")
    with pytest.raises(ValueError, match="reference"):
        make_esdp_policy(inst, 50, tables=tables,
                         solver="pallas_interpret", cache="warm")

"""Behavioural tests for ESDP, baselines, and the simulation env."""
import numpy as np
import pytest

from repro.core import (build_tables, generate_instance, make_esdp_policy,
                        make_hswf_policy, make_lcf_policy, make_lwtf_policy,
                        simulate)
from repro.core.graph import clipped_normal_mean
from repro.core.stats import g_logt_only


@pytest.fixture(scope="module")
def small():
    inst = generate_instance(seed=3, n_ports=4, n_servers=10, edge_prob=0.3)
    tables = build_tables(inst.A, inst.c)
    return inst, tables


def test_instance_sanity():
    inst = generate_instance(seed=0)
    assert inst.n_edges >= inst.n_ports  # ≥1 channel per port
    assert np.all(inst.A <= inst.c[:, None])  # solely-servable condition
    assert np.all((inst.v >= 0) & (inst.v <= 1))
    assert np.all(inst.sigma == inst.mu / 2)


def test_clipped_normal_mean_limits():
    # deep inside [0,1]: clip has no effect
    assert clipped_normal_mean(0.5, 1e-6) == pytest.approx(0.5, abs=1e-6)
    # mass far below 0 clips to ~0; far above 1 clips to ~1
    assert clipped_normal_mean(-5.0, 0.5) == pytest.approx(0.0, abs=1e-6)
    assert clipped_normal_mean(6.0, 0.5) == pytest.approx(1.0, abs=1e-6)
    # Monte-Carlo agreement
    rng = np.random.default_rng(0)
    for m, s in [(0.3, 0.15), (0.9, 0.45), (0.05, 0.5)]:
        mc = np.clip(rng.normal(m, s, 200_000), 0, 1).mean()
        assert clipped_normal_mean(m, s) == pytest.approx(mc, abs=3e-3)


def test_all_policies_feasible_every_slot(small):
    inst, tables = small
    T = 200
    for pol in [make_esdp_policy(inst, T, tables=tables),
                make_hswf_policy(inst), make_lcf_policy(inst),
                make_lwtf_policy(inst)]:
        res = simulate(inst, pol, T, seed=1, tables=tables)
        assert res.sw.shape == (T,)
        assert np.all(res.sw >= 0)
        assert np.all(res.n_dispatched <= inst.c.sum())  # loose capacity bound
        assert np.all(res.sw_oracle + 1e-5 >= 0)


def test_oracle_dominates_every_policy(small):
    """Per-slot expected regret is non-negative: the oracle is omniscient."""
    inst, tables = small
    T = 300
    for pol in [make_esdp_policy(inst, T, tables=tables),
                make_hswf_policy(inst), make_lcf_policy(inst)]:
        res = simulate(inst, pol, T, seed=7, tables=tables)
        assert np.all(res.regret >= -1e-4), pol.name


def test_esdp_explores_every_channel(small):
    """Forced exploration: every channel with a reachable port gets sampled."""
    inst, tables = small
    T = 400
    pol = make_esdp_policy(inst, T, tables=tables)
    res = simulate(inst, pol, T, seed=0, tables=tables)
    # total dispatches must cover many distinct slots; indirectly check via
    # regret decreasing trend (first-quarter mean vs last-quarter mean)
    q = T // 4
    assert res.regret[-q:].mean() < res.regret[:q].mean()


def test_esdp_regret_sublinear(small):
    """Cumulative regret growth slows: R(2T)−R(T) < R(T) for the tuned g."""
    inst, tables = small
    T = 1200
    pol = make_esdp_policy(inst, T, g_fn=g_logt_only, tables=tables)
    res = simulate(inst, pol, T, seed=5, tables=tables)
    cr = res.cum_regret
    first, second = cr[T // 2 - 1], cr[-1] - cr[T // 2 - 1]
    assert second < first * 0.95


def test_esdp_beats_literal_greedy():
    """vs the paper-literal (no-tiebreak) baselines on the paper's default
    instance, ESDP wins clearly (paper Fig. 2 regime)."""
    inst = generate_instance(seed=0)  # Table-2 defaults
    tables = build_tables(inst.A, inst.c)
    T = 1000
    esdp = simulate(inst, make_esdp_policy(inst, T, g_fn=g_logt_only,
                                           tables=tables), T, seed=2,
                    tables=tables)
    for mk in (make_hswf_policy, make_lcf_policy, make_lwtf_policy):
        base = simulate(inst, mk(inst, tiebreak=0.0), T, seed=2, tables=tables)
        assert esdp.asw[-1] > base.asw[-1]


def test_same_seed_same_stream(small):
    """Paired-comparison guarantee: identical arrival/valuation draws."""
    inst, tables = small
    a = simulate(inst, make_hswf_policy(inst), 100, seed=9, tables=tables)
    b = simulate(inst, make_hswf_policy(inst), 100, seed=9, tables=tables)
    np.testing.assert_allclose(a.sw, b.sw)
    np.testing.assert_allclose(a.sw_oracle, b.sw_oracle)

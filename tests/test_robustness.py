"""Robustness-layer tests: the failure-aware cluster runtime (crash/repair
scenarios, redundancy, opportunistic checkpointing, detection-driven
eligibility) and the graceful-degradation solver chain (FallbackSolver with
DP-invariant output validation and deterministic fault injection).

The load-bearing invariants:

  * ledger conservation — ``completed + lost + salvaged = dispatched``
    exactly, per slot, under every mitigation combination;
  * replay determinism — same seed, same crash stream, same ledger
    (counter-based injector, no hidden generator state);
  * zero-cost wrappers — a no-op FailureModel and a fault-free
    FallbackSolver are bit-invisible (identical sw/regret; identical
    jaxpr under trace);
  * exact degradation — with faults injected, results stay bit-identical
    to the fault-free run because every chain link is bit-exact.
"""
import copy

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import build_tables, simulate, simulate_batch
from repro.core.baselines import hswf_factory
from repro.core.dp import NEG
from repro.core.env import crash_events
from repro.core.solvers import FallbackSolver, get_solver
from repro.experiments import get_scenario, scenario_names, unroll_scenario
from repro.kernels.budgeted_dp.ops import VALUE_BOUND, validate_value_row
from repro.runtime.fault import (FAULT_RATE_ENV, InjectedFault,
                                 fault_rate_from_env, planned_fault)
from repro.sched import (ClusterSim, FailureModel, JobType, Slice,
                         build_instance, rate_matrix)

REF = get_solver("reference")


@pytest.fixture(scope="module")
def cluster():
    slices = [Slice("pod-a", "v5e", 256, 32, 4),
              Slice("pod-b", "v5e", 256, 32, 4),
              Slice("pod-c", "v5p", 256, 32, 4)]
    jobs = [JobType("train", "qwen2.5-32b", "train_4k", ("v5e", "v5p"),
                    256, 32, 4, value_rate=1.0),
            JobType("decode", "deepseek-v3-671b", "decode_32k", ("v5e",),
                    256, 32, 4, value_rate=1.2)]
    rates = rate_matrix(jobs, slices)
    inst, _ = build_instance(slices, jobs, rates, seed=0)
    return inst


def _lemon_scenario(**over):
    """The failure regime the recovery tests share: crashy cluster with a
    lemon subset and spare capacity for replicas."""
    kw = dict(p_crash=0.12, p_repair=0.6, lemon_frac=0.34, lemon_mult=3.0,
              arr_scale=0.6)
    kw.update(over)
    return get_scenario("server_failures", **kw)


# ---------------------------------------------------------------------------
# crash-event coupling
# ---------------------------------------------------------------------------

def test_crash_events_helper():
    alive = np.array([[1, 1], [0, 1], [1, 1], [1, 0]], bool)
    ev = crash_events(alive)
    # up at t, down at t+1 => crashed during slot t; last slot never flags
    np.testing.assert_array_equal(
        ev, np.array([[1, 0], [0, 0], [0, 1], [0, 0]], bool))


def test_server_failures_scenario_registered():
    assert "server_failures" in scenario_names()
    scn = _lemon_scenario()
    arr, speed, alive = unroll_scenario(scn, 120, 6, seed=4, n_ports=2)
    assert not alive.all() and alive.any()  # crashes AND repairs both fire
    assert crash_events(alive).any()
    np.testing.assert_allclose(arr, 0.6)  # arr_scale reaches the ports
    np.testing.assert_allclose(speed, 1.0)  # failures, not stragglers


def test_scenario_trace_invariance_server_failures(cluster):
    """server_failures runs identically through the jitted env (simulate /
    simulate_batch, decision bit-exact) and drives ClusterSim's aliveness:
    a down server gets zero dispatch share that slot."""
    inst = cluster
    tables = build_tables(inst.A, inst.c)
    T, seeds = 80, (0, 1)
    scn = _lemon_scenario()
    policy = hswf_factory()(inst, T, tables)
    batch = simulate_batch(inst, policy, T, seeds, tables=tables,
                           scenario=scn)
    for i, s in enumerate(seeds):
        one = simulate(inst, policy, T, seed=s, tables=tables, scenario=scn)
        np.testing.assert_array_equal(batch.n_dispatched[i], one.n_dispatched)
        np.testing.assert_array_equal(batch.regret[i], one.regret)
        np.testing.assert_allclose(batch.sw[i], one.sw, rtol=1e-6, atol=1e-6)

    _, _, alive = unroll_scenario(scn, T, inst.n_servers, seed=2)
    assert not alive.all()
    out = ClusterSim(inst, T, scenario=scn, seed=2).run("esdp")
    assert out.dispatch_share[~alive].sum() == 0.0


# ---------------------------------------------------------------------------
# failure-aware runtime: ledger conservation + determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", [
    FailureModel(p_crash=0.15),
    FailureModel(p_crash=0.15, redundancy=2),
    FailureModel(p_crash=0.15, checkpoints=2, checkpoint_cost=0.003),
    FailureModel(p_crash=0.1, n_racks=2, p_rack=0.1, detect=True),
    FailureModel(p_crash=0.2, redundancy=3, checkpoints=3,
                 checkpoint_cost=0.005, detect=True),
], ids=["bare", "redundant", "checkpoint", "racks+detect", "all"])
@pytest.mark.parametrize("seed", [0, 1])
def test_failure_ledger_conservation(cluster, model, seed):
    """dispatched = completed + lost + salvaged, exactly, per slot — and
    sw = completed + salvaged − checkpoint costs."""
    out = ClusterSim(cluster, 60, seed=seed, failures=model).run("esdp")
    led = out.failures
    np.testing.assert_allclose(
        led["dispatched"], led["completed"] + led["lost"] + led["salvaged"],
        rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(
        out.sw, led["completed"] + led["salvaged"] - led["ckpt_cost"],
        rtol=1e-5, atol=1e-5)
    assert led["total_dispatched"] > 0
    assert led["restarts"] >= int(led["lost"].sum() > 0)
    assert led["model"] == {
        "p_crash": model.p_crash, "n_racks": model.n_racks,
        "p_rack": model.p_rack, "redundancy": model.redundancy,
        "checkpoints": model.checkpoints,
        "checkpoint_cost": model.checkpoint_cost, "detect": model.detect}


def test_failure_runtime_replay_deterministic(cluster):
    model = FailureModel(p_crash=0.15, redundancy=2, checkpoints=2,
                         checkpoint_cost=0.003)
    a = ClusterSim(cluster, 60, seed=3, failures=model).run("esdp")
    b = ClusterSim(cluster, 60, seed=3, failures=model).run("esdp")
    np.testing.assert_array_equal(a.sw, b.sw)
    np.testing.assert_array_equal(a.regret, b.regret)
    assert a.failures["restarts"] == b.failures["restarts"]
    for k in ("dispatched", "completed", "lost", "salvaged", "crashes"):
        np.testing.assert_array_equal(a.failures[k], b.failures[k])


@pytest.mark.parametrize("model", [
    FailureModel(p_crash=0.15),
    FailureModel(p_crash=0.2, redundancy=2, checkpoints=2,
                 checkpoint_cost=0.003, detect=True),
], ids=["bare", "all"])
def test_engine_per_variant_ledger_conservation(cluster, model):
    """The streaming engine's A/B rollout keeps the PR 8 conservation law
    *per variant* — ``dispatched = completed + lost + salvaged`` for each
    arm — while overflow shedding is ledgered separately and neither shed
    nor rejected jobs ever enter the bandit statistics."""
    from repro.sched import DispatchEngine, EngineConfig, VariantSpec

    # global bound 1 with both ports arriving every slot: the second
    # arrival of a slot always overflows, so shedding provably fires
    cfg = EngineConfig(
        queue_capacity=1, total_capacity=1,
        backpressure="shed_by_utility",
        variants=(VariantSpec("esdp", weight=0.9),
                  VariantSpec("challenger", kind="hswf", weight=0.1)))
    out = DispatchEngine(cluster, 60, cfg, arr_scale=2.0, seed=1,
                         failures=model).run(mode="lockstep")
    fv = out.failures["per_variant"]
    assert set(fv) == set(out.variants)
    for name in out.variants:
        led = fv[name]
        np.testing.assert_allclose(
            np.asarray(led["dispatched"]),
            np.asarray(led["completed"]) + np.asarray(led["lost"])
            + np.asarray(led["salvaged"]), rtol=1e-6, atol=1e-6)
    # the combined ledger is exactly the sum of the per-variant ledgers
    np.testing.assert_allclose(
        np.asarray(out.failures["dispatched"]),
        sum(np.asarray(fv[n]["dispatched"]) for n in out.variants),
        rtol=1e-6, atol=1e-6)
    # shed jobs are ledgered, not silently lost — and every bandit
    # observation corresponds to a dispatched unit (shed/rejected jobs
    # never feed the estimator)
    led = out.ledger
    assert led["total_shed"] > 0
    assert led["total_arrivals"] == (led["total_rejected"]
                                     + led["total_blocked"]
                                     + led["total_admitted"])
    assert led["total_admitted"] == (led["total_dispatched"]
                                     + led["total_dropped"]
                                     + led["total_shed"]
                                     + led["final_queue"])
    assert int(np.asarray(out.n).sum()) == led["total_dispatched"]


def test_zero_failure_model_is_invisible(cluster):
    """A no-op FailureModel (no crash channels, all servers up) changes
    nothing: bit-identical sw/regret, and the ledger shows every dispatched
    unit completing."""
    plain = ClusterSim(cluster, 60, seed=5).run("esdp")
    fm = ClusterSim(cluster, 60, seed=5, failures=FailureModel()).run("esdp")
    np.testing.assert_array_equal(plain.sw, fm.sw)
    np.testing.assert_array_equal(plain.regret, fm.regret)
    led = fm.failures
    assert led["total_lost"] == 0.0 and led["total_salvaged"] == 0.0
    np.testing.assert_array_equal(led["dispatched"], led["completed"])
    assert led["restarts"] == 0


def test_run_batch_rejects_failures(cluster):
    sim = ClusterSim(cluster, 10, failures=FailureModel(p_crash=0.1))
    with pytest.raises(NotImplementedError):
        sim.run_batch((0, 1))


def test_failure_model_validates():
    with pytest.raises(ValueError):
        FailureModel(redundancy=0)
    with pytest.raises(ValueError):
        FailureModel(checkpoint_cost=-0.1)


# ---------------------------------------------------------------------------
# mitigations actually mitigate (the arXiv:1707.01655 axis)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def crashy_runs(cluster):
    """naive / redundant / checkpointing runs of the same crashy regime."""
    T, seed = 200, 4
    scn = _lemon_scenario()

    def run(model):
        return ClusterSim(cluster, T, scenario=scn, seed=seed,
                          failures=model).run("esdp")

    return {
        "naive": run(FailureModel()),
        "redundant": run(FailureModel(redundancy=2)),
        "checkpoint": run(FailureModel(checkpoints=3,
                                       checkpoint_cost=0.003)),
    }


def test_redundancy_recovers_lost_utility(crashy_runs):
    naive, red = crashy_runs["naive"], crashy_runs["redundant"]
    assert red.failures["replicas"].sum() > 0  # spare capacity was used
    assert red.failures["total_lost"] < naive.failures["total_lost"]
    assert red.asw > naive.asw


def test_checkpointing_recovers_lost_utility(crashy_runs):
    naive, ck = crashy_runs["naive"], crashy_runs["checkpoint"]
    assert ck.failures["total_salvaged"] > 0
    assert ck.failures["total_ckpt_cost"] > 0  # salvage is not free
    assert ck.failures["total_lost"] < naive.failures["total_lost"]
    assert ck.asw > naive.asw


def test_detection_routes_around_lemons(cluster):
    """With persistent lemon hosts, CrashRateTracker-driven eligibility
    cuts the number of crashed dispatches."""
    T, seed = 200, 4
    scn = _lemon_scenario()
    naive = ClusterSim(cluster, T, scenario=scn, seed=seed,
                       failures=FailureModel()).run("esdp")
    det = ClusterSim(cluster, T, scenario=scn, seed=seed,
                     failures=FailureModel(detect=True)).run("esdp")
    assert det.failures["restarts"] < naive.failures["restarts"]


# ---------------------------------------------------------------------------
# value-plane validation (the invariant checks behind the fallback chain)
# ---------------------------------------------------------------------------

def _solved_row():
    rng = np.random.default_rng(0)
    A = rng.integers(1, 3, size=(2, 6))
    c = rng.integers(2, 4, size=2)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(1, 5, size=6).astype(np.int32)
    sig = rng.integers(1, 5000, size=6).astype(np.int32)
    tables = build_tables(A, c)
    s_cap = int(ups.sum())
    _, info = REF(jnp.asarray(ups), jnp.asarray(sig), tables, s_cap,
                  jnp.int32(s_cap))
    return np.asarray(info["value_row"])


def test_validate_value_row_accepts_real_planes():
    row = _solved_row()
    assert validate_value_row(row) is None
    assert validate_value_row(np.stack([row, row])) is None  # batched


def test_validate_value_row_rejects_corruption():
    row = _solved_row()
    n_feas = int((row != NEG).sum())
    assert n_feas >= 3  # the checks below need an interior feasible entry

    def poisoned(idx, val):
        bad = row.copy()
        bad[idx] = val
        return bad

    assert "source" in validate_value_row(poisoned(0, NEG))
    assert "source" in validate_value_row(poisoned(0, -5))
    assert "neg-contract" in validate_value_row(poisoned(n_feas - 1, -5))
    assert "value-bound" in validate_value_row(poisoned(0, VALUE_BOUND))
    # NEG hole inside the feasible prefix
    assert "feasible-prefix" in validate_value_row(poisoned(n_feas // 2, NEG))
    # a value row must be non-increasing in the budget s
    rising = row.copy()
    rising[n_feas - 1] = rising[0] + 1
    assert "monotone" in validate_value_row(rising)
    # batched: the failing row is named
    assert "row 1" in validate_value_row(np.stack([row, rising]))


# ---------------------------------------------------------------------------
# FallbackSolver: chain construction, exactness, degradation accounting
# ---------------------------------------------------------------------------

def test_fallback_chain_construction():
    fb = FallbackSolver("pallas")
    assert fb.name == "fallback:pallas->pallas_interpret->reference"
    assert FallbackSolver("reference").chain == (REF,)
    assert FallbackSolver(
        "pallas_interpret").name == "fallback:pallas_interpret->reference"
    # solver-shaped wrappers pass through get_solver unchanged, so every
    # consumer taking solver= accepts a preassembled chain
    assert get_solver(fb) is fb
    with pytest.raises(ValueError):
        FallbackSolver(chain=())


def _fallback_problem():
    rng = np.random.default_rng(1)
    A = rng.integers(1, 3, size=(2, 6))
    c = rng.integers(2, 4, size=2)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(1, 5, size=6).astype(np.int32)
    sig = rng.integers(1, 5000, size=6).astype(np.int32)
    return build_tables(A, c), ups, sig, int(ups.sum())


def test_fallback_matches_plain_backend():
    tables, ups, sig, s_cap = _fallback_problem()
    fb = FallbackSolver("reference", fault_rate=0.0)
    x, info = fb(ups, sig, tables, s_cap, s_cap)
    xr, infor = REF(jnp.asarray(ups), jnp.asarray(sig), tables, s_cap,
                    jnp.int32(s_cap))
    np.testing.assert_array_equal(x, np.asarray(xr))
    np.testing.assert_array_equal(info["value_row"],
                                  np.asarray(infor["value_row"]))
    assert int(info["s_star"]) == int(infor["s_star"])
    st = fb.stats
    assert st["calls"] == 1 and st["served_by"]["reference"] == 1
    assert st["degraded_calls"] == 0 and st["events"] == []


def test_fallback_every_attempt_faulted_still_exact():
    """fault_rate=1.0 kills every non-final attempt (launch or corrupt —
    both kinds must occur and be caught); the final link always serves and
    the answers never change."""
    tables, ups, sig, s_cap = _fallback_problem()
    fb = FallbackSolver(chain=("pallas_interpret", "reference"),
                        fault_rate=1.0, fault_seed=0)
    for call in range(8):
        x, info = fb(ups, sig, tables, s_cap, s_cap)
        xr, _ = REF(jnp.asarray(ups), jnp.asarray(sig), tables, s_cap,
                    jnp.int32(s_cap))
        np.testing.assert_array_equal(x, np.asarray(xr))
        assert validate_value_row(info["value_row"]) is None
    st = fb.stats
    assert st["calls"] == 8 == st["degraded_calls"] == st["faults_injected"]
    assert st["served_by"] == {"pallas_interpret": 0, "reference": 8}
    assert st["launch_failures"] + st["validation_failures"] == 8
    assert st["launch_failures"] > 0 and st["validation_failures"] > 0
    kinds = {e["kind"] for e in st["events"]}
    assert kinds == {"launch", "validate"}
    assert all(e["injected"] for e in st["events"])


def test_fallback_final_link_failure_propagates():
    """A chain that cannot serve at all is an outage, not a degradation."""
    tables, ups, sig, s_cap = _fallback_problem()

    class Dead:
        name = "dead"
        accepts_batch = False
        interpret = None

        def __call__(self, *a, **k):
            raise InjectedFault("backend gone")

    fb = FallbackSolver(chain=(Dead(),))
    with pytest.raises(InjectedFault):
        fb(ups, sig, tables, s_cap, s_cap)


def test_fallback_traced_bypass_adds_zero_launches():
    """Under jit the wrapper is invisible: the jaxpr of a traced call
    through the chain equals the plain backend's, so fault-free production
    runs pay no extra launches."""
    tables, ups, sig, s_cap = _fallback_problem()
    fb = FallbackSolver("reference", fault_rate=0.0)

    def jaxpr_of(solver):
        def f(u, s, lim):
            return solver(u, s, tables, s_cap, lim)[0]
        return jax.make_jaxpr(f)(jnp.asarray(ups), jnp.asarray(sig),
                                 jnp.int32(s_cap))

    assert str(jaxpr_of(fb)) == str(jaxpr_of(REF))
    assert fb.stats["bypasses"] == 1 and fb.stats["calls"] == 0


def test_cluster_sim_fallback_bit_identical_under_faults(cluster):
    """The acceptance bar: a full ESDP ClusterSim run with faults injected
    at 5%+ completes with sw/regret BIT-IDENTICAL to the fault-free run,
    every degradation accounted in solve_stats."""
    T = 60
    plain = ClusterSim(cluster, T, seed=7).run("esdp")
    fb = FallbackSolver(chain=("pallas_interpret", "reference"),
                        fault_rate=0.2, fault_seed=1)
    out = ClusterSim(cluster, T, seed=7, solver=fb).run("esdp")
    np.testing.assert_array_equal(plain.sw, out.sw)
    np.testing.assert_array_equal(plain.regret, out.regret)
    st = out.solve_stats
    assert st["calls"] == T and st["faults_injected"] > 0
    assert st["degraded_calls"] == len(st["events"]) > 0
    assert sum(st["served_by"].values()) == T
    # fault-free wrapper: same answers, zero degradation events
    quiet = ClusterSim(cluster, T, seed=7, fallback=True).run("esdp")
    np.testing.assert_array_equal(plain.sw, quiet.sw)
    assert quiet.solve_stats["degraded_calls"] == 0
    assert quiet.solve_stats["events"] == []


def test_cluster_sim_fallback_excludes_incremental(cluster):
    with pytest.raises(ValueError):
        ClusterSim(cluster, 10, fallback=True, incremental="cache")


# ---------------------------------------------------------------------------
# deterministic fault hook + env plumbing
# ---------------------------------------------------------------------------

def test_planned_fault_deterministic():
    plan = [planned_fault(i, 0.5, seed=3) for i in range(64)]
    assert plan == [planned_fault(i, 0.5, seed=3) for i in range(64)]
    assert {"launch", "corrupt"} <= set(plan) and None in plan
    assert all(planned_fault(i, 0.0) is None for i in range(16))
    # attempts draw independently: a faulted first attempt does not force
    # the second to fault too
    a0 = [planned_fault(i, 0.5, seed=3, attempt=0) for i in range(64)]
    a1 = [planned_fault(i, 0.5, seed=3, attempt=1) for i in range(64)]
    assert a0 != a1


def test_fault_rate_env_parsing(monkeypatch):
    monkeypatch.delenv(FAULT_RATE_ENV, raising=False)
    assert fault_rate_from_env() == 0.0
    monkeypatch.setenv(FAULT_RATE_ENV, "0.25")
    assert fault_rate_from_env() == 0.25
    monkeypatch.setenv(FAULT_RATE_ENV, "lots")
    with pytest.warns(RuntimeWarning):
        assert fault_rate_from_env() == 0.0
    monkeypatch.setenv(FAULT_RATE_ENV, "1.5")
    with pytest.warns(RuntimeWarning):
        assert fault_rate_from_env() == 0.0


# ---------------------------------------------------------------------------
# solve_stats plumbing (run_batch per-seed copies)
# ---------------------------------------------------------------------------

def test_run_batch_stats_are_per_output_copies(cluster):
    """Every SimOutput owns its OWN solve_stats dict (fleet-labelled):
    mutating one seed's record must not leak into another's."""
    sim = ClusterSim(cluster, 30, incremental="cache")
    outs = sim.run_batch((0, 1, 2))
    stats = [o.solve_stats for o in outs]
    assert all(s["scope"] == "fleet" for s in stats)
    assert stats[0] == stats[1] == stats[2]
    assert stats[0] is not stats[1] and stats[1] is not stats[2]
    original = copy.deepcopy(stats[1])
    stats[0]["solves"] = -1
    stats[0]["scope"] = "tampered"
    assert stats[1] == original


def test_run_batch_fallback_stats_copied(cluster):
    """The deep-copy guard also covers wrapper-style nested stats
    (FallbackSolver's served_by/events live in nested containers)."""
    fb = FallbackSolver(fault_rate=0.0)
    outs = ClusterSim(cluster, 20, solver=fb).run_batch((0, 1))
    a, b = outs[0].solve_stats, outs[1].solve_stats
    assert a is not b and a["served_by"] is not b["served_by"]
    assert a == b
    a["served_by"]["reference"] = 10 ** 6
    assert b["served_by"] != a["served_by"]

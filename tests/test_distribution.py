"""Distribution-layer tests: sharding-rule fallbacks (host-side logic) and
multi-device semantics (pipeline parallelism, mesh building, dry-run lower)
exercised in subprocesses with forced host device counts."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.runtime.sharding import PRESETS


def _fake_mesh(shape, axes):
    """Rules only consult mesh.shape / axis_names — a stub suffices."""
    class M:
        axis_names = axes
        def __init__(self):
            self.shape = dict(zip(axes, shape))
    return M()


def _rules(preset="train", shape=(16, 16), axes=("data", "model")):
    from repro.runtime.sharding import Rules
    return Rules(mesh=_fake_mesh(shape, axes), table=dict(PRESETS[preset]))


def test_rules_basic_2d_weight():
    r = _rules()
    assert r.spec((5120, 5120), ("embed", "heads")) == P("data", "model")


def test_rules_divisibility_fallback():
    r = _rules()
    # kv_heads=8 cannot shard over model=16 -> replicated dim
    assert r.spec((4096, 8, 128), (None, "kv_heads", None)) == P(None, None, None)
    # but the flattened 1024 column dim can
    assert r.spec((4096, 1024), ("embed", "kv_heads")) == P("data", "model")


def test_rules_no_axis_reuse():
    r = _rules()
    # vocab and seq_sp both want "model": the later dim must fall back
    spec = r.spec((256, 4096, 152064), ("batch", "seq_sp", "vocab"))
    assert spec == P("data", "model", None)


def test_rules_multi_axis_batch():
    r = _rules(shape=(2, 16, 16), axes=("pod", "data", "model"))
    assert r.spec((256, 4096), ("batch", None)) == P(("pod", "data"), None)


def test_rules_fsdp_preset_two_axis_embed():
    r = _rules(preset="fsdp")
    assert r.spec((3072, 4096), ("embed", "heads")) == P(("data", "model"), None)


def test_rules_none_mesh_noop():
    from repro.runtime.sharding import make_rules
    r = make_rules(None)
    x = np.ones((4, 4))
    assert r(x, ("batch", None)) is x


_PP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, json
    from repro.launch.mesh import make_mesh_shape
    from repro.runtime.pp import gpipe, bubble_fraction

    S, M, mb, d = 4, 8, 2, 16
    mesh = make_mesh_shape((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, d, d)) * 0.3

    def stage(w, x):
        return jnp.tanh(x @ w)

    xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
    got = gpipe(stage, ws, xs, mesh=mesh, axis="stage")

    ref = xs
    for s in range(S):
        ref = jax.vmap(lambda x: stage(ws[s], x))(ref)

    ok = bool(jnp.allclose(got, ref, atol=1e-5))
    print(json.dumps({"ok": ok, "bubble": bubble_fraction(M, S)}))
""")


def test_gpipe_matches_sequential():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _PP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"], res
    assert res["bubble"] == pytest.approx(3 / 11)


_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax, json
    from repro.launch.mesh import make_production_mesh
    m1 = make_production_mesh()
    m2 = make_production_mesh(multi_pod=True)
    print(json.dumps({"single": dict(m1.shape), "multi": dict(m2.shape)}))
""")


def test_production_meshes_build():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["single"] == {"data": 16, "model": 16}
    assert res["multi"] == {"pod": 2, "data": 16, "model": 16}

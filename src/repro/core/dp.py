"""Polynomial-time dynamic programming (paper Algorithm 2) + oracle knapsack.

The budgeted integer program P4(s,t):  max Σ̂²ᵀx  s.t.  A x ≤ c,  Υ̂ᵀx ≥ s
is solved for *all* s ∈ S(t) at once by one DP over states
(s, remaining-capacity, edge index i) — paper problem P5(s,t,c,i):

    V(s, c', i) = max( V(s, c', i+1),
                       [A_{:,i} ≤ c']·( V(max(s−Υ̂_i,0), c'−A_{:,i}, i+1) + Σ̂²_i ) )

Capacity vectors are encoded as mixed-radix state ids (Π_k (c_k+1) states),
so the per-edge update is a (S × C) plane refresh: a *uniform shift* along s
(Υ̂_i is a per-edge scalar) and — because taking edge e from a feasible state
c always lands on c − offsets[e] — a *uniform shift* along the capacity axis
too. That structure is exactly what `kernels/budgeted_dp` exploits on TPU
(whole plane in VMEM, both shifts = padded dynamic slices, transitions = an
(E,) offset vector instead of an (E, C, C) one-hot; planes too big for
VMEM stream through C-blocked or 2-D S×C-tiled grids — both shifts read
only towards smaller indices, so one halo tile per axis covers them — and
the edge loop fuses into those grids in chunks of `block_e`, so each tile
streams HBM once per chunk instead of once per edge; see
docs/kernel_pipeline.md).
This module is the pure-JAX *reference* backend of the pluggable solver
registry (`core/solvers.py`); the Pallas kernel backend is validated against
`solve_budgeted_dp` by the differential harness in tests/test_solver_equiv.py.

Values are exact int32 (see stats.py for the bounds argument).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DPTables", "build_tables", "solve_budgeted_dp", "oracle_knapsack",
           "dp_edge_fold", "initial_plane"]

NEG = jnp.int32(-(2**29))  # -inf sentinel; NEG + max Σ̂² never overflows
FNEG = jnp.float32(-1e30)


# eq=False ⇒ identity hash (jit-static-safe)
@dataclasses.dataclass(frozen=True, eq=False)
class DPTables:
    """Static per-instance tables for capacity-state transitions.

    ``offsets`` is the structural fact the TPU kernel is built on: in the
    mixed-radix encoding, serving edge e from any *feasible* state c lands on
    ``next_state[c, e] == c - offsets[e]`` with ``offsets[e] = Σ_k
    A[k,e]·strides[k]`` a per-edge constant (no borrows can occur because
    feasibility means every digit satisfies cap_k ≥ A[k,e]).  That turns the
    per-edge capacity gather into a uniform shift along the state axis, so
    the kernel needs an (E,) int32 vector instead of an (E, C, C) one-hot
    tensor.  ``build_tables`` validates the identity on every feasible pair.
    """

    feasible: np.ndarray  # (n_states, E) bool — A_{:,e} ≤ capacity(state)
    next_state: np.ndarray  # (n_states, E) int32 — state after taking edge e
    n_states: int
    full_state: int  # encoding of the full capacity vector c
    radices: np.ndarray  # (K,) int32 — c_k + 1
    cap_of_state: np.ndarray  # (n_states, K) int32 — decoded capacity vectors
    strides: np.ndarray  # (K,) int64 — mixed-radix strides of the encoding
    offsets: np.ndarray  # (E,) int32 — Σ_k A[k,e]·strides[k] (see above)


def build_tables(A: np.ndarray, c: np.ndarray) -> DPTables:
    """Build the static capacity-state transition tables for one instance.

    Args:
      A: (K, E) int demand matrix — column e is edge e's device
        requirement vector a^e over the K resource types.
      c: (K,) int cluster capacities.

    Returns:
      :class:`DPTables` over the Π_k (c_k + 1) mixed-radix capacity
      states, with the per-edge transition offsets derived AND validated
      (``next_state[c, e] == c - offsets[e]`` is asserted on every
      feasible pair — the structural identity the TPU kernel's uniform
      capacity shift rests on).  Host numpy; build once per instance and
      share across slots/backends (every solver takes ``tables``).
    """
    A = np.asarray(A, dtype=np.int64)
    c = np.asarray(c, dtype=np.int64)
    K, E = A.shape
    radices = (c + 1).astype(np.int64)
    n_states = int(np.prod(radices))

    ids = np.arange(n_states, dtype=np.int64)
    cap = np.zeros((n_states, K), dtype=np.int64)
    rem = ids.copy()
    strides = np.zeros(K, dtype=np.int64)
    stride = 1
    for k in range(K):
        strides[k] = stride
        cap[:, k] = (rem // stride) % radices[k]
        stride *= radices[k]

    feasible = np.all(cap[:, None, :] >= A.T[None, :, :], axis=2)  # (n_states, E)
    nxt_cap = np.maximum(cap[:, None, :] - A.T[None, :, :], 0)  # (n_states, E, K)
    next_state = (nxt_cap * strides[None, None, :]).sum(axis=2)
    next_state = np.where(feasible, next_state, 0).astype(np.int32)

    # per-edge transition offsets: next(c) = c - offset_e on feasible states
    offsets = (A.T * strides[None, :]).sum(axis=1)  # (E,)
    expect = ids[:, None] - offsets[None, :]  # (n_states, E)
    if not np.array_equal(next_state[feasible],
                          expect.astype(np.int32)[feasible]):
        raise AssertionError(
            "mixed-radix offset identity violated: next_state[c, e] != "
            "c - offsets[e] on a feasible pair")

    full_state = int((c * strides).sum())
    assert full_state == n_states - 1
    return DPTables(
        feasible=feasible.astype(bool),
        next_state=next_state,
        n_states=n_states,
        full_state=full_state,
        radices=radices.astype(np.int32),
        cap_of_state=cap.astype(np.int32),
        strides=strides,
        offsets=offsets.astype(np.int32),
    )


def dp_edge_fold(V, ups, sig, feas_col, next_col, rows):
    """ONE fold step of the layered DP (plane refresh for a single edge).

    The body shared — verbatim — by the reference scan below and the
    warm-resume path (``core.incremental``): identical ops on identical
    int32 inputs is what makes a checkpointed resume bitwise-identical to
    a cold solve.  ``rows`` is ``arange(S)`` (hoisted by callers).
    """
    shifted = V[jnp.maximum(rows - ups, 0), :]  # s' = max(s-Υ̂_e, 0)
    take = jnp.take(shifted, next_col, axis=1) + sig  # capacity gather
    take = jnp.where(feas_col[None, :], take, NEG)
    decision = take > V  # strict ⇒ ties keep x_e=0
    return jnp.maximum(V, take), decision


def initial_plane(s_cap: int, n_states: int):
    """The cold-start DP plane: 0 at s = 0, NEG elsewhere."""
    return jnp.full((s_cap + 1, n_states), NEG, dtype=jnp.int32).at[0, :].set(0)


def _dp_forward(upsilon, sigma2, feasible, next_state, s_cap: int, v0=None):
    """Run the layered DP; returns (V at i=0, decision bits per edge).

    decisions[j] corresponds to edge e = E-1-j (the scan walks i downward).
    ``v0`` optionally seeds the value plane (the carried-plane hook the
    incremental layer resumes from); ``None`` is the cold start.
    """
    S = s_cap + 1
    rows = jnp.arange(S, dtype=jnp.int32)
    if v0 is None:
        v0 = initial_plane(s_cap, feasible.shape[0])

    def body(V, inputs):
        ups, sig, feas_e, next_e = inputs
        return dp_edge_fold(V, ups, sig, feas_e, next_e, rows)

    xs = (upsilon[::-1], sigma2[::-1], feasible[:, ::-1].T, next_state[:, ::-1].T)
    V_final, decisions = jax.lax.scan(body, v0, xs)
    return V_final, decisions


def solve_budgeted_dp(
    upsilon, sigma2, tables: DPTables, s_cap: int, s_limit, allowed=None
):
    """Solve {P4(s,t)}_{s∈S(t)} and apply the s*-selection rule (eq. 17).

    Args:
      upsilon: (E,) int32 scaled means Υ̂(t).
      sigma2:  (E,) int32 scaled variances Σ̂²(t).
      tables:  capacity-state transition tables.
      s_cap:   static bound on s (table height − 1).
      s_limit: dynamic ξ(t)·m — s values beyond it are masked out.
      allowed: optional (E,) bool — edges eligible this slot. P3(t) maximizes
        over Ω(t), which includes arrival constraint (2); masking here is the
        Ω(t)-faithful reading (Alg.-1 Steps 9–16 stay as a safety harness).

    Returns:
      x: (E,) int32 — the Alg.-1 Step-8 solution (before arrival zeroing).
      info: dict with s_star and the DP value row for diagnostics.
    """
    feasible = jnp.asarray(tables.feasible)
    if allowed is not None:
        feasible = feasible & allowed[None, :]
    next_state = jnp.asarray(tables.next_state)
    E = upsilon.shape[0]

    V, decisions = _dp_forward(upsilon, sigma2, feasible, next_state, s_cap)

    v_row = V[:, tables.full_state]  # (S,)
    s_vals = jnp.arange(s_cap + 1, dtype=jnp.int32)
    # feasible ⇔ value ≥ 0: Σ̂² ≥ 0 so reachable values are non-negative,
    # while NEG-seeded chains stay < 0 for any partial sum < 2²⁹ (same
    # classification the Pallas backend uses — keeps s* bit-identical).
    ok = (v_row >= 0) & (s_vals <= s_limit)
    score = s_vals.astype(jnp.float32) + jnp.sqrt(
        jnp.maximum(v_row, 0).astype(jnp.float32))
    score = jnp.where(ok, score, FNEG)
    s_star = jnp.argmax(score).astype(jnp.int32)

    def back_body(e, carry):
        s, cs, x = carry
        d = decisions[E - 1 - e, s, cs]
        x = x.at[e].set(d.astype(jnp.int32))
        s_new = jnp.maximum(s - upsilon[e], 0)
        cs_new = next_state[cs, e]
        return (jnp.where(d, s_new, s), jnp.where(d, cs_new, cs), x)

    x0 = jnp.zeros(E, dtype=jnp.int32)
    _, _, x = jax.lax.fori_loop(
        0, E, back_body, (s_star, jnp.int32(tables.full_state), x0))
    return x, {"s_star": s_star, "value_row": v_row}


def oracle_knapsack(values, tables: DPTables, take_allowed):
    """Omniscient per-slot optimum: max valuesᵀx s.t. Ax ≤ c, x∈{0,1}^E.

    ``take_allowed`` masks edges of ports with no arrival (constraint (2)).
    Exact DP over capacity states × edges; float32 objective.
    """
    feasible = jnp.asarray(tables.feasible)
    next_state = jnp.asarray(tables.next_state)
    E = values.shape[0]

    V0 = jnp.zeros(tables.n_states, dtype=jnp.float32)

    def body(V, inputs):
        val, allowed, feas_e, next_e = inputs
        take = jnp.take(V, next_e) + val
        take = jnp.where(feas_e & allowed, take, FNEG)
        decision = take > V
        return jnp.maximum(V, take), decision

    xs = (values[::-1], take_allowed[::-1], feasible[:, ::-1].T,
          next_state[:, ::-1].T)
    V, decisions = jax.lax.scan(body, V0, xs)

    def back_body(e, carry):
        cs, x = carry
        d = decisions[E - 1 - e, cs]
        x = x.at[e].set(d.astype(jnp.int32))
        return (jnp.where(d, next_state[cs, e], cs), x)

    _, x = jax.lax.fori_loop(
        0, E, back_body,
        (jnp.int32(tables.full_state), jnp.zeros(E, dtype=jnp.int32)))
    return x, V[tables.full_state]

"""Cross-slot incremental re-solves for the per-slot Algorithm-2 DP.

ESDP re-solves the budgeted DP from scratch every slot, but between slots
only the sampled statistics (Υ̂, Σ̂²) and the eligibility mask move — and
after the early exploration phase they move slowly, so most solves are
near-duplicates of the previous one.  This module exploits that drift
structure with two composable layers:

**Solve cache** (:class:`SolveCache`): a host-side memo keyed on the
quantized solve inputs ``(Υ̂ ÷ q_ups, Σ̂² ÷ q_sig, eligibility, s_limit)``.
With the default quantum 1 the key is the EXACT inputs, so a hit returns a
bit-identical ``(x, s_star, value_row)`` and skips the kernel launch
entirely.  Coarser quanta trade exactness for hit rate: a hit may serve a
solution computed for *nearby* statistics (still capacity-feasible — the
constraint set A x ≤ c does not depend on the statistics), bounded by
``max_stale`` cache ticks.  Consumed through
:class:`repro.core.solvers.CachedSolver`, which preserves the backend call
contract and ``accepts_batch``.

**Warm-started value planes** (:func:`solve_budgeted_dp_warm`): a traced,
scan-safe re-solve that carries the previous slot's fold artifacts
(checkpointed value planes every ``checkpoint_every`` fold steps, the full
decision tensor, and the previous inputs) and re-folds ONLY from the first
checkpoint at or before the first changed edge.  The per-edge *delta mask*
``changed_edge_mask`` determines the unchanged fold prefix; everything
before it is reused verbatim.

Why resume-from-checkpoint instead of "seed with the previous FINAL plane
and keep folding"?  Re-folding an edge into a plane that already absorbed
it double-takes the edge: with one edge (Υ̂=1, Σ̂²=10) and capacity 2, the
final plane has V[1, c=1] = 10, and folding the same edge again yields
V[2, c=0] = 20 — an infeasible 0/1 solution counted twice.  A checkpoint
is a plane that has absorbed exactly the fold prefix [0, j), so resuming
from it replays the suffix on untainted state: the warm path is
bit-identical to a cold solve *by construction* (the differential harness
in ``tests/test_solver_equiv.py`` enforces it anyway).

Fold order: both the reference scan (``core.dp._dp_forward``) and the
Pallas kernel process edges E-1 down to 0, so "fold step j" always means
edge ``E-1-j`` and all cross-slot comparisons here are in FOLD order.
The Pallas counterpart of the warm path — a host-driven segmented
carried-plane entry reusing the kernel's ``v0`` operand — lives in
``repro.kernels.budgeted_dp.ops.WarmPallasSolver``.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .dp import NEG, FNEG, DPTables, dp_edge_fold, initial_plane

__all__ = [
    "SolveCache", "CacheStats", "solve_key",
    "WarmCarry", "warm_carry_init", "solve_budgeted_dp_warm",
    "changed_edge_mask", "unchanged_fold_prefix",
]


# ---------------------------------------------------------------------------
# quantized solve keys + the host-side cache
# ---------------------------------------------------------------------------

def solve_key(
    upsilon, sigma2, allowed, s_limit, q_ups: int = 1, q_sig: int = 1
) -> bytes:
    """Deterministic cache key of one solve's dynamic inputs.

    ``q_ups``/``q_sig`` floor-divide the statistics into buckets; quantum 1
    keys the EXACT inputs.  Eligibility and ``s_limit`` are always exact —
    quantization only ever blurs the statistics, never the constraint set.
    Keys are compared within ONE cache (bound to one (tables, s_cap)
    problem), so the fixed field order plus fixed per-field width make
    distinct inputs collide-free.
    """
    ups = np.asarray(upsilon, np.int64) // int(q_ups)
    sig = np.asarray(sigma2, np.int64) // int(q_sig)
    alw = (np.ones(ups.shape, bool) if allowed is None
           else np.asarray(allowed, bool))
    return (np.int64(s_limit).tobytes() + ups.tobytes() + sig.tobytes()
            + np.packbits(alw).tobytes())


@dataclasses.dataclass
class CacheStats:
    """Counters of one :class:`SolveCache` (row granularity for batches)."""

    hits: int = 0  # key lookups served from the cache
    misses: int = 0  # key lookups that fell through
    evictions: int = 0  # entries dropped by the capacity bound
    stale_rejects: int = 0  # quantized entries refused by max_stale
    bypasses: int = 0  # traced calls routed straight to the backend
    launches_saved: int = 0  # backend launches skipped entirely

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "stale_rejects": self.stale_rejects,
                "bypasses": self.bypasses,
                "launches_saved": self.launches_saved,
                "cache_hit_rate": self.hit_rate}


class SolveCache:
    """Bounded host-side memo of budgeted-DP solutions.

    * ``capacity`` bounds the entry count; overflow evicts LRU order
      (lookup hits refresh recency), which is DETERMINISTIC for a given
      call sequence — replaying the same solves yields the same
      hit/miss/eviction trace.
    * ``q_ups``/``q_sig`` = 1 (default) is the bit-exact EXACT-KEY mode.
      Larger quanta give the bounded-staleness APPROXIMATE mode: nearby
      statistics share a key, and ``max_stale`` bounds how many cache
      ticks (see :meth:`tick` — one per solve slot) an entry may serve
      after insertion before it is refused and refreshed.
    * ``exact`` tells consumers which contract they get; approximate mode
      must never be silently treated as bit-exact (the bench reports its
      utility gap instead).
    """

    def __init__(
        self,
        capacity: int = 512,
        q_ups: int = 1,
        q_sig: int = 1,
        max_stale: "int | None" = None,
    ):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if q_ups < 1 or q_sig < 1:
            raise ValueError("quantization quanta must be >= 1")
        self.capacity = int(capacity)
        self.q_ups = int(q_ups)
        self.q_sig = int(q_sig)
        self.max_stale = max_stale
        self.stats = CacheStats()
        self._entries: "collections.OrderedDict[bytes, tuple[int, Any]]" = (
            collections.OrderedDict())
        self._tick = 0

    @property
    def exact(self) -> bool:
        return self.q_ups == 1 and self.q_sig == 1

    def key(self, upsilon, sigma2, allowed, s_limit) -> bytes:
        return solve_key(upsilon, sigma2, allowed, s_limit,
                         q_ups=self.q_ups, q_sig=self.q_sig)

    def tick(self) -> None:
        """Advance the staleness clock — call once per solve slot."""
        self._tick += 1

    def get(self, key: bytes):
        ent = self._entries.get(key)
        if ent is None:
            self.stats.misses += 1
            return None
        born, value = ent
        if self.max_stale is not None and self._tick - born > self.max_stale:
            del self._entries[key]
            self.stats.stale_rejects += 1
            self.stats.misses += 1
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        return value

    def put(self, key: bytes, value) -> None:
        self._entries[key] = (self._tick, value)
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1

    def clear(self) -> None:
        self._entries.clear()
        self.stats = CacheStats()
        self._tick = 0

    def __len__(self) -> int:
        return len(self._entries)


# ---------------------------------------------------------------------------
# delta mask + warm-started (checkpoint-resumed) reference solve
# ---------------------------------------------------------------------------

class WarmCarry(NamedTuple):
    """Cross-slot fold artifacts of one solve (a pytree — scan-carriable).

    All edge-indexed members are in FOLD order (entry j ↔ edge E-1-j).
    ``ckpts[i]`` is the value plane after exactly ``i·k`` fold steps
    (``ckpts[0]`` is the cold-start plane); ``v_final`` the plane after all
    E; ``decisions[j]`` the fold-step-j decision plane.  The invariant the
    warm solve maintains: the carry always holds exactly what a COLD solve
    of ``(ups_f, sig_f, alw_f)`` would have produced.
    """

    ups_f: jnp.ndarray  # (E,) int32
    sig_f: jnp.ndarray  # (E,) int32
    alw_f: jnp.ndarray  # (E,) bool
    ckpts: jnp.ndarray  # (n_ckpt, S, C) int32
    v_final: jnp.ndarray  # (S, C) int32
    decisions: jnp.ndarray  # (E, S, C) bool
    valid: jnp.ndarray  # () bool — False forces a full cold fold


def n_checkpoints(n_edges: int, checkpoint_every: int) -> int:
    """Planes stored at fold steps i·k for i = 0 .. (E-1)//k (a resume
    point is always < E; the final plane is carried separately)."""
    return max(1, (n_edges - 1) // checkpoint_every + 1)


def warm_carry_init(
    n_edges: int, s_cap: int, n_states: int, checkpoint_every: int = 8
) -> WarmCarry:
    """A fresh (invalid) carry: the first warm solve runs a full cold fold."""
    S = s_cap + 1
    n_ckpt = n_checkpoints(n_edges, checkpoint_every)
    ckpts = jnp.zeros((n_ckpt, S, n_states), jnp.int32)
    ckpts = ckpts.at[0].set(initial_plane(s_cap, n_states))
    return WarmCarry(
        ups_f=jnp.zeros(n_edges, jnp.int32),
        sig_f=jnp.zeros(n_edges, jnp.int32),
        alw_f=jnp.zeros(n_edges, bool),
        ckpts=ckpts,
        v_final=jnp.zeros((S, n_states), jnp.int32),
        decisions=jnp.zeros((n_edges, S, n_states), bool),
        valid=jnp.asarray(False))


def changed_edge_mask(carry: WarmCarry, upsilon, sigma2, allowed):
    """(E,) bool in FOLD order — the delta mask: True where the edge's
    solve inputs differ from the carried solve (an invalid carry marks
    every edge changed)."""
    alw = (jnp.ones(upsilon.shape, bool) if allowed is None
           else jnp.asarray(allowed, bool))
    changed = ((upsilon[::-1] != carry.ups_f)
               | (sigma2[::-1] != carry.sig_f)
               | (alw[::-1] != carry.alw_f))
    return changed | ~carry.valid


def unchanged_fold_prefix(changed):
    """Length of the leading all-False run of a fold-order delta mask."""
    return jnp.argmax(
        jnp.concatenate([changed, jnp.ones(1, bool)])).astype(jnp.int32)


def solve_budgeted_dp_warm(
    upsilon,
    sigma2,
    tables: DPTables,
    s_cap: int,
    s_limit,
    carry: WarmCarry,
    allowed=None,
    checkpoint_every: int = 8,
):
    """Warm-started :func:`repro.core.dp.solve_budgeted_dp` — bit-identical
    outputs, folding only the edges after the last valid checkpoint.

    Traced-safe (usable inside jit / lax.scan): the resume point is a
    dynamic lower bound of a ``fori_loop``, so a jitted caller executes
    only ``E - resume`` fold steps at runtime while compiling one program.
    ``s_limit`` is NOT part of the delta mask — the eq.-17 selection and
    backtrack are recomputed every call from the (possibly fully reused)
    plane, so a changed budget mask alone costs zero fold steps.

    Returns ``(x, info, carry')`` where ``info`` adds ``edges_folded`` (the
    number of fold steps actually executed — E minus the skip) to the
    backend contract's ``s_star``/``value_row``.  Memory: the carry holds
    the (E, S, C) decision tensor plus ``n_checkpoints`` int32 planes —
    the warm path trades memory for fold work and suits policy-scale
    planes, not the S=8192 benchmark regime.
    """
    E = upsilon.shape[0]
    S = s_cap + 1
    C = tables.n_states
    k = int(checkpoint_every)
    upsilon = jnp.asarray(upsilon, jnp.int32)
    sigma2 = jnp.asarray(sigma2, jnp.int32)
    alw = (jnp.ones(E, bool) if allowed is None
           else jnp.asarray(allowed, bool))

    ups_f, sig_f, alw_f = upsilon[::-1], sigma2[::-1], alw[::-1]
    changed = changed_edge_mask(carry, upsilon, sigma2, alw)
    p = unchanged_fold_prefix(changed)
    # resume at the last checkpoint at/below the first change; a fully
    # unchanged fold (p == E) resumes at E — zero fold steps, final plane
    # and decisions reused verbatim
    resume = jnp.where(p >= E, E, (p // k) * k)
    plane_ck = jax.lax.dynamic_index_in_dim(
        carry.ckpts, jnp.minimum(resume // k, carry.ckpts.shape[0] - 1),
        keepdims=False)
    plane0 = jnp.where(resume == E, carry.v_final, plane_ck)

    rows = jnp.arange(S, dtype=jnp.int32)
    feas = jnp.asarray(tables.feasible) & alw[None, :]  # (C, E)
    feas_f = feas[:, ::-1]
    nxt_f = jnp.asarray(tables.next_state)[:, ::-1]

    def body(j, state):
        V, dec, ck = state
        ck = jax.lax.cond(
            j % k == 0,
            lambda c: jax.lax.dynamic_update_index_in_dim(c, V, j // k, 0),
            lambda c: c, ck)
        feas_j = jax.lax.dynamic_index_in_dim(feas_f, j, 1, keepdims=False)
        nxt_j = jax.lax.dynamic_index_in_dim(nxt_f, j, 1, keepdims=False)
        V, d = dp_edge_fold(V, ups_f[j], sig_f[j], feas_j, nxt_j, rows)
        dec = jax.lax.dynamic_update_index_in_dim(dec, d, j, 0)
        return V, dec, ck

    V, decisions, ckpts = jax.lax.fori_loop(
        resume, E, body, (plane0, carry.decisions, carry.ckpts))

    # eq.-17 selection + backtrack — identical to the cold reference path
    v_row = V[:, tables.full_state]
    s_vals = jnp.arange(S, dtype=jnp.int32)
    ok = (v_row >= 0) & (s_vals <= s_limit)
    score = s_vals.astype(jnp.float32) + jnp.sqrt(
        jnp.maximum(v_row, 0).astype(jnp.float32))
    score = jnp.where(ok, score, FNEG)
    s_star = jnp.argmax(score).astype(jnp.int32)

    next_state = jnp.asarray(tables.next_state)

    def back_body(e, bc):
        s, cs, x = bc
        d = decisions[E - 1 - e, s, cs]
        x = x.at[e].set(d.astype(jnp.int32))
        s_new = jnp.maximum(s - upsilon[e], 0)
        cs_new = next_state[cs, e]
        return (jnp.where(d, s_new, s), jnp.where(d, cs_new, cs), x)

    x0 = jnp.zeros(E, dtype=jnp.int32)
    _, _, x = jax.lax.fori_loop(
        0, E, back_body, (s_star, jnp.int32(tables.full_state), x0))

    new_carry = WarmCarry(ups_f=ups_f, sig_f=sig_f, alw_f=alw_f,
                          ckpts=ckpts, v_final=V, decisions=decisions,
                          valid=jnp.asarray(True))
    # backend-contract sanitization (matches core.solvers): infeasible
    # entries are exactly NEG, not NEG plus accumulated fold offsets
    info = {"s_star": s_star, "value_row": jnp.where(v_row >= 0, v_row, NEG),
            "edges_folded": (E - resume).astype(jnp.int32)}
    return x, info, new_carry

"""Pluggable backends for the per-slot Algorithm-2 solve (paper P4/P5).

Every backend implements one contract::

    solver(upsilon, sigma2, tables, s_cap, s_limit,
           allowed=None, u_max=None) -> (x, info)

with ``x`` the (E,) int32 dispatch vector of Alg.-1 Step 8 and ``info`` a
dict holding ``s_star`` (int32 scalar) and ``value_row`` — the (s_cap+1,)
int32 DP value row with exactly ``dp.NEG`` at budget-infeasible entries.
``u_max`` is an optional static bound on max Υ̂ (``stats.u_max_for_horizon``)
that kernel backends may use to size scratch buffers; it must never change
results, and the reference backend ignores it.
Backends are *bit-exact interchangeable*: identical inputs yield identical
``x``, ``s_star``, and ``value_row`` (the differential-testing harness in
``tests/test_solver_equiv.py`` enforces this against brute force).

Registry:
  reference        — pure-JAX lax.scan over edges, exact int32 values
                     (``core.dp.solve_budgeted_dp``).
  pallas           — the VMEM-resident Pallas kernel
                     (``kernels.budgeted_dp``); compiled on TPU, Pallas
                     interpreter elsewhere (never silently interpreted on
                     real TPU hardware).  Plane tiling (whole-plane vs
                     C-blocked vs the 2-D S×C grid for long horizons, with
                     edge-fused chunks keeping tiles VMEM-resident across
                     ``block_e`` consecutive edges on the blocked paths) is
                     resolved inside the backend from the VMEM budget
                     (``kernels.budgeted_dp.kernel.choose_tiling``) — it is
                     an execution detail invisible at this contract, and
                     never changes results.  Batch-aware
                     (``accepts_batch``): under ``jax.vmap`` the solve
                     core's custom batching rule runs every mapped
                     instance in ONE fleet-batched kernel launch with the
                     DP-table operands shared across the batch.  See
                     ``docs/kernel_pipeline.md`` for the kernel internals.
  pallas_interpret — the same kernel forced through the interpreter on any
                     backend; what differential tests run on CPU CI.
  auto             — TPU → pallas (compiled), CPU/GPU → reference.

Selection: ``get_solver(None)`` consults the ``REPRO_DP_SOLVER`` env var and
falls back to ``auto``; an explicit name in code always wins over the env
var, except that explicit ``"auto"`` lets the env var refine it (so a sweep
declared with the default can be redirected from the shell).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable

import jax
import jax.numpy as jnp

from .dp import NEG, DPTables, solve_budgeted_dp

__all__ = ["SOLVER_ENV_VAR", "SOLVER_NAMES", "Solver", "resolve_solver",
           "get_solver"]

SOLVER_ENV_VAR = "REPRO_DP_SOLVER"
SOLVER_NAMES = ("auto", "reference", "pallas", "pallas_interpret")


def resolve_solver(name: str | None = None,
                   platform: str | None = None) -> str:
    """Resolve a requested backend to a concrete one.

    Returns ``"reference"``, ``"pallas"``, or ``"pallas_interpret"``.
    ``name=None``/``"auto"`` consults ``$REPRO_DP_SOLVER`` first, then picks
    by platform: TPU → compiled pallas, anything else → reference.
    ``platform`` overrides ``jax.default_backend()`` (unit-testable).
    """
    if name is None or name == "auto":
        name = os.environ.get(SOLVER_ENV_VAR) or "auto"
    if name == "auto":
        platform = platform or jax.default_backend()
        name = "pallas" if platform == "tpu" else "reference"
    if name not in ("reference", "pallas", "pallas_interpret"):
        raise ValueError(
            f"unknown DP solver backend {name!r}; choose from {SOLVER_NAMES}")
    return name


@dataclasses.dataclass(frozen=True, eq=False)   # identity hash — jit-static-safe
class Solver:
    """A resolved Algorithm-2 backend (callable with the shared contract)."""

    name: str                    # concrete backend name
    interpret: bool | None       # kernel mode (None = auto); reference: None
    _fn: Callable = dataclasses.field(repr=False)
    accepts_batch: bool = False  # vmap → ONE fleet-batched kernel launch

    def __call__(self, upsilon, sigma2, tables: DPTables, s_cap: int,
                 s_limit, allowed=None, u_max: int | None = None):
        """``u_max`` is an optional static bound on max Υ̂ (e.g. from
        ``stats.u_max_for_horizon``); the Pallas backends use it to shrink
        the kernel's shift scratch, the reference backend ignores it.

        Backends with ``accepts_batch`` carry a custom batching rule on
        the solve core: ``jax.vmap`` of this call dispatches all mapped
        instances through ONE batched kernel launch with the DP-table
        operands shared (never replicated per instance) — results stay
        bit-exact with a per-instance loop.  Other backends vmap
        conventionally (per-instance computation, replicated operands)."""
        return self._fn(upsilon, sigma2, tables, s_cap, s_limit, allowed,
                        u_max)


def _reference_solve(upsilon, sigma2, tables, s_cap, s_limit, allowed,
                     u_max=None):
    del u_max                       # exact scan needs no shift padding
    x, info = solve_budgeted_dp(upsilon, sigma2, tables, s_cap, s_limit,
                                allowed=allowed)
    row = info["value_row"]
    return x, {"s_star": info["s_star"],
               "value_row": jnp.where(row >= 0, row, NEG)}


def _make_pallas_solve(interpret: bool | None):
    from ..kernels.budgeted_dp.ops import solve_budgeted_dp_pallas

    def solve(upsilon, sigma2, tables, s_cap, s_limit, allowed, u_max=None):
        x, info = solve_budgeted_dp_pallas(
            upsilon, sigma2, tables, s_cap, s_limit, u_max=u_max,
            allowed=allowed, interpret=interpret)
        row = info["value_row"]                     # f32, kernel NEG sentinel
        row = jnp.where(row >= 0, row, float(NEG)).astype(jnp.int32)
        return x, {"s_star": info["s_star"], "value_row": row}

    return solve


_CACHE: dict[str, Solver] = {}


def get_solver(name: "str | Solver | None" = None,
               platform: str | None = None) -> Solver:
    """Resolve ``name`` (see :func:`resolve_solver`) and return the Solver.

    Instances are cached per concrete backend, so repeated policy builds
    share one identity (jit-static-friendly)."""
    if isinstance(name, Solver):
        return name
    concrete = resolve_solver(name, platform)
    solver = _CACHE.get(concrete)
    if solver is None:
        if concrete == "reference":
            solver = Solver(name=concrete, interpret=None,
                            _fn=_reference_solve)
        else:
            interpret = True if concrete == "pallas_interpret" else None
            solver = Solver(name=concrete, interpret=interpret,
                            _fn=_make_pallas_solve(interpret),
                            accepts_batch=True)
        _CACHE[concrete] = solver
    return solver

"""Pluggable backends for the per-slot Algorithm-2 solve (paper P4/P5).

Every backend implements one contract::

    solver(upsilon, sigma2, tables, s_cap, s_limit,
           allowed=None, u_max=None) -> (x, info)

with ``x`` the (E,) int32 dispatch vector of Alg.-1 Step 8 and ``info`` a
dict holding ``s_star`` (int32 scalar) and ``value_row`` — the (s_cap+1,)
int32 DP value row with exactly ``dp.NEG`` at budget-infeasible entries.
``u_max`` is an optional static bound on max Υ̂ (``stats.u_max_for_horizon``)
that kernel backends may use to size scratch buffers; it must never change
results, and the reference backend ignores it.
Backends are *bit-exact interchangeable*: identical inputs yield identical
``x``, ``s_star``, and ``value_row`` (the differential-testing harness in
``tests/test_solver_equiv.py`` enforces this against brute force).

Registry:
  reference        — pure-JAX lax.scan over edges, exact int32 values
                     (``core.dp.solve_budgeted_dp``).
  pallas           — the VMEM-resident Pallas kernel
                     (``kernels.budgeted_dp``); compiled on TPU, Pallas
                     interpreter elsewhere (never silently interpreted on
                     real TPU hardware).  Plane tiling (whole-plane vs
                     C-blocked vs the 2-D S×C grid for long horizons, with
                     edge-fused chunks keeping tiles VMEM-resident across
                     ``block_e`` consecutive edges on the blocked paths) is
                     resolved inside the backend from the VMEM budget
                     (``kernels.budgeted_dp.kernel.choose_tiling``) — it is
                     an execution detail invisible at this contract, and
                     never changes results.  Batch-aware
                     (``accepts_batch``): under ``jax.vmap`` the solve
                     core's custom batching rule runs every mapped
                     instance in ONE fleet-batched kernel launch with the
                     DP-table operands shared across the batch.  See
                     ``docs/kernel_pipeline.md`` for the kernel internals.
  pallas_interpret — the same kernel forced through the interpreter on any
                     backend; what differential tests run on CPU CI.
  auto             — TPU → pallas (compiled), CPU/GPU → reference.

Selection: ``get_solver(None)`` consults the ``REPRO_DP_SOLVER`` env var and
falls back to ``auto``; an explicit name in code always wins over the env
var, except that explicit ``"auto"`` lets the env var refine it (so a sweep
declared with the default can be redirected from the shell).  An INVALID
env var value warns and falls back to the ``auto`` resolution (a stale
shell var must not hard-crash policy builds that never asked for a
concrete backend); an invalid name passed in code still raises.

Incremental layer: :class:`CachedSolver` wraps any backend with the
quantized-statistics solve cache (``core.incremental.SolveCache``) —
same call contract, ``accepts_batch`` passthrough, kernel launches
skipped on concrete-input cache hits.  See ``docs/solvers.md``.

Degradation layer: :class:`FallbackSolver` wraps the registry with a
bounded retry chain (pallas → pallas_interpret → reference by default),
catching backend launch failures and rejecting corrupted value planes
(``kernels.budgeted_dp.ops.validate_value_row`` invariants) before
falling through — bit-identical results whichever link serves, because
backends are bit-exact interchangeable.  A deterministic fault-injection
hook (``runtime.fault.planned_fault``, env-togglable via
``$REPRO_DP_FAULT_RATE``) exercises the chain in CI without real
hardware faults.  See ``docs/robustness.md``.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from .dp import NEG, DPTables, solve_budgeted_dp

__all__ = ["SOLVER_ENV_VAR", "SOLVER_NAMES", "Solver", "resolve_solver",
           "get_solver", "CachedSolver", "FallbackSolver"]

SOLVER_ENV_VAR = "REPRO_DP_SOLVER"
SOLVER_NAMES = ("auto", "reference", "pallas", "pallas_interpret")


def _auto_backend(platform: str | None) -> str:
    platform = platform or jax.default_backend()
    return "pallas" if platform == "tpu" else "reference"


def resolve_solver(name: str | None = None, platform: str | None = None) -> str:
    """Resolve a requested backend to a concrete one.

    Returns ``"reference"``, ``"pallas"``, or ``"pallas_interpret"``.
    ``name=None``/``"auto"`` consults ``$REPRO_DP_SOLVER`` first, then picks
    by platform: TPU → compiled pallas, anything else → reference.
    ``platform`` overrides ``jax.default_backend()`` (unit-testable).

    Error handling distinguishes where a bad name came from: an invalid
    name passed IN CODE raises (the caller asked for something that does
    not exist), while an invalid ``$REPRO_DP_SOLVER`` only warns and falls
    back to the ``auto`` resolution — a stale shell var must never crash a
    policy build that requested ``None``/``"auto"``.
    """
    from_env = False
    if name is None or name == "auto":
        env_name = os.environ.get(SOLVER_ENV_VAR) or None
        if env_name is not None:
            name, from_env = env_name, True
        else:
            name = "auto"
    if name == "auto":
        name = _auto_backend(platform)
    if name not in ("reference", "pallas", "pallas_interpret"):
        if from_env:
            warnings.warn(
                f"ignoring invalid {SOLVER_ENV_VAR}={name!r} (choose from "
                f"{SOLVER_NAMES}); falling back to 'auto'",
                RuntimeWarning, stacklevel=2)
            return _auto_backend(platform)
        raise ValueError(
            f"unknown DP solver backend {name!r}; choose from {SOLVER_NAMES}")
    return name


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash — jit-static-safe
class Solver:
    """A resolved Algorithm-2 backend (callable with the shared contract)."""

    name: str  # concrete backend name
    interpret: bool | None  # kernel mode (None = auto); reference: None
    _fn: Callable = dataclasses.field(repr=False)
    accepts_batch: bool = False  # vmap → ONE fleet-batched kernel launch

    def __call__(
        self,
        upsilon,
        sigma2,
        tables: DPTables,
        s_cap: int,
        s_limit,
        allowed=None,
        u_max: int | None = None,
    ):
        """``u_max`` is an optional static bound on max Υ̂ (e.g. from
        ``stats.u_max_for_horizon``); the Pallas backends use it to shrink
        the kernel's shift scratch, the reference backend ignores it.

        Backends with ``accepts_batch`` carry a custom batching rule on
        the solve core: ``jax.vmap`` of this call dispatches all mapped
        instances through ONE batched kernel launch with the DP-table
        operands shared (never replicated per instance) — results stay
        bit-exact with a per-instance loop.  Other backends vmap
        conventionally (per-instance computation, replicated operands)."""
        return self._fn(upsilon, sigma2, tables, s_cap, s_limit, allowed,
                        u_max)


def _reference_solve(upsilon, sigma2, tables, s_cap, s_limit, allowed, u_max=None):
    del u_max  # exact scan needs no shift padding
    x, info = solve_budgeted_dp(upsilon, sigma2, tables, s_cap, s_limit,
                                allowed=allowed)
    row = info["value_row"]
    return x, {"s_star": info["s_star"],
               "value_row": jnp.where(row >= 0, row, NEG)}


def _make_pallas_solve(interpret: bool | None):
    from ..kernels.budgeted_dp.ops import solve_budgeted_dp_pallas

    def solve(upsilon, sigma2, tables, s_cap, s_limit, allowed, u_max=None):
        x, info = solve_budgeted_dp_pallas(
            upsilon, sigma2, tables, s_cap, s_limit, u_max=u_max,
            allowed=allowed, interpret=interpret)
        row = info["value_row"]  # f32, kernel NEG sentinel
        row = jnp.where(row >= 0, row, float(NEG)).astype(jnp.int32)
        return x, {"s_star": info["s_star"], "value_row": row}

    return solve


class CachedSolver:
    """A backend wrapped with the quantized-statistics solve cache.

    Same call contract as :class:`Solver` (and ``accepts_batch`` follows
    the wrapped backend), so it drops into every consumer that takes a
    solver.  The cache is HOST-side: it can only act when the solve inputs
    are concrete arrays.  Calls with traced inputs (inside a caller's
    ``jit``/``scan``/``vmap``) bypass it entirely — correctness is never
    at risk, only the hit opportunity — and are counted in
    ``stats.bypasses``.  Host-loop drivers (``sched.dispatcher``, the
    bench) call it with concrete per-slot statistics and skip the whole
    backend launch on a hit; for in-scan carried memoization use the
    ``cache=`"memo"`` policy mode in ``core.esdp`` instead.

    Batched concrete inputs (``(B, E)`` statistics) are keyed PER ROW —
    instance i's key never aliases instance j's — and the (single)
    batched launch is skipped only when every row hits; any miss solves
    the whole batch and refreshes all rows.

    With the default quanta the cache is EXACT: hits are bit-identical to
    cold solves.  Coarser ``q_ups``/``q_sig`` give bounded-staleness
    approximate reuse (see :class:`repro.core.incremental.SolveCache`);
    ``exact`` exposes which mode this wrapper is in.
    """

    def __init__(
        self,
        base: Solver,
        cache: "SolveCache | None" = None,
        scope: "str | None" = None,
        **cache_kwargs,
    ):
        from .incremental import SolveCache
        self.base = base
        self.cache = cache if cache is not None else SolveCache(**cache_kwargs)
        # consumers owning several wrappers (e.g. one per A/B variant in
        # sched.engine) label each one so its counters can't be confused
        self.scope = scope
        self._jitted: dict = {}

    def stats_dict(self) -> dict:
        """``stats.as_dict()`` plus the ``scope`` label when set."""
        d = self.cache.stats.as_dict()
        if self.scope is not None:
            d["scope"] = self.scope
        return d

    @property
    def name(self) -> str:
        return f"cached:{self.base.name}"

    @property
    def interpret(self):
        return self.base.interpret

    @property
    def accepts_batch(self) -> bool:
        return self.base.accepts_batch

    @property
    def exact(self) -> bool:
        return self.cache.exact

    @property
    def stats(self):
        return self.cache.stats

    def _base_jit(self, tables, s_cap, u_max, batched: bool):
        key = (id(tables), s_cap, u_max, batched)
        fn = self._jitted.get(key)
        if fn is None:
            def single(upsilon, sigma2, s_limit, allowed):
                return self.base(upsilon, sigma2, tables, s_cap, s_limit,
                                 allowed=allowed, u_max=u_max)
            fn = jax.jit(jax.vmap(single) if batched else single)
            self._jitted[key] = fn
        return fn

    def __call__(
        self,
        upsilon,
        sigma2,
        tables: DPTables,
        s_cap: int,
        s_limit,
        allowed=None,
        u_max: int | None = None,
    ):
        if any(isinstance(a, jax.core.Tracer)
               for a in (upsilon, sigma2, s_limit, allowed) if a is not None):
            self.cache.stats.bypasses += 1
            return self.base(upsilon, sigma2, tables, s_cap, s_limit,
                             allowed=allowed, u_max=u_max)

        import numpy as np
        ups = np.asarray(upsilon)
        self.cache.tick()
        if ups.ndim == 1:
            key = self.cache.key(ups, sigma2, allowed, int(s_limit))
            hit = self.cache.get(key)
            if hit is not None:
                self.cache.stats.launches_saved += 1
                return hit
            fn = self._base_jit(tables, s_cap, u_max, batched=False)
            alw = (jnp.ones(ups.shape[0], bool) if allowed is None
                   else jnp.asarray(allowed, bool))
            x, info = fn(jnp.asarray(upsilon), jnp.asarray(sigma2),
                         jnp.asarray(s_limit), alw)
            out = (np.asarray(x),
                   {"s_star": np.asarray(info["s_star"]),
                    "value_row": np.asarray(info["value_row"])})
            self.cache.put(key, out)
            return out

        # batched (B, E): per-row keys; skip the launch only on a full hit
        sig = np.asarray(sigma2)
        slim = np.broadcast_to(np.asarray(s_limit), (ups.shape[0],))
        alw = (np.ones(ups.shape, bool) if allowed is None
               else np.broadcast_to(np.asarray(allowed, bool), ups.shape))
        keys = [self.cache.key(ups[b], sig[b], alw[b], int(slim[b]))
                for b in range(ups.shape[0])]
        hits = [self.cache.get(k) for k in keys]
        if all(h is not None for h in hits):
            self.cache.stats.launches_saved += 1
            x = np.stack([h[0] for h in hits])
            info = {"s_star": np.stack([h[1]["s_star"] for h in hits]),
                    "value_row": np.stack([h[1]["value_row"] for h in hits])}
            return x, info
        fn = self._base_jit(tables, s_cap, u_max, batched=True)
        x, info = fn(jnp.asarray(ups), jnp.asarray(sig),
                     jnp.asarray(slim), jnp.asarray(alw))
        x = np.asarray(x)
        stars, rows = np.asarray(info["s_star"]), np.asarray(info["value_row"])
        for b, k in enumerate(keys):
            self.cache.put(k, (x[b], {"s_star": stars[b],
                                      "value_row": rows[b]}))
        return x, {"s_star": stars, "value_row": rows}


class FallbackSolver:
    """Graceful degradation of the solve path: a bounded backend retry chain.

    The production failure mode this guards is a kernel backend dying or
    corrupting its output at dispatch time — a failed ``pallas_call``
    launch, an OOM, a bad lowering after a toolchain bump, a clamped
    scratch silently poisoning a plane.  Because the registry backends are
    *bit-exact interchangeable* (``tests/test_solver_equiv.py``), any link
    of the chain can serve any solve with identical results, so degrading
    never changes ``x``/``s_star``/``value_row`` — it only costs speed.

    Per concrete-input call the wrapper walks ``chain`` (default: the
    primary backend, then ``pallas_interpret`` if the primary was compiled
    pallas, then ``reference``).  An attempt degrades when

      * the backend RAISES (launch failure — caught and recorded), or
      * the returned value row violates the DP-invariant checks of
        :func:`repro.kernels.budgeted_dp.ops.validate_value_row`
        (NEG-source contract, ``VALUE_BOUND``, feasible-prefix and
        monotone-in-budget checks — theorems of the recurrence, so a
        violation always means corruption, never a legitimate input).

    The LAST link is exempt from fault injection and its exceptions
    propagate: a chain that cannot serve at all is a real outage, not a
    degradation.  Every degradation is recorded as a structured event in
    ``stats["events"]`` and counted in ``stats``; consumers
    (``sched.dispatcher.ClusterSim``, the sweep engine) surface those via
    ``solve_stats``.

    Deterministic fault injection: with ``fault_rate > 0`` (explicit arg,
    else ``$REPRO_DP_FAULT_RATE``), each non-final attempt consults
    :func:`repro.runtime.fault.planned_fault` — a pure function of
    ``(fault_seed, call_index, attempt)`` — and either raises a synthetic
    :class:`repro.runtime.fault.InjectedFault` before launching or poisons
    the returned value row so validation must catch it.  Injection is a
    plan computed per call index, so a run is bit-reproducible and, since
    fallbacks are exact, bit-identical to the fault-free run.

    Host-side like :class:`CachedSolver`: calls with traced inputs bypass
    the chain entirely and run the primary backend (counted in
    ``stats["bypasses"]``) — under ``jit``/``vmap`` the wrapper is
    invisible and adds zero launches (guarded by a jaxpr test).
    ``accepts_batch`` follows the primary; batched (B, E) concrete inputs
    walk the same chain with per-row plane validation.
    """

    def __init__(
        self,
        base: "Solver | str | None" = None,
        chain: "tuple | None" = None,
        fault_rate: "float | None" = None,
        fault_seed: "int | None" = None,
        scope: "str | None" = None,
    ):
        from ..runtime.fault import FAULT_SEED_ENV, fault_rate_from_env
        if chain is not None:
            links = [get_solver(s) for s in chain]
            if not links:
                raise ValueError("FallbackSolver chain must be non-empty")
        else:
            primary = get_solver(base)
            links = [primary]
            if primary.name == "pallas":
                links.append(get_solver("pallas_interpret"))
            if primary.name != "reference":
                links.append(get_solver("reference"))
        self.chain = tuple(links)
        self.base = self.chain[0]
        self.fault_rate = (fault_rate_from_env() if fault_rate is None
                           else float(fault_rate))
        self.fault_seed = (int(os.environ.get(FAULT_SEED_ENV, "0") or 0)
                           if fault_seed is None else int(fault_seed))
        self._jitted: dict = {}
        # scope labels this wrapper's counters when a consumer owns several
        # (e.g. one chain per A/B variant in sched.engine)
        self.scope = scope
        self.stats: dict = {
            "calls": 0, "bypasses": 0, "degraded_calls": 0,
            "launch_failures": 0, "validation_failures": 0,
            "faults_injected": 0, "served_by": {s.name: 0 for s in links},
            "events": [],
        }
        if scope is not None:
            self.stats["scope"] = scope

    def stats_dict(self) -> dict:
        """A detached copy of the counters (scope label included)."""
        import copy as _copy

        d = _copy.deepcopy(self.stats)
        if self.scope is not None:
            d["scope"] = self.scope
        return d

    _MAX_EVENTS = 256  # structured events kept; counters never truncate

    @property
    def name(self) -> str:
        return "fallback:" + "->".join(s.name for s in self.chain)

    @property
    def interpret(self):
        return self.base.interpret

    @property
    def accepts_batch(self) -> bool:
        return self.base.accepts_batch

    def _record(self, **event) -> None:
        ev = self.stats["events"]
        if len(ev) < self._MAX_EVENTS:
            ev.append(event)

    def _link_jit(self, link: Solver, tables, s_cap, u_max, batched: bool):
        key = (link.name, id(tables), s_cap, u_max, batched)
        fn = self._jitted.get(key)
        if fn is None:
            def solve(upsilon, sigma2, s_limit, allowed):
                return link(upsilon, sigma2, tables, s_cap, s_limit,
                            allowed=allowed, u_max=u_max)
            fn = jax.jit(jax.vmap(solve) if batched else solve)
            self._jitted[key] = fn
        return fn

    def __call__(
        self,
        upsilon,
        sigma2,
        tables: DPTables,
        s_cap: int,
        s_limit,
        allowed=None,
        u_max: int | None = None,
    ):
        if any(isinstance(a, jax.core.Tracer)
               for a in (upsilon, sigma2, s_limit, allowed) if a is not None):
            self.stats["bypasses"] += 1
            return self.base(upsilon, sigma2, tables, s_cap, s_limit,
                             allowed=allowed, u_max=u_max)

        import numpy as np

        from ..kernels.budgeted_dp.ops import validate_value_row
        from ..runtime.fault import InjectedFault, planned_fault

        call = self.stats["calls"]
        self.stats["calls"] += 1
        shape = np.shape(upsilon)
        batched = len(shape) == 2
        ups = jnp.asarray(upsilon)
        alw = (np.ones(shape, bool) if allowed is None
               else np.broadcast_to(np.asarray(allowed, bool), shape))
        slim = (np.broadcast_to(np.asarray(s_limit), shape[:1]) if batched
                else np.asarray(s_limit))
        last = len(self.chain) - 1
        for attempt, link in enumerate(self.chain):
            fault = (None if attempt == last else planned_fault(
                call, self.fault_rate, seed=self.fault_seed,
                attempt=attempt))
            try:
                if fault == "launch":
                    self.stats["faults_injected"] += 1
                    raise InjectedFault(
                        f"injected launch failure (call {call}, "
                        f"attempt {attempt}, backend {link.name})")
                fn = self._link_jit(link, tables, s_cap, u_max, batched)
                x, info = fn(ups, jnp.asarray(sigma2),
                             jnp.asarray(slim), jnp.asarray(alw))
                row = np.asarray(info["value_row"])
                if fault == "corrupt":
                    # poison out of the f32-exact domain: validation MUST
                    # reject this row, proving the checks are live
                    self.stats["faults_injected"] += 1
                    row = row.copy()
                    row[..., 0] = 2 ** 24
            except Exception as err:  # noqa: BLE001 — any launch failure degrades
                if attempt == last:
                    raise
                self.stats["launch_failures"] += 1
                self._record(call=call, attempt=attempt, backend=link.name,
                             kind="launch",
                             injected=isinstance(err, InjectedFault),
                             error=f"{type(err).__name__}: {err}")
                continue
            reason = validate_value_row(row)
            if reason is not None:
                if attempt == last:
                    raise RuntimeError(
                        f"DP value plane failed validation on the final "
                        f"chain link {link.name!r}: {reason}")
                self.stats["validation_failures"] += 1
                self._record(call=call, attempt=attempt, backend=link.name,
                             kind="validate", injected=fault == "corrupt",
                             error=reason)
                continue
            if attempt > 0:
                self.stats["degraded_calls"] += 1
            self.stats["served_by"][link.name] += 1
            return (np.asarray(x),
                    {"s_star": np.asarray(info["s_star"]), "value_row": row})
        raise AssertionError("unreachable: final chain link never skips")


_CACHE: dict[str, Solver] = {}


def get_solver(
    name: "str | Solver | None" = None, platform: str | None = None
) -> Solver:
    """Resolve ``name`` (see :func:`resolve_solver`) and return the Solver.

    Instances are cached per concrete backend, so repeated policy builds
    share one identity (jit-static-friendly).  Solver-shaped wrapper
    objects (:class:`CachedSolver`, :class:`FallbackSolver`, or anything
    callable exposing ``name``/``accepts_batch``) pass through unchanged,
    so every consumer that takes ``solver=`` accepts a wrapped chain."""
    if isinstance(name, Solver) or (
            callable(name) and hasattr(name, "accepts_batch")
            and hasattr(name, "name")):
        return name
    concrete = resolve_solver(name, platform)
    solver = _CACHE.get(concrete)
    if solver is None:
        if concrete == "reference":
            solver = Solver(name=concrete, interpret=None,
                            _fn=_reference_solve)
        else:
            interpret = True if concrete == "pallas_interpret" else None
            solver = Solver(name=concrete, interpret=interpret,
                            _fn=_make_pallas_solve(interpret),
                            accepts_batch=True)
        _CACHE[concrete] = solver
    return solver

"""ESDP — Efficient Sampling-based Dynamic Programming (paper Algorithm 1).

A policy is a pair (init, step) consumed by env.simulate inside one
``lax.scan``; the shared observation statistics (n, Σz̃) live in the env carry
and are passed to step as (vhat, n).  ``step`` receives two masks:
``eligible`` (E,) — channels dispatchable this slot (port arrival ∧ server
alive, the scenario-aware Ω(t)) — and ``arrived`` (L,) — raw port arrivals,
which waiting-time policies need even when a port's channels are all dead.

The per-slot Algorithm-2 solve is pluggable: ``solver=`` names a backend
from ``core.solvers`` ("reference" | "pallas" | "pallas_interpret" |
"auto"/None — TPU → compiled Pallas kernel, CPU/GPU → reference scan, env
var ``REPRO_DP_SOLVER`` overrides).  Backends are bit-exact interchangeable.

Incremental re-solves (``cache=``): after the exploration phase the scaled
statistics drift slowly, so consecutive solves are near-duplicates.  Two
scan-carried modes exploit that WITHOUT leaving the jitted horizon scan:

  ``cache="memo"`` — a 1-entry exact memo: when this slot's (Υ̂, Σ̂²,
    eligibility, s_limit) equal the previous slot's, reuse the previous x
    through ``lax.cond`` (a real skip under the sequential scan; under
    ``vmap`` the cond lowers to a select — both branches run, results stay
    bit-identical).  Works with every backend.
  ``cache="warm"`` — carry the previous solve's checkpointed value planes
    and re-fold only from the first changed edge
    (``core.incremental.solve_budgeted_dp_warm``); requires the reference
    backend (the Pallas warm path is the host-driven
    ``kernels.budgeted_dp.ops.WarmPallasSolver``, used by
    ``sched.dispatcher``).

Both modes are bit-identical to ``cache=None`` and count their activity in
the policy state; ``Policy.finalize`` maps the final state (returned by the
env as ``SimResult.policy_final``) to a solve-stats dict for sweep columns.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import stats as stats_mod
from .dp import DPTables, build_tables
from .graph import Instance
from .incremental import solve_budgeted_dp_warm, warm_carry_init
from .solvers import Solver, get_solver

__all__ = ["Policy", "PolicyFactory", "make_esdp_policy", "esdp_factory"]

CACHE_MODES = (None, "memo", "warm")


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash — jit-static-safe
class Policy:
    name: str
    init: Callable[[], Any]
    # (state, t, eligible, arrived, vhat, n, key) -> (x, state)
    step: Callable[..., tuple]
    # optional: final policy state (concrete) -> solve-stats dict
    finalize: "Callable[[Any], dict] | None" = None


# Uniform constructor signature consumed by the sweep engine
# (repro.experiments.sweep): factory(instance, T, tables) -> Policy.
# Factories with ``accepts_solver = True`` additionally take a keyword
# ``solver=`` so SweepSpec can redirect the Algorithm-2 backend.
PolicyFactory = Callable[[Instance, int, "DPTables | None"], Policy]


def make_esdp_policy(
    instance: Instance,
    T: int,
    delta_fn=stats_mod.delta_default,
    g_fn=stats_mod.g_default,
    tables: DPTables | None = None,
    solver: "str | Solver | None" = None,
    cache: "str | None" = None,
    cache_checkpoint_every: int = 8,
) -> Policy:
    """Build the ESDP policy for an instance over horizon T.

    Follows Algorithm 1 literally: scale statistics with δ(t) (Step 3),
    solve {P4(s,t)} by the DP and pick s* (Steps 4–8, Algorithm 2), then
    zero channels of ports with no arrival (Steps 9–16, constraint (2)).
    ``solver`` selects the Algorithm-2 backend (see ``core.solvers``);
    resolution happens once, at policy-build time.  ``cache`` selects an
    incremental re-solve mode (``None`` | ``"memo"`` | ``"warm"``, see the
    module docstring) — both modes are bit-identical to ``cache=None``;
    ``cache_checkpoint_every`` is the warm path's fold-checkpoint spacing.
    """
    if cache not in CACHE_MODES:
        raise ValueError(
            f"unknown cache mode {cache!r}; choose from {CACHE_MODES}")
    if tables is None:
        tables = build_tables(instance.A, instance.c)
    solve = get_solver(solver)
    m = instance.m
    E = int(instance.A.shape[1])
    s_cap = stats_mod.s_cap_for_horizon(T, m, delta_fn)
    # tight static shift bound for the Pallas kernel scratch (Υ̂ ≤ ξ(T))
    u_max = stats_mod.u_max_for_horizon(T, m, delta_fn)

    def scaled(vhat, n, t):
        upsilon, sigma2, _, s_limit = stats_mod.scale_statistics(
            vhat, n, t, m, g_fn=g_fn, delta_fn=delta_fn)
        return upsilon, sigma2, s_limit

    if cache is None:
        def init():
            return ()  # all ESDP state is the shared (n, Σz̃) env carry

        def step(state, t, eligible, arrived, vhat, n, key):
            del arrived  # eligibility already folds in arrivals/aliveness
            upsilon, sigma2, s_limit = scaled(vhat, n, t)
            x, _ = solve(upsilon, sigma2, tables, s_cap, s_limit,
                         allowed=eligible, u_max=u_max)
            x = x * eligible.astype(jnp.int32)  # Alg.1 Steps 9–16
            return x, state

        return Policy(name="esdp", init=init, step=step)

    if cache == "memo":
        def init():
            return (jnp.zeros(E, jnp.int32), jnp.zeros(E, jnp.int32),
                    jnp.zeros(E, bool), jnp.int32(0),  # prev inputs
                    jnp.zeros(E, jnp.int32),  # prev x
                    jnp.asarray(False),  # valid
                    jnp.int32(0), jnp.int32(0))  # hits, solves

        def step(state, t, eligible, arrived, vhat, n, key):
            del arrived
            p_ups, p_sig, p_alw, p_slim, p_x, valid, hits, solves = state
            upsilon, sigma2, s_limit = scaled(vhat, n, t)
            same = (valid & jnp.all(upsilon == p_ups)
                    & jnp.all(sigma2 == p_sig)
                    & jnp.all(eligible == p_alw) & (s_limit == p_slim))

            def hit(_):
                return p_x

            def miss(_):
                x, _ = solve(upsilon, sigma2, tables, s_cap, s_limit,
                             allowed=eligible, u_max=u_max)
                return x

            x = jax.lax.cond(same, hit, miss, None)
            x = x * eligible.astype(jnp.int32)
            state = (upsilon, sigma2, eligible, s_limit, x,
                     jnp.asarray(True), hits + same.astype(jnp.int32),
                     solves + 1)
            return x, state

        def finalize(final_state):
            hits, solves = (int(final_state[6]), int(final_state[7]))
            return {"cache_hits": hits, "cache_solves": solves,
                    "cache_hit_rate": hits / solves if solves else 0.0}

        return Policy(name="esdp", init=init, step=step, finalize=finalize)

    # cache == "warm": the in-scan checkpoint-resumed reference path.  The
    # Pallas backends launch whole kernels per solve — their warm variant
    # is the host-driven WarmPallasSolver, which cannot live inside a scan.
    if solve.name != "reference":
        raise ValueError(
            'cache="warm" carries value-plane checkpoints through the '
            "horizon scan and is implemented for the 'reference' backend; "
            f"got {solve.name!r}. Use cache=\"memo\" (any backend) or the "
            "host-loop WarmPallasSolver in sched.dispatcher instead.")
    k = int(cache_checkpoint_every)

    def init():
        return (warm_carry_init(E, s_cap, tables.n_states, k),
                jnp.int32(0), jnp.int32(0))  # edges folded, solves

    def step(state, t, eligible, arrived, vhat, n, key):
        del arrived
        carry, folded, solves = state
        upsilon, sigma2, s_limit = scaled(vhat, n, t)
        x, info, carry = solve_budgeted_dp_warm(
            upsilon, sigma2, tables, s_cap, s_limit, carry,
            allowed=eligible, checkpoint_every=k)
        x = x * eligible.astype(jnp.int32)
        return x, (carry, folded + info["edges_folded"], solves + 1)

    def finalize(final_state):
        folded, solves = int(final_state[1]), int(final_state[2])
        total = solves * E
        return {"edges_folded": folded, "cache_solves": solves,
                "edge_skip_rate": 1.0 - folded / total if total else 0.0}

    return Policy(name="esdp", init=init, step=step, finalize=finalize)


def esdp_factory(**overrides) -> PolicyFactory:
    """Sweep-consumable factory: ``esdp_factory(g_fn=...)(inst, T, tables)``.

    ``overrides`` are forwarded to :func:`make_esdp_policy` (``delta_fn``,
    ``g_fn``, ``solver``, ``cache``); the horizon and DP tables come from the
    sweep grid point.  A ``solver=``/``cache=`` passed at call time (e.g.
    from ``SweepSpec``) applies unless the factory itself pinned one.
    """
    def make(
        instance: Instance,
        T: int,
        tables: DPTables | None = None,
        solver: "str | Solver | None" = None,
        cache: "str | None" = None,
    ) -> Policy:
        kw = dict(overrides)
        if solver is not None and "solver" not in kw:
            kw["solver"] = solver
        if cache is not None and "cache" not in kw:
            kw["cache"] = cache
        return make_esdp_policy(instance, T, tables=tables, **kw)

    make.policy_name = "esdp"
    make.accepts_solver = True
    make.accepts_cache = True
    return make

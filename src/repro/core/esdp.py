"""ESDP — Efficient Sampling-based Dynamic Programming (paper Algorithm 1).

A policy is a pair (init, step) consumed by env.simulate inside one
``lax.scan``; the shared observation statistics (n, Σz̃) live in the env carry
and are passed to step as (vhat, n).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from . import stats as stats_mod
from .dp import DPTables, build_tables, solve_budgeted_dp
from .graph import Instance

__all__ = ["Policy", "make_esdp_policy"]


@dataclasses.dataclass(frozen=True, eq=False)   # identity hash — jit-static-safe
class Policy:
    name: str
    init: Callable[[], Any]
    step: Callable[..., tuple]   # (state, t, arrived, vhat, n, key) -> (x, state)


def make_esdp_policy(
    instance: Instance,
    T: int,
    delta_fn=stats_mod.delta_default,
    g_fn=stats_mod.g_default,
    tables: DPTables | None = None,
) -> Policy:
    """Build the ESDP policy for an instance over horizon T.

    Follows Algorithm 1 literally: scale statistics with δ(t) (Step 3),
    solve {P4(s,t)} by the DP and pick s* (Steps 4–8, Algorithm 2), then
    zero channels of ports with no arrival (Steps 9–16, constraint (2)).
    """
    if tables is None:
        tables = build_tables(instance.A, instance.c)
    m = instance.m
    s_cap = stats_mod.s_cap_for_horizon(T, m, delta_fn)
    port_of_edge = jnp.asarray(instance.port_of_edge)

    def init():
        return ()   # all ESDP state is the shared (n, Σz̃) in the env carry

    def step(state, t, arrived, vhat, n, key):
        upsilon, sigma2, _, s_limit = stats_mod.scale_statistics(
            vhat, n, t, m, g_fn=g_fn, delta_fn=delta_fn)
        x, _ = solve_budgeted_dp(upsilon, sigma2, tables, s_cap, s_limit,
                                 allowed=arrived[port_of_edge])
        x = x * arrived[port_of_edge].astype(jnp.int32)    # Alg. 1 Steps 9–16
        return x, state

    return Policy(name="esdp", init=init, step=step)

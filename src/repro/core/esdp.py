"""ESDP — Efficient Sampling-based Dynamic Programming (paper Algorithm 1).

A policy is a pair (init, step) consumed by env.simulate inside one
``lax.scan``; the shared observation statistics (n, Σz̃) live in the env carry
and are passed to step as (vhat, n).  ``step`` receives two masks:
``eligible`` (E,) — channels dispatchable this slot (port arrival ∧ server
alive, the scenario-aware Ω(t)) — and ``arrived`` (L,) — raw port arrivals,
which waiting-time policies need even when a port's channels are all dead.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from . import stats as stats_mod
from .dp import DPTables, build_tables, solve_budgeted_dp
from .graph import Instance

__all__ = ["Policy", "PolicyFactory", "make_esdp_policy", "esdp_factory"]


@dataclasses.dataclass(frozen=True, eq=False)   # identity hash — jit-static-safe
class Policy:
    name: str
    init: Callable[[], Any]
    step: Callable[..., tuple]   # (state, t, eligible, arrived, vhat, n, key) -> (x, state)


# Uniform constructor signature consumed by the sweep engine
# (repro.experiments.sweep): factory(instance, T, tables) -> Policy.
PolicyFactory = Callable[[Instance, int, "DPTables | None"], Policy]


def make_esdp_policy(
    instance: Instance,
    T: int,
    delta_fn=stats_mod.delta_default,
    g_fn=stats_mod.g_default,
    tables: DPTables | None = None,
) -> Policy:
    """Build the ESDP policy for an instance over horizon T.

    Follows Algorithm 1 literally: scale statistics with δ(t) (Step 3),
    solve {P4(s,t)} by the DP and pick s* (Steps 4–8, Algorithm 2), then
    zero channels of ports with no arrival (Steps 9–16, constraint (2)).
    """
    if tables is None:
        tables = build_tables(instance.A, instance.c)
    m = instance.m
    s_cap = stats_mod.s_cap_for_horizon(T, m, delta_fn)

    def init():
        return ()   # all ESDP state is the shared (n, Σz̃) in the env carry

    def step(state, t, eligible, arrived, vhat, n, key):
        del arrived  # eligibility already folds in arrivals (and aliveness)
        upsilon, sigma2, _, s_limit = stats_mod.scale_statistics(
            vhat, n, t, m, g_fn=g_fn, delta_fn=delta_fn)
        x, _ = solve_budgeted_dp(upsilon, sigma2, tables, s_cap, s_limit,
                                 allowed=eligible)
        x = x * eligible.astype(jnp.int32)                 # Alg. 1 Steps 9–16
        return x, state

    return Policy(name="esdp", init=init, step=step)


def esdp_factory(**overrides) -> PolicyFactory:
    """Sweep-consumable factory: ``esdp_factory(g_fn=...)(inst, T, tables)``.

    ``overrides`` are forwarded to :func:`make_esdp_policy` (``delta_fn``,
    ``g_fn``); the horizon and DP tables come from the sweep grid point.
    """
    def make(instance: Instance, T: int, tables: DPTables | None = None) -> Policy:
        return make_esdp_policy(instance, T, tables=tables, **overrides)

    make.policy_name = "esdp"
    return make

"""ESDP — Efficient Sampling-based Dynamic Programming (paper Algorithm 1).

A policy is a pair (init, step) consumed by env.simulate inside one
``lax.scan``; the shared observation statistics (n, Σz̃) live in the env carry
and are passed to step as (vhat, n).  ``step`` receives two masks:
``eligible`` (E,) — channels dispatchable this slot (port arrival ∧ server
alive, the scenario-aware Ω(t)) — and ``arrived`` (L,) — raw port arrivals,
which waiting-time policies need even when a port's channels are all dead.

The per-slot Algorithm-2 solve is pluggable: ``solver=`` names a backend
from ``core.solvers`` ("reference" | "pallas" | "pallas_interpret" |
"auto"/None — TPU → compiled Pallas kernel, CPU/GPU → reference scan, env
var ``REPRO_DP_SOLVER`` overrides).  Backends are bit-exact interchangeable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

from . import stats as stats_mod
from .dp import DPTables, build_tables
from .graph import Instance
from .solvers import Solver, get_solver

__all__ = ["Policy", "PolicyFactory", "make_esdp_policy", "esdp_factory"]


@dataclasses.dataclass(frozen=True, eq=False)   # identity hash — jit-static-safe
class Policy:
    name: str
    init: Callable[[], Any]
    # (state, t, eligible, arrived, vhat, n, key) -> (x, state)
    step: Callable[..., tuple]


# Uniform constructor signature consumed by the sweep engine
# (repro.experiments.sweep): factory(instance, T, tables) -> Policy.
# Factories with ``accepts_solver = True`` additionally take a keyword
# ``solver=`` so SweepSpec can redirect the Algorithm-2 backend.
PolicyFactory = Callable[[Instance, int, "DPTables | None"], Policy]


def make_esdp_policy(
    instance: Instance,
    T: int,
    delta_fn=stats_mod.delta_default,
    g_fn=stats_mod.g_default,
    tables: DPTables | None = None,
    solver: "str | Solver | None" = None,
) -> Policy:
    """Build the ESDP policy for an instance over horizon T.

    Follows Algorithm 1 literally: scale statistics with δ(t) (Step 3),
    solve {P4(s,t)} by the DP and pick s* (Steps 4–8, Algorithm 2), then
    zero channels of ports with no arrival (Steps 9–16, constraint (2)).
    ``solver`` selects the Algorithm-2 backend (see ``core.solvers``);
    resolution happens once, at policy-build time.
    """
    if tables is None:
        tables = build_tables(instance.A, instance.c)
    solve = get_solver(solver)
    m = instance.m
    s_cap = stats_mod.s_cap_for_horizon(T, m, delta_fn)
    # tight static shift bound for the Pallas kernel scratch (Υ̂ ≤ ξ(T))
    u_max = stats_mod.u_max_for_horizon(T, m, delta_fn)

    def init():
        return ()   # all ESDP state is the shared (n, Σz̃) in the env carry

    def step(state, t, eligible, arrived, vhat, n, key):
        del arrived  # eligibility already folds in arrivals (and aliveness)
        upsilon, sigma2, _, s_limit = stats_mod.scale_statistics(
            vhat, n, t, m, g_fn=g_fn, delta_fn=delta_fn)
        x, _ = solve(upsilon, sigma2, tables, s_cap, s_limit,
                     allowed=eligible, u_max=u_max)
        x = x * eligible.astype(jnp.int32)                 # Alg. 1 Steps 9–16
        return x, state

    return Policy(name="esdp", init=init, step=step)


def esdp_factory(**overrides) -> PolicyFactory:
    """Sweep-consumable factory: ``esdp_factory(g_fn=...)(inst, T, tables)``.

    ``overrides`` are forwarded to :func:`make_esdp_policy` (``delta_fn``,
    ``g_fn``, ``solver``); the horizon and DP tables come from the sweep grid
    point.  A ``solver=`` passed at call time (e.g. from ``SweepSpec.solver``)
    applies unless the factory itself pinned one.
    """
    def make(instance: Instance, T: int, tables: DPTables | None = None,
             solver: "str | Solver | None" = None) -> Policy:
        kw = dict(overrides)
        if solver is not None and "solver" not in kw:
            kw["solver"] = solver
        return make_esdp_policy(instance, T, tables=tables, **kw)

    make.policy_name = "esdp"
    make.accepts_solver = True
    return make

"""The paper's contribution: ESDP dispatching of multi-server jobs.

Public API:
  generate_instance / Instance          — bipartite-graph problem instances
  build_tables / solve_budgeted_dp      — Algorithm 2 (budgeted DP, reference)
  get_solver / resolve_solver / Solver  — pluggable Algorithm-2 backends
                                          (reference | pallas | auto)
  CachedSolver / SolveCache             — quantized-statistics solve cache
  solve_budgeted_dp_warm / WarmCarry    — warm-started (checkpoint-resumed)
                                          re-solves across slots
  make_esdp_policy / esdp_factory       — Algorithm 1 (ESDP)
  make_hswf_policy / make_lcf_policy / make_lwtf_policy — paper baselines
  hswf_factory / lcf_factory / lwtf_factory — sweep-consumable constructors
  simulate / simulate_batch / SimResult — the EASW simulation environment
  Scenario / default_scenario           — pluggable generative regimes
                                          (registry: repro.experiments)
"""
from .baselines import (hswf_factory, lcf_factory, lwtf_factory,
                        make_hswf_policy, make_lcf_policy, make_lwtf_policy)
from .dp import DPTables, build_tables, oracle_knapsack, solve_budgeted_dp
from .env import (Scenario, SimResult, default_scenario, simulate,
                  simulate_batch, simulate_grid)
from .esdp import Policy, PolicyFactory, esdp_factory, make_esdp_policy
from .graph import Instance, generate_instance
from .incremental import (CacheStats, SolveCache, WarmCarry,
                          solve_budgeted_dp_warm, warm_carry_init)
from .solvers import (SOLVER_NAMES, CachedSolver, Solver, get_solver,
                      resolve_solver)
from . import stats

__all__ = [
    "Instance", "generate_instance",
    "DPTables", "build_tables", "solve_budgeted_dp", "oracle_knapsack",
    "SOLVER_NAMES", "Solver", "get_solver", "resolve_solver",
    "CachedSolver", "SolveCache", "CacheStats",
    "WarmCarry", "warm_carry_init", "solve_budgeted_dp_warm",
    "Policy", "PolicyFactory", "make_esdp_policy", "esdp_factory",
    "make_hswf_policy", "make_lcf_policy", "make_lwtf_policy",
    "hswf_factory", "lcf_factory", "lwtf_factory",
    "Scenario", "default_scenario",
    "SimResult", "simulate", "simulate_batch", "simulate_grid", "stats",
]

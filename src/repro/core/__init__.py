"""The paper's contribution: ESDP dispatching of multi-server jobs.

Public API:
  generate_instance / Instance          — bipartite-graph problem instances
  build_tables / solve_budgeted_dp      — Algorithm 2 (budgeted DP)
  make_esdp_policy                      — Algorithm 1 (ESDP)
  make_hswf_policy / make_lcf_policy / make_lwtf_policy — paper baselines
  simulate / SimResult                  — the EASW simulation environment
"""
from .baselines import make_hswf_policy, make_lcf_policy, make_lwtf_policy
from .dp import DPTables, build_tables, oracle_knapsack, solve_budgeted_dp
from .env import SimResult, simulate
from .esdp import Policy, make_esdp_policy
from .graph import Instance, generate_instance
from . import stats

__all__ = [
    "Instance", "generate_instance",
    "DPTables", "build_tables", "solve_budgeted_dp", "oracle_knapsack",
    "Policy", "make_esdp_policy",
    "make_hswf_policy", "make_lcf_policy", "make_lwtf_policy",
    "SimResult", "simulate", "stats",
]

"""Simulation environment for the EASW maximization problem (paper Sec. 2).

One jitted ``lax.scan`` over the horizon: draw arrivals ~ Bernoulli(ρ_l) and
net valuations z̃_e(t) = clip(N(μ_e·speed_r(t) − cost_e, σ_e), 0, 1), ask the
policy for x(t), enforce constraint (2), realize SW(x(t)) = Σ_e x_e·z̃_e
(eq. 4), update the shared observation statistics, and account the per-slot
regret against the omniscient oracle x*(t) (eq. 5–6).

The generative regime — how arrival intensities, processing speeds, and
server aliveness evolve over time — is pluggable through the ``Scenario``
protocol below.  The default scenario (constant unit speeds, constant ρ, all
servers alive) reproduces the paper's iid-Gaussian setting bit-for-bit; the
named fluctuation regimes (Markov-modulated DVFS, bursty MMPP arrivals,
chronic stragglers, transient brownouts, elastic outages) live in
``repro.experiments.scenarios`` and are consumed both here and by
``repro.sched.dispatcher`` — one scenario interface for both simulators.

Batched evaluation: ``simulate_batch`` vmaps the whole scan over a seed
batch (one jitted call per (policy × scenario × grid-point)), and
``repro.experiments.sweep`` adds a ``lax.map`` over scenario-parameter
grids on top.  This is what replaces the per-seed Python loops the
benchmarks used to run.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .dp import DPTables, build_tables, oracle_knapsack
from .esdp import Policy
from .graph import Instance

__all__ = [
    "Scenario", "default_scenario", "SimResult",
    "simulate", "simulate_batch", "simulate_grid", "crash_events",
]

# Salt folded into the simulation key to derive the scenario's private PRNG
# chain.  Keeping the chains separate means *adding* a stochastic scenario
# never perturbs the arrival/valuation/policy streams of the base seed —
# paired comparisons across scenarios stay paired.
_SCENARIO_SALT = 0x5CE


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash — jit-static-safe
class Scenario:
    """A named generative regime for arrivals and processing speeds.

    ``init(params, key, n_servers) -> state`` builds the scenario's carry
    (e.g. Markov regime indicators plus a private PRNG key); ``step(params,
    state, t, n_servers) -> (state, arr_scale, speed, alive)`` advances it one
    slot and emits:

      arr_scale: scalar or (L,) f32 — multiplies the instance's ρ (clipped to
        [0, 1]); models bursty / modulated arrival processes.
      speed:     (R,) f32 — per-server processing-speed multiplier; the mean
        net valuation of channel e = (l, r) becomes μ_e·speed_r − cost_e
        (the paper's "fluctuated processing speeds").
      alive:     (R,) bool — dead servers make their channels infeasible
        (elastic scale-down/up; the dispatcher's ``allowed`` mask).

    ``params`` is a pytree of scalars/arrays and is passed *traced*, so sweeps
    can ``lax.map`` over stacked parameter grids without recompiling.
    ``fluctuates`` must be True iff ``speed`` can differ from 1: it switches
    the regret oracle from the precomputed true means to per-slot clipped
    means (a static branch — each scenario compiles its own jaxpr).

    ``speed_bounds`` is the regime's declared (lo, hi) envelope for every
    emitted per-server speed — a *contract*, not a hint: the scenario
    contract suite (``tests/test_scenario_contracts.py``) asserts each
    registered regime's realized speeds stay inside its declared bounds.
    Builders derive it from their resolved parameters (e.g. ``markov_dvfs``
    declares ``(slow_speed, 1.0)``); the default ``(1.0, 1.0)`` is the
    non-fluctuating contract.
    """

    name: str
    init: Callable[..., Any]
    step: Callable[..., tuple]
    params: dict = dataclasses.field(default_factory=dict)
    fluctuates: bool = False
    description: str = ""
    speed_bounds: tuple = (1.0, 1.0)


def _default_init(params, key, n_servers):
    return ()


def _default_step(params, state, t, n_servers):
    return (state, jnp.float32(1.0), jnp.ones(n_servers, jnp.float32),
            jnp.ones(n_servers, dtype=bool))


def default_scenario() -> Scenario:
    """The paper's baseline regime: iid-Gaussian valuations, constant ρ,
    unit speeds, every server alive.  Multiplying by the emitted unit scales
    is IEEE-exact, so this reproduces the pre-Scenario simulator bit-for-bit.
    """
    return Scenario(
        name="iid",
        init=_default_init,
        step=_default_step,
        fluctuates=False,
        description="iid clipped-Gaussian valuations at constant unit speed "
                    "(paper Sec. 5 baseline setting)",
    )


def crash_events(alive):
    """(T, R) bool: server r crashed DURING slot t.

    The aliveness trace encodes crashes as up→down transitions: a server
    that was alive when slot t dispatched but is dead at slot t+1 died
    mid-slot, so work dispatched onto it in slot t is at risk (the
    failure-aware runtime in ``sched.dispatcher`` uses exactly this
    coupling — the ``server_failures`` scenario emits ``alive`` BEFORE
    applying the slot's crash draws so the transition is observable).
    The final slot has no successor to compare against and reports no
    crashes.  Host-side numpy helper; pure in the trace.
    """
    alive = np.asarray(alive, dtype=bool)
    out = np.zeros_like(alive)
    out[:-1] = alive[:-1] & ~alive[1:]
    return out


_DEFAULT_SCENARIO = default_scenario()

_SQRT2 = math.sqrt(2.0)
_INV_SQRT_2PI = 1.0 / math.sqrt(2.0 * math.pi)


def _clipped_normal_mean_jnp(m, s, lo=0.0, hi=1.0):
    """E[clip(N(m, s), lo, hi)] — traced counterpart of
    ``graph.clipped_normal_mean`` for per-slot fluctuated oracle means."""
    s = jnp.maximum(s, 1e-6)
    a = (lo - m) / s
    b = (hi - m) / s
    phi_a = _INV_SQRT_2PI * jnp.exp(-0.5 * a * a)
    phi_b = _INV_SQRT_2PI * jnp.exp(-0.5 * b * b)
    Phi_a = 0.5 * (1.0 + jax.scipy.special.erf(a / _SQRT2))
    Phi_b = 0.5 * (1.0 + jax.scipy.special.erf(b / _SQRT2))
    inner = m * (Phi_b - Phi_a) - s * (phi_b - phi_a)
    return lo * Phi_a + hi * (1.0 - Phi_b) + inner


@dataclasses.dataclass(frozen=True)
class SimResult:
    """Per-slot traces.  Arrays are (T,) for ``simulate`` and gain leading
    batch axes — (S, T) for ``simulate_batch``, (G, S, T) for parameter
    grids — with all derived quantities accumulating along the last axis."""

    sw: np.ndarray  # (..., T) realized social welfare per slot
    sw_oracle: np.ndarray  # (..., T) oracle expected welfare ṽᵀx*(t)
    regret: np.ndarray  # (..., T) ṽᵀx*(t) − ṽᵀx(t)  (expected per-slot gap)
    n_dispatched: np.ndarray  # (..., T) ‖x(t)‖₁
    # final policy state (numpy pytree, batch axes as above) — e.g. the
    # incremental-solve counters that Policy.finalize turns into stats
    policy_final: Any = None

    @property
    def asw(self) -> np.ndarray:
        return np.cumsum(self.sw, axis=-1)

    @property
    def cum_regret(self) -> np.ndarray:
        return np.cumsum(self.regret, axis=-1)


def _run_impl(
    policy: Policy,
    T: int,
    tables: DPTables,
    scenario: Scenario,
    n_servers: int,
    arrays,
    key,
    scn_params,
):
    v_true, mu, sigma, cost, rho, port, server = arrays
    E = v_true.shape[0]
    L = rho.shape[0]

    scn_state0 = scenario.init(scn_params, jax.random.fold_in(
        key, _SCENARIO_SALT), n_servers)

    def slot(carry, t):
        n, sumz, pstate, sstate, key = carry
        key, k_arr, k_val, k_pol = jax.random.split(key, 4)

        sstate, arr_scale, speed, alive = scenario.step(
            scn_params, sstate, t, n_servers)
        rho_t = jnp.clip(rho * arr_scale, 0.0, 1.0)
        arrived = jax.random.uniform(k_arr, (L,)) < rho_t
        mean_e = mu * speed[server] - cost
        z = jnp.clip(mean_e + sigma * jax.random.normal(k_val, (E,)), 0.0, 1.0)
        eligible = arrived[port] & alive[server]

        vhat = jnp.where(n > 0, sumz / jnp.maximum(n, 1).astype(jnp.float32), 0.0)
        x, pstate = policy.step(pstate, t.astype(jnp.float32), eligible,
                                arrived, vhat, n, k_pol)
        x = x * eligible.astype(jnp.int32)  # constraint (2)

        xf = x.astype(jnp.float32)
        sw = jnp.sum(xf * z)  # realized SW (eq. 4)
        if scenario.fluctuates:  # static branch
            v_t = _clipped_normal_mean_jnp(mean_e, sigma)
        else:
            v_t = v_true
        x_star, sw_star = oracle_knapsack(v_t, tables, eligible)
        regret = sw_star - jnp.sum(xf * v_t)  # expected gap (eq. 5)

        n = n + x
        sumz = sumz + xf * z
        return (n, sumz, pstate, sstate, key), (sw, sw_star, regret, jnp.sum(x))

    carry0 = (jnp.zeros(E, jnp.int32), jnp.zeros(E, jnp.float32),
              policy.init(), scn_state0, key)
    ts = jnp.arange(1, T + 1)
    carry, (sw, sw_star, regret, nd) = jax.lax.scan(slot, carry0, ts)
    return (sw, sw_star, regret, nd), carry[2]  # traces + final policy state


_STATIC = ("policy", "T", "tables", "scenario", "n_servers")

_run = functools.partial(jax.jit, static_argnames=_STATIC)(_run_impl)


@functools.partial(jax.jit, static_argnames=_STATIC)
def _run_batch(policy, T, tables, scenario, n_servers, arrays, keys, scn_params):
    """One jitted call: vmap the whole horizon scan over a seed batch."""
    return jax.vmap(
        lambda k: _run_impl(policy, T, tables, scenario, n_servers, arrays, k,
                            scn_params))(keys)


@functools.partial(jax.jit, static_argnames=_STATIC)
def _run_param_grid(
    policy, T, tables, scenario, n_servers, arrays, keys, stacked_params
):
    """lax.map over a stacked scenario-parameter grid of vmapped seed
    batches — one compilation covers the whole (grid × seeds) sweep."""
    def one(params):
        return jax.vmap(
            lambda k: _run_impl(policy, T, tables, scenario, n_servers,
                                arrays, k, params))(keys)
    return jax.lax.map(one, stacked_params)


def _instance_arrays(instance: Instance):
    return (
        jnp.asarray(instance.v), jnp.asarray(instance.mu),
        jnp.asarray(instance.sigma), jnp.asarray(instance.cost),
        jnp.asarray(instance.rho), jnp.asarray(instance.port_of_edge),
        jnp.asarray(instance.edges[:, 1].astype(np.int32)),
    )


def _scenario_args(instance, tables, scenario):
    if tables is None:
        tables = build_tables(instance.A, instance.c)
    if scenario is None:
        scenario = _DEFAULT_SCENARIO
    params = jax.tree.map(jnp.asarray, scenario.params)
    return tables, scenario, params


def simulate(
    instance: Instance,
    policy: Policy,
    T: int,
    seed: int = 0,
    tables: DPTables | None = None,
    scenario: Scenario | None = None,
) -> SimResult:
    """Run one policy for T slots; identical seeds ⇒ identical arrival and
    valuation streams across policies (paired comparison, as in the paper).
    ``scenario=None`` uses the paper's iid baseline regime."""
    tables, scenario, params = _scenario_args(instance, tables, scenario)
    key = jax.random.PRNGKey(seed)
    (sw, sw_star, regret, nd), pfinal = _run(
        policy, T, tables, scenario, instance.n_servers,
        _instance_arrays(instance), key, params)
    return SimResult(
        sw=np.asarray(sw), sw_oracle=np.asarray(sw_star),
        regret=np.asarray(regret), n_dispatched=np.asarray(nd),
        policy_final=jax.tree.map(np.asarray, pfinal))


def simulate_grid(
    instance: Instance,
    policy: Policy,
    T: int,
    seeds,
    scenario: Scenario,
    stacked_params,
    tables: DPTables | None = None,
) -> SimResult:
    """Sweep a scenario-parameter grid in one jitted call: ``lax.map`` over
    the stacked parameter axis wrapping the vmapped seed batch.

    ``stacked_params`` must match ``scenario.params`` in structure with every
    leaf gaining a leading grid axis of the same length G; the scenario's
    state/output shapes must not depend on parameter *values* (true for all
    registered scenarios).  Returns a SimResult of shape (G, len(seeds), T).
    """
    if tables is None:
        tables = build_tables(instance.A, instance.c)
    stacked = jax.tree.map(jnp.asarray, stacked_params)
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    (sw, sw_star, regret, nd), pfinal = _run_param_grid(
        policy, T, tables, scenario, instance.n_servers,
        _instance_arrays(instance), keys, stacked)
    return SimResult(
        sw=np.asarray(sw), sw_oracle=np.asarray(sw_star),
        regret=np.asarray(regret), n_dispatched=np.asarray(nd),
        policy_final=jax.tree.map(np.asarray, pfinal))


def simulate_batch(
    instance: Instance,
    policy: Policy,
    T: int,
    seeds,
    tables: DPTables | None = None,
    scenario: Scenario | None = None,
) -> SimResult:
    """Vectorized ``simulate`` over a seed batch: one jitted vmapped call.

    Returns a SimResult whose arrays have shape (len(seeds), T).  Row i is
    decision-identical to ``simulate(..., seed=seeds[i])``: the dispatch
    vectors, oracle values, and regret match bit-for-bit (identical PRNG
    streams per key).  The realized-welfare slot sums Σ_e x_e·z̃_e may differ
    in the last float32 ulp only, because XLA reorders the E-way reduction
    when it vectorizes over the batch axis.

    With a batch-aware DP backend (``Solver.accepts_batch`` — the Pallas
    backends), the vmap over seeds triggers the solve core's custom
    batching rule: each slot issues ONE fleet-batched kernel launch for
    the whole seed batch, with the DP-table operands shared across seeds
    rather than replicated per instance."""
    tables, scenario, params = _scenario_args(instance, tables, scenario)
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    (sw, sw_star, regret, nd), pfinal = _run_batch(
        policy, T, tables, scenario, instance.n_servers,
        _instance_arrays(instance), keys, params)
    return SimResult(
        sw=np.asarray(sw), sw_oracle=np.asarray(sw_star),
        regret=np.asarray(regret), n_dispatched=np.asarray(nd),
        policy_final=jax.tree.map(np.asarray, pfinal))

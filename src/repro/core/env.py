"""Simulation environment for the EASW maximization problem (paper Sec. 2).

One jitted ``lax.scan`` over the horizon: draw arrivals ~ Bernoulli(ρ_l) and
net valuations z̃_e(t) = clip(N(μ_e − cost_e, σ_e), 0, 1), ask the policy for
x(t), enforce constraint (2), realize SW(x(t)) = Σ_e x_e·z̃_e (eq. 4), update
the shared observation statistics, and account the per-slot regret against
the omniscient oracle x*(t) (eq. 5–6).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .dp import DPTables, build_tables, oracle_knapsack
from .esdp import Policy
from .graph import Instance

__all__ = ["SimResult", "simulate"]


@dataclasses.dataclass(frozen=True)
class SimResult:
    sw: np.ndarray          # (T,) realized social welfare per slot
    sw_oracle: np.ndarray   # (T,) oracle expected welfare ṽᵀx*(t)
    regret: np.ndarray      # (T,) ṽᵀx*(t) − ṽᵀx(t)  (expected per-slot gap)
    n_dispatched: np.ndarray  # (T,) ‖x(t)‖₁

    @property
    def asw(self) -> np.ndarray:
        return np.cumsum(self.sw)

    @property
    def cum_regret(self) -> np.ndarray:
        return np.cumsum(self.regret)


@functools.partial(jax.jit, static_argnames=("policy", "T", "tables"))
def _run(policy: Policy, T: int, tables: DPTables, arrays, key):
    v_true, mu, sigma, cost, rho, port = arrays
    E = v_true.shape[0]
    L = rho.shape[0]

    def slot(carry, t):
        n, sumz, pstate, key = carry
        key, k_arr, k_val, k_pol = jax.random.split(key, 4)
        arrived = jax.random.uniform(k_arr, (L,)) < rho
        z = jnp.clip(
            mu - cost + sigma * jax.random.normal(k_val, (E,)), 0.0, 1.0)

        vhat = jnp.where(n > 0, sumz / jnp.maximum(n, 1).astype(jnp.float32), 0.0)
        x, pstate = policy.step(pstate, t.astype(jnp.float32), arrived, vhat, n,
                                k_pol)
        x = x * arrived[port].astype(jnp.int32)            # constraint (2)

        xf = x.astype(jnp.float32)
        sw = jnp.sum(xf * z)                               # realized SW (eq. 4)
        x_star, sw_star = oracle_knapsack(v_true, tables, arrived[port])
        regret = sw_star - jnp.sum(xf * v_true)            # expected gap (eq. 5)

        n = n + x
        sumz = sumz + xf * z
        return (n, sumz, pstate, key), (sw, sw_star, regret, jnp.sum(x))

    carry0 = (jnp.zeros(E, jnp.int32), jnp.zeros(E, jnp.float32),
              policy.init(), key)
    ts = jnp.arange(1, T + 1)
    _, (sw, sw_star, regret, nd) = jax.lax.scan(slot, carry0, ts)
    return sw, sw_star, regret, nd


def simulate(instance: Instance, policy: Policy, T: int, seed: int = 0,
             tables: DPTables | None = None) -> SimResult:
    """Run one policy for T slots; identical seeds ⇒ identical arrival and
    valuation streams across policies (paired comparison, as in the paper)."""
    if tables is None:
        tables = build_tables(instance.A, instance.c)
    arrays = (
        jnp.asarray(instance.v), jnp.asarray(instance.mu),
        jnp.asarray(instance.sigma), jnp.asarray(instance.cost),
        jnp.asarray(instance.rho), jnp.asarray(instance.port_of_edge),
    )
    key = jax.random.PRNGKey(seed)
    sw, sw_star, regret, nd = _run(policy, T, tables, arrays, key)
    return SimResult(
        sw=np.asarray(sw), sw_oracle=np.asarray(sw_star),
        regret=np.asarray(regret), n_dispatched=np.asarray(nd))

"""Evolving statistics of ESDP (paper eqs. 7–15).

All schedules take a (possibly traced) time ``t`` (1-based) and return jnp
scalars, so the whole simulation can live inside one ``lax.scan``.

Integer-domain bounds (why int32 is exact here):
  Υ̂_e = ⌈ξ v̂_e⌉ ≤ ξ                      (v̂ ∈ [0,1])
  Σ̂²_e = ⌈ξ² g/(2n)⌉ ≤ ⌈ξ² g/2⌉          (n ≥ 1)
  With the default schedules at T = 10⁵: ξ ≲ 60·m and g ≲ 200, so
  Σ̂² ≲ 2.1e5·m² and the UNEXPLORED bonus (m+1)·⌈ξ²g/2⌉ with DP sums over
  ‖x‖₁ ≤ Σ_k c_k stays far below 2³¹ for every configuration we run.
  The DP therefore uses exact int32 arithmetic (no float accumulation error),
  which is also the natural datatype for the TPU VPU — see kernels/budgeted_dp.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "delta_default", "delta_fast", "delta_slow",
    "g_default", "g_no_logt", "g_logt_only",
    "xi_of", "s_cap_for_horizon", "u_max_for_horizon",
    "horizon_for_s_cap", "scale_statistics",
    "DELTA_VARIANTS", "G_VARIANTS",
]

# --------------------------------------------------------------------------
# δ(t) — converge-to-zero relaxation sequence (paper eq. 11 & Fig. 7 variants)
# --------------------------------------------------------------------------

def delta_fast(t):
    """(ln(t+1)+1)^-1 — fastest decay."""
    return 1.0 / (jnp.log(t + 1.0) + 1.0)


def delta_default(t):
    """(ln(ln(t+1)+1)+1)^-1 — the paper's default."""
    return 1.0 / (jnp.log(jnp.log(t + 1.0) + 1.0) + 1.0)


def delta_slow(t):
    """(ln(ln(ln(t+1)+1)+1)+1)^-1 — slowest decay."""
    return 1.0 / (jnp.log(jnp.log(jnp.log(t + 1.0) + 1.0) + 1.0) + 1.0)


DELTA_VARIANTS: dict[str, Callable] = {
    "fast": delta_fast, "default": delta_default, "slow": delta_slow,
}


# Host-side float64 mirrors of the registered schedules.  The sizing
# helpers below evaluate δ at STATIC horizons up to t_max = 10¹², far past
# the f32-exact integer range (2²⁴): jnp.float32(T) collapses ≈ 2¹⁷-wide
# plateaus of horizons onto one value there, which made
# ``horizon_for_s_cap`` return a plateau edge instead of the true
# threshold.  Pure ``math`` keeps the host path exact (f64) and jax-free.

def _delta_fast_host(t: float) -> float:
    return 1.0 / (math.log(t + 1.0) + 1.0)


def _delta_default_host(t: float) -> float:
    return 1.0 / (math.log(math.log(t + 1.0) + 1.0) + 1.0)


def _delta_slow_host(t: float) -> float:
    return 1.0 / (math.log(math.log(math.log(t + 1.0) + 1.0) + 1.0) + 1.0)


_DELTA_HOST: dict[Callable, Callable[[float], float]] = {
    delta_fast: _delta_fast_host,
    delta_default: _delta_default_host,
    delta_slow: _delta_slow_host,
}

# --------------------------------------------------------------------------
# g(t) — exploration scale (paper eq. 10 & Fig. 8 variants); m = ⌈α|E|⌉
# --------------------------------------------------------------------------

def g_default(t, m):
    """ln(t+1) + 4 ln(ln(t+1)+1)·m — the paper's default experimental g."""
    return jnp.log(t + 1.0) + 4.0 * jnp.log(jnp.log(t + 1.0) + 1.0) * m


def g_no_logt(t, m):
    """4 ln(ln(t+1)+1)·m."""
    return 4.0 * jnp.log(jnp.log(t + 1.0) + 1.0) * m


def g_logt_only(t, m):
    """ln(t+1) — the variant the paper found 'overwhelmingly' best (Fig. 8)."""
    return jnp.log(t + 1.0)


G_VARIANTS: dict[str, Callable] = {
    "default": g_default, "no_logt": g_no_logt, "logt_only": g_logt_only,
}

# --------------------------------------------------------------------------
# ξ(t) and scaled statistics (paper eqs. 13–15)
# --------------------------------------------------------------------------

def xi_of(t, m, delta_fn=delta_default):
    """ξ(t) = ⌈m / δ(t)⌉ (paper eq. 15)."""
    return jnp.ceil(m / delta_fn(t)).astype(jnp.int32)


def _delta_at_host(T: int, delta_fn=delta_default) -> float:
    """δ(T) evaluated host-side in float64.

    Registered schedules use their pure-``math`` mirrors; custom schedules
    are evaluated under ``jax.experimental.enable_x64`` so a python-int
    horizon survives intact (``jnp.float32(T)`` is exact only below 2²⁴ —
    the old f32 path made the T ↦ ξ(T) map constant across ≈ 2¹⁷-wide
    plateaus near t_max and mislocated every threshold inside one)."""
    host = _DELTA_HOST.get(delta_fn)
    if host is not None:
        return host(float(T))
    with jax.experimental.enable_x64():
        return float(delta_fn(jnp.float64(T)))


def _xi_at_horizon(T: int, m: int, delta_fn=delta_default) -> int:
    """ξ(T) as a host-side static int — the max of ξ(t) over t ≤ T (δ
    decreasing ⇒ ξ increasing ⇒ maximum at t = T).  Evaluated in float64
    (see :func:`_delta_at_host`) so horizons above 2²⁴ stay exact."""
    return int(math.ceil(m / _delta_at_host(T, delta_fn)))


def s_cap_for_horizon(T: int, m: int, delta_fn=delta_default) -> int:
    """Static bound on max_t ξ(t)·m over a horizon."""
    return _xi_at_horizon(T, m, delta_fn) * int(m)


def u_max_for_horizon(T: int, m: int, delta_fn=delta_default) -> int:
    """Static bound on max_{t,e} Υ̂_e(t) + 1 over a horizon.

    Υ̂_e = ⌈ξ(t)·v̂_e⌉ ≤ ξ(t) ≤ ξ(T) because v̂ ∈ [0,1] (env clips z̃).  The +1
    keeps the kernel's shift-padding contract with margin.  This is the
    tight shift-scratch height for the Pallas budgeted-DP kernel: ξ(T)+1
    rows instead of the always-safe s_cap+1 = ξ(T)·m+1 — an m-fold
    reduction of the pad at default horizons.
    """
    return _xi_at_horizon(T, m, delta_fn) + 1


def horizon_for_s_cap(
    s_cap: int, m: int, delta_fn=delta_default, t_max: int = 10 ** 12
) -> "int | None":
    """Smallest horizon T ≤ ``t_max`` whose budget axis reaches ``s_cap``
    (inverse of :func:`s_cap_for_horizon`, which is nondecreasing in T
    because δ decays).  Sizing helper for the S-tiled DP pipeline: it
    answers "what sampling horizon does an S = s_cap + 1 value plane
    correspond to?" — e.g. the S = 4096/8192 benchmark configs.

    Returns ``None`` when even ``t_max`` does not reach ``s_cap``: because
    ξ grows only logarithmically, a given S is reachable at sane horizons
    only for large-enough m (s_cap ≈ ξ(T)·m ≳ m²), and the log-log default
    δ would otherwise push the doubling search past f32 range.  Returns 1
    if T = 1 already reaches ``s_cap``; doubling + bisection, O(log T)
    host calls.
    """
    if s_cap_for_horizon(1, m, delta_fn) >= s_cap:
        return 1
    lo, hi = 1, 2
    while s_cap_for_horizon(hi, m, delta_fn) < s_cap:
        if hi >= t_max:
            return None  # even t_max itself falls short
        lo, hi = hi, min(hi * 2, t_max)
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        if s_cap_for_horizon(mid, m, delta_fn) < s_cap:
            lo = mid
        else:
            hi = mid
    return hi


def scale_statistics(vhat, n, t, m, g_fn=g_default, delta_fn=delta_default):
    """Compute (Υ̂, Σ̂², ξ, s_limit) at time t — eqs. (13)–(15).

    Unexplored channels (n=0) get a finite *dominance* bonus
    ``UNEXP = (m+1)·⌈ξ²g/2⌉`` instead of the paper's +∞: any feasible set
    containing an unexplored channel then strictly beats any set without one
    (the DP objective is a sum of ≤ m terms each ≤ ⌈ξ²g/2⌉), preserving the
    forced-exploration semantics in exact int32 (DESIGN.md §4).
    """
    xi = xi_of(t, m, delta_fn)
    g = g_fn(t, m)
    xif = xi.astype(jnp.float32)
    upsilon = jnp.ceil(xif * vhat).astype(jnp.int32)
    max_explored = jnp.ceil(xif * xif * g / 2.0).astype(jnp.int32)
    sigma2_explored = jnp.ceil(
        xif * xif * g / (2.0 * jnp.maximum(n, 1).astype(jnp.float32))
    ).astype(jnp.int32)
    unexp = (m + 1) * max_explored
    sigma2 = jnp.where(n > 0, sigma2_explored, unexp)
    s_limit = xi * m
    return upsilon, sigma2, xi, s_limit

"""Bipartite graph model for multi-server job dispatching (paper Sec. 2).

Ports (left vertices) are job types; servers (right vertices) hold devices.
An edge (l, r) is a *channel*: type-l jobs may be served by server r, with a
per-channel device requirement vector ``A[:, e]`` over the K device types and
a cluster-wide capacity vector ``c`` (constraint (1) of the paper).

Everything here is host-side numpy; the JAX solvers consume the arrays.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["Instance", "generate_instance", "clipped_normal_mean"]


def _phi(z: float) -> float:
    return math.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


def _Phi(z: float) -> float:
    return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))


def clipped_normal_mean(m: float, s: float, lo: float = 0.0, hi: float = 1.0) -> float:
    """Exact mean of clip(N(m, s), lo, hi) — the true channel valuation mean.

    The paper normalizes the net valuations Z̃ into [0,1] "W.O.L.G."; we clip
    and use the *clipped* mean as the ground truth ṽ so the omniscient oracle
    and the regret accounting are exactly consistent with what policies see.
    """
    if s <= 0.0:
        return min(max(m, lo), hi)
    a = (lo - m) / s
    b = (hi - m) / s
    pa, pb = _Phi(a), _Phi(b)
    mid = pb - pa
    # E[X | a<=Z<=b] * P(...) for X = m + s Z
    inner = m * mid - s * (_phi(b) - _phi(a))
    return lo * pa + hi * (1.0 - pb) + inner


@dataclasses.dataclass(frozen=True)
class Instance:
    """A generated dispatching problem (paper Table 2 parameterization)."""

    n_ports: int  # |L|
    n_servers: int  # |R|
    edges: np.ndarray  # (E, 2) int32 — (l, r) per channel
    A: np.ndarray  # (K, E) int32 — device requirements per channel
    c: np.ndarray  # (K,) int32 — cluster-wide capacities
    cost: np.ndarray  # (E,) float32 — Σ_k f_k(a_k^e), the supply cost
    mu: np.ndarray  # (E,) float32 — gross valuation means (pre-clip)
    sigma: np.ndarray  # (E,) float32 — valuation noise std (= mu/2)
    v: np.ndarray  # (E,) float32 — TRUE net means
                                  #   ṽ = E[clip(N(mu-cost, sigma), 0, 1)]
    rho: np.ndarray  # (L,) float32 — per-port arrival probabilities
    alpha: float  # m = ceil(alpha * |E|) (paper's g(t)/ξ(t) scale)

    @property
    def n_edges(self) -> int:
        return int(self.edges.shape[0])

    @property
    def n_device_types(self) -> int:
        return int(self.A.shape[0])

    @property
    def m(self) -> int:
        """The paper's max_t max_{x∈Ω} ‖x‖₁ surrogate: ⌈α|E|⌉."""
        return max(1, int(math.ceil(self.alpha * self.n_edges)))

    @property
    def port_of_edge(self) -> np.ndarray:
        return self.edges[:, 0].astype(np.int32)

    def edges_of_port(self, port: int) -> np.ndarray:
        return np.nonzero(self.edges[:, 0] == port)[0]


def generate_instance(
    seed: int = 0,
    n_ports: int = 8,
    n_servers: int = 40,
    edge_prob: float = 0.1,
    n_device_types: int = 3,
    a_lo: int = 1,
    a_hi: int = 2,
    c_lo: int = 1,
    c_hi: int = 2,
    rho: float = 0.9,
    alpha: float = 0.5,
    cost_scale: float | None = None,
) -> Instance:
    """Generate an instance with the paper's Table-2 defaults.

    ``A`` entries ~ U{a_lo..a_hi}, capacities ~ U{c_lo..c_hi} (clipped so every
    channel is individually feasible), edges ~ Bernoulli(edge_prob) with at
    least one channel per port, μ ~ U[0.1, 1], σ = μ/2, f_k(a) = w_k·a with
    w_k ~ |N(0.5, 0.1)| rescaled so the mean channel cost is ~0.3 (the paper
    normalizes Z̃ into [0,1] without specifying the cost scale).
    """
    rng = np.random.default_rng(seed)
    K = n_device_types

    adj = rng.random((n_ports, n_servers)) < edge_prob
    for port in range(n_ports):  # every port keeps at least one channel
        if not adj[port].any():
            adj[port, rng.integers(n_servers)] = True
    ls, rs = np.nonzero(adj)
    edges = np.stack([ls, rs], axis=1).astype(np.int32)
    E = edges.shape[0]

    c = rng.integers(c_lo, c_hi + 1, size=K).astype(np.int32)
    A = rng.integers(a_lo, a_hi + 1, size=(K, E)).astype(np.int32)
    A = np.minimum(A, c[:, None])  # edge exists ⇒ solely servable (Sec 2.1 cond. 2)

    w = np.abs(rng.normal(0.5, 0.1, size=K)).astype(np.float32)
    raw_cost = (w[:, None] * A).sum(axis=0)
    if cost_scale is None:
        cost_scale = 0.3 / max(float(raw_cost.mean()), 1e-9)
    cost = (raw_cost * cost_scale).astype(np.float32)

    mu = rng.uniform(0.1, 1.0, size=E).astype(np.float32)
    sigma = (mu / 2.0).astype(np.float32)
    v = np.array(
        [clipped_normal_mean(float(mu[e] - cost[e]), float(sigma[e]))
         for e in range(E)],
        dtype=np.float32,
    )

    return Instance(
        n_ports=n_ports,
        n_servers=n_servers,
        edges=edges,
        A=A,
        c=c,
        cost=cost,
        mu=mu,
        sigma=sigma,
        v=v,
        rho=np.full(n_ports, rho, dtype=np.float32),
        alpha=alpha,
    )

"""Handcrafted baseline policies from paper Sec. 4.1: HSWF, LCF, LWTF.

All three estimate Z̃ by the average of historical observations (the shared
(n, Σz̃) carry) and then dispatch greedily by a ranking until capacity (1)
blocks. The paper ranks *ports* and is silent on channel choice within a
port; we rank edges lexicographically (port-rank, then estimated value),
which is the natural edge-level refinement (DESIGN.md §8.4). Greedy skips
infeasible edges and keeps scanning (charitable variant — a stronger
baseline than stop-at-first-violation), and rank ties are broken uniformly
at random each slot (otherwise an all-zero initial estimate deterministically
locks a greedy policy onto one arbitrary channel forever — clearly not the
paper's intent for its strongest baseline).

``*_factory`` helpers expose each baseline through the uniform
``PolicyFactory`` signature the sweep engine consumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .esdp import Policy, PolicyFactory
from .graph import Instance

__all__ = [
    "make_hswf_policy", "make_lcf_policy", "make_lwtf_policy", "greedy_pack",
    "make_msr_greedy_policy", "make_msr_index_policy",
    "hswf_factory", "lcf_factory", "lwtf_factory",
    "msr_greedy_factory", "msr_index_factory",
]


def greedy_pack(scores, eligible, A, c):
    """Greedily set x_e = 1 in descending score order under A x ≤ c.

    scores: (E,) f32; eligible: (E,) bool; A: (K,E) i32; c: (K,) i32.
    """
    E = scores.shape[0]
    order = jnp.argsort(jnp.where(eligible, scores, -jnp.inf))[::-1]

    def body(j, carry):
        cap, x = carry
        e = order[j]
        ok = eligible[e] & jnp.all(cap >= A[:, e])
        x = x.at[e].set(ok.astype(jnp.int32))
        cap = cap - jnp.where(ok, A[:, e], 0)
        return cap, x

    _, x = jax.lax.fori_loop(
        0, E, body, (c, jnp.zeros(E, dtype=jnp.int32)))
    return x


def _common(instance: Instance):
    A = jnp.asarray(instance.A)
    c = jnp.asarray(instance.c)
    port = jnp.asarray(instance.port_of_edge)
    cost = jnp.asarray(instance.cost)
    return A, c, port, cost


def _tiebreak(key, E, scale):
    if scale == 0.0:
        return jnp.zeros(E, dtype=jnp.float32)
    return jax.random.uniform(key, (E,)) * scale


def make_hswf_policy(instance: Instance, tiebreak: float = 1e-4) -> Policy:
    """Highest (estimated) Social Welfare First.

    ``tiebreak=0`` gives the paper-literal deterministic variant (which locks
    onto one channel under all-zero initial estimates).
    """
    A, c, _, _ = _common(instance)
    E = instance.n_edges

    def step(state, t, eligible, arrived, vhat, n, key):
        return greedy_pack(vhat + _tiebreak(key, E, tiebreak), eligible, A, c), state

    return Policy(name="hswf", init=lambda: (), step=step)


def make_lcf_policy(instance: Instance, tiebreak: float = 1e-4) -> Policy:
    """Lowest Cost First (ascending supply cost Σ_k f_k(a_k^e))."""
    A, c, _, cost = _common(instance)
    E = instance.n_edges

    def step(state, t, eligible, arrived, vhat, n, key):
        return greedy_pack(-cost + _tiebreak(key, E, tiebreak), eligible, A, c), state

    return Policy(name="lcf", init=lambda: (), step=step)


def make_lwtf_policy(instance: Instance, tiebreak: float = 1e-4) -> Policy:
    """Longest Waiting Time First (port-level priority, value tiebreak)."""
    A, c, port, _ = _common(instance)
    L = instance.n_ports
    E = instance.n_edges

    def init():
        return jnp.zeros(L, dtype=jnp.int32)  # waiting slots per port

    def step(waiting, t, eligible, arrived, vhat, n, key):
        # lexicographic: waiting time dominates, v̂ breaks ties within a port
        score = (waiting[port].astype(jnp.float32) * 1e3 + vhat
                 + _tiebreak(key, E, tiebreak))
        x = greedy_pack(score, eligible, A, c)
        served = jnp.zeros(L, dtype=bool).at[port].max(x > 0)
        waiting = jnp.where(served, 0, waiting + arrived.astype(jnp.int32))
        return x, waiting

    return Policy(name="lwtf", init=init, step=step)


# ---------------------------------------------------------------------------
# Markovian-service-rate baselines (arXiv:2412.08915)
#
# Both policies model each *server*'s effective service rate as a slowly
# mixing Markov chain and track a per-server rate estimate ŝ_r alongside the
# shared per-edge value estimates.  The policy interface never exposes
# realized observations directly, but it passes both v̂ (running mean) and n
# (observation count) — so the newest observations on edge e are
# reconstructible exactly as (n·v̂ − n_prev·v̂_prev) / (n − n_prev).  Each
# slot the mean new observation is compared to the previous estimate v̂_prev
# (an obs/expectation ratio ≈ the server's current relative speed), folded
# into ŝ_r by an EMA; servers with no fresh observation mean-revert toward 1
# (the chain mixes back to its stationary regime).  MSR-greedy ranks edges by
# v̂·ŝ_r; MSR-index adds a UCB exploration bonus c·√(log(t+1)/(n+1)).
# ---------------------------------------------------------------------------

def _msr_common(instance: Instance):
    A, c, _, _ = _common(instance)
    server = jnp.asarray(instance.edges[:, 1], jnp.int32)
    return A, c, server


def _msr_init(instance: Instance):
    E, R = instance.n_edges, instance.n_servers
    return (jnp.zeros(E, jnp.float32),  # previous v̂
            jnp.zeros(E, jnp.int32),  # previous n
            jnp.ones(R, jnp.float32))  # per-server rate estimate ŝ


def _msr_update(state, vhat, n, server, n_servers, ema, revert):
    """Fold this slot's fresh observations into the per-server rate chain."""
    prev_vhat, prev_n, shat = state
    dn = (n - prev_n).astype(jnp.float32)
    seen = dn > 0
    # mean of the observations that landed on e since last slot
    obs = jnp.where(
        seen,
        (n.astype(jnp.float32) * vhat
         - prev_n.astype(jnp.float32) * prev_vhat) / jnp.maximum(dn, 1.0),
        0.0)
    # obs vs the *pre-observation* estimate ≈ realized relative speed
    base = jnp.maximum(jnp.where(prev_n > 0, prev_vhat, vhat), 1e-3)
    ratio = jnp.clip(obs / base, 0.0, 2.0)
    cnt = jnp.zeros(n_servers, jnp.float32).at[server].add(
        seen.astype(jnp.float32))
    rsum = jnp.zeros(n_servers, jnp.float32).at[server].add(
        jnp.where(seen, ratio, 0.0))
    robs = rsum / jnp.maximum(cnt, 1.0)
    shat = jnp.where(cnt > 0,
                     (1.0 - ema) * shat + ema * robs,
                     shat + revert * (1.0 - shat))
    return (vhat, n, shat), shat


def make_msr_greedy_policy(
    instance: Instance,
    ema: float = 0.35,
    revert: float = 0.1,
    tiebreak: float = 1e-4,
) -> Policy:
    """MSR-greedy: rank edges by v̂ · ŝ_server (certainty-equivalent greedy
    against the tracked Markovian rate state)."""
    A, c, server = _msr_common(instance)
    E, R = instance.n_edges, instance.n_servers

    def step(state, t, eligible, arrived, vhat, n, key):
        state, shat = _msr_update(state, vhat, n, server, R, ema, revert)
        score = vhat * shat[server] + _tiebreak(key, E, tiebreak)
        return greedy_pack(score, eligible, A, c), state

    return Policy(name="msr_greedy", init=lambda: _msr_init(instance),
                  step=step)


def make_msr_index_policy(
    instance: Instance,
    ema: float = 0.35,
    revert: float = 0.1,
    ucb: float = 0.15,
    tiebreak: float = 1e-4,
) -> Policy:
    """MSR-index: v̂ · ŝ_server plus a UCB bonus c·√(log(t+1)/(n+1)) — the
    index variant that keeps probing channels whose rate chain may have
    drifted since they were last observed."""
    A, c, server = _msr_common(instance)
    E, R = instance.n_edges, instance.n_servers

    def step(state, t, eligible, arrived, vhat, n, key):
        state, shat = _msr_update(state, vhat, n, server, R, ema, revert)
        bonus = ucb * jnp.sqrt(
            jnp.log(t.astype(jnp.float32) + 1.0)
            / (n.astype(jnp.float32) + 1.0))
        score = vhat * shat[server] + bonus + _tiebreak(key, E, tiebreak)
        return greedy_pack(score, eligible, A, c), state

    return Policy(name="msr_index", init=lambda: _msr_init(instance),
                  step=step)


def _factory(make, name: str, tiebreak: float) -> PolicyFactory:
    def factory(instance: Instance, T: int, tables=None) -> Policy:
        del T, tables  # greedy baselines are horizon-free and DP-free
        return make(instance, tiebreak=tiebreak)

    factory.policy_name = name
    return factory


def hswf_factory(tiebreak: float = 1e-4) -> PolicyFactory:
    return _factory(make_hswf_policy, "hswf", tiebreak)


def lcf_factory(tiebreak: float = 1e-4) -> PolicyFactory:
    return _factory(make_lcf_policy, "lcf", tiebreak)


def lwtf_factory(tiebreak: float = 1e-4) -> PolicyFactory:
    return _factory(make_lwtf_policy, "lwtf", tiebreak)


def msr_greedy_factory(tiebreak: float = 1e-4, **kw) -> PolicyFactory:
    def factory(instance: Instance, T: int, tables=None) -> Policy:
        del T, tables
        return make_msr_greedy_policy(instance, tiebreak=tiebreak, **kw)

    factory.policy_name = "msr_greedy"
    return factory


def msr_index_factory(tiebreak: float = 1e-4, **kw) -> PolicyFactory:
    def factory(instance: Instance, T: int, tables=None) -> Policy:
        del T, tables
        return make_msr_index_policy(instance, tiebreak=tiebreak, **kw)

    factory.policy_name = "msr_index"
    return factory

"""Handcrafted baseline policies from paper Sec. 4.1: HSWF, LCF, LWTF.

All three estimate Z̃ by the average of historical observations (the shared
(n, Σz̃) carry) and then dispatch greedily by a ranking until capacity (1)
blocks. The paper ranks *ports* and is silent on channel choice within a
port; we rank edges lexicographically (port-rank, then estimated value),
which is the natural edge-level refinement (DESIGN.md §8.4). Greedy skips
infeasible edges and keeps scanning (charitable variant — a stronger
baseline than stop-at-first-violation), and rank ties are broken uniformly
at random each slot (otherwise an all-zero initial estimate deterministically
locks a greedy policy onto one arbitrary channel forever — clearly not the
paper's intent for its strongest baseline).

``*_factory`` helpers expose each baseline through the uniform
``PolicyFactory`` signature the sweep engine consumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .esdp import Policy, PolicyFactory
from .graph import Instance

__all__ = [
    "make_hswf_policy", "make_lcf_policy", "make_lwtf_policy", "greedy_pack",
    "hswf_factory", "lcf_factory", "lwtf_factory",
]


def greedy_pack(scores, eligible, A, c):
    """Greedily set x_e = 1 in descending score order under A x ≤ c.

    scores: (E,) f32; eligible: (E,) bool; A: (K,E) i32; c: (K,) i32.
    """
    E = scores.shape[0]
    order = jnp.argsort(jnp.where(eligible, scores, -jnp.inf))[::-1]

    def body(j, carry):
        cap, x = carry
        e = order[j]
        ok = eligible[e] & jnp.all(cap >= A[:, e])
        x = x.at[e].set(ok.astype(jnp.int32))
        cap = cap - jnp.where(ok, A[:, e], 0)
        return cap, x

    _, x = jax.lax.fori_loop(
        0, E, body, (c, jnp.zeros(E, dtype=jnp.int32)))
    return x


def _common(instance: Instance):
    A = jnp.asarray(instance.A)
    c = jnp.asarray(instance.c)
    port = jnp.asarray(instance.port_of_edge)
    cost = jnp.asarray(instance.cost)
    return A, c, port, cost


def _tiebreak(key, E, scale):
    if scale == 0.0:
        return jnp.zeros(E, dtype=jnp.float32)
    return jax.random.uniform(key, (E,)) * scale


def make_hswf_policy(instance: Instance, tiebreak: float = 1e-4) -> Policy:
    """Highest (estimated) Social Welfare First.

    ``tiebreak=0`` gives the paper-literal deterministic variant (which locks
    onto one channel under all-zero initial estimates).
    """
    A, c, _, _ = _common(instance)
    E = instance.n_edges

    def step(state, t, eligible, arrived, vhat, n, key):
        return greedy_pack(vhat + _tiebreak(key, E, tiebreak), eligible, A, c), state

    return Policy(name="hswf", init=lambda: (), step=step)


def make_lcf_policy(instance: Instance, tiebreak: float = 1e-4) -> Policy:
    """Lowest Cost First (ascending supply cost Σ_k f_k(a_k^e))."""
    A, c, _, cost = _common(instance)
    E = instance.n_edges

    def step(state, t, eligible, arrived, vhat, n, key):
        return greedy_pack(-cost + _tiebreak(key, E, tiebreak), eligible, A, c), state

    return Policy(name="lcf", init=lambda: (), step=step)


def make_lwtf_policy(instance: Instance, tiebreak: float = 1e-4) -> Policy:
    """Longest Waiting Time First (port-level priority, value tiebreak)."""
    A, c, port, _ = _common(instance)
    L = instance.n_ports
    E = instance.n_edges

    def init():
        return jnp.zeros(L, dtype=jnp.int32)  # waiting slots per port

    def step(waiting, t, eligible, arrived, vhat, n, key):
        # lexicographic: waiting time dominates, v̂ breaks ties within a port
        score = (waiting[port].astype(jnp.float32) * 1e3 + vhat
                 + _tiebreak(key, E, tiebreak))
        x = greedy_pack(score, eligible, A, c)
        served = jnp.zeros(L, dtype=bool).at[port].max(x > 0)
        waiting = jnp.where(served, 0, waiting + arrived.astype(jnp.int32))
        return x, waiting

    return Policy(name="lwtf", init=init, step=step)


def _factory(make, name: str, tiebreak: float) -> PolicyFactory:
    def factory(instance: Instance, T: int, tables=None) -> Policy:
        del T, tables  # greedy baselines are horizon-free and DP-free
        return make(instance, tiebreak=tiebreak)

    factory.policy_name = name
    return factory


def hswf_factory(tiebreak: float = 1e-4) -> PolicyFactory:
    return _factory(make_hswf_policy, "hswf", tiebreak)


def lcf_factory(tiebreak: float = 1e-4) -> PolicyFactory:
    return _factory(make_lcf_policy, "lcf", tiebreak)


def lwtf_factory(tiebreak: float = 1e-4) -> PolicyFactory:
    return _factory(make_lwtf_policy, "lwtf", tiebreak)

"""Batched scenario-sweep engine for the ESDP reproduction.

Two pieces:
  scenarios — registry of named generative regimes for fluctuated processing
              speeds / arrivals (DVFS, MMPP bursts, stragglers, brownouts,
              elastic outages) behind the ``core.env.Scenario`` protocol.
  sweep     — declarative (policy × scenario × grid) sweeps, vmapped over
              seed batches (one jitted call per grid point) with lax.map
              over scenario-parameter grids, plus CSV/JSON sinks.
"""
from .scenarios import (SCENARIOS, get_scenario, register_scenario,
                        scenario_names, unroll_scenario)
from .sweep import (POLICY_FACTORIES, GridPoint, SweepRow, SweepSpec,
                    default_policies, engine_variant_records, run_spec,
                    summarize, sweep_scenario_param, write_csv, write_json)

__all__ = [
    "SCENARIOS", "get_scenario", "register_scenario", "scenario_names",
    "unroll_scenario",
    "POLICY_FACTORIES", "GridPoint", "SweepRow", "SweepSpec",
    "default_policies", "engine_variant_records", "run_spec", "summarize",
    "sweep_scenario_param", "write_csv", "write_json",
]

"""Scenario registry: named generative regimes for fluctuated speeds/arrivals.

The paper's motivation (Sec. 1) is that the *actual* service rate a
multi-server job experiences fluctuates — DVFS, power oversubscription,
multi-tenant co-location — and ESDP must learn under that fluctuation.  The
seed repo hard-coded a single iid-Gaussian regime; this module names a
*family* of regimes behind the :class:`repro.core.env.Scenario` protocol so
every "does ESDP still win under regime X?" question is a registry lookup,
not a new script.  See ``docs/scenarios.md`` for the phenomenon each regime
models and its parameters.

All step functions are pure jnp (traceable): they run inside the jitted
``lax.scan`` of ``core.env.simulate``, under ``jax.vmap`` over seed batches,
and under ``lax.map`` over stacked parameter grids.  Stochastic scenarios
carry their own PRNG key in their state (derived from the simulation seed
via ``fold_in``), so turning a scenario on never perturbs the base
arrival/valuation streams — cross-scenario comparisons stay paired.

``unroll_scenario`` materializes a regime into host-side (arr_scale, speed,
alive) streams; ``sched.dispatcher.ClusterSim`` consumes those, so the
cluster simulator and the jitted environment share one scenario interface.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.env import Scenario, default_scenario

__all__ = [
    "SCENARIOS", "register_scenario", "get_scenario", "scenario_names",
    "unroll_scenario", "power_allocation",
]

# name -> builder(**params) -> Scenario
SCENARIOS: dict[str, Callable[..., Scenario]] = {}


def register_scenario(name: str):
    """Decorator: register ``builder(**params) -> Scenario`` under ``name``."""
    def deco(builder: Callable[..., Scenario]):
        SCENARIOS[name] = builder
        builder.scenario_name = name
        return builder
    return deco


def get_scenario(name: str, **overrides) -> Scenario:
    """Build a registered scenario, overriding its default parameters.

    Raises ``ValueError`` (listing the registered names) on an unknown name —
    this is the single validation boundary every consumer (``SweepSpec``,
    ``ClusterSim``, benches) routes through.
    """
    if name not in SCENARIOS:
        raise ValueError(
            f"unknown scenario {name!r}; registered scenarios: "
            f"{', '.join(sorted(SCENARIOS))}")
    return SCENARIOS[name](**overrides)


def scenario_names() -> tuple[str, ...]:
    return tuple(sorted(SCENARIOS))


def _ones_speed(n_servers):
    return jnp.ones(n_servers, jnp.float32)


def _all_alive(n_servers):
    return jnp.ones(n_servers, dtype=bool)


# ---------------------------------------------------------------------------
# iid — the paper's baseline setting (re-exported from core.env so the
# registry covers the default regime too)
# ---------------------------------------------------------------------------

@register_scenario("iid")
def iid() -> Scenario:
    """iid clipped-Gaussian valuations, constant ρ, unit speeds (paper Sec. 5)."""
    return default_scenario()


# ---------------------------------------------------------------------------
# markov_dvfs — per-server two-state Markov-modulated speeds
# ---------------------------------------------------------------------------

def _dvfs_init(params, key, n_servers):
    # all servers start in the fast regime; private key drives the switching
    return (jnp.zeros(n_servers, jnp.int32), key)


def _dvfs_step(params, state, t, n_servers):
    regime, key = state
    key, k = jax.random.split(key)
    u = jax.random.uniform(k, (n_servers,))
    go_slow = (regime == 0) & (u < params["p_slow"])
    go_fast = (regime == 1) & (u < params["p_fast"])
    regime = jnp.where(go_slow, 1, jnp.where(go_fast, 0, regime))
    speed = jnp.where(regime == 1, params["slow_speed"],
                      1.0).astype(jnp.float32)
    return ((regime, key), jnp.float32(1.0), speed, _all_alive(n_servers))


@register_scenario("markov_dvfs")
def markov_dvfs(
    slow_speed: float = 0.5, p_slow: float = 0.05, p_fast: float = 0.25
) -> Scenario:
    """DVFS / co-location throttling: each server's speed follows an
    independent two-state Markov chain {fast=1, slow=slow_speed}."""
    return Scenario(
        name="markov_dvfs",
        init=_dvfs_init,
        step=_dvfs_step,
        params={"slow_speed": slow_speed, "p_slow": p_slow, "p_fast": p_fast},
        fluctuates=True,
        description="per-server two-state Markov speed modulation (DVFS)",
        speed_bounds=(slow_speed, 1.0),
    )


# ---------------------------------------------------------------------------
# mmpp_arrivals — bursty arrivals via a global on/off Markov modulation
# ---------------------------------------------------------------------------

def _mmpp_init(params, key, n_servers):
    return (jnp.int32(0), key)  # phase 0 = quiet, 1 = burst


def _mmpp_step(params, state, t, n_servers):
    phase, key = state
    key, k = jax.random.split(key)
    u = jax.random.uniform(k, ())
    to_burst = (phase == 0) & (u < params["p_burst"])
    to_quiet = (phase == 1) & (u < params["p_quiet"])
    phase = jnp.where(to_burst, 1, jnp.where(to_quiet, 0, phase))
    scale = jnp.where(phase == 1, params["burst_scale"],
                      params["quiet_scale"]).astype(jnp.float32)
    return ((phase, key), scale, _ones_speed(n_servers),
            _all_alive(n_servers))


@register_scenario("mmpp_arrivals")
def mmpp_arrivals(
    quiet_scale: float = 0.4,
    burst_scale: float = 1.2,
    p_burst: float = 0.05,
    p_quiet: float = 0.1,
) -> Scenario:
    """Bursty traffic: a cluster-wide two-phase Markov-modulated Bernoulli
    process scales every port's arrival probability (MMPP discretization)."""
    return Scenario(
        name="mmpp_arrivals",
        init=_mmpp_init,
        step=_mmpp_step,
        params={"quiet_scale": quiet_scale, "burst_scale": burst_scale,
                "p_burst": p_burst, "p_quiet": p_quiet},
        fluctuates=False,  # speeds stay 1 ⇒ true means unchanged
        description="global on/off Markov modulation of arrival intensity",
    )


# ---------------------------------------------------------------------------
# chronic_straggler — a random subset of servers is persistently degraded
# ---------------------------------------------------------------------------

def _straggler_init(params, key, n_servers):
    perm = jax.random.permutation(key, n_servers)
    n_slow = jnp.ceil(params["frac"] * n_servers).astype(jnp.int32)
    return perm < n_slow  # (R,) bool straggler mask


def _straggler_step(params, state, t, n_servers):
    speed = jnp.where(state, params["straggler_speed"],
                      1.0).astype(jnp.float32)
    return (state, jnp.float32(1.0), speed, _all_alive(n_servers))


@register_scenario("chronic_straggler")
def chronic_straggler(frac: float = 0.25, straggler_speed: float = 0.35) -> Scenario:
    """Chronic stragglers: a seed-dependent ⌈frac·R⌉-subset of servers runs
    at straggler_speed for the whole horizon (bad hosts / slow pods)."""
    return Scenario(
        name="chronic_straggler",
        init=_straggler_init,
        step=_straggler_step,
        params={"frac": frac, "straggler_speed": straggler_speed},
        fluctuates=True,
        description="a persistent random subset of servers is degraded",
        speed_bounds=(straggler_speed, 1.0),
    )


# ---------------------------------------------------------------------------
# transient_brownout — deterministic cluster-wide speed dip in a window
# ---------------------------------------------------------------------------

def _brownout_init(params, key, n_servers):
    return ()


def _brownout_step(params, state, t, n_servers):
    tf = t.astype(jnp.float32)
    in_window = (tf >= params["t_start"]) & (tf < params["t_end"])
    speed = jnp.where(in_window, params["brownout_speed"],
                      1.0).astype(jnp.float32)
    return (state, jnp.float32(1.0),
            jnp.broadcast_to(speed, (n_servers,)), _all_alive(n_servers))


@register_scenario("transient_brownout")
def transient_brownout(
    t_start: float = 300.0, t_end: float = 600.0, brownout_speed: float = 0.5
) -> Scenario:
    """Power-oversubscription brownout: every server is throttled to
    brownout_speed during [t_start, t_end) and recovers afterwards."""
    return Scenario(
        name="transient_brownout",
        init=_brownout_init,
        step=_brownout_step,
        params={"t_start": t_start, "t_end": t_end,
                "brownout_speed": brownout_speed},
        fluctuates=True,
        description="cluster-wide speed dip in a fixed time window",
        speed_bounds=(brownout_speed, 1.0),
    )


# ---------------------------------------------------------------------------
# elastic_outage — servers die and rejoin (aliveness, not speed)
# ---------------------------------------------------------------------------

def _outage_init(params, key, n_servers):
    perm = jax.random.permutation(key, n_servers)
    n_dead = jnp.ceil(params["frac"] * n_servers).astype(jnp.int32)
    return perm < n_dead  # (R,) bool outage-candidate mask


def _outage_step(params, state, t, n_servers):
    tf = t.astype(jnp.float32)
    in_window = (tf >= params["t_down"]) & (tf < params["t_up"])
    alive = ~(state & in_window)
    return (state, jnp.float32(1.0), _ones_speed(n_servers), alive)


@register_scenario("elastic_outage")
def elastic_outage(
    frac: float = 0.25, t_down: float = 200.0, t_up: float = 400.0
) -> Scenario:
    """Elastic scale-down/up: a seed-dependent ⌈frac·R⌉-subset of servers is
    dead during [t_down, t_up) — their channels become infeasible — and
    rejoins afterwards."""
    return Scenario(
        name="elastic_outage",
        init=_outage_init,
        step=_outage_step,
        params={"frac": frac, "t_down": t_down, "t_up": t_up},
        fluctuates=False,  # live servers run at unit speed
        description="a random subset of servers is down for a window",
    )


# ---------------------------------------------------------------------------
# server_failures — Markov crash/repair per server, optional rack correlation
# ---------------------------------------------------------------------------

def _failures_init(params, key, n_servers):
    key, k_lemon = jax.random.split(key)
    perm = jax.random.permutation(k_lemon, n_servers)
    n_lemon = jnp.ceil(params["lemon_frac"] * n_servers).astype(jnp.int32)
    # (down mask — all start up, lemon mask, private chain key)
    return (jnp.zeros(n_servers, dtype=bool), perm < n_lemon, key)


def _failures_step(params, state, t, n_servers):
    down, lemon, key = state
    key, k_rep, k_crash, k_rack = jax.random.split(key, 4)
    # repairs land at the slot boundary: a repaired server serves slot t
    repaired = down & (jax.random.uniform(k_rep, (n_servers,))
                       < params["p_repair"])
    down = down & ~repaired
    alive = ~down
    # crash draws are taken AFTER emitting aliveness: a server crashing in
    # slot t still shows alive[t]=True (it accepted work) and goes down
    # from t+1 until repaired — the up→down transition IS the crash event
    # (core.env.crash_events), which is what lets the failure-aware
    # dispatcher charge the lost in-slot work deterministically.
    p = params["p_crash"] * jnp.where(lemon, params["lemon_mult"], 1.0)
    crash = alive & (jax.random.uniform(k_crash, (n_servers,)) < p)
    # correlated rack failures: servers partition into n_racks contiguous
    # groups; one uniform draw per rack (read through the rack's first
    # server, static-shape-safe) can take the whole group down at once
    G = jnp.maximum(params["n_racks"].astype(jnp.int32), 1)
    r_ids = jnp.arange(n_servers)
    rack = (r_ids * G) // n_servers  # (R,) rack id, non-decreasing
    first = (rack * n_servers + G - 1) // G  # first server of own rack
    u_rack = jax.random.uniform(k_rack, (n_servers,))[first]
    rack_crash = (params["n_racks"] > 0) & alive & (u_rack < params["p_rack"])
    down = down | crash | rack_crash
    return ((down, lemon, key), params["arr_scale"].astype(jnp.float32),
            _ones_speed(n_servers), alive)


@register_scenario("server_failures")
def server_failures(
    p_crash: float = 0.03,
    p_repair: float = 0.4,
    n_racks: int = 0,
    p_rack: float = 0.0,
    lemon_frac: float = 0.0,
    lemon_mult: float = 1.0,
    arr_scale: float = 1.0,
) -> Scenario:
    """Seeded Markov crash/repair per server: an alive server crashes with
    p_crash per slot (losing that slot's in-flight work — see
    ``docs/robustness.md``) and stays down until repaired with p_repair per
    slot.  With ``n_racks > 0`` servers also partition into contiguous rack
    groups and each rack fails as a unit with p_rack per slot (correlated
    failure domains: shared switch / power feed).  ``lemon_frac``/
    ``lemon_mult`` make a seeded ⌈frac·R⌉-subset of servers crash
    lemon_mult× as often (persistent bad hosts — what detection-driven
    eligibility in ``sched.dispatcher.FailureRuntime`` is for), and
    ``arr_scale`` uniformly scales arrival intensity (redundant dispatch
    needs spare capacity to place replicas)."""
    return Scenario(
        name="server_failures",
        init=_failures_init,
        step=_failures_step,
        params={"p_crash": p_crash, "p_repair": p_repair,
                "n_racks": n_racks, "p_rack": p_rack,
                "lemon_frac": lemon_frac, "lemon_mult": lemon_mult,
                "arr_scale": arr_scale},
        fluctuates=False,  # live servers run at unit speed
        description="Markov crash/repair per server, optional correlated "
                    "rack-group failures and crash-prone lemon hosts",
    )


# ---------------------------------------------------------------------------
# power_coupled — shared sum-power budget couples per-server speeds
# ---------------------------------------------------------------------------

def power_allocation(demand, budget):
    """Ration a shared power budget across servers, proportionally.

    demand: (R,) f32 per-server power draw this slot (≥ 0); budget: scalar
    total budget P (≥ 0 after clamping).  Returns p (R,) with
    ``p_i = d_i · min(1, P / Σd)`` — each server's allocation is cut by the
    same oversubscription ratio, the droop model of a shared power feed.

    Two invariants the hypothesis suite pins: ``Σp = min(P, Σd) ≤ P`` (the
    budget is never exceeded), and p is monotone non-decreasing in P
    elementwise (more budget never slows anyone).  Pure jnp: safe inside the
    jitted scan, under vmap, and under ``lax.map`` parameter grids.
    """
    d = jnp.asarray(demand, jnp.float32)
    B = jnp.maximum(jnp.asarray(budget, jnp.float32), 0.0)
    total = jnp.sum(d)
    ratio = jnp.where(total > B, B / jnp.maximum(total, 1e-9), 1.0)
    return d * ratio


def _power_init(params, key, n_servers):
    # burst mask (co-located tenant bursting on that server) + private key
    return (jnp.zeros(n_servers, dtype=bool), key)


def _power_step(params, state, t, n_servers):
    burst, key = state
    key, k = jax.random.split(key)
    u = jax.random.uniform(k, (n_servers,))
    start = ~burst & (u < params["p_burst"])
    stop = burst & (u < params["p_calm"])
    burst = (burst | start) & ~stop
    # demand: 1 unit for the scheduled job, plus (burst_mult − 1) drawn by a
    # bursting co-tenant; the feed rations everyone by the same factor, and
    # the co-tenant's draw comes off the top of the server's allocation —
    # one tenant's burst slows *every* server (the coupling), and bursting
    # servers slow the most.
    demand = jnp.where(burst, params["burst_mult"], 1.0).astype(jnp.float32)
    p = power_allocation(demand, params["budget"] * n_servers)
    job_power = jnp.clip(p - (demand - 1.0), 0.0, 1.0)
    speed = jnp.clip(job_power ** params["alpha"],
                     params["s_min"], 1.0).astype(jnp.float32)
    return ((burst, key), jnp.float32(1.0), speed, _all_alive(n_servers))


@register_scenario("power_coupled")
def power_coupled(
    budget: float = 1.1,
    burst_mult: float = 3.0,
    p_burst: float = 0.08,
    p_calm: float = 0.25,
    alpha: float = 0.5,
    s_min: float = 0.05,
) -> Scenario:
    """Power-oversubscribed co-location (arXiv:2108.06935): all R servers
    share one power feed with total budget ``budget·R``.  Each server hosts
    a co-located tenant whose draw follows a two-state Markov chain (calm =
    1 unit, burst = ``burst_mult`` units, entered w.p. ``p_burst``, left
    w.p. ``p_calm``).  The feed rations proportionally
    (:func:`power_allocation`), the co-tenant's draw comes off the top, and
    the scheduled job's speed is ``clip(job_power^alpha, s_min, 1)`` —
    s_i ∝ p_i^α.  Unlike every independent-perturbation regime, enough
    bursts anywhere slow *all* servers at once."""
    if burst_mult < 1.0:
        raise ValueError(f"burst_mult must be ≥ 1, got {burst_mult}")
    return Scenario(
        name="power_coupled",
        init=_power_init,
        step=_power_step,
        params={"budget": budget, "burst_mult": burst_mult,
                "p_burst": p_burst, "p_calm": p_calm,
                "alpha": alpha, "s_min": s_min},
        fluctuates=True,
        description="shared sum-power budget: co-located bursts slow every "
                    "server via proportional power rationing, s_i ∝ p_i^α",
        speed_bounds=(s_min, 1.0),
    )


# ---------------------------------------------------------------------------
# host-side unrolling (shared interface with sched.dispatcher.ClusterSim)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("scenario", "T", "n_servers", "n_ports"))
def _unroll(scenario: Scenario, T: int, n_servers: int, n_ports: int, key, params):
    state0 = scenario.init(params, key, n_servers)

    def slot(state, t):
        state, arr_scale, speed, alive = scenario.step(
            params, state, t, n_servers)
        # contract allows scalar or (L,) arr_scale — normalize to (L,)
        return state, (jnp.broadcast_to(arr_scale, (n_ports,)), speed, alive)

    _, (arr_scale, speed, alive) = jax.lax.scan(
        slot, state0, jnp.arange(1, T + 1))
    return arr_scale, speed, alive


def unroll_scenario(
    scenario: Scenario, T: int, n_servers: int, seed: int = 0, n_ports: int = 1
):
    """Materialize a scenario into host arrays (arr_scale (T, n_ports),
    speed (T, R), alive (T, R)), using the same keying as
    ``core.env.simulate`` (the scenario chain is
    ``fold_in(PRNGKey(seed), salt)``), so a host-side consumer like
    ``ClusterSim`` sees the same regime realization the jitted environment
    would.  Scalar per-slot arrival scales are broadcast across ports."""
    from ..core import env as _env
    key = jax.random.fold_in(jax.random.PRNGKey(seed), _env._SCENARIO_SALT)
    params = jax.tree.map(jnp.asarray, scenario.params)
    arr_scale, speed, alive = _unroll(scenario, T, n_servers, n_ports, key,
                                      params)
    return (np.asarray(arr_scale), np.asarray(speed), np.asarray(alive))

"""Batched scenario-sweep engine: (policy × scenario × grid-point) → stats.

Replaces the per-seed Python loops the benchmarks used to run: for every
grid point, the whole horizon scan is vmapped over the seed batch and run as
ONE jitted call (``core.env.simulate_batch``); scenario-parameter grids with
fixed shapes additionally fold into a single compilation via ``lax.map``
(:func:`sweep_scenario_param`).

A sweep is declared, not scripted::

    spec = SweepSpec(
        name="fig6", T=1500, seeds=(11, 12),
        policies={"esdp": esdp_factory(), "hswf": hswf_factory()},
        grid=tuple(GridPoint(f"c_hi{c}", instance_kwargs={"seed": 2, "c_hi": c})
                   for c in (1, 2, 4, 6)),
    )
    rows = run_spec(spec)
    write_csv(rows, "results/fig6.csv")

Each :class:`SweepRow` carries the stacked per-seed traces (for curve plots)
plus mean/CI aggregates; ``write_csv``/``write_json`` sink the aggregates.
"""
from __future__ import annotations

import csv
import dataclasses
import json
import math
import pathlib
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import (build_tables, generate_instance, simulate_batch,
                    simulate_grid)
from ..core.baselines import (hswf_factory, lcf_factory, lwtf_factory,
                              msr_greedy_factory, msr_index_factory)
from ..core.dp import DPTables
from ..core.env import Scenario, SimResult
from ..core.esdp import PolicyFactory, esdp_factory
from ..core.graph import Instance
from .scenarios import get_scenario

__all__ = [
    "GridPoint", "SweepSpec", "SweepRow",
    "run_spec", "summarize", "sweep_scenario_param", "engine_variant_records",
    "write_csv", "write_json", "POLICY_FACTORIES", "default_policies",
]

# name -> zero-arg factory constructor with that policy's defaults
POLICY_FACTORIES = {
    "esdp": esdp_factory,
    "hswf": hswf_factory,
    "lcf": lcf_factory,
    "lwtf": lwtf_factory,
    "msr_greedy": msr_greedy_factory,
    "msr_index": msr_index_factory,
}


def default_policies(
    g_fn=None,
    tiebreak: float = 1e-4,
    names: Sequence[str] = ("esdp", "hswf", "lcf", "lwtf", "msr_greedy", "msr_index"),
    solver: str | None = None,
) -> dict[str, PolicyFactory]:
    """The full policy lineup as a sweep-ready dict: the paper's four
    (Fig. 2–4) plus the two Markovian-service-rate baselines
    (``core.baselines`` — arXiv:2412.08915), so sweeps report ESDP against
    a stronger field than the paper's three benchmarks by default.

    Unknown names raise ``ValueError`` listing the registry — the
    ``SweepSpec`` boundary's counterpart of ``get_scenario``'s check.
    ``solver`` pins the Algorithm-2 backend for ESDP (see ``core.solvers``)."""
    out: dict[str, PolicyFactory] = {}
    for n in names:
        if n not in POLICY_FACTORIES:
            raise ValueError(
                f"unknown policy {n!r}; registered policies: "
                f"{', '.join(sorted(POLICY_FACTORIES))}")
        if n == "esdp":
            kw = {"g_fn": g_fn} if g_fn else {}
            if solver is not None:
                kw["solver"] = solver
            out[n] = esdp_factory(**kw)
        else:
            out[n] = POLICY_FACTORIES[n](tiebreak=tiebreak)
    return out


@dataclasses.dataclass(frozen=True)
class GridPoint:
    """One cell of a sweep grid: overrides applied on top of the spec."""

    label: str
    instance_kwargs: Mapping = dataclasses.field(default_factory=dict)
    scenario_params: Mapping = dataclasses.field(default_factory=dict)
    T: int | None = None


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative description of one figure/table's worth of runs."""

    name: str
    T: int
    seeds: tuple[int, ...]
    policies: Mapping[str, PolicyFactory]
    scenario: str | Scenario = "iid"
    scenario_params: Mapping = dataclasses.field(default_factory=dict)
    instance_kwargs: Mapping = dataclasses.field(default_factory=dict)
    grid: tuple[GridPoint, ...] = (GridPoint("default"),)
    # Algorithm-2 backend for solver-aware policies: a core.solvers name,
    # or a preassembled wrapper object (e.g. a FallbackSolver degradation
    # chain — its counters then surface as fallback_* record columns);
    # None keeps each factory's own default (env var / auto resolution).
    solver: "str | object | None" = None
    # incremental re-solve mode for cache-aware policies (None | "memo" |
    # "warm", see core.esdp) — bit-identical to None; per-sweep hit/skip
    # rates surface as solve_stats columns in the records.
    cache: str | None = None

    def smoke(self, T: int = 120, seeds: tuple[int, ...] = (0,)) -> "SweepSpec":
        """A cheap variant for CI smoke runs: shrink horizon and seed batch."""
        grid = tuple(
            dataclasses.replace(p, T=min(p.T, T) if p.T else None)
            for p in self.grid)
        return dataclasses.replace(self, T=min(self.T, T), seeds=seeds,
                                   grid=grid)


@dataclasses.dataclass(frozen=True)
class SweepRow:
    """One (grid-point × policy) cell: aggregates + full per-seed traces."""

    spec: str
    point: str
    policy: str
    scenario: str
    T: int
    seeds: tuple[int, ...]
    asw_mean: float  # mean over seeds of ASW(T)
    asw_ci95: float  # 1.96·σ/√S (0 for a single seed)
    regret_mean: float  # mean over seeds of cumulative regret(T)
    regret_ci95: float
    oracle_asw_mean: float  # mean over seeds of Σ_t ṽᵀx*(t)
    n_dispatched_mean: float  # mean ‖x(t)‖₁ per slot
    result: SimResult  # stacked (S, T) traces
    instance: Instance
    tables: DPTables
    # Algorithm-2 backend requested by the spec (name or wrapper object)
    solver: "str | object | None" = None
    # incremental-solve counters (hit/skip rates etc.) aggregated over the
    # seed batch by Policy.finalize, plus fallback_* degradation counters
    # when the spec's solver is a FallbackSolver chain; None otherwise
    solve_stats: Mapping | None = None
    # A/B rollout lineage (sched.engine): the VariantSpec name this row's
    # traffic slice was routed to, "" for whole-fleet (non-engine) sweeps
    variant: str = ""

    def to_record(self) -> dict:
        """Sink-friendly flat record (drops the arrays)."""
        rec = {
            "spec": self.spec, "point": self.point, "policy": self.policy,
            "variant": self.variant,
            "scenario": self.scenario, "T": self.T,
            "solver": getattr(self.solver, "name", self.solver) or "default",
            "seeds": ";".join(str(s) for s in self.seeds),
            "asw_mean": self.asw_mean, "asw_ci95": self.asw_ci95,
            "regret_mean": self.regret_mean, "regret_ci95": self.regret_ci95,
            "oracle_asw_mean": self.oracle_asw_mean,
            "n_dispatched_mean": self.n_dispatched_mean,
            "n_edges": self.instance.n_edges,
            "n_states": self.tables.n_states,
        }
        if self.solve_stats:
            rec.update(self.solve_stats)
        return rec


def _ci95(x: np.ndarray) -> float:
    if x.size <= 1:
        return 0.0
    return float(1.96 * x.std(ddof=1) / math.sqrt(x.size))


def summarize(res: SimResult) -> dict:
    """Mean/CI aggregates over the leading seed axis of a batched result."""
    asw = res.asw[..., -1]
    creg = res.cum_regret[..., -1]
    return {
        "asw_mean": float(asw.mean()),
        "asw_ci95": _ci95(asw),
        "regret_mean": float(creg.mean()),
        "regret_ci95": _ci95(creg),
        "oracle_asw_mean": float(res.sw_oracle.sum(axis=-1).mean()),
        "n_dispatched_mean": float(res.n_dispatched.mean()),
    }


def engine_variant_records(
    out, spec: str = "engine", point: str = "default"
) -> list[dict]:
    """Per-variant flat records from a ``sched.engine.EngineOutput``.

    One record per A/B rollout arm, sink-compatible with ``write_csv``/
    ``write_json``: the ``variant`` column carries the arm name, and each
    record reports that arm's routed/dispatched volume, realized welfare,
    cumulative regret, and — because the record shape matches
    ``SweepRow.to_record`` where fields overlap — slots next to ordinary
    sweep rows in one table.
    """
    recs = []
    routed = np.asarray(out.routed_variant).sum(axis=0)
    for i, name in enumerate(out.variants):
        recs.append({
            "spec": spec, "point": point, "policy": name, "variant": name,
            "T": int(np.asarray(out.sw).shape[0]),
            "asw_mean": float(np.asarray(out.sw_variant)[:, i].sum()),
            "regret_mean": float(np.asarray(out.regret_variant)[:, i].sum()),
            "routed": int(routed[i]),
            "dispatched": int(np.asarray(out.dispatched_variant)[:, i].sum()),
            "mode": out.mode,
        })
    return recs


def _resolve_scenario(
    scenario, base_params: Mapping, point_params: Mapping
) -> Scenario:
    params = {**base_params, **point_params}
    if isinstance(scenario, str):
        return get_scenario(scenario, **params)
    if params:
        return dataclasses.replace(scenario,
                                   params={**scenario.params, **params})
    return scenario


def _batch_solve_stats(policy, res: SimResult) -> "dict | None":
    """Seed-batch aggregate of ``Policy.finalize`` counters.

    ``res.policy_final`` carries the final policy state with a leading seed
    axis; finalize each row and average the numeric values (hit/skip rates
    are per-seed ratios, so the mean is the per-seed mean, not a pooled
    ratio)."""
    if getattr(policy, "finalize", None) is None or res.policy_final is None:
        return None
    leaves = jax.tree.leaves(res.policy_final)
    if not leaves:
        return None
    S = np.shape(leaves[0])[0]
    dicts = [policy.finalize(jax.tree.map(lambda a: np.asarray(a)[i],
                                          res.policy_final))
             for i in range(S)]
    return {k: float(np.mean([d[k] for d in dicts])) for k in dicts[0]}


def run_spec(spec: SweepSpec) -> list[SweepRow]:
    """Execute a sweep: one jitted vmapped call per (grid-point × policy)."""
    rows: list[SweepRow] = []
    for point in spec.grid:
        inst_kwargs = {**spec.instance_kwargs, **point.instance_kwargs}
        instance = generate_instance(**inst_kwargs)
        tables = build_tables(instance.A, instance.c)
        T = point.T if point.T is not None else spec.T
        scenario = _resolve_scenario(spec.scenario, spec.scenario_params,
                                     point.scenario_params)
        for pname, factory in spec.policies.items():
            kw = {}
            if spec.solver is not None and getattr(factory, "accepts_solver",
                                                   False):
                kw["solver"] = spec.solver
            if spec.cache is not None and getattr(factory, "accepts_cache",
                                                  False):
                kw["cache"] = spec.cache
            policy = factory(instance, T, tables, **kw)
            res = simulate_batch(instance, policy, T, spec.seeds,
                                 tables=tables, scenario=scenario)
            stats = _batch_solve_stats(policy, res)
            fb = getattr(spec.solver, "stats", None)
            if isinstance(fb, dict):
                # FallbackSolver-style degradation counters: surface the
                # numeric ones as record columns (jitted sweeps bypass the
                # host chain, so expect bypasses; host-loop consumers see
                # the full launch/validate/degraded accounting)
                stats = {**(stats or {}),
                         **{f"fallback_{k}": v for k, v in fb.items()
                            if isinstance(v, (int, float))}}
            rows.append(SweepRow(
                spec=spec.name, point=point.label, policy=pname,
                scenario=scenario.name, T=T, seeds=tuple(spec.seeds),
                result=res, instance=instance, tables=tables,
                solver=spec.solver,
                solve_stats=stats,
                **summarize(res)))
    return rows


def sweep_scenario_param(
    instance: Instance,
    factory: PolicyFactory,
    T: int,
    seeds,
    scenario_name: str,
    param: str,
    values,
    tables: DPTables | None = None,
    **scenario_kwargs,
) -> SimResult:
    """Sweep ONE scenario parameter over a value grid in a single jitted
    call: ``lax.map`` over the stacked parameter axis, ``vmap`` over seeds.

    Returns a SimResult with shape (len(values), len(seeds), T).  Requires
    the scenario's state/output shapes to be parameter-independent (true for
    every registered scenario).
    """
    scenario = get_scenario(scenario_name, **scenario_kwargs)
    if tables is None:
        tables = build_tables(instance.A, instance.c)
    params = {k: jnp.asarray(v) for k, v in scenario.params.items()}
    if param not in params:
        raise KeyError(f"scenario {scenario.name!r} has no parameter "
                       f"{param!r}; available: {sorted(params)}")
    G = len(values)
    stacked = {
        k: (jnp.asarray(values, jnp.result_type(v)) if k == param
            else jnp.broadcast_to(v, (G,) + jnp.shape(v)))
        for k, v in params.items()
    }
    policy = factory(instance, T, tables)
    return simulate_grid(instance, policy, T, seeds, scenario, stacked,
                         tables=tables)


# ---------------------------------------------------------------------------
# result sinks
# ---------------------------------------------------------------------------

def _records(rows: Sequence[SweepRow]) -> list[dict]:
    return [r.to_record() for r in rows]


def write_csv(rows: Sequence[SweepRow], path) -> pathlib.Path:
    """Write aggregate records as CSV (one row per grid-point × policy)."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    recs = _records(rows)
    with path.open("w", newline="") as f:
        if recs:
            # union the keys across records — cache-aware rows carry extra
            # solve_stats columns that cache-less rows lack
            fieldnames = list(dict.fromkeys(k for r in recs for k in r))
            w = csv.DictWriter(f, fieldnames=fieldnames, restval="")
            w.writeheader()
            w.writerows(recs)
    return path


def write_json(rows: Sequence[SweepRow], path) -> pathlib.Path:
    """Write aggregate records as a JSON array."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(_records(rows), indent=2))
    return path

"""Gradient compression: top-k sparsification with error feedback.

At 1000+-node scale the DP all-reduce of dense grads dominates the
collective roofline term; top-k + error feedback (Stich et al.) keeps
convergence while shrinking the reduced payload by ~1/ratio. The compressed
tensor is materialized densely (zeros off the top-k support) so the same
psum path applies — on real hardware one would pair this with a sparse
collective; the *numerics* (what the optimizer sees) are exact either way,
which is what the integration test checks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["topk_compress_with_feedback"]


def _compress_leaf(g, err, ratio: float):
    flat = (g.astype(jnp.float32) + err).reshape(-1)
    k = max(1, int(flat.size * ratio))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = jnp.zeros_like(flat).at[idx].set(flat[idx])
    new_err = flat - kept
    return kept.reshape(g.shape).astype(g.dtype), new_err.reshape(g.shape)


def topk_compress_with_feedback(grads, err_state, ratio: float = 0.01):
    """Returns (compressed_grads, new_error_state).

    err_state: f32 tree like grads (init zeros). The dropped mass is carried
    into the next step (error feedback), so no gradient signal is lost.
    """
    if err_state is None:
        err_state = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree.map(lambda g, e: _compress_leaf(g, e, ratio),
                       grads, err_state)
    comp = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda o: o[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, err

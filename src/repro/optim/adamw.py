"""AdamW in plain JAX, dtype-policy aware.

Moments are kept in f32 regardless of the parameter dtype (bf16 params at
the giant dry-run scale still get f32 moments — the standard mixed-precision
recipe). State shards exactly like the parameters (same logical axes), so
the Rules.tree_shardings of params applies verbatim to (m, v).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "OptState"]


@dataclasses.dataclass(frozen=True)
class OptState:
    step: jnp.ndarray  # () int32
    m: Any  # f32 tree, same structure as params
    v: Any


jax.tree_util.register_dataclass(
    OptState, data_fields=["step", "m", "v"], meta_fields=[])


@dataclasses.dataclass(frozen=True, eq=False)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0

    def init(self, params) -> OptState:
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: OptState, params):
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
        else:
            gnorm = jnp.float32(0)
            scale = jnp.float32(1)

        lr = jnp.asarray(self._lr(step), jnp.float32)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=step, m=new_m, v=new_v), gnorm

"""Optimizer substrate: AdamW, LR schedules, gradient compression."""
from .adamw import AdamW, OptState
from .schedule import cosine_schedule, linear_warmup_cosine
from .compression import topk_compress_with_feedback

__all__ = ["AdamW", "OptState", "cosine_schedule", "linear_warmup_cosine",
           "topk_compress_with_feedback"]

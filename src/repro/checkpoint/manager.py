"""Atomic, async-capable checkpoint manager with reshard-on-load.

Fault-tolerance contract (the piece checkpoint/restart at 1000+ nodes needs):
  * atomicity     — write to step_XXXX.tmp/, fsync, rename; a crash mid-save
                    never corrupts the latest checkpoint;
  * async saves   — a background thread serializes a host snapshot while the
                    train loop keeps stepping (snapshot taken synchronously,
                    I/O overlapped);
  * retention     — keep_n newest checkpoints are retained;
  * reshard-on-load — arrays are stored as full host arrays + the pytree
                    structure; restoring onto ANY mesh re-applies that mesh's
                    shardings (elastic re-scale path: 512 → 256 chips just
                    works);
  * self-describing — metadata.json carries step, pytree structure and
                    dtype/shape manifest for validation.

Storage is npz (zstd-compressed via numpy's deflate) per checkpoint — this
container has no orbax; the format is deliberately dependency-free.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str | pathlib.Path, keep_n: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None

    # ---------------- save ----------------

    def save(self, step: int, state: Any, async_: bool = False):
        """Snapshot is taken synchronously (correctness); serialization and
        fsync+rename run on a thread when async_."""
        flat = _flatten(state)  # host copy now
        treedef = jax.tree_util.tree_structure(state)
        meta = {
            "step": int(step),
            "treedef": str(treedef),
            "manifest": {k: [list(v.shape), str(v.dtype)]
                         for k, v in flat.items()},
        }
        if async_:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, meta)

    def _write(self, step: int, flat: dict, meta: dict):
        tmp = self.dir / f"step_{step:08d}.tmp"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez_compressed(tmp / "arrays.npz", **flat)
        (tmp / "metadata.json").write_text(json.dumps(meta))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------- restore ----------------

    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if not p.name.endswith(".tmp"))

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, like: Any, step: Optional[int] = None, shardings: Any = None
    ) -> tuple[Any, int]:
        """Restore into the structure of ``like``; optionally device_put with
        a (possibly different-mesh) shardings tree — the elastic path."""
        self.wait()
        if step is None:
            step = self.latest_step()
        assert step is not None, f"no checkpoints in {self.dir}"
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "arrays.npz")
        flat_like = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for kpath, leaf in flat_like[0]:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in kpath)
            arr = data[key]
            assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                           leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
        state = jax.tree_util.tree_unflatten(flat_like[1], leaves)
        if shardings is not None:
            state = jax.device_put(state, shardings)
        return state, step

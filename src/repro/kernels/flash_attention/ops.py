"""jit'd wrapper over the flash-attention kernel, standard (B,S,H,hd) layout.

On this CPU container the kernel is validated with interpret=True; on TPU
the same call site sets interpret=False. ``flash_attention_op`` is layout-
compatible with models.attention.chunked_attention.
"""
from __future__ import annotations

from .kernel import flash_attention

__all__ = ["flash_attention_op"]


def flash_attention_op(
    q,
    k,
    v,
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    blk_q: int = 128,
    blk_k: int = 512,
    interpret: bool = True,
):
    """q: (B, Sq, H, hd); k, v: (B, Sk, KH, hd) with H = KH·g."""
    B, Sq, H, hd = q.shape
    _, Sk, KH, _ = k.shape
    g = H // KH
    qk = q.reshape(B, Sq, KH, g, hd).transpose(0, 2, 3, 1, 4)
    kk = k.transpose(0, 2, 1, 3)
    vk = v.transpose(0, 2, 1, 3)
    o = flash_attention(qk, kk, vk, scale=scale, causal=causal,
                        window=window, blk_q=blk_q, blk_k=blk_k,
                        interpret=interpret)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)

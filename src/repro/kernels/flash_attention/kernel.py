"""Pallas TPU flash-attention (forward) for prefill/training compute.

Grid (B, KH, Sq/blk_q): each program owns one q block of one kv-head group
and streams KV in blk_k slices from VMEM with the online-softmax
recurrence (m, l, acc in f32). Causal + sliding-window masking is applied
per block; fully-masked KV blocks are skipped via jax.lax.cond at trip
granularity (blocks strictly above the diagonal).

Layouts: q (B, KH, g, Sq, hd); k/v (B, KH, Sk, hd) — GQA folds the group
into the q block (g·blk_q rows hit the MXU together). blk sizes default to
(128, 512); hd must be a multiple of 8 (MXU/VREG alignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    blk_k: int,
    sk: int,
):
    _, _, g, blk_q, hd = q_ref.shape
    qb = pl.program_id(2)
    q = q_ref[0, 0].reshape(g * blk_q, hd).astype(jnp.float32) * scale

    m0 = jnp.full((g * blk_q,), NEG, jnp.float32)
    l0 = jnp.zeros((g * blk_q,), jnp.float32)
    acc0 = jnp.zeros((g * blk_q, hd), jnp.float32)

    q_pos = qb * blk_q + jax.lax.broadcasted_iota(
        jnp.int32, (g, blk_q), 1).reshape(g * blk_q) + (sk - pl.num_programs(2) * blk_q)

    def kv_step(i, carry):
        m, lsum, acc = carry
        k = k_ref[0, 0, pl.ds(i * blk_k, blk_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(i * blk_k, blk_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = i * blk_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, blk_k), 1)[0]
        mask = jnp.ones((g * blk_q, blk_k), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask, s, NEG)
        m_new = jnp.maximum(m, s.max(axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = lsum * alpha + p.sum(axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    n_kv = sk // blk_k
    if causal:
        # skip blocks strictly above the diagonal of this q block
        last_q = qb * blk_q + blk_q - 1 + (sk - pl.num_programs(2) * blk_q)
        n_live = jnp.minimum((last_q // blk_k) + 1, n_kv)
    else:
        n_live = n_kv
    m, lsum, acc = jax.lax.fori_loop(0, n_live, kv_step, (m0, l0, acc0))
    out = acc / jnp.maximum(lsum, 1e-30)[:, None]
    o_ref[0, 0] = out.reshape(g, blk_q, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "blk_q", "blk_k", "interpret"))
def flash_attention(
    q,
    k,
    v,
    *,
    scale: float,
    causal: bool = True,
    window: int = 0,
    blk_q: int = 128,
    blk_k: int = 512,
    interpret: bool = True,
):
    """q: (B, KH, g, Sq, hd); k, v: (B, KH, Sk, hd). Returns like q."""
    B, KH, g, Sq, hd = q.shape
    Sk = k.shape[2]
    blk_q = min(blk_q, Sq)
    blk_k = min(blk_k, Sk)
    assert Sq % blk_q == 0 and Sk % blk_k == 0, (Sq, blk_q, Sk, blk_k)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, blk_k=blk_k, sk=Sk)
    return pl.pallas_call(
        kernel,
        grid=(B, KH, Sq // blk_q),
        in_specs=[
            pl.BlockSpec((1, 1, g, blk_q, hd),
                         lambda b, h, i: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, Sk, hd), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Sk, hd), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, blk_q, hd),
                               lambda b, h, i: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)

"""Pure-jnp oracle: full-softmax attention (materializes logits)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, scale: float, causal: bool = True, window: int = 0):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KH, hd). Returns (B, Sq, H, vh)."""
    B, Sq, H, hd = q.shape
    _, Sk, KH, _ = k.shape
    g = H // KH
    qg = q.reshape(B, Sq, KH, g, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= qi - kj < window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    attn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", attn, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)

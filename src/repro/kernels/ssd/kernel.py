"""Pallas TPU kernel for the Mamba2 SSD chunked scan (train/prefill).

Grid (B·H, n_chunks): the chunk axis is the innermost (sequential) grid
dimension; the (P, N) recurrent state lives in a VMEM scratch that persists
across grid steps of the same (b, h) program row and is reset at chunk 0.
Per chunk (all f32 in VMEM):

  cum   = cumsum(dt·A)                              (Q,)
  decay = exp(cum_i − cum_j)·[i ≥ j]                (Q, Q)
  y     = ((C Bᵀ) ⊙ decay ⊙ dt_j) x                 intra-chunk, MXU
        + (C state) ⊙ exp(cum)                      inter-chunk
  state = exp(cum_last)·state + Bᵀ((exp(cum_last−cum)·dt) ⊙ x)

This is the TPU-native blocking of the SSD algorithm: the quadratic
intra-chunk term is a dense (Q×Q)(Q×P) MXU matmul, the state update a
(N×Q)(Q×P) matmul — no sequential per-token work at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, state_ref):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[:, :] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0]  # scalar (per head)
    Bm = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)  # (Q, N)
    Q = x.shape[0]

    dA = dt * A
    cum = jnp.cumsum(dA)
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    M = cb * decay * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (Q, P)

    state = state_ref[:, :]  # (N, P)
    y += jax.lax.dot_general(Cm, state, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]

    wj = jnp.exp(cum[-1] - cum) * dt  # (Q,)
    upd = jax.lax.dot_general(Bm, x * wj[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (N, P)
    state = state * jnp.exp(cum[-1]) + upd
    state_ref[:, :] = state

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == pl.num_programs(1) - 1)
    def _emit_state():
        state_out_ref[0] = state.astype(state_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(x, dt, A, B_, C_, *, interpret: bool = True):
    """x: (BH, NC, Q, P); dt: (BH, NC, Q); A: (BH,); B_/C_: (BH, NC, Q, N).
    Returns (y: (BH, NC, Q, P), final_state: (BH, N, P))."""
    BH, NC, Q, P = x.shape
    N = B_.shape[-1]
    return pl.pallas_call(
        _ssd_kernel,
        grid=(BH, NC),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, Q, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, N, P), lambda b, c: (b, 0, 0)),
        ),
        out_shape=(jax.ShapeDtypeStruct((BH, NC, Q, P), x.dtype),
                   jax.ShapeDtypeStruct((BH, N, P), jnp.float32)),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B_, C_)

"""jit'd wrapper over the SSD kernel, substrate (B,S,H,P) layout."""
from __future__ import annotations

import jax.numpy as jnp

from .kernel import ssd_scan

__all__ = ["ssd_op"]


def ssd_op(x, dt, A, B_, C_, chunk: int, interpret: bool = True):
    """Same contract as models.ssm.ssd_chunked (B/C shared across heads).

    x: (B, S, H, P); dt: (B, S, H); A: (H,); B_/C_: (B, S, N).
    Returns (y (B,S,H,P) f32, final_state (B,H,N,P) f32).
    """
    B, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    NC = Sp // Q

    xk = (x.reshape(B, NC, Q, H, P).transpose(0, 3, 1, 2, 4)
          .reshape(B * H, NC, Q, P))
    dtk = (dt.reshape(B, NC, Q, H).transpose(0, 3, 1, 2)
           .reshape(B * H, NC, Q))
    Ak = jnp.broadcast_to(A[None, :], (B, H)).reshape(B * H)
    Bk = jnp.broadcast_to(B_.reshape(B, 1, NC, Q, N),
                          (B, H, NC, Q, N)).reshape(B * H, NC, Q, N)
    Ck = jnp.broadcast_to(C_.reshape(B, 1, NC, Q, N),
                          (B, H, NC, Q, N)).reshape(B * H, NC, Q, N)

    y, state = ssd_scan(xk.astype(jnp.float32), dtk.astype(jnp.float32),
                        Ak.astype(jnp.float32), Bk.astype(jnp.float32),
                        Ck.astype(jnp.float32), interpret=interpret)
    y = (y.reshape(B, H, NC, Q, P).transpose(0, 2, 3, 1, 4)
         .reshape(B, Sp, H, P)[:, :S])
    return y, state.reshape(B, H, N, P)

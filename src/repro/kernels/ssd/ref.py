"""Pure-jnp oracle: delegates to the substrate's chunked SSD (single source
of truth — models/ssm.py is itself validated by the prefill/decode
consistency tests)."""
from __future__ import annotations

import jax.numpy as jnp

from ...models.ssm import ssd_chunked


def ssd_ref(x, dt, A, B_, C_, chunk: int):
    """x: (B, S, H, P); dt: (B, S, H); A: (H,); B_/C_: (B, S, N).
    Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    return ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32),
                       A.astype(jnp.float32), B_.astype(jnp.float32),
                       C_.astype(jnp.float32), chunk)

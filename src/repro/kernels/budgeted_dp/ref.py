"""Pure-jnp oracle for the budgeted-DP kernel (mirrors core/dp._dp_forward
in the kernel's f32 value domain)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import NEG


def dp_forward_ref(upsilon, sigma2, feasible, next_onehot, v0):
    """Same contract as kernel.dp_forward_pallas, computed with jnp gathers."""
    E = upsilon.shape[0]
    S, C = v0.shape
    rows = jnp.arange(S)
    next_idx = jnp.argmax(next_onehot, axis=1)        # (E, C) source index

    def body(V, e_rev):
        e = E - 1 - e_rev
        u = upsilon[e]
        shifted = V[jnp.maximum(rows - u, 0), :]
        take = jnp.take(shifted, next_idx[e], axis=1) + sigma2[e].astype(
            jnp.float32)
        take = jnp.where(feasible[e][None, :] > 0, take, NEG)
        dec = (take > V).astype(jnp.float32)
        return jnp.maximum(V, take), dec

    V, decs = jax.lax.scan(body, v0, jnp.arange(E))
    decisions = decs[::-1]                            # index by edge id
    return V, decisions

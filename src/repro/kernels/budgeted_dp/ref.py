"""Pure-jnp oracle for the budgeted-DP kernel (mirrors core/dp._dp_forward
in the kernel's f32 value domain, including the bit-packed decision words
and the offset-encoded capacity transition next(c) = c − offsets[e]).

This oracle is the CONTRACT every kernel tiling must reproduce bit for
bit: whole-plane, C-blocked, and the 2-D (S-tile × C-tile) grid all
compare against the same ``dp_forward_ref`` output — the tiling is an
execution detail, never a numeric one (enforced in tests/test_kernels.py
and the hypothesis sweep in tests/test_solver_equiv.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import NEG, packed_words


def dp_forward_ref(upsilon, sigma2, feasible, offsets, v0):
    """Same contract as kernel.dp_forward_pallas, computed with jnp gathers:
    returns (V (S, C) f32, decisions (⌈E/32⌉, S, C) i32 bit-packed).

    The capacity gather clamps c − offsets[e] at 0; clamped reads are
    exactly the states with c < offsets[e], which are infeasible and masked
    to NEG — the same inertness argument the kernel's pad columns rely on.
    """
    E = upsilon.shape[0]
    S, C = v0.shape
    rows = jnp.arange(S)
    cols = jnp.arange(C)

    def body(V, e_rev):
        e = E - 1 - e_rev
        u = upsilon[e]
        off = offsets[e]
        shifted = V[jnp.maximum(rows - u, 0), :]
        take = shifted[:, jnp.maximum(cols - off, 0)] + sigma2[e].astype(
            jnp.float32)
        take = jnp.where(feasible[e][None, :] > 0, take, NEG)
        dec = (take > V).astype(jnp.int32)
        return jnp.maximum(V, take), dec

    V, decs = jax.lax.scan(body, v0, jnp.arange(E))
    decs = decs[::-1]  # index by edge id
    # pack edge bits into int32 words: bit (e % 32) of word (e // 32)
    W = packed_words(E)
    pad = W * 32 - E
    decs = jnp.concatenate(
        [decs, jnp.zeros((pad, S, C), jnp.int32)], axis=0)
    shifts = jnp.arange(32, dtype=jnp.int32)[None, :, None, None]
    packed = (decs.reshape(W, 32, S, C) << shifts).sum(
        axis=1).astype(jnp.int32)
    return V, packed

"""Pallas TPU kernel for the ESDP budgeted DP (paper Algorithm 2).

TPU-native design (DESIGN.md §4):
  * the whole (S × C) value plane lives in VMEM (default sizes ≈ 80 KB);
  * the edge loop runs INSIDE one pallas_call via fori_loop;
  * the s-shift gather V[max(s−Υ_e, 0)] uses a padded VMEM scratch whose
    first U_MAX rows hold the clamp row V[0]; a dynamic-START static-SIZE
    slice (pl.ds) then reads the shifted window — no gather op at all;
  * the capacity-state gather becomes a tiny (C × C) one-hot MATMUL on the
    MXU — the standard TPU idiom replacing GPU warp gathers;
  * backtrack decisions are BIT-PACKED into int32 lanes: word ⌊e/32⌋ of the
    (⌈E/32⌉, S, C) output holds bit (e mod 32) for edge e.  At production
    sizes the unpacked (E, S, C) f32 tensor dominated VMEM (E=64, S=512,
    C=256 ⇒ 32 MB — over the ~16 MB/core budget); packing is 32× smaller.

Arithmetic is f32 with integer values; exactness holds for values < 2²⁴
(ops.py enforces the bound — see core/stats.py for why defaults are ≪ 2²⁴).

Backend resolution: ``interpret=None`` (the default) compiles on TPU and
falls back to the Pallas interpreter elsewhere — the kernel is never
silently interpreted on real TPU hardware.  Pass an explicit bool to force
either mode (``interpret=True`` is how the differential tests exercise the
kernel logic on CPU CI).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["NEG", "resolve_interpret", "packed_words", "dp_forward_pallas"]

NEG = -float(2 ** 24)


def resolve_interpret(interpret: bool | None = None,
                      platform: str | None = None) -> bool:
    """Resolve the kernel execution mode.

    ``None`` → auto: compiled (``False``) on TPU, interpreter (``True``)
    everywhere else.  ``platform`` overrides ``jax.default_backend()`` so the
    resolution table is unit-testable without the hardware.
    """
    if interpret is not None:
        return bool(interpret)
    platform = platform or jax.default_backend()
    return platform != "tpu"


def packed_words(n_edges: int) -> int:
    """Leading dim of the packed decision tensor: ⌈E/32⌉ int32 words."""
    return (n_edges + 31) // 32


def _dp_kernel(ups_ref, sig_ref, feas_ref, next_oh_ref, v0_ref,
               vout_ref, dec_ref, vpad_ref, *, n_edges: int, u_max: int):
    S, C = v0_ref.shape
    W = dec_ref.shape[0]
    vout_ref[:, :] = v0_ref[:, :]
    dec_ref[:, :, :] = jnp.zeros((W, S, C), jnp.int32)

    def edge_step(j, _):
        e = n_edges - 1 - j
        u = ups_ref[e]
        sig = sig_ref[e].astype(jnp.float32)

        V = vout_ref[:, :]
        # padded shift buffer: rows [0, u_max) = clamp row V[0], then V
        vpad_ref[:u_max, :] = jnp.broadcast_to(V[0:1, :], (u_max, C))
        vpad_ref[pl.ds(u_max, S), :] = V
        shifted = vpad_ref[pl.ds(u_max - u, S), :]        # V[max(s-u, 0)]

        # capacity gather as one-hot matmul: take[:, c] = shifted[:, next(c)]
        oh = next_oh_ref[e, :, :]                          # (C, C) one-hot
        take = jax.lax.dot_general(
            shifted, oh, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) + sig

        feas = feas_ref[e, :]                              # (C,) 0/1
        take = jnp.where(feas[None, :] > 0, take, NEG)
        dec = (take > V).astype(jnp.int32)
        # OR edge e's decision bit into its int32 word (bit = e mod 32;
        # multiply by the power of two — exact, and 1<<31 wraps to the sign
        # bit whose pattern is still the bit we want)
        bit = jnp.left_shift(jnp.int32(1), e % 32)
        word = dec_ref[pl.ds(e // 32, 1), :, :]
        dec_ref[pl.ds(e // 32, 1), :, :] = word | (dec * bit)[None]
        vout_ref[:, :] = jnp.maximum(V, take)
        return 0

    jax.lax.fori_loop(0, n_edges, edge_step, 0)


@functools.partial(jax.jit, static_argnames=("n_edges", "u_max", "interpret"))
def dp_forward_pallas(upsilon, sigma2, feasible, next_onehot, v0,
                      *, n_edges: int, u_max: int,
                      interpret: bool | None = None):
    """upsilon/sigma2: (E,) i32; feasible: (E, C) f32 0/1;
    next_onehot: (E, C, C) f32 (one_hot of next-state ids, axis 1 = source);
    v0: (S, C) f32.  Returns (V_final (S, C) f32,
    decisions (⌈E/32⌉, S, C) i32 — bit (e%32) of word (e//32) is edge e).

    ``interpret=None`` resolves via :func:`resolve_interpret` (compiled on
    TPU, interpreter elsewhere)."""
    S, C = v0.shape
    W = packed_words(n_edges)
    kernel = functools.partial(_dp_kernel, n_edges=n_edges, u_max=u_max)
    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((S, C), jnp.float32),
                   jax.ShapeDtypeStruct((W, S, C), jnp.int32)),
        in_specs=[
            pl.BlockSpec((n_edges,), lambda: (0,)),
            pl.BlockSpec((n_edges,), lambda: (0,)),
            pl.BlockSpec((n_edges, C), lambda: (0, 0)),
            pl.BlockSpec((n_edges, C, C), lambda: (0, 0, 0)),
            pl.BlockSpec((S, C), lambda: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((S, C), lambda: (0, 0)),
                   pl.BlockSpec((W, S, C), lambda: (0, 0, 0))),
        scratch_shapes=[pltpu.VMEM((u_max + S, C), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(upsilon, sigma2, feasible, next_onehot, v0)

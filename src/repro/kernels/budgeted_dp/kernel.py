"""Pallas TPU kernel for the ESDP budgeted DP (paper Algorithm 2).

TPU-native design (DESIGN.md §4):
  * the whole (S × C) value plane lives in VMEM (default sizes ≈ 80 KB);
  * the edge loop runs INSIDE one pallas_call via fori_loop;
  * the s-shift gather V[max(s−Υ_e, 0)] uses a padded VMEM scratch whose
    first U_MAX rows hold the clamp row V[0]; a dynamic-START static-SIZE
    slice (pl.ds) then reads the shifted window — no gather op at all;
  * the capacity-state gather becomes a tiny (C × C) one-hot MATMUL on the
    MXU — the standard TPU idiom replacing GPU warp gathers.

Arithmetic is f32 with integer values; exactness holds for values < 2²⁴
(ops.py asserts the bound — see core/stats.py for why defaults are ≪ 2²⁴).
Decisions for the backtrack are written as an (E, S, C) f32 0/1 tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -float(2 ** 24)


def _dp_kernel(ups_ref, sig_ref, feas_ref, next_oh_ref, v0_ref,
               vout_ref, dec_ref, vpad_ref, *, n_edges: int, u_max: int):
    S, C = v0_ref.shape
    vout_ref[:, :] = v0_ref[:, :]

    def edge_step(j, _):
        e = n_edges - 1 - j
        u = ups_ref[e]
        sig = sig_ref[e].astype(jnp.float32)

        V = vout_ref[:, :]
        # padded shift buffer: rows [0, u_max) = clamp row V[0], then V
        vpad_ref[:u_max, :] = jnp.broadcast_to(V[0:1, :], (u_max, C))
        vpad_ref[pl.ds(u_max, S), :] = V
        shifted = vpad_ref[pl.ds(u_max - u, S), :]        # V[max(s-u, 0)]

        # capacity gather as one-hot matmul: take[:, c] = shifted[:, next(c)]
        oh = next_oh_ref[e, :, :]                          # (C, C) one-hot
        take = jax.lax.dot_general(
            shifted, oh, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) + sig

        feas = feas_ref[e, :]                              # (C,) 0/1
        take = jnp.where(feas[None, :] > 0, take, NEG)
        dec = (take > V).astype(jnp.float32)
        dec_ref[e, :, :] = dec
        vout_ref[:, :] = jnp.maximum(V, take)
        return 0

    jax.lax.fori_loop(0, n_edges, edge_step, 0)


@functools.partial(jax.jit, static_argnames=("n_edges", "u_max", "interpret"))
def dp_forward_pallas(upsilon, sigma2, feasible, next_onehot, v0,
                      *, n_edges: int, u_max: int, interpret: bool = True):
    """upsilon/sigma2: (E,) i32; feasible: (E, C) f32 0/1;
    next_onehot: (E, C, C) f32 (one_hot of next-state ids, axis 1 = source);
    v0: (S, C) f32. Returns (V_final (S, C) f32, decisions (E, S, C) f32)."""
    S, C = v0.shape
    kernel = functools.partial(_dp_kernel, n_edges=n_edges, u_max=u_max)
    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((S, C), jnp.float32),
                   jax.ShapeDtypeStruct((n_edges, S, C), jnp.float32)),
        in_specs=[
            pl.BlockSpec((n_edges,), lambda: (0,)),
            pl.BlockSpec((n_edges,), lambda: (0,)),
            pl.BlockSpec((n_edges, C), lambda: (0, 0)),
            pl.BlockSpec((n_edges, C, C), lambda: (0, 0, 0)),
            pl.BlockSpec((S, C), lambda: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((S, C), lambda: (0, 0)),
                   pl.BlockSpec((n_edges, S, C), lambda: (0, 0, 0))),
        scratch_shapes=[pltpu.VMEM((u_max + S, C), jnp.float32)],
        interpret=interpret,
    )(upsilon, sigma2, feasible, next_onehot, v0)

"""Pallas TPU kernel for the ESDP budgeted DP (paper Algorithm 2).

TPU-native design (DESIGN.md §4):
  * the whole (S × C) value plane lives in VMEM (default sizes ≈ 80 KB);
  * the edge loop runs INSIDE one pallas_call via fori_loop;
  * BOTH per-edge gathers are uniform shifts read from one padded VMEM
    scratch with a dynamic-START static-SIZE slice (pl.ds) — no gather op
    and no matmul at all:
      - the s-shift V[max(s−Υ_e, 0)] shifts along the budget (sublane) axis
        through U_MAX clamp rows holding V[0];
      - the capacity transition next(c) = c − offset_e (the mixed-radix
        offset identity validated in core.dp.build_tables) shifts along the
        state (lane) axis through OFF_MAX pad columns; reads landing in the
        pad are exactly the states with c < offset_e, which are infeasible
        and masked to NEG.
    The former (E, C, C) one-hot transition operand — 4·E·C² bytes and an
    O(S·C²) MXU matmul per edge — is now an (E,) int32 offset vector and an
    O(S·C) VPU update, which is what lets large capacity spaces fit VMEM;
  * backtrack decisions are BIT-PACKED into int32 lanes: word ⌊e/32⌋ of the
    (⌈E/32⌉, S, C) output holds bit (e mod 32) for edge e.  At production
    sizes the unpacked (E, S, C) f32 tensor dominated VMEM (E=64, S=512,
    C=256 ⇒ 32 MB — over the ~16 MB/core budget); packing is 32× smaller.

When even the (S, C) value plane outgrows VMEM, ``block_c`` switches to a
C-BLOCKED pipeline: a lax.scan over edges, each edge one pallas_call gridded
over capacity tiles.  The offset shift only ever reads LEFT (towards smaller
state ids), so a tile plus its left neighbor — a haloed block load expressed
as two BlockSpec views of the same plane, legal because block_c ≥ OFF_MAX —
covers every read, and the plane streams HBM↔VMEM one (S, block_c) tile at
a time.  Functional double-buffering (the per-edge call maps V → V′) keeps
the pipeline free of in-place aliasing hazards.

Long horizons additionally tile the BUDGET axis: ``block_s`` extends the
pipeline to a 2-D (S-tile × C-tile) grid.  The s-shift only ever reads UP
(towards smaller budgets, by at most u_max ≤ block_s rows), so each tile
needs an up-neighbor halo of u_max rows on top of the left-neighbor halo —
four BlockSpec views of the same plane per grid step ((i−1, j−1), (i−1, j),
(i, j−1), (i, j)) assembled into one (u_max + block_s, 2·block_c) scratch.
Tile row 0 has no up neighbor and replicates the plane's clamp row V[0]
instead, exactly like the whole-plane kernel's clamp rows.  Per-tile VMEM
is then independent of BOTH plane extents, which is what lets S ≳ 4096
with large C run at all; ``choose_tiling`` picks the largest (block_s,
block_c) pair that fits the VMEM budget.

``block_e`` FUSES the edge loop into that grid — a temporal blocking of
the DP recurrence.  The per-edge-scan pipelines above re-stream the whole
value plane HBM↔VMEM once per edge; the fused pipeline runs one
pallas_call per chunk of ``block_e`` consecutive edges, and each tile stays
VMEM-resident (in the shift scratch's body region) across the whole chunk,
cutting plane traffic ``block_e``-fold.  The price is the halo: by the
time tile (i, j) runs, its up/left neighbors have already advanced through
ALL ``block_e`` edges of the chunk, so their boundary values at each
*intermediate* edge must be preserved.  Two persistent VMEM scratch
buffers carry exactly that history across grid steps (the TPU grid is
sequential, and scratch survives grid iterations):

  * ``lefth`` — (block_e, block_s, off_max): the last off_max columns of
    the previous C-tile *before* each edge of the chunk.  Each tile reads
    its left halo for edge k from ``lefth[k]``, then overwrites it with
    its own pre-edge-k boundary for the next tile (read-then-write within
    one grid step, so a single buffer suffices along C);
  * ``rowh`` — (2 × block_e, u_max, C_padded): the bottom u_max rows of
    every tile of the previous S-row, per edge, double-banked by S-row
    parity — tile (i, j) reads bank (i−1) mod 2 (up halo at columns of
    tiles j−1 and j, the j−1 part being the up-left corner) and writes
    bank i mod 2, so row i's writes never clobber the corner history row
    i+1 still needs.

Decision bits for the whole chunk pack into ONE (S, C) int32 word-plane
per tile (bit Υ = global edge id mod 32 — legal because block_e ≤ 32 keeps
in-chunk bit positions distinct); the host scan ORs each chunk word into
the packed (⌈E/32⌉, S, C) decision planes through static per-chunk word
masks, which also handles chunks straddling a 32-bit word boundary.

``dp_forward_pallas_batched`` runs a FLEET of B independent solves in one
pallas_call.  The batch rides the grid: ``block_b`` instances advance
together per grid step, the shared operands (feasibility plane, offsets,
v0) are loaded through index maps that ignore the batch index — one copy
in HBM, never replicated B-fold the way folding per-instance eligibility
into the feasibility plane under ``jax.vmap`` replicates it — and the
per-instance inputs (Υ̂, Σ̂², ``allowed``) stream as (block_b, E) SMEM
rows.  Inside a step the edge loop is VECTORIZED across the block's
instances: the per-instance budget shift V[max(s−Υ̂_e, 0)] becomes
⌈log₂(u_max+1)⌉ static slice-concat stages selected per instance by the
bits of Υ̂_e (clamped shifts compose exactly: T_b∘T_a = T_{a+b}), so the
kernel stays gather-free with a batch-varying shift.  ``block_b = 1``
degenerates to the single-instance schedule (one dynamic-start read, no
log-shift stages) — bit-identical either way.  Ragged batches pad with
inert instances (``allowed ≡ 0`` masks every edge to NEG, so the pads
compute v0 and zero decisions).  When the per-instance plane outgrows
VMEM the batch instead becomes the OUTERMOST grid dimension of the
edge-fused pipeline (block_b pinned to 1): each instance re-initializes
the halo-history scratches at its own (i=0, j=0) corner, so the fused
kernel body is reused unchanged.  ``choose_tiling(..., batch=B)``
resolves the whole (block_b, block_e, block_s, block_c) split, shrinking
the batch axis BEFORE the plane axes.

Arithmetic is f32 with integer values; exactness holds for values < 2²⁴
(ops.py enforces the bound — see core/stats.py for why defaults are ≪ 2²⁴).

Backend resolution: ``interpret=None`` (the default) compiles on TPU and
falls back to the Pallas interpreter elsewhere — the kernel is never
silently interpreted on real TPU hardware.  Pass an explicit bool to force
either mode (``interpret=True`` is how the differential tests exercise the
kernel logic on CPU CI).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["NEG", "VMEM_BUDGET_BYTES", "MAX_BLOCK_E", "resolve_interpret",
           "packed_words", "unblocked_vmem_bytes", "c_blocked_tile_vmem_bytes",
           "tiled_vmem_bytes", "fused_tile_vmem_bytes", "batched_vmem_bytes",
           "batched_fused_tile_vmem_bytes", "modeled_hbm_bytes",
           "batched_modeled_hbm_bytes", "choose_tiling", "dp_forward_pallas",
           "dp_forward_pallas_batched"]

NEG = -float(2 ** 24)

# conservative share of the ~16 MB/core VMEM left to this kernel
VMEM_BUDGET_BYTES = 12 * 2 ** 20

# fused chunks pack their decision bits into ONE int32 word-plane, so
# in-chunk bit positions (global edge id mod 32) must be distinct
MAX_BLOCK_E = 32


def resolve_interpret(
    interpret: bool | None = None, platform: str | None = None
) -> bool:
    """Resolve the kernel execution mode.

    ``None`` → auto: compiled (``False``) on TPU, interpreter (``True``)
    everywhere else.  ``platform`` overrides ``jax.default_backend()`` so the
    resolution table is unit-testable without the hardware.
    """
    if interpret is not None:
        return bool(interpret)
    platform = platform or jax.default_backend()
    return platform != "tpu"


def packed_words(n_edges: int) -> int:
    """Leading dim of the packed decision tensor: ⌈E/32⌉ int32 words."""
    return (n_edges + 31) // 32


def unblocked_vmem_bytes(S: int, C: int, n_edges: int, u_max: int, off_max: int) -> int:
    """VMEM footprint of the whole-plane kernel: v0 + V + packed decisions +
    the (u_max+S, off_max+C) shift scratch + the (E, C) feasibility plane +
    the three (E,) operand vectors, all 4-byte."""
    W = packed_words(n_edges)
    return 4 * ((2 + W) * S * C + (u_max + S) * (off_max + C)
                + n_edges * (C + 3))


def c_blocked_tile_vmem_bytes(S: int, block_c: int, u_max: int) -> int:
    """Per-grid-step VMEM of the C-blocked (full-height) pipeline: two
    haloed (S, block_c) input views + two output tiles + the
    (u_max + S, 2·block_c) shift scratch + the feasibility tile, 4-byte."""
    return 4 * (4 * S * block_c + (u_max + S) * 2 * block_c + block_c)


def tiled_vmem_bytes(block_s: int, block_c: int, u_max: int) -> int:
    """Per-grid-step VMEM of the 2-D (S-tile × C-tile) pipeline: four
    haloed (block_s, block_c) input views + two output tiles + the
    (u_max + block_s, 2·block_c) shift scratch + the feasibility tile —
    independent of both plane extents."""
    return 4 * (6 * block_s * block_c
                + (u_max + block_s) * 2 * block_c + block_c)


def fused_tile_vmem_bytes(
    block_e: int, block_s: int, block_c: int, u_max: int, off_max: int, S: int, C: int
) -> int:
    """Per-grid-step VMEM of the edge-fused pipeline: one (block_s, block_c)
    input tile + two output tiles (value + chunk bits) + the
    (u_max + block_s, off_max + block_c) shift scratch + the per-chunk
    feasibility block + the two persistent halo-history scratches —
    ``lefth`` (block_e, block_s, off_max) and the double-banked ``rowh``
    (2·block_e, u_max, C_padded), the only term that scales with the plane
    width.  A single-S-row grid (block_s ≥ S, i.e. full-height tiles) has
    no up neighbors: ``rowh`` is neither allocated nor charged, which is
    what keeps large fused chunks affordable at very large C.  All
    4-byte."""
    Cp = -(-C // block_c) * block_c
    rowh = 0 if block_s >= S else 2 * block_e * max(u_max, 1) * Cp
    return 4 * (3 * block_s * block_c
                + (u_max + block_s) * (off_max + block_c)
                + block_e * block_c  # feasibility chunk
                + rowh  # rowh banks
                + block_e * block_s * max(off_max, 1)  # lefth
                + 4 * block_e)  # SMEM scalars


def batched_vmem_bytes(
    S: int, C: int, n_edges: int, u_max: int, off_max: int, block_b: int
) -> int:
    """VMEM footprint of one grid step of the whole-plane BATCHED kernel:
    the per-instance value plane + packed decision words + shift scratch +
    the three (E,) operand rows, all charged × ``block_b``, plus the
    SHARED v0 plane, feasibility plane, and offset vector (loaded once per
    step regardless of the batch).  ``block_b = 1`` keeps the
    single-instance clamp-row scratch geometry (u_max extra rows); the
    vectorized path (block_b > 1) shifts through log₂ stages instead and
    drops them.  All 4-byte."""
    W = packed_words(n_edges)
    pad_rows = u_max if block_b == 1 else 0
    per = (1 + W) * S * C + (pad_rows + S) * (off_max + C) + 3 * n_edges
    return 4 * (block_b * per + S * C + n_edges * (C + 1))


def batched_fused_tile_vmem_bytes(
    block_e: int,
    block_s: int,
    block_c: int,
    u_max: int,
    off_max: int,
    S: int,
    C: int,
    block_b: int,
) -> int:
    """Per-grid-step VMEM of the BATCHED edge-fused pipeline: the shared
    per-chunk feasibility block and offset/bit-position rows load once;
    everything per-instance — the plane tile, the shift scratch, both
    halo-history scratches, and the (1, block_e) Υ̂/Σ̂²/allowed rows —
    charges × ``block_b``.  The batched driver pins ``block_b = 1`` on
    this path (one instance per grid step — the per-instance halo
    histories are what overflowed the budget in the first place), but the
    model keeps the general form so the batched decision rule charges the
    batch axis uniformly.  All 4-byte."""
    Cp = -(-C // block_c) * block_c
    rowh = 0 if block_s >= S else 2 * block_e * max(u_max, 1) * Cp
    per = (3 * block_s * block_c
           + (u_max + block_s) * (off_max + block_c)
           + rowh
           + block_e * block_s * max(off_max, 1)
           + 3 * block_e)  # Υ̂/Σ̂²/allowed SMEM rows
    shared = block_e * block_c + 2 * block_e  # feas chunk + offs/bitpos
    return 4 * (block_b * per + shared)


def modeled_hbm_bytes(
    S: int, C: int, n_edges: int, u_max: int, off_max: int, block_e, block_s, block_c
) -> int:
    """Modeled HBM bytes streamed by one DP forward solve under a tiling.

    Counts the plane-sized flows only (operand vectors are O(E)): value
    blocks read/written by the pallas pipeline, the per-step feasibility
    blocks, and the host-side merge of decision bits into the packed
    (⌈E/32⌉, S, C) words (a read-modify-write of one word plane per edge
    for the scan pipelines, of all W planes per chunk for the fused one).
    The whole-plane kernel streams everything exactly once.  This is the
    ``hbm_bytes_streamed`` model `benchmarks/dp_bench.py` records — a
    traffic model for the perf trend, not a measurement.
    """
    W = packed_words(n_edges)
    if block_c is None:  # whole-plane, VMEM-resident
        return 4 * (S * C  # v0 in
                    + n_edges * C  # feasibility plane in
                    + S * C  # V out
                    + W * S * C)  # packed decisions out
    Cp = -(-C // block_c) * block_c
    Sp = S if block_s is None else -(-S // block_s) * block_s
    plane = 4 * Sp * Cp
    if block_e is None:
        # one pallas_call per edge: every tile re-loads its halo views
        # (2 for the C-blocked row, 4 for the 2-D grid), writes V' + bits,
        # and the host ORs the bits into one packed word (read + write)
        views = 2 if block_s is None else 4
        per_edge = (views + 2) * plane + 2 * plane + 4 * Cp
        return n_edges * per_edge
    # fused: each chunk streams the plane in/out ONCE, plus the chunk's
    # bits plane and the W-word packed-decision merge
    n_chunks = -(-n_edges // block_e)
    per_chunk = (1 + 2) * plane + (1 + 2 * W) * plane + 4 * block_e * Cp
    return n_chunks * per_chunk


def batched_modeled_hbm_bytes(
    S: int,
    C: int,
    n_edges: int,
    u_max: int,
    off_max: int,
    batch: int,
    block_e=None,
    block_s=None,
    block_c=None,
) -> int:
    """Modeled HBM bytes streamed by ONE batched forward of ``batch``
    solves: the shared operands stream once, the per-instance flows ×
    ``batch``.  The vmapped-single-launch alternative replicates the
    shared operands per instance (vmap folds per-instance eligibility
    into ``batch`` copies of the feasibility plane), so its model is
    simply ``batch · modeled_hbm_bytes(...)`` — the ratio of the two is
    the ``hbm_reduction_vs_vmapped`` figure dp_bench records."""
    per = modeled_hbm_bytes(S, C, n_edges, u_max, off_max,
                            block_e, block_s, block_c)
    if block_c is None:
        shared = 4 * (S * C + n_edges * C)  # v0 + feasibility plane
    else:
        Cp = -(-C // block_c) * block_c
        if block_e is None:
            shared = 4 * n_edges * Cp  # feasibility tiles per edge
        else:
            shared = 4 * -(-n_edges // block_e) * block_e * Cp
    return shared + batch * (per - shared)


def _tile_candidates(extent: int, unit: int, floor: int) -> list:
    """Descending tile widths for one axis: the full extent plus every
    power-of-two multiple of ``unit`` below it, all ≥ ``floor`` (the halo
    legality bound — off_max along C, u_max along S)."""
    cands = {extent}
    width = unit
    while width < extent:
        if width >= floor:
            cands.add(width)
        width *= 2
    return sorted(cands, reverse=True)


def choose_tiling(
    S: int,
    C: int,
    n_edges: int,
    u_max: int,
    off_max: int,
    budget: int = VMEM_BUDGET_BYTES,
    batch: int | None = None,
):
    """Pick ``(block_e, block_s, block_c)`` for :func:`dp_forward_pallas`.

    With ``batch=B`` the return value is instead the 4-tuple ``(block_b,
    block_e, block_s, block_c)`` for :func:`dp_forward_pallas_batched`,
    and the BATCH axis shrinks FIRST: the largest ``block_b`` ∈ {B} ∪
    {powers of two below B} whose batched whole-plane footprint
    (:func:`batched_vmem_bytes`) fits the budget keeps every instance's
    full plane VMEM-resident — a smaller fleet per grid step is always
    cheaper than giving up plane residency.  Only when even ``block_b =
    1`` overflows does the per-instance plane tile (by the 3-tuple rule
    below) with ``block_b`` pinned to 1 (the fused pipeline batches as
    the outermost grid dimension, one instance per step).

    Returns ``(None, None, None)`` when the whole-plane kernel fits the
    VMEM budget (edges already run inside one pallas_call there — nothing
    to fuse).  Otherwise the plane tiles exactly as before — ``block_s is
    None`` selects the C-blocked (full-height) pipeline when some legal
    capacity tile fits, else the largest 2-D tile pair (maximizing
    block_s·block_c, ties to the wider lane-contiguous block_c) — and
    ``block_e`` is then the largest edge-chunk ≤ min(``MAX_BLOCK_E``, E)
    whose fused pipeline (``fused_tile_vmem_bytes``: the plane tile plus
    the halo-history scratches) still fits the budget, cutting HBM plane
    traffic ``block_e``-fold.  ``block_e is None`` falls back to the
    per-edge-scan pipelines (one pallas_call per edge) — only reachable
    when even a 1-edge chunk's history scratch overflows the budget.

    Tiles respect the halo floors (block_c ≥ off_max, block_s ≥ u_max) and
    the VPU lane/sublane units (128 along C, 8 along S) wherever the
    floors allow; if even the smallest legal pair exceeds the budget it is
    returned anyway — no smaller tiling exists.
    """
    if batch is not None:
        if batch < 1:
            raise ValueError(f"batch={batch} must be >= 1")
        for bb in _tile_candidates(batch, 1, 1):
            if batched_vmem_bytes(S, C, n_edges, u_max, off_max,
                                  bb) <= budget:
                return bb, None, None, None
        return (1,) + choose_tiling(S, C, n_edges, u_max, off_max, budget)
    if unblocked_vmem_bytes(S, C, n_edges, u_max, off_max) <= budget:
        return None, None, None
    c_cands = _tile_candidates(C, 128, off_max)
    block_s = block_c = None
    for bc in c_cands:  # widest full-height first
        if c_blocked_tile_vmem_bytes(S, bc, u_max) <= budget:
            block_c = bc
            break
    if block_c is None:
        s_cands = _tile_candidates(S, 8, max(u_max, 1))
        best = None
        for bs in s_cands:
            for bc in c_cands:
                if bs == S and bc == C:
                    continue  # that is the whole plane
                if tiled_vmem_bytes(bs, bc, u_max) > budget:
                    continue
                if (best is None or bs * bc > best[0] * best[1]
                        or (bs * bc == best[0] * best[1] and bc > best[1])):
                    best = (bs, bc)
        if best is None:
            best = (s_cands[-1], c_cands[-1])  # floor pair: best possible
        block_s, block_c = best
    bs_eff = S if block_s is None else block_s
    for be in range(min(MAX_BLOCK_E, max(n_edges, 1)), 0, -1):
        if fused_tile_vmem_bytes(be, bs_eff, block_c, u_max, off_max,
                                 S, C) <= budget:
            return be, block_s, block_c
    return None, block_s, block_c


def _dp_kernel(
    ups_ref,
    sig_ref,
    offs_ref,
    feas_ref,
    v0_ref,
    vout_ref,
    dec_ref,
    vpad_ref,
    *,
    n_edges: int,
    u_max: int,
    off_max: int,
):
    S, C = v0_ref.shape
    W = dec_ref.shape[0]
    vout_ref[:, :] = v0_ref[:, :]
    dec_ref[:, :, :] = jnp.zeros((W, S, C), jnp.int32)
    if off_max:
        # pad columns: read only for states with c < offset_e, all infeasible
        # and masked to NEG below — NEG keeps the reads inert either way
        vpad_ref[:, :off_max] = jnp.full((u_max + S, off_max), NEG,
                                         jnp.float32)

    def edge_step(j, _):
        e = n_edges - 1 - j
        u = jnp.minimum(ups_ref[e], u_max)  # clamp: never read past pad
        off = jnp.minimum(offs_ref[e], off_max)
        sig = sig_ref[e].astype(jnp.float32)

        V = vout_ref[:, :]
        # padded shift buffer: rows [0, u_max) = clamp row V[0], then V;
        # the value plane sits at columns [off_max, off_max + C)
        vpad_ref[:u_max, off_max:] = jnp.broadcast_to(V[0:1, :], (u_max, C))
        vpad_ref[pl.ds(u_max, S), off_max:] = V
        # one 2-D shifted read: V[max(s-u, 0), c - off]
        take = vpad_ref[pl.ds(u_max - u, S), pl.ds(off_max - off, C)] + sig

        feas = feas_ref[e, :]  # (C,) 0/1
        take = jnp.where(feas[None, :] > 0, take, NEG)
        dec = (take > V).astype(jnp.int32)
        # OR edge e's decision bit into its int32 word (bit = e mod 32;
        # multiply by the power of two — exact, and 1<<31 wraps to the sign
        # bit whose pattern is still the bit we want)
        bit = jnp.left_shift(jnp.int32(1), e % 32)
        word = dec_ref[pl.ds(e // 32, 1), :, :]
        dec_ref[pl.ds(e // 32, 1), :, :] = word | (dec * bit)[None]
        vout_ref[:, :] = jnp.maximum(V, take)
        return 0

    jax.lax.fori_loop(0, n_edges, edge_step, 0)


def _shift_rows_clamped(x, u, u_max: int):
    """Per-instance clamped budget shift: y[b, s, c] = x[b, max(s − u[b], 0), c].

    Decomposed into ⌈log₂(u_max + 1)⌉ STATIC slice-concat stages, stage k
    applied only to instances with bit k set in u — legal because clamped
    shifts compose exactly (T_b ∘ T_a = T_{a+b}: clamping at 0 is
    idempotent under further down-shifts).  Keeps the batch-varying shift
    gather-free and lane-contiguous on the VPU."""
    bb, S, C = x.shape
    shift = 1
    while shift <= u_max:
        if shift < S:
            rolled = jnp.concatenate(
                [jnp.broadcast_to(x[:, :1], (bb, shift, C)), x[:, :S - shift]],
                axis=1)
        else:
            rolled = jnp.broadcast_to(x[:, :1], (bb, S, C))
        x = jnp.where((u & shift).astype(bool)[:, None, None], rolled, x)
        shift *= 2
    return x


def _dp_kernel_batched(
    ups_ref,
    sig_ref,
    alw_ref,
    offs_ref,
    feas_ref,
    v0_ref,
    vout_ref,
    dec_ref,
    vpad_ref,
    *,
    n_edges: int,
    u_max: int,
    off_max: int,
):
    """Whole-plane DP forward over ``block_b`` instances per grid step.

    Per-instance operands arrive as (block_b, E) SMEM rows; the
    feasibility plane and v0 are the SHARED blocks (their index maps
    ignore the batch index).  Per-instance eligibility multiplies into
    the mask HERE (``live = feasible ∧ allowed``) instead of being folded
    into per-instance feasibility copies on the host.  The edge loop runs
    per 32-edge word with the decision word accumulated in registers and
    written back once per word (static-index write).  ``block_b == 1``
    reduces the budget shift to the single-instance schedule — one
    dynamic-start read through u_max clamp rows, bit-identical to
    :func:`_dp_kernel`; ``block_b > 1`` vectorizes it through
    :func:`_shift_rows_clamped` on a clamp-row-free scratch."""
    block_b, S, C = vout_ref.shape
    W = dec_ref.shape[1]
    vout_ref[:, :, :] = jnp.broadcast_to(v0_ref[:, :][None], (block_b, S, C))
    if off_max:
        # pad columns: read only by states with c < offset_e, all
        # infeasible and masked to NEG below — inert either way
        vpad_ref[:, :, :off_max] = jnp.full(
            (block_b, vpad_ref.shape[1], off_max), NEG, jnp.float32)

    for w in range(W - 1, -1, -1):  # edges E-1 … 0, word-major
        e_lo = w * 32
        e_hi = min(e_lo + 32, n_edges)

        def edge_step(jj, word, e_hi=e_hi):
            e = e_hi - 1 - jj
            u = jnp.minimum(ups_ref[:, pl.ds(e, 1)][:, 0], u_max)
            off = jnp.minimum(offs_ref[e], off_max)
            sig = sig_ref[:, pl.ds(e, 1)].astype(jnp.float32)[:, :, None]
            alw = alw_ref[:, pl.ds(e, 1)][:, :, None]
            V = vout_ref[:, :, :]
            if block_b == 1:
                # single-instance schedule: scalar shift through clamp rows
                vpad_ref[:, :u_max, off_max:] = jnp.broadcast_to(
                    V[:, 0:1, :], (1, u_max, C))
                vpad_ref[:, pl.ds(u_max, S), off_max:] = V
                take = vpad_ref[:, pl.ds(u_max - u[0], S),
                                pl.ds(off_max - off, C)]
            else:
                vpad_ref[:, :, off_max:] = V
                shifted = vpad_ref[:, :, pl.ds(off_max - off, C)]
                take = _shift_rows_clamped(shifted, u, u_max)
            take = take + sig
            live = (feas_ref[pl.ds(e, 1), :][None] > 0) & (alw > 0)
            take = jnp.where(live, take, NEG)
            dec = (take > V).astype(jnp.int32)
            vout_ref[:, :, :] = jnp.maximum(V, take)
            return word | (dec * jnp.left_shift(jnp.int32(1), e % 32))

        word = jax.lax.fori_loop(0, e_hi - e_lo, edge_step,
                                 jnp.zeros((block_b, S, C), jnp.int32))
        dec_ref[:, w] = word


def _edge_tile_kernel(
    u_ref,
    off_ref,
    sig_ref,
    feas_ref,
    vleft_ref,
    vcur_ref,
    vout_ref,
    bits_ref,
    vpad_ref,
    *,
    u_max: int,
):
    """One edge update on one (S, B) capacity tile.

    ``vleft``/``vcur`` are two views of the SAME value plane: the tile and
    its left neighbor (tile 0 reads itself — those columns are c < offset_e,
    infeasible, masked).  The concatenated (u_max+S, 2B) scratch makes both
    shifts single dynamic-start reads, exactly like the whole-plane kernel.
    """
    S, B = vcur_ref.shape
    u = jnp.minimum(u_ref[0], u_max)
    off = jnp.minimum(off_ref[0], B)
    sig = sig_ref[0].astype(jnp.float32)
    left = vleft_ref[:, :]
    cur = vcur_ref[:, :]

    vpad_ref[:u_max, :B] = jnp.broadcast_to(left[0:1, :], (u_max, B))
    vpad_ref[:u_max, B:] = jnp.broadcast_to(cur[0:1, :], (u_max, B))
    vpad_ref[pl.ds(u_max, S), :B] = left
    vpad_ref[pl.ds(u_max, S), B:] = cur
    take = vpad_ref[pl.ds(u_max - u, S), pl.ds(B - off, B)] + sig

    take = jnp.where(feas_ref[0:1, :] > 0, take, NEG)
    bits_ref[:, :] = (take > cur).astype(jnp.int32)
    vout_ref[:, :] = jnp.maximum(cur, take)


def _edge_stile_kernel(
    u_ref,
    off_ref,
    sig_ref,
    feas_ref,
    vup_left_ref,
    vup_cur_ref,
    vleft_ref,
    vcur_ref,
    vout_ref,
    bits_ref,
    vpad_ref,
    *,
    u_max: int,
):
    """One edge update on one (block_s, block_c) tile of the 2-D grid.

    The four ``v*`` refs are views of the SAME value plane: the tile, its
    left neighbor, and the up-neighbor row of both (S-tile 0 reads itself
    upward and substitutes the plane's clamp row V[0] — budgets below 0
    clamp to V[0], exactly the whole-plane kernel's clamp rows; C-tile 0
    reads itself leftward — those columns are c < offset_e, infeasible,
    masked).  The (u_max + block_s, 2·block_c) scratch makes both shifts
    single dynamic-start reads."""
    Bs, Bc = vcur_ref.shape
    u = jnp.minimum(u_ref[0], u_max)
    off = jnp.minimum(off_ref[0], Bc)
    sig = sig_ref[0].astype(jnp.float32)
    left = vleft_ref[:, :]
    cur = vcur_ref[:, :]

    if u_max:
        # halo rows [0, u_max): last u_max rows of the up-neighbor tile,
        # or the replicated clamp row V[0] on the first S tile (u_max ≤
        # block_s keeps the halo inside ONE up neighbor)
        first = pl.program_id(0) == 0
        vpad_ref[:u_max, :Bc] = jnp.where(
            first, jnp.broadcast_to(left[0:1, :], (u_max, Bc)),
            vup_left_ref[Bs - u_max:, :])
        vpad_ref[:u_max, Bc:] = jnp.where(
            first, jnp.broadcast_to(cur[0:1, :], (u_max, Bc)),
            vup_cur_ref[Bs - u_max:, :])
    vpad_ref[pl.ds(u_max, Bs), :Bc] = left
    vpad_ref[pl.ds(u_max, Bs), Bc:] = cur
    take = vpad_ref[pl.ds(u_max - u, Bs), pl.ds(Bc - off, Bc)] + sig

    take = jnp.where(feas_ref[0:1, :] > 0, take, NEG)
    bits_ref[:, :] = (take > cur).astype(jnp.int32)
    vout_ref[:, :] = jnp.maximum(cur, take)


def _edge_call(
    V, feas_e, u1, off1, sig1, *, u_max: int, block_s, block_c: int, interpret: bool
):
    Sp, Cp = V.shape
    scalar_specs = [
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec(memory_space=pltpu.SMEM),
        pl.BlockSpec(memory_space=pltpu.SMEM),
    ]
    if block_s is None:
        kernel = functools.partial(_edge_tile_kernel, u_max=u_max)
        return pl.pallas_call(
            kernel,
            grid=(Cp // block_c,),
            out_shape=(jax.ShapeDtypeStruct((Sp, Cp), jnp.float32),
                       jax.ShapeDtypeStruct((Sp, Cp), jnp.int32)),
            in_specs=scalar_specs + [
                pl.BlockSpec((1, block_c), lambda j: (0, j)),
                pl.BlockSpec((Sp, block_c),
                             lambda j: (0, jnp.maximum(j - 1, 0))),
                pl.BlockSpec((Sp, block_c), lambda j: (0, j)),
            ],
            out_specs=(pl.BlockSpec((Sp, block_c), lambda j: (0, j)),
                       pl.BlockSpec((Sp, block_c), lambda j: (0, j))),
            scratch_shapes=[pltpu.VMEM((u_max + Sp, 2 * block_c),
                                       jnp.float32)],
            interpret=interpret,
        )(u1, off1, sig1, feas_e, V, V)
    kernel = functools.partial(_edge_stile_kernel, u_max=u_max)

    def up(i):
        return jnp.maximum(i - 1, 0)

    return pl.pallas_call(
        kernel,
        grid=(Sp // block_s, Cp // block_c),
        out_shape=(jax.ShapeDtypeStruct((Sp, Cp), jnp.float32),
                   jax.ShapeDtypeStruct((Sp, Cp), jnp.int32)),
        in_specs=scalar_specs + [
            pl.BlockSpec((1, block_c), lambda i, j: (0, j)),
            pl.BlockSpec((block_s, block_c), lambda i, j: (up(i), up(j))),
            pl.BlockSpec((block_s, block_c), lambda i, j: (up(i), j)),
            pl.BlockSpec((block_s, block_c), lambda i, j: (i, up(j))),
            pl.BlockSpec((block_s, block_c), lambda i, j: (i, j)),
        ],
        out_specs=(pl.BlockSpec((block_s, block_c), lambda i, j: (i, j)),
                   pl.BlockSpec((block_s, block_c), lambda i, j: (i, j))),
        scratch_shapes=[pltpu.VMEM((u_max + block_s, 2 * block_c),
                                   jnp.float32)],
        interpret=interpret,
    )(u1, off1, sig1, feas_e, V, V, V, V)


def _fused_chunk_kernel(
    ups_ref,
    offs_ref,
    sig_ref,
    bitpos_ref,
    feas_ref,
    vin_ref,
    vout_ref,
    bits_ref,
    vpad_ref,
    rowh_ref,
    lefth_ref,
    *,
    n_chunk: int,
    u_max: int,
    off_max: int,
    multi_row: bool,
    grid_base: int = 0,
    alw_ref=None,
):
    """``n_chunk`` consecutive edges on one (block_s, block_c) tile.

    The tile lives in the BODY region of ``vpad`` (rows [u_max:], columns
    [off_max:]) for the whole chunk — loaded from HBM once, written back
    once.  Per edge k the halo regions refresh from the persistent history
    scratches (see the module docstring): ``lefth[k]`` holds the left
    neighbor's last off_max columns *before* edge k (read, then overwritten
    with this tile's own pre-edge-k boundary for the next C-tile), and
    ``rowh`` holds the previous S-row's bottom u_max rows per edge,
    double-banked by row parity so the up-left corner read never races the
    current row's writes.  S-row 0 replicates the clamp row V[0] (= body
    row 0, and the left halo's row 0 for the corner columns) exactly like
    the unfused kernels; C-tile 0's left halo is garbage by construction —
    every read landing there is a state c < offset_e, infeasible, masked
    to NEG.  Decision bits of the whole chunk OR into one int32 word plane
    at bit ``bitpos[k]`` (global edge id mod 32).

    Batched reuse: the batched pipeline prepends the batch as grid axis 0
    (``grid_base=1`` shifts the (i, j) grid ids right) and passes the
    per-instance eligibility row as ``alw_ref`` — everything else is
    byte-identical, because each instance re-initializes the body, bits,
    and halo state at its own (i=0, j=0) corner: the body reloads from
    ``vin`` every step, the clamp-row branch covers i=0 without reading
    ``rowh``, and the j=0 ``lefth`` columns are only ever read by
    infeasible (masked) states."""
    Bs = vin_ref.shape[0]
    Bc = vin_ref.shape[1]
    i = pl.program_id(grid_base)
    rd = (i + 1) % 2  # rowh bank written by S-row i-1
    wr = i % 2
    j = pl.program_id(grid_base + 1)
    vpad_ref[pl.ds(u_max, Bs), pl.ds(off_max, Bc)] = vin_ref[:, :]
    bits_ref[:, :] = jnp.zeros((Bs, Bc), jnp.int32)

    def edge_step(k, _):
        u = jnp.minimum(ups_ref[k], u_max)
        off = jnp.minimum(offs_ref[k], off_max)
        sig = sig_ref[k].astype(jnp.float32)
        bit = jnp.left_shift(jnp.int32(1), bitpos_ref[k])

        if off_max:
            # left halo for edge k, then this tile's own boundary history
            # (pre-edge-k values) — read-then-write keeps one buffer legal
            vpad_ref[pl.ds(u_max, Bs), :off_max] = \
                lefth_ref[pl.ds(k, 1)][0]
            lefth_ref[pl.ds(k, 1)] = \
                vpad_ref[pl.ds(u_max, Bs), pl.ds(Bc, off_max)][None]
        if u_max and multi_row:
            @pl.when(i > 0)
            def _up_from_history():
                bank = rd * n_chunk + k
                vpad_ref[:u_max, pl.ds(off_max, Bc)] = \
                    rowh_ref[pl.ds(bank, 1), :, pl.ds(j * Bc, Bc)][0]
                if off_max:
                    # up-left corner: bottom-right of tile (i-1, j-1);
                    # j == 0 clamps to garbage that only infeasible
                    # states (c < offset_e) ever read
                    start = jnp.maximum(j * Bc - off_max, 0)
                    vpad_ref[:u_max, :off_max] = \
                        rowh_ref[pl.ds(bank, 1), :, pl.ds(start, off_max)][0]

            @pl.when(i == 0)
            def _up_from_clamp_row():
                # budgets below 0 clamp to V[0] — body row 0 across the
                # full scratch width (the corner columns got the left
                # halo's row 0, written just above)
                vpad_ref[:u_max, :] = jnp.broadcast_to(
                    vpad_ref[pl.ds(u_max, 1), :], (u_max, off_max + Bc))
            # bottom-rows history (pre-edge-k) for S-row i+1
            rowh_ref[pl.ds(wr * n_chunk + k, 1), :, pl.ds(j * Bc, Bc)] = \
                vpad_ref[pl.ds(Bs, u_max), pl.ds(off_max, Bc)][None]
        elif u_max:
            # single-S-row grid: no up neighbors exist, no history to keep
            # — every tile just replicates its clamp row V[0]
            vpad_ref[:u_max, :] = jnp.broadcast_to(
                vpad_ref[pl.ds(u_max, 1), :], (u_max, off_max + Bc))

        cur = vpad_ref[pl.ds(u_max, Bs), pl.ds(off_max, Bc)]
        take = vpad_ref[pl.ds(u_max - u, Bs), pl.ds(off_max - off, Bc)] + sig
        take = jnp.where(feas_ref[k, :][None, :] > 0, take, NEG)
        if alw_ref is not None:
            take = jnp.where(alw_ref[k] > 0, take, NEG)
        dec = (take > cur).astype(jnp.int32)
        bits_ref[:, :] = bits_ref[:, :] | (dec * bit)
        vpad_ref[pl.ds(u_max, Bs), pl.ds(off_max, Bc)] = \
            jnp.maximum(cur, take)
        return 0

    jax.lax.fori_loop(0, n_chunk, edge_step, 0)
    vout_ref[:, :] = vpad_ref[pl.ds(u_max, Bs), pl.ds(off_max, Bc)]


def _chunk_word_masks(n_edges: int, block_e: int) -> np.ndarray:
    """(n_chunks, ⌈E/32⌉) int32: word w's bits owned by chunk c.

    Edges are processed in reverse (E-1 … 0) in chunks of ``block_e``; a
    chunk's bits land at positions e mod 32 of its single word plane, and
    these masks route them into the packed word e // 32 — including chunks
    that straddle a word boundary (their two words get disjoint masks)."""
    W = packed_words(n_edges)
    n_chunks = -(-n_edges // block_e)
    masks = np.zeros((n_chunks, W), np.uint32)
    for idx, e in enumerate(range(n_edges - 1, -1, -1)):
        masks[idx // block_e, e // 32] |= np.uint32(1) << np.uint32(e % 32)
    return masks.view(np.int32)


def _dp_forward_fused(
    upsilon,
    sigma2,
    feasible,
    offsets,
    v0,
    *,
    n_edges: int,
    u_max: int,
    off_max: int,
    block_e: int,
    block_s,
    block_c: int,
    interpret: bool,
):
    if not 1 <= block_e <= MAX_BLOCK_E:
        raise ValueError(
            f"block_e={block_e} outside [1, {MAX_BLOCK_E}]: a fused chunk "
            "packs its decision bits into one int32 word plane, so "
            "in-chunk bit positions (edge id mod 32) must stay distinct")
    S, C = v0.shape
    Cp = -(-C // block_c) * block_c
    bs = S if block_s is None else block_s
    Sp = -(-S // bs) * bs
    V0 = jnp.pad(v0, ((0, Sp - S), (0, Cp - C)), constant_values=NEG)
    feas_p = jnp.pad(feasible, ((0, 0), (0, Cp - C)))  # pad states masked
    W = packed_words(n_edges)
    dec0 = jnp.zeros((W, Sp, Cp), jnp.int32)

    # edges processed E-1 … 0, padded up to whole chunks with inert edges
    # (feasible ≡ 0 masks them to NEG everywhere, so dec ≡ 0)
    n_chunks = -(-n_edges // block_e)
    Ep = n_chunks * block_e
    pad_e = Ep - n_edges
    rev = slice(None, None, -1)

    def _chunked(arr, pad_width):
        return jnp.pad(arr[rev], pad_width).reshape((n_chunks, block_e)
                                                    + arr.shape[1:])

    e_ids = jnp.arange(n_edges - 1, -1, -1, dtype=jnp.int32)
    xs = (_chunked(upsilon, (0, pad_e)),
          _chunked(offsets, (0, pad_e)),
          _chunked(sigma2, (0, pad_e)),
          jnp.pad(e_ids % 32, (0, pad_e)).reshape(n_chunks, block_e),
          _chunked(feas_p, ((0, pad_e), (0, 0))),
          jnp.asarray(_chunk_word_masks(n_edges, block_e)))

    multi_row = Sp // bs > 1
    kernel = functools.partial(_fused_chunk_kernel, n_chunk=block_e,
                               u_max=u_max, off_max=off_max,
                               multi_row=multi_row)
    scalar_specs = [pl.BlockSpec(memory_space=pltpu.SMEM)] * 4
    # a single-S-row grid never reads rowh — allocate a token buffer so
    # the large-C fused regime is not charged 2·block_e·u_max·Cp for it
    rowh_shape = (2 * block_e, max(u_max, 1), Cp) if multi_row else (1, 1, 1)
    call = pl.pallas_call(
        kernel,
        grid=(Sp // bs, Cp // block_c),
        out_shape=(jax.ShapeDtypeStruct((Sp, Cp), jnp.float32),
                   jax.ShapeDtypeStruct((Sp, Cp), jnp.int32)),
        in_specs=scalar_specs + [
            pl.BlockSpec((block_e, block_c), lambda i, j: (0, j)),
            pl.BlockSpec((bs, block_c), lambda i, j: (i, j)),
        ],
        out_specs=(pl.BlockSpec((bs, block_c), lambda i, j: (i, j)),
                   pl.BlockSpec((bs, block_c), lambda i, j: (i, j))),
        scratch_shapes=[
            pltpu.VMEM((u_max + bs, off_max + block_c), jnp.float32),
            pltpu.VMEM(rowh_shape, jnp.float32),
            pltpu.VMEM((block_e, bs, max(off_max, 1)), jnp.float32),
        ],
        interpret=interpret,
    )

    def body(carry, x):
        V, dec = carry
        ups_c, offs_c, sig_c, bitpos_c, feas_c, mask_c = x
        Vn, bits = call(ups_c, offs_c, sig_c, bitpos_c, feas_c, V)
        dec = dec | (bits[None, :, :] & mask_c[:, None, None])
        return (Vn, dec), None

    (V, dec), _ = jax.lax.scan(body, (V0, dec0), xs)
    return V[:S, :C], dec[:, :S, :C]


class _Lead0:
    """Fixed-leading-index view of a batch-blocked ref.

    The batched fused pipeline blocks per-instance operands as (1, …)
    slabs; this adapter lets the 2-D fused-kernel body run on them
    unchanged (every read/write gains a leading 0)."""

    def __init__(self, ref):
        self._ref = ref

    @property
    def shape(self):
        return self._ref.shape[1:]

    @staticmethod
    def _at(idx):
        return (0,) + (idx if isinstance(idx, tuple) else (idx,))

    def __getitem__(self, idx):
        return self._ref[self._at(idx)]

    def __setitem__(self, idx, val):
        self._ref[self._at(idx)] = val


def _batched_fused_kernel(
    ups_ref,
    offs_ref,
    sig_ref,
    bitpos_ref,
    alw_ref,
    feas_ref,
    vin_ref,
    vout_ref,
    bits_ref,
    vpad_ref,
    rowh_ref,
    lefth_ref,
    *,
    n_chunk: int,
    u_max: int,
    off_max: int,
    multi_row: bool,
):
    """Batch-blocked adapter around :func:`_fused_chunk_kernel`: the body
    runs unchanged on the (1, …) instance blocks through
    fixed-leading-index views, with the (i, j) grid ids shifted one axis
    right (batch is the outermost grid dimension) and the per-instance
    ``allowed`` row masking every edge.  Scratches are per-instance state
    and stay 2-D."""
    _fused_chunk_kernel(
        _Lead0(ups_ref), offs_ref, _Lead0(sig_ref), bitpos_ref, feas_ref,
        _Lead0(vin_ref), _Lead0(vout_ref), _Lead0(bits_ref), vpad_ref,
        rowh_ref, lefth_ref, n_chunk=n_chunk, u_max=u_max, off_max=off_max,
        multi_row=multi_row, grid_base=1, alw_ref=_Lead0(alw_ref))


def _dp_forward_fused_batched(
    upsilon,
    sigma2,
    allowed,
    feasible,
    offsets,
    v0,
    *,
    n_edges: int,
    u_max: int,
    off_max: int,
    block_e: int,
    block_s,
    block_c: int,
    interpret: bool,
):
    if not 1 <= block_e <= MAX_BLOCK_E:
        raise ValueError(
            f"block_e={block_e} outside [1, {MAX_BLOCK_E}]: a fused chunk "
            "packs its decision bits into one int32 word plane, so "
            "in-chunk bit positions (edge id mod 32) must stay distinct")
    B = upsilon.shape[0]
    S, C = v0.shape
    Cp = -(-C // block_c) * block_c
    bs = S if block_s is None else block_s
    Sp = -(-S // bs) * bs
    V0 = jnp.broadcast_to(
        jnp.pad(v0, ((0, Sp - S), (0, Cp - C)), constant_values=NEG)[None],
        (B, Sp, Cp))
    feas_p = jnp.pad(feasible, ((0, 0), (0, Cp - C)))  # pad states masked
    W = packed_words(n_edges)
    dec0 = jnp.zeros((B, W, Sp, Cp), jnp.int32)

    n_chunks = -(-n_edges // block_e)
    pad_e = n_chunks * block_e - n_edges
    rev = slice(None, None, -1)

    def _shared_chunks(arr, pad_width):
        return jnp.pad(arr[rev], pad_width).reshape((n_chunks, block_e)
                                                    + arr.shape[1:])

    def _inst_chunks(arr):  # (B, E) → (n_chunks, B, block_e)
        return (jnp.pad(arr[:, rev], ((0, 0), (0, pad_e)))
                .reshape(B, n_chunks, block_e).transpose(1, 0, 2))

    e_ids = jnp.arange(n_edges - 1, -1, -1, dtype=jnp.int32)
    xs = (_inst_chunks(upsilon),
          _shared_chunks(offsets, (0, pad_e)),
          _inst_chunks(sigma2),
          jnp.pad(e_ids % 32, (0, pad_e)).reshape(n_chunks, block_e),
          _inst_chunks(allowed),
          _shared_chunks(feas_p, ((0, pad_e), (0, 0))),
          jnp.asarray(_chunk_word_masks(n_edges, block_e)))

    multi_row = Sp // bs > 1
    kernel = functools.partial(_batched_fused_kernel, n_chunk=block_e,
                               u_max=u_max, off_max=off_max,
                               multi_row=multi_row)
    rowh_shape = (2 * block_e, max(u_max, 1), Cp) if multi_row else (1, 1, 1)
    inst_row = pl.BlockSpec((1, block_e), lambda b, i, j: (b, 0),
                            memory_space=pltpu.SMEM)
    call = pl.pallas_call(
        kernel,
        grid=(B, Sp // bs, Cp // block_c),
        out_shape=(jax.ShapeDtypeStruct((B, Sp, Cp), jnp.float32),
                   jax.ShapeDtypeStruct((B, Sp, Cp), jnp.int32)),
        in_specs=[
            inst_row,  # Υ̂ chunk
            pl.BlockSpec(memory_space=pltpu.SMEM),  # offsets
            inst_row,  # Σ̂² chunk
            pl.BlockSpec(memory_space=pltpu.SMEM),  # bit positions
            inst_row,  # allowed chunk
            pl.BlockSpec((block_e, block_c), lambda b, i, j: (0, j)),
            pl.BlockSpec((1, bs, block_c), lambda b, i, j: (b, i, j)),
        ],
        out_specs=(
            pl.BlockSpec((1, bs, block_c), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, bs, block_c), lambda b, i, j: (b, i, j))),
        scratch_shapes=[
            pltpu.VMEM((u_max + bs, off_max + block_c), jnp.float32),
            pltpu.VMEM(rowh_shape, jnp.float32),
            pltpu.VMEM((block_e, bs, max(off_max, 1)), jnp.float32),
        ],
        interpret=interpret,
    )

    def body(carry, x):
        V, dec = carry
        ups_c, offs_c, sig_c, bitpos_c, alw_c, feas_c, mask_c = x
        Vn, bits = call(ups_c, offs_c, sig_c, bitpos_c, alw_c, feas_c, V)
        dec = dec | (bits[:, None] & mask_c[None, :, None, None])
        return (Vn, dec), None

    (V, dec), _ = jax.lax.scan(body, (V0, dec0), xs)
    return V[:, :S, :C], dec[:, :, :S, :C]


def _dp_forward_blocked(
    upsilon,
    sigma2,
    feasible,
    offsets,
    v0,
    *,
    n_edges: int,
    u_max: int,
    off_max: int,
    block_s,
    block_c: int,
    interpret: bool,
):
    if block_c < off_max:
        raise ValueError(
            f"block_c={block_c} < off_max={off_max}: the offset shift would "
            "reach past the left-neighbor halo tile")
    if block_s is not None and block_s < u_max:
        raise ValueError(
            f"block_s={block_s} < u_max={u_max}: the budget shift would "
            "reach past the up-neighbor halo tile")
    S, C = v0.shape
    Cp = -(-C // block_c) * block_c
    Sp = S if block_s is None else -(-S // block_s) * block_s
    # pad rows/columns sit at the high end of each axis: both shifts read
    # towards SMALLER indices, so real entries never read a pad entry (pad
    # rows/states compute garbage that is sliced away at the end)
    V0 = jnp.pad(v0, ((0, Sp - S), (0, Cp - C)), constant_values=NEG)
    feas_p = jnp.pad(feasible, ((0, 0), (0, Cp - C)))  # pad states masked
    W = packed_words(n_edges)
    dec0 = jnp.zeros((W, Sp, Cp), jnp.int32)

    rev = slice(None, None, -1)  # edges E-1 … 0
    xs = (upsilon[rev], offsets[rev], sigma2[rev], feas_p[rev],
          jnp.arange(n_edges - 1, -1, -1, dtype=jnp.int32))

    def body(carry, x):
        V, dec = carry
        u, off, sig, feas_e, e = x
        Vn, bits = _edge_call(
            V, feas_e[None, :], u[None], off[None], sig[None],
            u_max=u_max, block_s=block_s, block_c=block_c,
            interpret=interpret)
        w = e // 32
        word = jax.lax.dynamic_slice(dec, (w, 0, 0), (1, Sp, Cp))
        word = word | (bits << (e % 32))[None]
        return (Vn, jax.lax.dynamic_update_slice(dec, word, (w, 0, 0))), None

    (V, dec), _ = jax.lax.scan(body, (V0, dec0), xs)
    return V[:S, :C], dec[:, :S, :C]


@functools.partial(jax.jit, static_argnames=("n_edges", "u_max", "off_max",
                                             "interpret", "block_c",
                                             "block_s", "block_e"))
def dp_forward_pallas(
    upsilon,
    sigma2,
    feasible,
    offsets,
    v0,
    *,
    n_edges: int,
    u_max: int,
    off_max: int,
    interpret: bool | None = None,
    block_c: int | None = None,
    block_s: int | None = None,
    block_e: int | None = None,
):
    """upsilon/sigma2/offsets: (E,) i32; feasible: (E, C) f32 0/1;
    v0: (S, C) f32.  Returns (V_final (S, C) f32,
    decisions (⌈E/32⌉, S, C) i32 — bit (e%32) of word (e//32) is edge e).

    ``offsets[e]`` is the mixed-radix transition constant (next(c) = c −
    offsets[e] on feasible states; ``off_max`` ≥ max offsets); ``block_c``
    selects the blocked pipelines, ``block_s`` additionally tiles the
    budget axis (2-D grid; requires ``block_c``), and ``block_e`` fuses
    chunks of that many consecutive edges into each pallas_call (temporal
    blocking — tiles stay VMEM-resident across the chunk; requires
    ``block_c``, 1 ≤ block_e ≤ ``MAX_BLOCK_E``; need not divide E).
    ``choose_tiling`` picks all three from the VMEM budget.
    ``interpret=None`` resolves via :func:`resolve_interpret` (compiled on
    TPU, interpreter elsewhere)."""
    interp = resolve_interpret(interpret)
    if block_s is not None and block_c is None:
        raise ValueError(
            "block_s tiles the budget axis of the blocked pipeline and "
            "needs block_c (pass block_c=C for a single full-width tile)")
    if block_e is not None and block_c is None:
        raise ValueError(
            "block_e fuses edges into the blocked pipeline's grid and "
            "needs block_c (pass block_c=C for a single full-width tile)")
    if block_c is not None:
        if block_c < off_max:
            raise ValueError(
                f"block_c={block_c} < off_max={off_max}: the offset shift "
                "would reach past the left-neighbor halo")
        if block_s is not None and block_s < u_max:
            raise ValueError(
                f"block_s={block_s} < u_max={u_max}: the budget shift "
                "would reach past the up-neighbor halo")
        if block_e is not None:
            return _dp_forward_fused(
                upsilon, sigma2, feasible, offsets, v0, n_edges=n_edges,
                u_max=u_max, off_max=off_max, block_e=block_e,
                block_s=block_s, block_c=block_c, interpret=interp)
        return _dp_forward_blocked(
            upsilon, sigma2, feasible, offsets, v0, n_edges=n_edges,
            u_max=u_max, off_max=off_max, block_s=block_s, block_c=block_c,
            interpret=interp)
    S, C = v0.shape
    W = packed_words(n_edges)
    kernel = functools.partial(_dp_kernel, n_edges=n_edges, u_max=u_max,
                               off_max=off_max)
    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((S, C), jnp.float32),
                   jax.ShapeDtypeStruct((W, S, C), jnp.int32)),
        in_specs=[
            pl.BlockSpec((n_edges,), lambda: (0,)),
            pl.BlockSpec((n_edges,), lambda: (0,)),
            pl.BlockSpec((n_edges,), lambda: (0,)),
            pl.BlockSpec((n_edges, C), lambda: (0, 0)),
            pl.BlockSpec((S, C), lambda: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((S, C), lambda: (0, 0)),
                   pl.BlockSpec((W, S, C), lambda: (0, 0, 0))),
        scratch_shapes=[pltpu.VMEM((u_max + S, off_max + C), jnp.float32)],
        interpret=interp,
    )(upsilon, sigma2, offsets, feasible, v0)


@functools.partial(jax.jit, static_argnames=("n_edges", "u_max", "off_max",
                                             "interpret", "block_b",
                                             "block_c", "block_s",
                                             "block_e"))
def dp_forward_pallas_batched(
    upsilon,
    sigma2,
    allowed,
    feasible,
    offsets,
    v0,
    *,
    n_edges: int,
    u_max: int,
    off_max: int,
    interpret: bool | None = None,
    block_b: int | None = None,
    block_c: int | None = None,
    block_s: int | None = None,
    block_e: int | None = None,
):
    """B independent DP forwards in ONE pallas_call.

    upsilon/sigma2/allowed: (B, E); ``feasible`` (E, C) and ``offsets``
    (E,) are SHARED across the batch — per-instance eligibility rides the
    (B, E) ``allowed`` rows and multiplies into the feasibility mask
    INSIDE the kernel, so the plane is never replicated per instance.
    Returns ``(V (B, S, C) f32, decisions (B, ⌈E/32⌉, S, C) i32)``.

    ``block_b`` instances advance per grid step (default: the whole
    batch in one step); ragged batches (B not a multiple of block_b) pad
    with inert ``allowed ≡ 0`` instances whose outputs are dropped.  With
    a plane tiling (``block_c`` + ``block_e``) the batch becomes the
    outermost grid dimension of the edge-fused pipeline and ``block_b``
    must be 1.  ``choose_tiling(..., batch=B)`` picks all four."""
    interp = resolve_interpret(interpret)
    B = upsilon.shape[0]
    bb = B if block_b is None else block_b
    if not 1 <= bb <= B:
        raise ValueError(
            f"block_b={bb} outside [1, {B}]: the batch grid advances "
            "block_b instances per step and cannot exceed the batch")
    allowed = jnp.asarray(allowed, jnp.int32)
    if block_s is not None and block_c is None:
        raise ValueError(
            "block_s tiles the budget axis of the blocked pipeline and "
            "needs block_c (pass block_c=C for a single full-width tile)")
    if block_e is not None and block_c is None:
        raise ValueError(
            "block_e fuses edges into the blocked pipeline's grid and "
            "needs block_c (pass block_c=C for a single full-width tile)")
    if block_c is not None:
        if block_e is None:
            raise ValueError(
                "batched dispatch supports the whole-plane kernel "
                "(block_c=None) and the edge-fused pipeline (block_e "
                "set); the per-edge-scan pipelines re-stream the plane "
                "once per edge and gain nothing from sharing a launch — "
                "run those instances sequentially instead")
        if bb != 1:
            raise ValueError(
                f"block_b={bb}: the fused pipeline batches as the "
                "outermost grid dimension with one instance per grid "
                "step (block_b=1) — the per-instance halo histories are "
                "what overflowed the VMEM budget in the first place")
        if block_c < off_max:
            raise ValueError(
                f"block_c={block_c} < off_max={off_max}: the offset "
                "shift would reach past the left-neighbor halo")
        if block_s is not None and block_s < u_max:
            raise ValueError(
                f"block_s={block_s} < u_max={u_max}: the budget shift "
                "would reach past the up-neighbor halo")
        return _dp_forward_fused_batched(
            upsilon, sigma2, allowed, feasible, offsets, v0,
            n_edges=n_edges, u_max=u_max, off_max=off_max, block_e=block_e,
            block_s=block_s, block_c=block_c, interpret=interp)
    S, C = v0.shape
    W = packed_words(n_edges)
    Bp = -(-B // bb) * bb
    pad = Bp - B
    upsilon = jnp.pad(upsilon, ((0, pad), (0, 0)))
    sigma2 = jnp.pad(sigma2, ((0, pad), (0, 0)))
    allowed = jnp.pad(allowed, ((0, pad), (0, 0)))  # allowed ≡ 0 ⇒ inert
    scratch = (pltpu.VMEM((1, u_max + S, off_max + C), jnp.float32)
               if bb == 1
               else pltpu.VMEM((bb, S, off_max + C), jnp.float32))
    kernel = functools.partial(_dp_kernel_batched, n_edges=n_edges,
                               u_max=u_max, off_max=off_max)
    inst = pl.BlockSpec((bb, n_edges), lambda g: (g, 0),
                        memory_space=pltpu.SMEM)
    V, dec = pl.pallas_call(
        kernel,
        grid=(Bp // bb,),
        out_shape=(jax.ShapeDtypeStruct((Bp, S, C), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, W, S, C), jnp.int32)),
        in_specs=[
            inst,  # Υ̂ rows
            inst,  # Σ̂² rows
            inst,  # allowed rows
            pl.BlockSpec(memory_space=pltpu.SMEM),  # shared offsets
            pl.BlockSpec((n_edges, C), lambda g: (0, 0)),
            pl.BlockSpec((S, C), lambda g: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((bb, S, C), lambda g: (g, 0, 0)),
                   pl.BlockSpec((bb, W, S, C), lambda g: (g, 0, 0, 0))),
        scratch_shapes=[scratch],
        interpret=interp,
    )(upsilon, sigma2, allowed, offsets, feasible, v0)
    return V[:B], dec[:B]

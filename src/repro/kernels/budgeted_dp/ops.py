"""jit'd wrapper: ESDP Algorithm 2 on the Pallas budgeted-DP kernel.

Drop-in equivalent of core.dp.solve_budgeted_dp (tested for exact
agreement): derives the offset-encoded kernel operands, runs the
VMEM-resident kernel (or its blocked pipelines — C-blocked for large
capacity spaces, (S-tile × C-tile) for long horizons, both edge-FUSED by
default so every tile stays VMEM-resident across ``block_e`` consecutive
edges instead of re-streaming the plane per edge; ``choose_tiling``
resolves the whole (block_e, block_s, block_c) split), then applies the
eq.-17 s* rule and backtracks in plain jnp from the bit-packed decision
words.  The backtrack is
tiling-oblivious: the forward pass returns the full packed-decision plane
(device memory, not VMEM), and the walk reads ONE 1-element slice per
edge, so the same scan serves every tiling.

Operand contract (what makes this usable from the hot path):
  * the kernel operands are the (E, C) feasibility plane and the (E,) int32
    transition-offset vector — O(E·C) and O(E) memory.  ``offsets`` is a
    field of ``DPTables`` itself, built and VALIDATED in
    ``core.dp.build_tables`` (the old per-instance one-hot cache bolted on
    via ``object.__setattr__`` is gone: a frozen or ``dataclasses.replace``d
    tables object can never carry a stale operand again);
  * operands are prepared with HOST numpy so repeated traces never leak a
    tracer; ``prepare_tables`` is a cheap pure function of the tables;
  * the whole wrapper is vmap-safe: ``simulate_batch``/``simulate_grid``
    can map it over seed batches (Pallas batches the call; the operands
    stay unbatched constants);
  * decisions come back packed (⌈E/32⌉, S, C) int32 — 32× less memory than
    the old (E, S, C) f32 tensor — and the backtrack walks them with pure
    offset arithmetic (cs − offsets[e]), per-edge constants streamed as
    lax.scan inputs instead of per-element table gathers.

VALUE_BOUND contract: kernel arithmetic is f32, exact for integers < 2²⁴.
Whenever this wrapper is called with CONCRETE statistics it verifies that no
capacity-feasible subset can accumulate a value ≥ 2²⁴ and raises otherwise;
traced calls (inside jit/scan) skip the check, which is why
``tests/test_solver_equiv.py`` pins the default schedules under the bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.dp import DPTables
from .kernel import (NEG, choose_tiling, dp_forward_pallas,
                     resolve_interpret)

__all__ = ["VALUE_BOUND", "prepare_tables", "max_achievable_value",
           "solve_budgeted_dp_pallas", "resolve_interpret"]

VALUE_BOUND = 2 ** 24          # f32-exact integer domain (kernel contract)


def prepare_tables(tables: DPTables):
    """(feasible (E, C) f32, offsets (E,) i32) kernel operands.

    Pure host-numpy derivations of ``DPTables`` fields — nothing is cached
    on the tables object, so there is no stale-cache hazard.  Offsets of
    never-feasible edges (infeasible even at full capacity) are zeroed:
    they are masked everywhere, and zeroing keeps ``max(offsets)`` — the
    kernel's pad width — tight.
    """
    feas = np.asarray(tables.feasible).T.astype(np.float32)        # (E, C)
    usable = np.asarray(tables.feasible)[tables.full_state]        # (E,)
    offsets = np.where(usable, np.asarray(tables.offsets), 0)
    return feas, offsets.astype(np.int32)


def max_achievable_value(sigma2, tables: DPTables) -> int:
    """Upper bound on any DP partial sum: max Σ̂²ᵀx over capacity-feasible x.

    Per-edge requirements are recovered from the transition out of the
    full-capacity state; if every usable edge consumes ≥ 1 device the
    selection size is capped by Σ_k c_k, else by E.  The top-k sum of Σ̂²
    then bounds every value the kernel can ever materialize (feasible or
    not — infeasible states only accumulate subsets of the same sums).
    """
    sig = np.asarray(sigma2, dtype=np.int64)
    E = sig.shape[0]
    usable = np.asarray(tables.feasible)[tables.full_state]        # (E,)
    if not usable.any():
        return 0
    cap = np.asarray(tables.cap_of_state, dtype=np.int64)
    c = np.asarray(tables.radices, dtype=np.int64) - 1
    nxt = np.asarray(tables.next_state)[tables.full_state]         # (E,)
    req_total = (c[None, :] - cap[nxt]).sum(axis=1)                # (E,)
    if np.all(req_total[usable] >= 1):
        k = min(E, int(c.sum()))
    else:
        k = E
    top = np.sort(sig[usable])[::-1][:k]
    return int(top.sum())


def _check_value_bound(sigma2, tables: DPTables) -> None:
    if isinstance(sigma2, jax.core.Tracer):
        return                      # traced call — bound pinned by tests
    bound = max_achievable_value(sigma2, tables)
    if bound >= VALUE_BOUND:
        raise ValueError(
            f"budgeted-DP values can reach {bound} ≥ 2^24: the Pallas "
            "kernel's f32 arithmetic is no longer exact. Rescale Σ̂² or "
            "use the 'reference' (int32) solver backend.")


def _check_u_max(upsilon, u_max: int) -> None:
    """The kernel clamps shifts at u_max for memory safety, which would
    SILENTLY corrupt values if any Υ̂ exceeded it — turn a contract breach
    into an error whenever the statistics are concrete (traced calls are
    covered by the u_max_for_horizon bound test)."""
    if isinstance(upsilon, jax.core.Tracer):
        return
    top = int(np.max(np.asarray(upsilon))) if np.size(upsilon) else 0
    if top > u_max:
        raise ValueError(
            f"max Υ̂ = {top} exceeds u_max = {u_max}: the shift scratch is "
            "too short and the kernel would clamp (wrong values). Pass "
            "u_max ≥ max Υ̂ (stats.u_max_for_horizon bounds the default "
            "schedules) or leave u_max=None.")


@functools.partial(jax.jit,
                   static_argnames=("s_cap", "u_max", "off_max", "full_state",
                                    "interpret", "block_c", "block_s",
                                    "block_e"))
def _solve(upsilon, sigma2, feasible, offsets, s_limit,
           *, s_cap: int, u_max: int, off_max: int, full_state: int,
           interpret: bool, block_c: int | None, block_s: int | None,
           block_e: int | None):
    E = upsilon.shape[0]
    S = s_cap + 1
    v0 = jnp.full((S, feasible.shape[1]), NEG, jnp.float32).at[0, :].set(0.0)

    V, decisions = dp_forward_pallas(
        upsilon, sigma2, feasible, offsets, v0,
        n_edges=E, u_max=u_max, off_max=off_max, interpret=interpret,
        block_c=block_c, block_s=block_s, block_e=block_e)

    v_row = V[:, full_state]
    s_vals = jnp.arange(S, dtype=jnp.int32)
    # feasible ⇔ value ≥ 0: Σ̂² ≥ 0 so reachable values are non-negative,
    # while NEG-seeded chains stay < 0 for any partial sum < 2²⁴ (the
    # VALUE_BOUND contract) — sharper than thresholding at NEG/2.
    ok = (v_row >= 0) & (s_vals <= s_limit)
    score = s_vals.astype(jnp.float32) + jnp.sqrt(jnp.maximum(v_row, 0.0))
    s_star = jnp.argmax(jnp.where(ok, score, -jnp.inf)).astype(jnp.int32)

    # backtrack on offset arithmetic: the per-edge constants (Υ̂, offset,
    # word id, bit id) stream in as scan inputs, so the loop body is scalar
    # arithmetic plus ONE 1-element dynamic slice of the packed words — no
    # per-element gathers from (E, C) transition tables
    e_ids = jnp.arange(E, dtype=jnp.int32)

    def back(carry, x):
        s, cs = carry
        u, off, w, b = x
        word = jax.lax.dynamic_slice(decisions, (w, s, cs), (1, 1, 1))
        d = (word[0, 0, 0] >> b) & 1
        taken = d > 0
        s = jnp.where(taken, jnp.maximum(s - u, 0), s)
        cs = jnp.where(taken, cs - off, cs)
        return (s, cs), d

    (_, _), x = jax.lax.scan(
        back, (s_star, jnp.int32(full_state)),
        (upsilon, offsets, e_ids // 32, e_ids % 32))
    return x, s_star, v_row


def solve_budgeted_dp_pallas(upsilon, sigma2, tables: DPTables, s_cap: int,
                             s_limit, u_max: int | None = None,
                             allowed=None, interpret: bool | None = None,
                             block_c: "int | str | None" = "auto",
                             block_s: int | None = None,
                             block_e: int | None = None):
    """Same contract as :func:`repro.core.dp.solve_budgeted_dp`, executed on
    the Pallas kernel (+ kernel knobs).

    Args:
      upsilon, sigma2: (E,) int32 scaled statistics Υ̂(t), Σ̂²(t).
      tables: :class:`repro.core.dp.DPTables` from ``build_tables``.
      s_cap: static bound on s (value-row height − 1).
      s_limit: dynamic ξ(t)·m budget mask (s values beyond it are ignored
        by the eq.-17 selection).
      u_max: static bound on max Υ̂ used to size the kernel's shift
        scratch.  ``None`` uses the always-safe ``s_cap + 1`` padding;
        callers that know the schedule bound
        (``stats.u_max_for_horizon``) should pass it — the scratch shrinks
        m-fold.  An undersized concrete bound raises instead of clamping.
      allowed: optional (E,) bool eligibility mask (arrival ∧ aliveness).
      interpret: ``None`` auto-resolves (compiled on TPU, Pallas
        interpreter elsewhere); an explicit bool forces the mode.
      block_c, block_s, block_e: the plane tiling.  ``block_c="auto"``
        (default) picks all three from the VMEM budget via
        ``choose_tiling``: whole-plane when it fits, C-blocked for large
        capacity spaces, the 2-D (S-tile × C-tile) grid for long
        horizons — and on every blocked pipeline the largest edge-fused
        chunk ``block_e`` that fits, so tiles stay VMEM-resident across
        ``block_e`` consecutive edges instead of re-streaming per edge.
        Explicit ints force a tiling (``block_c=None`` forces whole-plane;
        ``block_s``/``block_e`` require a concrete ``block_c``).

    Returns:
      ``(x, info)`` — the (E,) int32 dispatch vector and ``{"s_star",
      "value_row"}``, bit-exact vs the reference backend for every tiling.
    """
    _check_value_bound(sigma2, tables)
    feas, offs = prepare_tables(tables)
    if allowed is not None:
        feas = feas * jnp.asarray(allowed, jnp.float32)[:, None]
    if u_max is None:
        u_max = s_cap + 1
    _check_u_max(upsilon, int(u_max))
    E = offs.shape[0]
    off_max = int(offs.max()) if E else 0
    if block_c == "auto":
        if block_s is not None or block_e is not None:
            forced = "block_s" if block_s is not None else "block_e"
            raise ValueError(
                f'{forced} was forced but block_c is "auto": the auto '
                "tiling would overwrite it — pass a concrete block_c "
                "(e.g. the number of capacity states for a single "
                "full-width tile)")
        block_e, block_s, block_c = choose_tiling(
            s_cap + 1, tables.n_states, E, int(u_max), off_max)
    x, s_star, v_row = _solve(
        jnp.asarray(upsilon, jnp.int32), jnp.asarray(sigma2, jnp.int32),
        feas, jnp.asarray(offs), jnp.asarray(s_limit, jnp.int32),
        s_cap=s_cap, u_max=int(u_max), off_max=off_max,
        full_state=tables.full_state,
        interpret=resolve_interpret(interpret), block_c=block_c,
        block_s=block_s, block_e=block_e)
    return x, {"s_star": s_star, "value_row": v_row}

"""jit'd wrapper: ESDP Algorithm 2 on the Pallas budgeted-DP kernel.

Drop-in equivalent of core.dp.solve_budgeted_dp (tested for exact
agreement): prepares the one-hot gather operands, runs the VMEM-resident
kernel, then applies the eq.-17 s* rule and backtracks in plain jnp from
the bit-packed decision words.

Batch-readiness (what makes this usable from the hot path):
  * kernel operands are built ONCE per DPTables instance and cached on the
    tables object — repeated per-slot calls (and every trace of a jitted
    scan) reuse the same constants instead of re-deriving an (E, C, C)
    one-hot on the host;
  * the whole wrapper is vmap-safe: ``simulate_batch``/``simulate_grid``
    can map it over seed batches (Pallas batches the call; the cached
    operands stay unbatched constants);
  * decisions come back packed (⌈E/32⌉, S, C) int32 — 32× less memory than
    the old (E, S, C) f32 tensor.

VALUE_BOUND contract: kernel arithmetic is f32, exact for integers < 2²⁴.
Whenever this wrapper is called with CONCRETE statistics it verifies that no
capacity-feasible subset can accumulate a value ≥ 2²⁴ and raises otherwise;
traced calls (inside jit/scan) skip the check, which is why
``tests/test_solver_equiv.py`` pins the default schedules under the bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.dp import DPTables
from .kernel import NEG, dp_forward_pallas, resolve_interpret

__all__ = ["VALUE_BOUND", "prepare_tables", "max_achievable_value",
           "solve_budgeted_dp_pallas", "resolve_interpret"]

VALUE_BOUND = 2 ** 24          # f32-exact integer domain (kernel contract)

_OPERAND_CACHE_ATTR = "_pallas_operands"


def _build_operands(tables: DPTables):
    # cached as HOST numpy: a jnp array materialized during a trace would be
    # a tracer, and caching a tracer across calls leaks it out of its trace
    feas = np.asarray(tables.feasible).T.astype(np.float32)        # (E, C)
    nxt = np.asarray(tables.next_state).T                          # (E, C)
    E, C = nxt.shape
    oh = np.zeros((E, C, C), np.float32)
    oh[np.arange(E)[:, None], nxt, np.arange(C)[None, :]] = 1.0    # oh[e, src, dst]
    return feas, oh


def prepare_tables(tables: DPTables):
    """(feasible (E,C) f32, next_onehot (E,C,C) f32) kernel operands.

    Cached on the DPTables instance: the first call pays the host-side
    one-hot construction, every later call (e.g. per slot inside the ESDP
    hot path, or per trace of a batched scan) is a dict lookup.
    """
    cached = getattr(tables, _OPERAND_CACHE_ATTR, None)
    if cached is None:
        cached = _build_operands(tables)
        object.__setattr__(tables, _OPERAND_CACHE_ATTR, cached)
    return cached


def max_achievable_value(sigma2, tables: DPTables) -> int:
    """Upper bound on any DP partial sum: max Σ̂²ᵀx over capacity-feasible x.

    Per-edge requirements are recovered from the transition out of the
    full-capacity state; if every usable edge consumes ≥ 1 device the
    selection size is capped by Σ_k c_k, else by E.  The top-k sum of Σ̂²
    then bounds every value the kernel can ever materialize (feasible or
    not — infeasible states only accumulate subsets of the same sums).
    """
    sig = np.asarray(sigma2, dtype=np.int64)
    E = sig.shape[0]
    usable = np.asarray(tables.feasible)[tables.full_state]        # (E,)
    if not usable.any():
        return 0
    cap = np.asarray(tables.cap_of_state, dtype=np.int64)
    c = np.asarray(tables.radices, dtype=np.int64) - 1
    nxt = np.asarray(tables.next_state)[tables.full_state]         # (E,)
    req_total = (c[None, :] - cap[nxt]).sum(axis=1)                # (E,)
    if np.all(req_total[usable] >= 1):
        k = min(E, int(c.sum()))
    else:
        k = E
    top = np.sort(sig[usable])[::-1][:k]
    return int(top.sum())


def _check_value_bound(sigma2, tables: DPTables) -> None:
    if isinstance(sigma2, jax.core.Tracer):
        return                      # traced call — bound pinned by tests
    bound = max_achievable_value(sigma2, tables)
    if bound >= VALUE_BOUND:
        raise ValueError(
            f"budgeted-DP values can reach {bound} ≥ 2^24: the Pallas "
            f"kernel's f32 arithmetic is no longer exact. Rescale Σ̂² or "
            f"use the 'reference' (int32) solver backend.")


@functools.partial(jax.jit,
                   static_argnames=("s_cap", "u_max", "full_state",
                                    "interpret"))
def _solve(upsilon, sigma2, feasible, next_onehot, s_limit,
           *, s_cap: int, u_max: int, full_state: int, interpret: bool):
    E = upsilon.shape[0]
    S = s_cap + 1
    C = feasible.shape[1]
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)

    V, decisions = dp_forward_pallas(
        upsilon, sigma2, feasible, next_onehot, v0,
        n_edges=E, u_max=u_max, interpret=interpret)

    v_row = V[:, full_state]
    s_vals = jnp.arange(S, dtype=jnp.int32)
    # feasible ⇔ value ≥ 0: Σ̂² ≥ 0 so reachable values are non-negative,
    # while NEG-seeded chains stay < 0 for any partial sum < 2²⁴ (the
    # VALUE_BOUND contract) — sharper than thresholding at NEG/2.
    ok = (v_row >= 0) & (s_vals <= s_limit)
    score = s_vals.astype(jnp.float32) + jnp.sqrt(jnp.maximum(v_row, 0.0))
    s_star = jnp.argmax(jnp.where(ok, score, -jnp.inf)).astype(jnp.int32)

    next_idx = jnp.argmax(next_onehot, axis=1)       # (E, C)

    def back(e, carry):
        s, cs, x = carry
        word = decisions[e // 32, s, cs]
        d = ((word >> (e % 32)) & 1) > 0
        x = x.at[e].set(d.astype(jnp.int32))
        s_new = jnp.maximum(s - upsilon[e], 0)
        return (jnp.where(d, s_new, s),
                jnp.where(d, next_idx[e, cs], cs), x)

    _, _, x = jax.lax.fori_loop(
        0, E, back, (s_star, jnp.int32(full_state),
                     jnp.zeros(E, jnp.int32)))
    return x, s_star, v_row


def solve_budgeted_dp_pallas(upsilon, sigma2, tables: DPTables, s_cap: int,
                             s_limit, u_max: int | None = None,
                             allowed=None, interpret: bool | None = None):
    """Same contract as core.dp.solve_budgeted_dp (+ interpret switch).

    ``interpret=None`` auto-resolves (compiled on TPU, interpreter
    elsewhere); ``u_max=None`` uses the always-safe s_cap+1 shift padding.
    """
    _check_value_bound(sigma2, tables)
    feas, oh = prepare_tables(tables)
    if allowed is not None:
        feas = feas * jnp.asarray(allowed, jnp.float32)[:, None]
    if u_max is None:
        u_max = s_cap + 1
    x, s_star, v_row = _solve(
        jnp.asarray(upsilon, jnp.int32), jnp.asarray(sigma2, jnp.int32),
        feas, oh, jnp.asarray(s_limit, jnp.int32),
        s_cap=s_cap, u_max=int(u_max), full_state=tables.full_state,
        interpret=resolve_interpret(interpret))
    return x, {"s_star": s_star, "value_row": v_row}

"""jit'd wrapper: ESDP Algorithm 2 on the Pallas budgeted-DP kernel.

Drop-in equivalent of core.dp.solve_budgeted_dp (tested for exact
agreement): derives the offset-encoded kernel operands, runs the
VMEM-resident kernel (or its blocked pipelines — C-blocked for large
capacity spaces, (S-tile × C-tile) for long horizons, both edge-FUSED by
default so every tile stays VMEM-resident across ``block_e`` consecutive
edges instead of re-streaming the plane per edge; ``choose_tiling``
resolves the whole (block_e, block_s, block_c) split), then applies the
eq.-17 s* rule and backtracks in plain jnp from the bit-packed decision
words.  The backtrack is
tiling-oblivious: the forward pass returns the full packed-decision plane
(device memory, not VMEM), and the walk reads ONE 1-element slice per
edge, so the same scan serves every tiling.

Operand contract (what makes this usable from the hot path):
  * the kernel operands are the (E, C) feasibility plane and the (E,) int32
    transition-offset vector — O(E·C) and O(E) memory.  ``offsets`` is a
    field of ``DPTables`` itself, built and VALIDATED in
    ``core.dp.build_tables`` (the old per-instance one-hot cache bolted on
    via ``object.__setattr__`` is gone: a frozen or ``dataclasses.replace``d
    tables object can never carry a stale operand again);
  * operands are prepared with HOST numpy so repeated traces never leak a
    tracer; ``prepare_tables`` is a cheap pure function of the tables;
  * the whole wrapper is vmap-safe AND batch-aware: a ``custom_vmap``
    rule on the solve core dispatches every mapped instance through ONE
    :func:`repro.kernels.budgeted_dp.kernel.dp_forward_pallas_batched`
    launch — ``simulate_batch``/``simulate_grid`` mapping it over seed
    batches get one fleet-batched kernel per slot instead of B replicated
    launches, the shared (E, C) feasibility plane stays an unbatched
    constant (per-instance eligibility multiplies into the mask inside
    the kernel, never folded into B feasibility copies), and
    ``prepare_tables`` derives the host operands exactly once per tables
    object (identity-cached).  :func:`solve_budgeted_dp_batched` is the
    explicit batched entry point for callers that already hold stacked
    (B, E) statistics;
  * decisions come back packed (⌈E/32⌉, S, C) int32 — 32× less memory than
    the old (E, S, C) f32 tensor — and the backtrack walks them with pure
    offset arithmetic (cs − offsets[e]), per-edge constants streamed as
    lax.scan inputs instead of per-element table gathers.

VALUE_BOUND contract: kernel arithmetic is f32, exact for integers < 2²⁴.
Whenever this wrapper is called with CONCRETE statistics it verifies that no
capacity-feasible subset can accumulate a value ≥ 2²⁴ and raises otherwise;
traced calls (inside jit/scan) skip the check, which is why
``tests/test_solver_equiv.py`` pins the default schedules under the bound.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core import dp as core_dp
from ...core.dp import DPTables
from .kernel import (NEG, choose_tiling, dp_forward_pallas,
                     dp_forward_pallas_batched, resolve_interpret)

__all__ = ["VALUE_BOUND", "prepare_tables", "max_achievable_value",
           "validate_value_row", "solve_budgeted_dp_pallas",
           "solve_budgeted_dp_batched", "WarmPallasSolver",
           "resolve_interpret"]

VALUE_BOUND = 2 ** 24  # f32-exact integer domain (kernel contract)


def validate_value_row(value_row) -> "str | None":
    """Cheap host-side invariant check of a returned DP value row.

    The checked properties are THEOREMS of the P4/P5 recurrence — true for
    any correct backend and tiling, so a violation means the plane is
    corrupted (bad launch, clamped shift, bit flip), never a legitimate
    input.  On the contract row (int32, ``core.dp.NEG`` at
    budget-infeasible entries; see ``core.solvers``):

      * source: ``value_row[0] >= 0`` — the empty selection achieves s=0;
      * NEG contract: every entry is ``>= 0`` or exactly the sentinel;
      * VALUE_BOUND: feasible values stay ``< 2**24`` (the f32-exact
        domain the kernel is allowed to produce);
      * prefix feasibility: feasible s form a prefix — any x with
        ``Υ̂ᵀx >= s`` also witnesses every ``s' < s``;
      * monotone: values are non-increasing in s over the feasible prefix
        (raising the budget floor only shrinks the feasible set).

    Accepts an (S,) row or a batched (B, S) stack; returns ``None`` when
    every invariant holds, else a short reason string (first violation).
    """
    row = np.asarray(value_row)
    if row.ndim == 2:
        for b in range(row.shape[0]):
            reason = validate_value_row(row[b])
            if reason is not None:
                return f"row {b}: {reason}"
        return None
    neg = int(core_dp.NEG)
    feas = row != neg
    if not feas[0] or row[0] < 0:
        return f"source: value_row[0] = {row[0]} (must be >= 0)"
    bad = feas & (row < 0)
    if bad.any():
        s = int(np.flatnonzero(bad)[0])
        return (f"neg-contract: value_row[{s}] = {row[s]} is negative but "
                f"not the NEG sentinel ({neg})")
    over = feas & (row >= VALUE_BOUND)
    if over.any():
        s = int(np.flatnonzero(over)[0])
        return (f"value-bound: value_row[{s}] = {row[s]} >= 2^24 "
                "(outside the f32-exact domain)")
    n_feas = int(feas.sum())
    if not feas[:n_feas].all():
        s = int(np.flatnonzero(~feas)[0])
        return (f"feasible-prefix: value_row[{s}] is infeasible but a "
                "larger budget is feasible")
    pre = row[:n_feas]
    rising = np.flatnonzero(np.diff(pre.astype(np.int64)) > 0)
    if rising.size:
        s = int(rising[0])
        return (f"monotone: value_row[{s + 1}] = {pre[s + 1]} > "
                f"value_row[{s}] = {pre[s]} (must be non-increasing in s)")
    return None


@functools.lru_cache(maxsize=32)
def prepare_tables(tables: DPTables):
    """(feasible (E, C) f32, offsets (E,) i32) kernel operands.

    Pure host-numpy derivations of ``DPTables`` fields — nothing is cached
    on the tables object, so there is no stale-cache hazard.  Offsets of
    never-feasible edges (infeasible even at full capacity) are zeroed:
    they are masked everywhere, and zeroing keeps ``max(offsets)`` — the
    kernel's pad width — tight.

    Memoized by tables IDENTITY (``DPTables`` is frozen with ``eq=False``,
    so the object itself is the hashable key and the cache holds it
    alive): every solver call against the same tables — in particular all
    B instances of a vmapped or batched dispatch — derives the operands
    exactly ONCE.  A ``dataclasses.replace``d or rebuilt tables object is
    a different key, so the cache can never serve stale operands; the
    returned arrays are shared and must be treated as read-only.
    """
    feas = np.asarray(tables.feasible).T.astype(np.float32)  # (E, C)
    usable = np.asarray(tables.feasible)[tables.full_state]  # (E,)
    offsets = np.where(usable, np.asarray(tables.offsets), 0)
    return feas, offsets.astype(np.int32)


def max_achievable_value(sigma2, tables: DPTables) -> int:
    """Upper bound on any DP partial sum: max Σ̂²ᵀx over capacity-feasible x.

    Per-edge requirements are recovered from the transition out of the
    full-capacity state; if every usable edge consumes ≥ 1 device the
    selection size is capped by Σ_k c_k, else by E.  The top-k sum of Σ̂²
    then bounds every value the kernel can ever materialize (feasible or
    not — infeasible states only accumulate subsets of the same sums).
    """
    sig = np.asarray(sigma2, dtype=np.int64)
    E = sig.shape[0]
    usable = np.asarray(tables.feasible)[tables.full_state]  # (E,)
    if not usable.any():
        return 0
    cap = np.asarray(tables.cap_of_state, dtype=np.int64)
    c = np.asarray(tables.radices, dtype=np.int64) - 1
    nxt = np.asarray(tables.next_state)[tables.full_state]  # (E,)
    req_total = (c[None, :] - cap[nxt]).sum(axis=1)  # (E,)
    if np.all(req_total[usable] >= 1):
        k = min(E, int(c.sum()))
    else:
        k = E
    top = np.sort(sig[usable])[::-1][:k]
    return int(top.sum())


def _check_value_bound(sigma2, tables: DPTables) -> None:
    if isinstance(sigma2, jax.core.Tracer):
        return  # traced call — bound pinned by tests
    bound = max_achievable_value(sigma2, tables)
    if bound >= VALUE_BOUND:
        raise ValueError(
            f"budgeted-DP values can reach {bound} ≥ 2^24: the Pallas "
            "kernel's f32 arithmetic is no longer exact. Rescale Σ̂² or "
            "use the 'reference' (int32) solver backend.")


def _check_u_max(upsilon, u_max: int) -> None:
    """The kernel clamps shifts at u_max for memory safety, which would
    SILENTLY corrupt values if any Υ̂ exceeded it — turn a contract breach
    into an error whenever the statistics are concrete (traced calls are
    covered by the u_max_for_horizon bound test)."""
    if isinstance(upsilon, jax.core.Tracer):
        return
    top = int(np.max(np.asarray(upsilon))) if np.size(upsilon) else 0
    if top > u_max:
        raise ValueError(
            f"max Υ̂ = {top} exceeds u_max = {u_max}: the shift scratch is "
            "too short and the kernel would clamp (wrong values). Pass "
            "u_max ≥ max Υ̂ (stats.u_max_for_horizon bounds the default "
            "schedules) or leave u_max=None.")


@functools.partial(jax.jit,
                   static_argnames=("s_cap", "u_max", "off_max", "full_state",
                                    "interpret", "block_c", "block_s",
                                    "block_e"))
def _solve(
    upsilon,
    sigma2,
    feasible,
    offsets,
    s_limit,
    *,
    s_cap: int,
    u_max: int,
    off_max: int,
    full_state: int,
    interpret: bool,
    block_c: int | None,
    block_s: int | None,
    block_e: int | None,
):
    E = upsilon.shape[0]
    S = s_cap + 1
    v0 = jnp.full((S, feasible.shape[1]), NEG, jnp.float32).at[0, :].set(0.0)

    V, decisions = dp_forward_pallas(
        upsilon, sigma2, feasible, offsets, v0,
        n_edges=E, u_max=u_max, off_max=off_max, interpret=interpret,
        block_c=block_c, block_s=block_s, block_e=block_e)

    v_row = V[:, full_state]
    s_vals = jnp.arange(S, dtype=jnp.int32)
    # feasible ⇔ value ≥ 0: Σ̂² ≥ 0 so reachable values are non-negative,
    # while NEG-seeded chains stay < 0 for any partial sum < 2²⁴ (the
    # VALUE_BOUND contract) — sharper than thresholding at NEG/2.
    ok = (v_row >= 0) & (s_vals <= s_limit)
    score = s_vals.astype(jnp.float32) + jnp.sqrt(jnp.maximum(v_row, 0.0))
    s_star = jnp.argmax(jnp.where(ok, score, -jnp.inf)).astype(jnp.int32)

    # backtrack on offset arithmetic: the per-edge constants (Υ̂, offset,
    # word id, bit id) stream in as scan inputs, so the loop body is scalar
    # arithmetic plus ONE 1-element dynamic slice of the packed words — no
    # per-element gathers from (E, C) transition tables
    e_ids = jnp.arange(E, dtype=jnp.int32)

    def back(carry, x):
        s, cs = carry
        u, off, w, b = x
        word = jax.lax.dynamic_slice(decisions, (w, s, cs), (1, 1, 1))
        d = (word[0, 0, 0] >> b) & 1
        taken = d > 0
        s = jnp.where(taken, jnp.maximum(s - u, 0), s)
        cs = jnp.where(taken, cs - off, cs)
        return (s, cs), d

    (_, _), x = jax.lax.scan(
        back, (s_star, jnp.int32(full_state)),
        (upsilon, offsets, e_ids // 32, e_ids % 32))
    return x, s_star, v_row


@functools.partial(jax.jit,
                   static_argnames=("s_cap", "u_max", "off_max", "full_state",
                                    "interpret", "block_b", "block_c",
                                    "block_s", "block_e"))
def _solve_batched(
    upsilon,
    sigma2,
    allowed,
    feasible,
    offsets,
    s_limit,
    *,
    s_cap: int,
    u_max: int,
    off_max: int,
    full_state: int,
    interpret: bool,
    block_b: int | None,
    block_c: int | None,
    block_s: int | None,
    block_e: int | None,
):
    """Batched :func:`_solve`: B solves through ONE kernel launch.

    upsilon/sigma2/allowed are (B, E), ``s_limit`` is (B,); the tables
    operands stay SHARED (unbatched).  The eq.-17 selection runs across
    the batch axis, and the backtrack scans all B walks in lockstep —
    per-edge constants stream once, each step reads one 1-element slice
    of each instance's packed-decision words."""
    B, E = upsilon.shape
    S = s_cap + 1
    v0 = jnp.full((S, feasible.shape[1]), NEG, jnp.float32).at[0, :].set(0.0)

    V, decisions = dp_forward_pallas_batched(
        upsilon, sigma2, allowed, feasible, offsets, v0,
        n_edges=E, u_max=u_max, off_max=off_max, interpret=interpret,
        block_b=block_b, block_c=block_c, block_s=block_s, block_e=block_e)

    v_row = V[:, :, full_state]  # (B, S)
    s_vals = jnp.arange(S, dtype=jnp.int32)
    ok = (v_row >= 0) & (s_vals[None, :] <= s_limit[:, None])
    score = (s_vals[None, :].astype(jnp.float32)
             + jnp.sqrt(jnp.maximum(v_row, 0.0)))
    s_star = jnp.argmax(jnp.where(ok, score, -jnp.inf),
                        axis=1).astype(jnp.int32)

    e_ids = jnp.arange(E, dtype=jnp.int32)

    def back(carry, x):
        s, cs = carry  # (B,) each
        u, off, w, b = x  # u (B,); rest scalar
        word = jax.vmap(
            lambda d, s_, c_: jax.lax.dynamic_slice(
                d, (w, s_, c_), (1, 1, 1))[0, 0, 0])(decisions, s, cs)
        d = (word >> b) & 1
        taken = d > 0
        s = jnp.where(taken, jnp.maximum(s - u, 0), s)
        cs = jnp.where(taken, cs - off, cs)
        return (s, cs), d

    (_, _), x = jax.lax.scan(
        back, (s_star, jnp.full((B,), full_state, jnp.int32)),
        (upsilon.T, offsets, e_ids // 32, e_ids % 32))
    return x.T, s_star, v_row


@functools.lru_cache(maxsize=None)
def _vmappable_core(
    s_cap: int,
    u_max: int,
    off_max: int,
    full_state: int,
    interpret: bool,
    block_c,
    block_s,
    block_e,
    auto_tiling: bool,
    n_edges: int,
    n_states: int,
):
    """The solve core for one static kernel config, with a custom vmap rule.

    The single-instance path folds ``allowed`` into the feasibility plane
    and runs :func:`_solve` exactly as before.  Under ``jax.vmap`` the
    rule fires instead and routes ALL mapped instances through ONE
    :func:`dp_forward_pallas_batched` launch: the shared (E, C)
    feasibility plane stays an unbatched constant (vmapping the fold
    would materialize B per-instance copies of it), per-instance
    eligibility rides the (B, E) ``allowed`` rows, and when the tiling is
    auto it re-resolves for the batch via ``choose_tiling(batch=B)``.
    Cached per static config so repeated solver calls reuse one
    ``custom_vmap`` object and its jit traces."""

    def plain(upsilon, sigma2, s_limit, allowed, feasible, offsets):
        feas = feasible * allowed.astype(jnp.float32)[:, None]
        return _solve(upsilon, sigma2, feas, offsets, s_limit,
                      s_cap=s_cap, u_max=u_max, off_max=off_max,
                      full_state=full_state, interpret=interpret,
                      block_c=block_c, block_s=block_s, block_e=block_e)

    core = jax.custom_batching.custom_vmap(plain)

    @core.def_vmap
    def _batched_rule(
        axis_size, in_batched, upsilon, sigma2, s_limit, allowed, feasible, offsets
    ):
        up_b, sg_b, sl_b, al_b, fe_b, of_b = in_batched
        if fe_b or of_b:
            raise NotImplementedError(
                "the DP tables are shared across a batch: vmap over "
                "per-instance feasibility/offset operands is not "
                "supported — rebuild per-instance tables and solve them "
                "separately instead")
        B = axis_size

        def bcast(x, batched):
            return x if batched else jnp.broadcast_to(x, (B,) + jnp.shape(x))

        ups = bcast(upsilon, up_b)
        sig = bcast(sigma2, sg_b)
        sl = bcast(s_limit, sl_b)
        alw = bcast(allowed, al_b)

        if auto_tiling:
            bb, be, bs, bc = choose_tiling(
                s_cap + 1, n_states, n_edges, u_max, off_max, batch=B)
        else:
            be, bs, bc = block_e, block_s, block_c
            if bc is not None and be is None:
                # a forced per-edge-scan tiling has no batched pipeline
                # (re-streaming the plane per edge gains nothing from a
                # shared launch) — run the instances sequentially, one
                # trace, bit-exact by construction
                outs = jax.lax.map(
                    lambda t: plain(t[0], t[1], t[2], t[3], feasible,
                                    offsets), (ups, sig, sl, alw))
                return outs, (True, True, True)
            bb = 1 if bc is not None else choose_tiling(
                s_cap + 1, n_states, n_edges, u_max, off_max, batch=B)[0]
        outs = _solve_batched(
            ups, sig, alw, feasible, offsets, sl,
            s_cap=s_cap, u_max=u_max, off_max=off_max,
            full_state=full_state, interpret=interpret, block_b=bb,
            block_c=bc, block_s=bs, block_e=be)
        return outs, (True, True, True)

    return core


def solve_budgeted_dp_pallas(
    upsilon,
    sigma2,
    tables: DPTables,
    s_cap: int,
    s_limit,
    u_max: int | None = None,
    allowed=None,
    interpret: bool | None = None,
    block_c: "int | str | None" = "auto",
    block_s: int | None = None,
    block_e: int | None = None,
):
    """Same contract as :func:`repro.core.dp.solve_budgeted_dp`, executed on
    the Pallas kernel (+ kernel knobs).

    Args:
      upsilon, sigma2: (E,) int32 scaled statistics Υ̂(t), Σ̂²(t).
      tables: :class:`repro.core.dp.DPTables` from ``build_tables``.
      s_cap: static bound on s (value-row height − 1).
      s_limit: dynamic ξ(t)·m budget mask (s values beyond it are ignored
        by the eq.-17 selection).
      u_max: static bound on max Υ̂ used to size the kernel's shift
        scratch.  ``None`` uses the always-safe ``s_cap + 1`` padding;
        callers that know the schedule bound
        (``stats.u_max_for_horizon``) should pass it — the scratch shrinks
        m-fold.  An undersized concrete bound raises instead of clamping.
      allowed: optional (E,) bool eligibility mask (arrival ∧ aliveness).
      interpret: ``None`` auto-resolves (compiled on TPU, Pallas
        interpreter elsewhere); an explicit bool forces the mode.
      block_c, block_s, block_e: the plane tiling.  ``block_c="auto"``
        (default) picks all three from the VMEM budget via
        ``choose_tiling``: whole-plane when it fits, C-blocked for large
        capacity spaces, the 2-D (S-tile × C-tile) grid for long
        horizons — and on every blocked pipeline the largest edge-fused
        chunk ``block_e`` that fits, so tiles stay VMEM-resident across
        ``block_e`` consecutive edges instead of re-streaming per edge.
        Explicit ints force a tiling (``block_c=None`` forces whole-plane;
        ``block_s``/``block_e`` require a concrete ``block_c``).

    Returns:
      ``(x, info)`` — the (E,) int32 dispatch vector and ``{"s_star",
      "value_row"}``, bit-exact vs the reference backend for every tiling.

    Under ``jax.vmap`` the solve core's custom batching rule dispatches
    every mapped instance through ONE batched kernel launch (see
    :func:`_vmappable_core`) — callers never need to opt in.
    """
    _check_value_bound(sigma2, tables)
    feas, offs = prepare_tables(tables)
    if u_max is None:
        u_max = s_cap + 1
    _check_u_max(upsilon, int(u_max))
    E = offs.shape[0]
    off_max = int(offs.max()) if E else 0
    auto = block_c == "auto"
    if auto:
        if block_s is not None or block_e is not None:
            forced = "block_s" if block_s is not None else "block_e"
            raise ValueError(
                f'{forced} was forced but block_c is "auto": the auto '
                "tiling would overwrite it — pass a concrete block_c "
                "(e.g. the number of capacity states for a single "
                "full-width tile)")
        block_e, block_s, block_c = choose_tiling(
            s_cap + 1, tables.n_states, E, int(u_max), off_max)
    core = _vmappable_core(
        s_cap, int(u_max), off_max, tables.full_state,
        resolve_interpret(interpret), block_c, block_s, block_e, auto,
        E, tables.n_states)
    alw = (jnp.ones((E,), jnp.int32) if allowed is None
           else jnp.asarray(allowed, jnp.int32))
    x, s_star, v_row = core(
        jnp.asarray(upsilon, jnp.int32), jnp.asarray(sigma2, jnp.int32),
        jnp.asarray(s_limit, jnp.int32), alw, jnp.asarray(feas),
        jnp.asarray(offs))
    return x, {"s_star": s_star, "value_row": v_row}


def solve_budgeted_dp_batched(
    upsilon,
    sigma2,
    tables: DPTables,
    s_cap: int,
    s_limit,
    u_max: int | None = None,
    allowed=None,
    interpret: bool | None = None,
    block_b: "int | str" = "auto",
    block_c: "int | str | None" = "auto",
    block_s: int | None = None,
    block_e: int | None = None,
):
    """B solves against SHARED tables in ONE kernel launch.

    The explicit batched entry point for callers that already hold
    stacked statistics (``jax.vmap`` of :func:`solve_budgeted_dp_pallas`
    reaches the same kernel through the custom batching rule).

    Args:
      upsilon, sigma2: (B, E) int32 per-instance statistics.
      s_limit: scalar or (B,) per-instance budget mask.
      allowed: optional (B, E) per-instance eligibility; the (E, C)
        feasibility plane itself stays shared — eligibility multiplies
        into the mask inside the kernel.
      block_b: instances advanced per grid step.  ``"auto"`` (default)
        resolves with the tiling; an explicit int outside [1, B] raises,
        and forcing it while ``block_c="auto"`` raises (the auto tiling
        would overwrite it).  B need not be a multiple of block_b: ragged
        batches pad with inert ``allowed ≡ 0`` instances.
      Everything else matches :func:`solve_budgeted_dp_pallas`.

    Returns:
      ``(x, info)`` — (B, E) int32 dispatch vectors and ``{"s_star":
      (B,), "value_row": (B, S)}``, bit-exact vs a per-instance loop
      over the reference backend.
    """
    if not isinstance(sigma2, jax.core.Tracer):
        # worst case per edge across the batch bounds every instance
        _check_value_bound(np.max(np.asarray(sigma2), axis=0), tables)
    feas, offs = prepare_tables(tables)
    if u_max is None:
        u_max = s_cap + 1
    _check_u_max(upsilon, int(u_max))
    E = offs.shape[0]
    B = int(np.shape(upsilon)[0])
    off_max = int(offs.max()) if E else 0
    if block_c == "auto":
        forced = next((name for name, val in (("block_b", block_b),
                                              ("block_s", block_s),
                                              ("block_e", block_e))
                       if val is not None and val != "auto"), None)
        if forced is not None:
            raise ValueError(
                f'{forced} was forced but block_c is "auto": the auto '
                "tiling would overwrite it — pass a concrete block_c "
                "(e.g. the number of capacity states for a single "
                "full-width tile)")
        block_b, block_e, block_s, block_c = choose_tiling(
            s_cap + 1, tables.n_states, E, int(u_max), off_max, batch=B)
    elif block_b == "auto":
        block_b = (1 if block_c is not None else choose_tiling(
            s_cap + 1, tables.n_states, E, int(u_max), off_max,
            batch=B)[0])
    alw = (jnp.ones((B, E), jnp.int32) if allowed is None
           else jnp.asarray(allowed, jnp.int32))
    sl = jnp.broadcast_to(jnp.asarray(s_limit, jnp.int32), (B,))
    x, s_star, v_row = _solve_batched(
        jnp.asarray(upsilon, jnp.int32), jnp.asarray(sigma2, jnp.int32),
        alw, jnp.asarray(feas), jnp.asarray(offs), sl,
        s_cap=s_cap, u_max=int(u_max), off_max=off_max,
        full_state=tables.full_state,
        interpret=resolve_interpret(interpret), block_b=block_b,
        block_c=block_c, block_s=block_s, block_e=block_e)
    return x, {"s_star": s_star, "value_row": v_row}


class WarmPallasSolver:
    """Warm-started Pallas path: carried value planes + per-segment launches.

    The kernel entry :func:`dp_forward_pallas` already takes a seed plane
    ``v0`` (the carried-plane hook), so warm-starting needs NO kernel
    changes — only a host driver that splits the edge fold into fixed
    SEGMENTS of ``checkpoint_every`` fold steps and launches them chained
    (each segment's output plane seeds the next).  A chain of segment
    launches executes the identical f32 op sequence as one launch, so the
    split itself is bit-invisible.  Across slots the driver keeps every
    inter-segment plane plus each segment's packed decision words: when a
    new solve's delta mask (vs the previous inputs, in FOLD order — edge
    ``E-1-j`` at fold step ``j``) leaves a prefix of fold steps unchanged,
    all fully-unchanged segments are SKIPPED — their planes and decisions
    are reused verbatim — and the fold resumes from the stored plane
    before the first touched segment.  Resuming from a pre-segment plane
    (not the final plane) is what keeps the result bit-identical to a cold
    solve: re-folding an edge into a plane that already absorbed it would
    double-take it (see ``core.incremental`` for the worked example).

    The eq.-17 selection and the backtrack are recomputed every call (so a
    changed ``s_limit`` alone costs zero launches).  Decision words are
    packed per segment in LOCAL edge numbering and concatenated along the
    word axis; the backtrack streams host-precomputed (word-row, bit)
    constants per global edge, so it never shifts between packings.

    This is a HOST-side driver: inputs must be concrete (calls with traced
    arrays raise — put it behind ``sched.dispatcher``'s host loop, not
    inside a ``lax.scan``).  Call contract and returned ``info`` match the
    ``pallas`` Solver backend (``value_row`` sanitized to int32/NEG), plus
    ``edges_folded``.  One instance is bound to one (tables, s_cap, u_max)
    problem; ``accepts_batch`` is False — batched fleets should use the
    solve cache instead (``core.solvers.CachedSolver``).
    """

    accepts_batch = False
    interpret = None

    def __init__(
        self,
        tables: DPTables,
        s_cap: int,
        u_max: int | None = None,
        checkpoint_every: int = 8,
        interpret: bool | None = None,
    ):
        feas, offs = prepare_tables(tables)
        self.tables = tables
        self.s_cap = int(s_cap)
        self.u_max = int(u_max) if u_max is not None else self.s_cap + 1
        self.k = int(checkpoint_every)
        if self.k < 1:
            raise ValueError("checkpoint_every must be >= 1")
        self.interpret = resolve_interpret(interpret)
        self._feas, self._offs = feas, offs
        E = offs.shape[0]
        S = self.s_cap + 1
        self._E = E
        self._off_max = int(offs.max()) if E else 0

        # fixed fold-order segmentation: segment si covers fold steps
        # [si·k, (si+1)·k) = edges [max(E-(si+1)k, 0), E-si·k)
        k = self.k
        self._n_seg = max(1, -(-E // k))
        self._bounds = [(max(E - (si + 1) * k, 0), E - si * k)
                        for si in range(self._n_seg)]
        word_off, off = [], 0
        for lo, hi in self._bounds:
            word_off.append(off)
            off += -(-(hi - lo) // 32)
        # global edge e → its word row / bit in the concatenated packing
        e_ids = np.arange(E)
        si_of = np.minimum((E - 1 - e_ids) // k, self._n_seg - 1)
        lo_of = np.array([self._bounds[si][0] for si in si_of])
        local = e_ids - lo_of
        self._w_rows = (np.array([word_off[si] for si in si_of])
                        + local // 32).astype(np.int32)
        self._bits = (local % 32).astype(np.int32)

        self._launch = [self._make_launch(lo, hi) for lo, hi in self._bounds]
        self._select_back = self._make_select_back()

        # carried fold artifacts (host side)
        self._v0 = jnp.full((S, tables.n_states), NEG,
                            jnp.float32).at[0, :].set(0.0)
        self._planes = [self._v0] + [None] * self._n_seg
        self._dec = [None] * self._n_seg
        self._dec_cat = None
        self._prev = None  # (ups, sig, alw) of the carried solve
        self.stats = {"solves": 0, "segments_launched": 0,
                      "segments_skipped": 0, "edges_folded": 0,
                      "edges_skipped": 0, "full_hits": 0}

    @property
    def name(self) -> str:
        return "warm:pallas" + ("_interpret" if self.interpret else "")

    @property
    def skip_rate(self) -> float:
        n = self.stats["edges_folded"] + self.stats["edges_skipped"]
        return self.stats["edges_skipped"] / n if n else 0.0

    def _make_launch(self, lo: int, hi: int):
        feas_seg = jnp.asarray(self._feas[lo:hi])
        offs_seg = jnp.asarray(self._offs[lo:hi])
        be, bs, bc = choose_tiling(self.s_cap + 1, self.tables.n_states,
                                   hi - lo, self.u_max, self._off_max)

        @jax.jit
        def launch(ups, sig, alw, v0):
            f = feas_seg * alw.astype(jnp.float32)[:, None]
            return dp_forward_pallas(
                ups, sig, f, offs_seg, v0, n_edges=hi - lo,
                u_max=self.u_max, off_max=self._off_max,
                interpret=self.interpret, block_c=bc, block_s=bs,
                block_e=be)

        return launch

    def _make_select_back(self):
        offs = jnp.asarray(self._offs)
        w_rows, bits = jnp.asarray(self._w_rows), jnp.asarray(self._bits)
        full_state = self.tables.full_state
        S = self.s_cap + 1

        @jax.jit
        def select_back(V, decisions, upsilon, s_limit):
            v_row = V[:, full_state]
            s_vals = jnp.arange(S, dtype=jnp.int32)
            ok = (v_row >= 0) & (s_vals <= s_limit)
            score = s_vals.astype(jnp.float32) + jnp.sqrt(
                jnp.maximum(v_row, 0.0))
            s_star = jnp.argmax(jnp.where(ok, score,
                                          -jnp.inf)).astype(jnp.int32)

            def back(carry, x):
                s, cs = carry
                u, off, w, b = x
                word = jax.lax.dynamic_slice(decisions, (w, s, cs),
                                             (1, 1, 1))
                d = (word[0, 0, 0] >> b) & 1
                taken = d > 0
                s = jnp.where(taken, jnp.maximum(s - u, 0), s)
                cs = jnp.where(taken, cs - off, cs)
                return (s, cs), d

            (_, _), x = jax.lax.scan(
                back, (s_star, jnp.int32(full_state)),
                (upsilon, offs, w_rows, bits))
            # contract sanitization: budget-infeasible entries become the
            # CORE int32 sentinel (−2²⁹), not the kernel's f32 one
            row = jnp.where(v_row >= 0, v_row,
                            float(core_dp.NEG)).astype(jnp.int32)
            return x, s_star, row

        return select_back

    def reset(self) -> None:
        """Drop the carried solve (the next call folds everything)."""
        self._planes = [self._v0] + [None] * self._n_seg
        self._dec = [None] * self._n_seg
        self._dec_cat = None
        self._prev = None

    def __call__(
        self,
        upsilon,
        sigma2,
        tables: DPTables,
        s_cap: int,
        s_limit,
        allowed=None,
        u_max: int | None = None,
    ):
        if tables is not self.tables or int(s_cap) != self.s_cap:
            raise ValueError(
                "WarmPallasSolver is bound to one (tables, s_cap) problem; "
                "build a new instance for a different one")
        if any(isinstance(a, jax.core.Tracer)
               for a in (upsilon, sigma2, s_limit, allowed)
               if a is not None):
            raise TypeError(
                "WarmPallasSolver carries host state and needs concrete "
                "inputs; inside jit/scan use the reference warm path "
                "(core.incremental.solve_budgeted_dp_warm) or the solve "
                "cache instead")
        _check_value_bound(np.asarray(sigma2), self.tables)
        _check_u_max(np.asarray(upsilon), self.u_max)

        E = self._E
        ups = np.asarray(upsilon, np.int32)
        sig = np.asarray(sigma2, np.int32)
        alw = (np.ones(E, bool) if allowed is None
               else np.asarray(allowed, bool))

        # delta mask in fold order → longest unchanged fold prefix
        if self._prev is None:
            p = 0
        else:
            pu, ps, pa = self._prev
            changed = ((ups[::-1] != pu[::-1]) | (sig[::-1] != ps[::-1])
                       | (alw[::-1] != pa[::-1]))
            nz = np.flatnonzero(changed)
            p = int(nz[0]) if nz.size else E
        si_r = self._n_seg if p >= E else p // self.k

        self.stats["solves"] += 1
        self.stats["segments_skipped"] += si_r
        self.stats["segments_launched"] += self._n_seg - si_r
        folded = 0
        if si_r == self._n_seg:
            self.stats["full_hits"] += 1
        else:
            V = self._planes[si_r]
            for si in range(si_r, self._n_seg):
                lo, hi = self._bounds[si]
                V, dec = self._launch[si](
                    jnp.asarray(ups[lo:hi]), jnp.asarray(sig[lo:hi]),
                    jnp.asarray(alw[lo:hi]), V)
                self._planes[si + 1] = V
                self._dec[si] = dec
                folded += hi - lo
            self._dec_cat = jnp.concatenate(self._dec, axis=0)
            # defensive copies: np.asarray above is a no-copy view, and a
            # host loop that mutates its statistics buffers in place would
            # otherwise mutate the carried inputs too — blinding the delta
            # mask and silently serving stale planes
            self._prev = (ups.copy(), sig.copy(), alw.copy())
        self.stats["edges_folded"] += folded
        self.stats["edges_skipped"] += E - folded

        x, s_star, row = self._select_back(
            self._planes[self._n_seg], self._dec_cat, jnp.asarray(ups),
            jnp.asarray(np.int32(s_limit)))
        return x, {"s_star": s_star, "value_row": row,
                   "edges_folded": folded}

"""jit'd wrapper: ESDP Algorithm 2 on the Pallas budgeted-DP kernel.

Drop-in equivalent of core.dp.solve_budgeted_dp (tested for exact
agreement): prepares the one-hot gather operands, runs the VMEM-resident
kernel, then applies the eq.-17 s* rule and backtracks in plain jnp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ...core.dp import DPTables
from .kernel import NEG, dp_forward_pallas

__all__ = ["prepare_tables", "solve_budgeted_dp_pallas"]

VALUE_BOUND = 2 ** 24          # f32-exact integer domain (kernel contract)


def prepare_tables(tables: DPTables):
    """(feasible (E,C) f32, next_onehot (E,C,C) f32) kernel operands."""
    feas = np.asarray(tables.feasible).T.astype(np.float32)        # (E, C)
    nxt = np.asarray(tables.next_state).T                          # (E, C)
    C = tables.n_states
    oh = np.zeros((nxt.shape[0], C, C), np.float32)
    for e in range(nxt.shape[0]):
        oh[e][nxt[e], np.arange(C)] = 1.0       # oh[e, src, dst]
    return jnp.asarray(feas), jnp.asarray(oh)


@functools.partial(jax.jit,
                   static_argnames=("s_cap", "u_max", "full_state",
                                    "interpret"))
def _solve(upsilon, sigma2, feasible, next_onehot, s_limit,
           *, s_cap: int, u_max: int, full_state: int, interpret: bool):
    E = upsilon.shape[0]
    S = s_cap + 1
    C = feasible.shape[1]
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)

    V, decisions = dp_forward_pallas(
        upsilon, sigma2, feasible, next_onehot, v0,
        n_edges=E, u_max=u_max, interpret=interpret)

    v_row = V[:, full_state]
    s_vals = jnp.arange(S, dtype=jnp.int32)
    ok = (v_row > NEG / 2) & (s_vals <= s_limit)
    score = s_vals.astype(jnp.float32) + jnp.sqrt(jnp.maximum(v_row, 0.0))
    s_star = jnp.argmax(jnp.where(ok, score, -jnp.inf)).astype(jnp.int32)

    next_idx = jnp.argmax(next_onehot, axis=1)       # (E, C)

    def back(e, carry):
        s, cs, x = carry
        d = decisions[e, s, cs] > 0.5
        x = x.at[e].set(d.astype(jnp.int32))
        s_new = jnp.maximum(s - upsilon[e], 0)
        return (jnp.where(d, s_new, s),
                jnp.where(d, next_idx[e, cs], cs), x)

    _, _, x = jax.lax.fori_loop(
        0, E, back, (s_star, jnp.int32(full_state),
                     jnp.zeros(E, jnp.int32)))
    return x, s_star, v_row


def solve_budgeted_dp_pallas(upsilon, sigma2, tables: DPTables, s_cap: int,
                             s_limit, u_max: int | None = None,
                             allowed=None, interpret: bool = True):
    """Same contract as core.dp.solve_budgeted_dp (+ interpret switch)."""
    feas, oh = prepare_tables(tables)
    if allowed is not None:
        feas = feas * jnp.asarray(allowed, jnp.float32)[:, None]
    if u_max is None:
        u_max = s_cap + 1
    x, s_star, v_row = _solve(
        jnp.asarray(upsilon, jnp.int32), jnp.asarray(sigma2, jnp.int32),
        feas, oh, jnp.asarray(s_limit, jnp.int32),
        s_cap=s_cap, u_max=int(u_max), full_state=tables.full_state,
        interpret=interpret)
    return x, {"s_star": s_star, "value_row": v_row}

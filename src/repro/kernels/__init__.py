"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships as a triple: kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd wrapper in substrate layout), ref.py (pure-jnp
oracle). Validated in interpret mode on CPU; interpret=False on real TPU.

  budgeted_dp      — the paper's Algorithm-2 hot loop (VMEM-resident plane,
                     shift-slice + one-hot-matmul gathers)
  flash_attention  — online-softmax attention for prefill/training
  ssd              — Mamba2 chunked state-space scan
"""

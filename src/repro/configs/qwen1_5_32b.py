"""qwen1.5-32b [dense] — MHA(40kv), QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab=152064, qkv_bias=True, activation="swiglu",
    rope_theta=1_000_000.0, param_dtype="bfloat16", compute_dtype="bfloat16",
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)

REDUCED = FULL.replace(
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=8, head_dim=16,
    d_ff=384, vocab=512, param_dtype="float32", compute_dtype="float32",
)

"""zamba2-7b [hybrid] — Mamba2 backbone + SHARED attention blocks.
[arXiv:2411.15242; unverified]

Layout approximation (DESIGN.md §6): 81 layers = 13 groups of
[5 mamba2 + 1 shared-weight attention block] + 3 trailing mamba2 layers.
"""
from .base import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32000, activation="swiglu",
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, hybrid_every=6,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2411.15242; unverified",
)

REDUCED = FULL.replace(
    n_layers=13, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=384, vocab=512, ssm_state=16, ssm_head_dim=32, hybrid_every=4,
    ssm_chunk=32, param_dtype="float32", compute_dtype="float32",
)

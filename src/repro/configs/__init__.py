"""Architecture registry: ``get_config(arch_id, reduced=False)``."""
from . import (dbrx_132b, deepseek_v3_671b, gemma3_27b, gemma_7b,
               mamba2_2_7b, qwen1_5_32b, qwen2_5_32b, qwen2_vl_72b,
               whisper_medium, zamba2_7b)
from .base import SHAPES, ModelConfig, Shape, shape_applicable

_MODULES = {
    "qwen2.5-32b": qwen2_5_32b,
    "gemma3-27b": gemma3_27b,
    "gemma-7b": gemma_7b,
    "qwen1.5-32b": qwen1_5_32b,
    "zamba2-7b": zamba2_7b,
    "dbrx-132b": dbrx_132b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "whisper-medium": whisper_medium,
    "mamba2-2.7b": mamba2_2_7b,
    "qwen2-vl-72b": qwen2_vl_72b,
}

ARCHS = tuple(_MODULES.keys())


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = _MODULES[arch]
    return mod.REDUCED if reduced else mod.FULL


__all__ = ["ARCHS", "SHAPES", "ModelConfig", "Shape", "get_config",
           "shape_applicable"]

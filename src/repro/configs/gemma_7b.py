"""gemma-7b [dense] — MHA(16kv), GeGLU, head_dim=256. [arXiv:2403.08295; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000, activation="geglu",
    norm_plus_one=True, embed_scale=True, tie_embeddings=True,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2403.08295; hf",
)

REDUCED = FULL.replace(
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=512, vocab=512, param_dtype="float32", compute_dtype="float32",
)

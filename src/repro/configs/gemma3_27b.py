"""gemma3-27b [dense] — GQA(16kv), 5 local : 1 global, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144, activation="geglu",
    global_every=6, window=1024, rope_theta=10_000.0,
    norm_plus_one=True, embed_scale=True, tie_embeddings=True,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="hf:google/gemma-3-1b-pt; unverified",
)

REDUCED = FULL.replace(
    n_layers=6, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=384, vocab=512, window=64,
    param_dtype="float32", compute_dtype="float32",
)

"""whisper-medium [audio] — enc-dec; conv frontend is a STUB (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab=51865, activation="gelu",
    use_rope=False, enc_len=1500, max_positions=32768, tie_embeddings=True,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2212.04356; unverified",
)

REDUCED = FULL.replace(
    n_layers=3, n_enc_layers=3, d_model=128, n_heads=4, n_kv_heads=4,
    head_dim=32, d_ff=256, vocab=512, enc_len=64, max_positions=256,
    param_dtype="float32", compute_dtype="float32",
)

"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    vocab=129280, activation="swiglu",
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    nope_head_dim=128, rope_head_dim=64, v_head_dim=128,
    d_ff=18432,  # the 3 leading dense layers
    n_experts=256, top_k=8, n_shared_experts=1, d_ff_expert=2048,
    moe_layer_start=3, mtp=True,
    # moe_combine="scatter_ar" measured WORSE (§Perf P5 refuted: GSPMD's
    # scatter partitioning dominates the wire-cost argument) — keep gather.
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2412.19437; hf",
)

REDUCED = FULL.replace(
    n_layers=5, d_model=128, n_heads=4,
    q_lora_rank=48, kv_lora_rank=32, nope_head_dim=16, rope_head_dim=8,
    v_head_dim=16, d_ff=384, n_experts=8, top_k=2, d_ff_expert=64,
    moe_layer_start=2, vocab=512,
    param_dtype="float32", compute_dtype="float32",
)

"""dbrx-132b [moe] — 16 experts top-4, fine-grained. [hf:databricks/dbrx-base;
unverified]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab=100352, activation="swiglu",
    n_experts=16, top_k=4, d_ff_expert=10752, rope_theta=500_000.0,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="hf:databricks/dbrx-base; unverified",
)

REDUCED = FULL.replace(
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, n_experts=4, top_k=2, d_ff_expert=256, vocab=512,
    param_dtype="float32", compute_dtype="float32",
)

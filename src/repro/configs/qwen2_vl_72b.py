"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution; vision tower is a STUB
(input_specs provides patch embeddings). [arXiv:2409.12191; hf]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064, qkv_bias=True, activation="swiglu",
    rope_theta=1_000_000.0, mrope_sections=(16, 24, 24),
    n_vision_tokens=1024,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2409.12191; hf",
)

REDUCED = FULL.replace(
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, head_dim=32,
    d_ff=384, vocab=512, mrope_sections=(4, 6, 6), n_vision_tokens=16,
    param_dtype="float32", compute_dtype="float32",
)

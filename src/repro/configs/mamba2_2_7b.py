"""mamba2-2.7b [ssm] — attention-free SSD. [arXiv:2405.21060; unverified]"""
from .base import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    source="arXiv:2405.21060; unverified",
)

REDUCED = FULL.replace(
    n_layers=4, d_model=128, vocab=512, ssm_state=16, ssm_head_dim=32,
    ssm_chunk=32, param_dtype="float32", compute_dtype="float32",
)

"""Model configuration + input-shape registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "Shape", "SHAPES", "shape_applicable"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One dataclass covers all 10 assigned families; unused fields stay None.

    Weights are stored flattened-2D wherever possible ((in, out) matrices) so
    the logical-axis sharding rules stay uniform (runtime/sharding.py).
    """

    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int

    # --- attention ---
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    use_rope: bool = True  # whisper uses absolute positions instead
    rope_theta: float = 10_000.0
    # sliding-window pattern: every `global_every`-th layer is global, rest
    # local with window `window` (gemma3's 5:1); 0 ⇒ all global.
    global_every: int = 0
    window: int = 0
    # M-RoPE (qwen2-vl): sizes of the (t, h, w) rotary sections (pairs).
    mrope_sections: Optional[tuple[int, int, int]] = None

    # --- MLA (deepseek) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    nope_head_dim: int = 0
    rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MLP ---
    d_ff: int = 0
    activation: str = "swiglu"  # swiglu | geglu | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_layer_start: int = 0  # deepseek: first k layers stay dense
    capacity_factor: float = 1.0
    # combine strategy (§Perf P5): "gather" reshards ye to expert-unsharded
    # then scatters locally (wire ≈ k·Tg·d — wins for small E/k, e.g. dbrx);
    # "scatter_ar" scatters expert-sharded partials and all-reduces
    # (wire ≈ 2·Tg·d — wins for large E/k, e.g. deepseek's 256/8).
    moe_combine: str = "gather"

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4
    # hybrid (zamba2): one SHARED attention block every `hybrid_every` layers
    hybrid_every: int = 0

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_len: int = 0  # fixed encoder length (1500 = 30s audio)
    max_positions: int = 0  # learned positional table size (whisper)

    # --- blocking knobs (memory/compute trade; §Perf levers) ---
    attn_chunk: int = 1024  # KV-chunk for online-softmax attention
    xent_chunk: int = 2048  # seq-chunk for the cross-entropy (0=full)
    # cost-model support: unroll layer scans so cost_analysis counts every
    # layer (XLA counts while bodies once; see launch/cost_model.py)
    unroll_scans: bool = False

    # --- misc ---
    norm_eps: float = 1e-6
    norm_plus_one: bool = False  # gemma-style (1 + w) RMSNorm
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scale
    tie_embeddings: bool = False
    mtp: bool = False  # deepseek multi-token prediction head
    n_vision_tokens: int = 0  # vlm: leading patch-embedding positions
    source: str = ""  # provenance tag from the assignment table

    # dtypes (dry-run realism for the giant configs; smoke tests use f32)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? SSM/hybrid only."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, Shape] = {
    "train_4k":    Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k":   Shape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(config: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — the DESIGN.md §6 skip policy."""
    if shape.name == "long_500k" and not config.sub_quadratic:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{config.name} is full-attention (family={config.family})")
    return True, ""

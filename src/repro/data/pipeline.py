"""Deterministic, shardable synthetic data pipeline.

Design: the stream is a pure function of (seed, step, batch-row index) —
no state on any host. That gives the three properties a 1000-node pipeline
needs for free:
  * restart-exactness : resuming at step k reproduces the same batches, so
    checkpoint/restart does not perturb training;
  * host sharding     : each host materializes only its batch rows
    (``host_slice``) — no cross-host data traffic;
  * elasticity        : re-sharding after a topology change is just a new
    host_slice of the same pure function.

The generator is a Markov-ish token process (mixture of n-gram-style
structure + noise) so tiny-model training has learnable signal — examples
train ~100M models on it and the loss visibly drops.

For the VLM/audio stubs the same stream yields deterministic pseudo
patch/frame embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

__all__ = ["SyntheticLM", "make_batch_iterator"]


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq_len: int  # tokens per example INCLUDING the label shift
    global_batch: int
    seed: int = 0
    structure: int = 97  # period of the learnable component

    def _tokens(self, step: int, rows: np.ndarray) -> np.ndarray:
        """(len(rows), seq_len+1) int32, pure function of (seed, step, row)."""
        rng_keys = (self.seed * 1_000_003 + step) * 131 + rows[:, None]
        t = np.arange(self.seq_len + 1)[None, :]
        # learnable structure: position-dependent affine walk mod vocab
        base = (rng_keys % self.structure + 1)
        walk = (base * t + (rng_keys // 7) % 13) % max(self.vocab - 3, 1)
        # deterministic "noise": xor-shift hash, 20% of positions
        h = (rng_keys * 2654435761 + t * 40503) & 0xFFFFFFFF
        h = (h ^ (h >> 13)) & 0xFFFFFFFF
        noisy = (h % 5) == 0
        noise_tok = h % max(self.vocab - 3, 1)
        out = np.where(noisy, noise_tok, walk) + 2  # reserve 0/1
        return out.astype(np.int32)

    def batch(self, step: int, host_slice: Optional[slice] = None) -> dict:
        rows = np.arange(self.global_batch)
        if host_slice is not None:
            rows = rows[host_slice]
        return {"tokens": self._tokens(step, rows)}


def make_batch_iterator(
    ds: SyntheticLM,
    start_step: int = 0,
    host_slice: Optional[slice] = None,
    extras=None,
) -> Iterator[dict]:
    """extras(step, batch) may attach modality stubs (patch/frame embeds)."""
    step = start_step
    while True:
        b = ds.batch(step, host_slice)
        if extras is not None:
            b = extras(step, b)
        yield step, b
        step += 1

"""Roofline-grounded service rates for the dispatcher.

Mean service rate of (arch × shape) on a slice = tokens/s implied by the
compiled dry-run roofline record (results/dryrun/*.json): the step time is
max(compute, memory, collective) and throughput = tokens_per_step / step_s,
scaled by the slice's relative capability. When a record is missing (e.g.
the sweep has not produced that cell) a parametric fallback keyed on the
arch's active-param count is used — rates stay positive and ordered.

This closes the loop promised in DESIGN.md §2: the unknown service rates the
paper learns are the measured-systems quantity, fluctuated by multi-tenancy
noise and straggler degradation (sched/dispatcher.py).
"""
from __future__ import annotations

import json
import pathlib

import numpy as np

from ..configs import SHAPES

__all__ = ["roofline_rate", "rate_matrix"]

_ACTIVE_B = {  # fallback active-params (B) if no dry-run record
    "qwen2.5-32b": 32.8, "gemma3-27b": 27.0, "gemma-7b": 8.5,
    "qwen1.5-32b": 35.2, "zamba2-7b": 5.7, "dbrx-132b": 36.0,
    "deepseek-v3-671b": 37.0, "whisper-medium": 0.79,
    "mamba2-2.7b": 2.8, "qwen2-vl-72b": 72.7,
}


def roofline_rate(
    arch: str, shape_name: str, results_dir: str = "results/dryrun"
) -> float:
    """Normalized tokens/s per chip for the single-pod mesh."""
    shape = SHAPES[shape_name]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    path = pathlib.Path(results_dir) / f"{arch}_{shape_name}_single.json"
    if path.exists():
        rec = json.loads(path.read_text())
        if "roofline" in rec:
            t = rec["roofline"]
            step_s = max(t["compute_s"], t["memory_s"], t["collective_s"],
                         1e-9)
            return tokens / step_s / 256.0
    # parametric fallback: compute-bound estimate at 40% MFU
    n_active = _ACTIVE_B.get(arch, 10.0) * 1e9
    factor = 6.0 if shape.kind == "train" else 2.0
    step_s = factor * n_active * tokens / (0.4 * 197e12 * 256)
    return tokens / max(step_s, 1e-9) / 256.0


def rate_matrix(
    jobs, slices, results_dir: str = "results/dryrun", slice_speed: dict | None = None
) -> np.ndarray:
    """mean_rates[l, r] for build_instance; slice_speed scales per slice
    (heterogeneous fleets / chronic stragglers)."""
    out = np.zeros((len(jobs), len(slices)), np.float32)
    for li, job in enumerate(jobs):
        base = roofline_rate(job.arch, job.shape, results_dir)
        for r, sl in enumerate(slices):
            speed = (slice_speed or {}).get(sl.name, 1.0)
            out[li, r] = base * speed * sl.chips
    return out

"""Cluster-level integration: ESDP as the gang dispatcher for multi-pod
training/serving jobs (DESIGN.md §2)."""
from .cluster import JobType, Slice, build_instance
from .dispatcher import ClusterSim, FailureModel, FailureRuntime, SimOutput
from .ratemodel import rate_matrix, roofline_rate

__all__ = ["JobType", "Slice", "build_instance", "ClusterSim", "SimOutput",
           "FailureModel", "FailureRuntime", "rate_matrix", "roofline_rate"]

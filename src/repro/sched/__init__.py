"""Cluster-level integration: ESDP as the gang dispatcher for multi-pod
training/serving jobs (DESIGN.md §2)."""
from .cluster import JobType, Slice, build_instance, validate_jobs
from .dispatcher import (ClusterSim, FailureModel, FailureRuntime,
                         MalleableModel, MalleableRuntime, SimOutput)
from .engine import (BACKPRESSURE_POLICIES, LOCKSTEP_POLICIES, DispatchEngine,
                     EngineConfig, EngineOutput, VariantSpec, feasible_ports)
from .ratemodel import rate_matrix, roofline_rate

__all__ = ["JobType", "Slice", "build_instance", "validate_jobs",
           "ClusterSim", "SimOutput", "FailureModel", "FailureRuntime",
           "MalleableModel", "MalleableRuntime",
           "BACKPRESSURE_POLICIES", "LOCKSTEP_POLICIES", "DispatchEngine",
           "EngineConfig", "EngineOutput", "VariantSpec", "feasible_ports",
           "rate_matrix", "roofline_rate"]

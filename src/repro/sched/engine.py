"""Streaming dispatch engine: admission → bounded queue → dispatch.

``ClusterSim.run`` (the paper-faithful lockstep research loop, preserved
bit-for-bit as :func:`lockstep_run` below) assumes every arrival is
dispatchable the slot it lands and silently forgets the ones that are not.
Production model-serving schedulers do neither: arrivals are *validated*
(fail-fast rejection of jobs that can never run — wrong accelerator
family, gang larger than the fleet), *queued* under an explicit bound with
a backpressure policy, and *dispatched* against capacity checks, while a
new learned policy rolls out to a weighted fraction of traffic next to the
incumbent.  :class:`DispatchEngine` is that loop for this repo's
bipartite multi-server-job model (modeled on osml-model-runner's
validate-then-queue + throttling design; see ``docs/engine.md``):

* **Admission** — arrivals whose port has no feasible edge (no
  capacity-respecting (job, server) pair) are rejected into a dead-letter
  ledger *before* touching the queue: rejected jobs never consume
  capacity and never enter the bandit statistics.
* **Bounded queue** — per-port FIFO of depth ``queue_capacity`` plus a
  global bound ``total_capacity``; on overflow the configured
  backpressure policy fires: ``drop_oldest`` (evict the oldest queued
  job), ``block`` (refuse the newcomer), or ``shed_by_utility`` (evict
  the lowest-estimated-value job, newest first on ties).
* **Dispatch** — each port serves at most its *head* (oldest) job per
  slot, on one edge; contention is broken by estimated utility, then
  oldest job first, then least-loaded server, then edge index, and every
  start is capacity-checked against the residual ``c − A·x`` in that
  order (challenger variants pack into what the primary left).
* **A/B routing** — jobs hash (job-id × seed, splitmix-style) onto
  weighted policy variants (e.g. ESDP 90 / greedy challenger 10);
  utility, regret, and bandit state are tracked *per variant*, so a
  challenger's regret is read directly off the output.

Two execution modes share one set of slot functions:

* ``stream`` — the whole horizon is ONE jitted ``lax.scan``: a
  million-arrival trace is a single device call (the jaxpr is
  horizon-independent — ``tests/test_engine.py`` asserts it), and
  ``run_batch`` vmaps it so fleet solves hit the PR 6 batched-kernel
  dispatch.
* ``lockstep`` — the same slot functions driven from the host, one slot
  at a time, so host-side solver wrappers (``CachedSolver``,
  ``FallbackSolver`` — PR 7/8) see concrete inputs and can cache, skip,
  or degrade, and the PR 8 failure runtime can settle crashes per slot.
  Fault-free, ``lockstep`` is bit-identical to ``stream``.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import build_tables, stats as stats_mod
from ..core.baselines import greedy_pack
from ..core.dp import oracle_knapsack
from ..core.env import Scenario
from ..core.graph import Instance
from ..core.solvers import Solver, get_solver

__all__ = ["BACKPRESSURE_POLICIES", "LOCKSTEP_POLICIES", "VariantSpec",
           "EngineConfig", "EngineOutput", "DispatchEngine",
           "feasible_ports", "lockstep_run"]

BACKPRESSURE_POLICIES = ("drop_oldest", "block", "shed_by_utility")
VARIANT_KINDS = ("esdp", "hswf", "lcf", "lwtf")
# named policies the host lockstep loop implements (ClusterSim.run /
# run_batch validate against this — an unknown name used to silently fall
# through to lwtf)
LOCKSTEP_POLICIES = ("esdp", "hswf", "lcf", "lwtf")

_EMPTY = -1  # queue sentinel: no job in this slot of the FIFO


def feasible_ports(instance: Instance) -> np.ndarray:
    """(P,) bool: ports with at least one capacity-respecting edge.

    A port fails when it has no edges at all (service locality or
    solely-servable filters dropped every server — ``build_instance``)
    or when every edge's requirement column exceeds cluster capacity.
    Arrivals on such ports can NEVER run; the engine dead-letters them
    at admission instead of letting them camp in the queue.
    """
    ok = np.zeros(instance.n_ports, bool)
    fits = np.all(np.asarray(instance.A) <= np.asarray(instance.c)[:, None],
                  axis=0)
    np.logical_or.at(ok, instance.port_of_edge, fits)
    return ok


@dataclasses.dataclass(frozen=True)
class VariantSpec:
    """One policy variant in the weighted A/B rollout.

    ``kind`` picks the dispatch rule (``esdp`` — the paper's
    Algorithm 1/2 bandit; ``hswf``/``lcf``/``lwtf`` — the greedy
    baselines); ``weight`` is the traffic fraction (normalized over the
    config); ``solver`` optionally pins the Algorithm-2 backend for an
    ``esdp`` variant (name or solver object — host-side wrappers such as
    ``CachedSolver``/``FallbackSolver`` need ``mode="lockstep"`` to act).
    """
    name: str
    kind: str = "esdp"
    weight: float = 1.0
    solver: "str | object | None" = None

    def __post_init__(self):
        if self.kind not in VARIANT_KINDS:
            raise ValueError(f"unknown variant kind {self.kind!r}; "
                             f"choose from {VARIANT_KINDS}")
        if not self.weight > 0:
            raise ValueError("variant weight must be > 0")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Queueing + rollout knobs of the streaming engine.

    ``queue_capacity`` bounds each port's FIFO; ``total_capacity`` bounds
    the whole queue (default: ``P × queue_capacity``, i.e. only the
    per-port bound binds).  ``backpressure`` picks the overflow policy
    (:data:`BACKPRESSURE_POLICIES`).  ``route_salt`` perturbs the
    deterministic job-id → variant hash (same seed + salt ⇒ same split).
    """
    queue_capacity: int = 4
    total_capacity: "int | None" = None
    backpressure: str = "drop_oldest"
    variants: "tuple[VariantSpec, ...]" = (VariantSpec("esdp"),)
    route_salt: int = 0x5A17

    def __post_init__(self):
        if self.backpressure not in BACKPRESSURE_POLICIES:
            raise ValueError(
                f"unknown backpressure policy {self.backpressure!r}; "
                f"choose from {BACKPRESSURE_POLICIES}")
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        if not self.variants:
            raise ValueError("need at least one variant")
        names = [v.name for v in self.variants]
        if len(set(names)) != len(names):
            raise ValueError(f"variant names must be unique: {names}")


@dataclasses.dataclass(frozen=True)
class EngineOutput:
    """Per-slot traces + per-variant accounting + the conservation ledger.

    ``ledger`` is exactly conserving (asserted by ``tests/test_engine.py``):

        arrivals  = rejected + blocked + admitted          (admission)
        admitted  = dispatched + dropped + shed + final_queue   (queue)

    with ``rejected`` the dead-letter count (never-feasible ports) and
    ``dispatched`` counting jobs started.  ``n``/``sumz`` are the final
    per-variant bandit statistics — rejected/shed jobs never appear in
    them (they are never dispatched, and only dispatch updates the
    bandit).
    """
    sw: np.ndarray  # (T,)
    regret: np.ndarray  # (T,)
    dispatch_share: np.ndarray  # (T, R)
    asw: float
    variants: "tuple[str, ...]"
    sw_variant: np.ndarray  # (T, V)
    regret_variant: np.ndarray  # (T, V)
    dispatched_variant: np.ndarray  # (T, V) jobs started per variant
    routed_variant: np.ndarray  # (T, V) admitted arrivals routed per variant
    n: np.ndarray  # (V, E) final bandit pull counts
    sumz: np.ndarray  # (V, E) final bandit reward sums
    ledger: dict  # per-slot int32 arrays + totals (see class docstring)
    queue_len: np.ndarray  # (T,) jobs queued after each slot
    mode: str
    solve_stats: "dict | None" = None  # {variant: counters} for wrappers
    failures: "dict | None" = None  # combined + per-variant crash ledgers

    @property
    def cum_regret(self):
        return np.cumsum(self.regret)


def _route_u01(job_id, salt):
    """Deterministic job-id → [0, 1) hash (splitmix-style avalanche)."""
    h = job_id.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
    h = h ^ jnp.asarray(salt).astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h.astype(jnp.float32) * jnp.float32(2.0**-32)


class DispatchEngine:
    """The streaming admission/queue/dispatch loop over one instance.

    Construction mirrors :class:`ClusterSim` (scenario= or raw
    ``speed_fn``/``alive_fn`` schedules; the schedule is shared by every
    seed), plus an :class:`EngineConfig`.  ``ClusterSim.engine()`` builds
    one that shares the sim's instance, horizon, schedule, and seed.
    """

    def __init__(
        self,
        instance: Instance,
        T: int,
        config: "EngineConfig | None" = None,
        *,
        scenario: Optional[Scenario] = None,
        speed_fn: Optional[Callable[[int], np.ndarray]] = None,
        alive_fn: Optional[Callable[[int], np.ndarray]] = None,
        arr_scale: "np.ndarray | None" = None,
        g_fn=stats_mod.g_logt_only,
        seed: int = 0,
        failures=None,
    ):
        self.inst = instance
        self.T = int(T)
        self.config = config or EngineConfig()
        self.g_fn = g_fn
        self.seed = int(seed)
        self.failures = failures
        self.tables = build_tables(instance.A, instance.c)
        self.m = instance.m
        self.s_cap = stats_mod.s_cap_for_horizon(T, self.m)
        self.u_max = stats_mod.u_max_for_horizon(T, self.m)
        P, R = instance.n_ports, instance.n_servers

        if scenario is not None:
            if speed_fn is not None or alive_fn is not None:
                raise ValueError("pass either scenario= or "
                                 "speed_fn/alive_fn, not both")
            from ..experiments.scenarios import unroll_scenario
            arr_scale, speeds, alive = unroll_scenario(
                scenario, T, R, seed, n_ports=P)
            self.speed = np.asarray(speeds, np.float32)
            self.alive = np.asarray(alive, bool)
        else:
            self.speed = (np.ones((T, R), np.float32) if speed_fn is None
                          else np.stack([np.asarray(speed_fn(t), np.float32)
                                         for t in range(T)]))
            self.alive = (np.ones((T, R), bool) if alive_fn is None
                          else np.stack([np.asarray(alive_fn(t), bool)
                                         for t in range(T)]))
        self.arr_scale = (np.ones((T, P), np.float32) if arr_scale is None
                          else np.asarray(arr_scale, np.float32))
        self.port_ok = feasible_ports(instance)

        cfg = self.config
        self.Q = int(cfg.queue_capacity)
        self.Ktot = int(cfg.total_capacity if cfg.total_capacity is not None
                        else P * self.Q)
        w = np.asarray([v.weight for v in cfg.variants], np.float64)
        # routing thresholds: variant v wins u01 ∈ [cum[v-1], cum[v])
        self._cum_w = np.cumsum(w / w.sum())[:-1].astype(np.float32)
        self._solvers = []
        for v in cfg.variants:
            if v.kind != "esdp":
                self._solvers.append(None)
            elif v.solver is None or isinstance(v.solver, str):
                self._solvers.append(get_solver(v.solver))
            else:
                if getattr(v.solver, "scope", "") is None:
                    v.solver.scope = v.name  # per-variant stats scoping
                self._solvers.append(v.solver)
        self._jit_cache: dict = {}

    # -- host-side randomness ------------------------------------------
    def _streams(self, seed: "int | None" = None):
        """(arrivals (T,P) bool, noise (T,E) f32, tiebreak (T,E) f32).

        Same generator layout as ``ClusterSim._streams`` (arrivals +
        valuation noise off ``seed``) with the greedy tie-break stream
        off ``seed + 1`` — one seed fully determines a trace, and
        ``run_batch([s])`` replays ``run(seed=s)``.
        """
        seed = self.seed if seed is None else int(seed)
        rng = np.random.default_rng(seed)
        inst = self.inst
        rho_t = np.clip(inst.rho[None, :] * self.arr_scale, 0.0, 1.0)
        arrivals = rng.random((self.T, inst.n_ports)) < rho_t
        noise = rng.normal(0.0, 1.0, (self.T, inst.n_edges)).astype(np.float32)
        tb = np.random.default_rng(seed + 1).random(
            (self.T, inst.n_edges)).astype(np.float32)
        return arrivals, noise, tb

    def _xs(self, streams):
        arrivals, noise, tb = streams
        return {
            "arrived": jnp.asarray(arrivals),
            "noise": jnp.asarray(noise),
            "tb": jnp.asarray(tb),
            "speed": jnp.asarray(self.speed),
            "alive": jnp.asarray(self.alive),
            "t": jnp.arange(self.T, dtype=jnp.int32),
        }

    def _carry0(self):
        inst, V = self.inst, len(self.config.variants)
        return {
            "queue": jnp.full((inst.n_ports, self.Q), _EMPTY, jnp.int32),
            "n": jnp.zeros((V, inst.n_edges), jnp.int32),
            "sumz": jnp.zeros((V, inst.n_edges), jnp.float32),
            "load": jnp.zeros(inst.n_servers, jnp.int32),
        }

    # -- slot functions (shared by stream scan and lockstep host loop) --
    def _consts(self):
        inst = self.inst
        return (jnp.asarray(inst.A), jnp.asarray(inst.c),
                jnp.asarray(inst.port_of_edge),
                jnp.asarray(inst.edges[:, 1]),
                jnp.asarray(inst.cost), jnp.asarray(inst.mu),
                jnp.asarray(inst.sigma), jnp.asarray(self.port_ok),
                jnp.asarray(self._cum_w))

    def _slot_pre(self, queue, n, sumz, arrived_raw, alive_t, suspicious, t0, salt):
        """Admission + enqueue + head/variant/eligibility computation.

        ``salt`` is the per-trace routing salt (u32 scalar, a pure
        function of config.route_salt and the TRACE seed — an argument,
        not a baked constant, so ``run_batch`` routes each seed exactly
        as its single-seed run would)."""
        A, c, port, server, cost, mu, sigma, port_ok, cum_w = self._consts()
        P, Q, Ktot = self.inst.n_ports, self.Q, self.Ktot
        V = len(self.config.variants)
        bp = self.config.backpressure
        i32 = jnp.int32

        arrived = arrived_raw & port_ok
        rejected = jnp.sum((arrived_raw & ~port_ok).astype(i32))

        # pooled value estimate → per-port utility (the shedding signal)
        n_all = jnp.sum(n, axis=0)
        vpool = jnp.where(n_all > 0,
                          jnp.sum(sumz, axis=0) / jnp.maximum(n_all, 1), 0.0)
        u_port = jnp.zeros(P, jnp.float32).at[port].max(
            vpool.astype(jnp.float32))

        def row_count(row):
            return jnp.sum((row >= 0).astype(i32))

        def append(qs, l):
            return qs.at[l, row_count(qs[l])].set(t0.astype(i32))

        def evict_head(qs, p):
            shifted = jnp.concatenate(
                [qs[p, 1:], jnp.full((1,), _EMPTY, i32)])
            return qs.at[p].set(shifted)

        def evict_newest(qs, p):
            k = jnp.maximum(row_count(qs[p]) - 1, 0)
            return qs.at[p, k].set(_EMPTY)

        def enq_body(l, st):
            qs, blocked, dropped, shed, admitted = st
            arr = arrived[l]
            port_full = row_count(qs[l]) >= Q
            glob_full = jnp.sum((qs >= 0).astype(i32)) >= Ktot
            overflow = arr & (port_full | glob_full)
            room = arr & ~(port_full | glob_full)
            qs_app = jnp.where(room, append(qs, l), qs)
            if bp == "block":
                return (qs_app, blocked + overflow.astype(i32), dropped,
                        shed, admitted + room.astype(i32))
            if bp == "drop_oldest":
                heads = qs[:, 0]
                oldest = jnp.argmin(jnp.where(heads >= 0, heads,
                                              jnp.iinfo(i32).max))
                tgt = jnp.where(port_full, l, oldest)
                qs_ev = append(evict_head(qs, tgt), l)
                qs2 = jnp.where(overflow, qs_ev, qs_app)
                return (qs2, blocked, dropped + overflow.astype(i32),
                        shed, admitted + (room | overflow).astype(i32))
            # shed_by_utility: evict the lowest-utility job, newest first
            # on ties — a structurally-full port ties with the newcomer,
            # so the newcomer itself is shed
            cnts = jnp.sum((qs >= 0).astype(i32), axis=1)
            uq = jnp.where(cnts > 0, u_port, jnp.inf)
            pmin = jnp.argmin(uq)
            shed_new = port_full | (u_port[l] <= uq[pmin])
            qs_ev = append(evict_newest(qs, pmin), l)
            qs2 = jnp.where(overflow & ~shed_new, qs_ev, qs_app)
            return (qs2, blocked, dropped, shed + overflow.astype(i32),
                    admitted + (room | overflow).astype(i32))

        zero = jnp.zeros((), i32)
        queue2, blocked, dropped, shed, admitted = jax.lax.fori_loop(
            0, P, enq_body, (queue, zero, zero, zero, zero))

        head = queue2[:, 0]
        has = head >= 0
        age = jnp.where(has, t0.astype(i32) - head, 0)
        ports = jnp.arange(P, dtype=i32)
        u01 = _route_u01(head * P + ports, salt)
        hvar = jnp.sum((u01[None, :] >= cum_w[:, None]).astype(i32), axis=0)
        # admission-time routing split: the job id of THIS slot's arrival
        # on port l is t0·P + l, the same id its queue head carries later
        u01_arr = _route_u01(t0.astype(i32) * P + ports, salt)
        avar = jnp.sum((u01_arr[None, :] >= cum_w[:, None]).astype(i32),
                       axis=0)
        routed = jnp.stack([jnp.sum((arrived & (avar == v)).astype(i32))
                            for v in range(V)])

        elig_base = has[port] & alive_t[server] & ~suspicious[server]
        elig = jnp.stack([elig_base & (hvar[port] == v) for v in range(V)])
        vhat = jnp.where(n > 0, sumz / jnp.maximum(n, 1), 0.0).astype(
            jnp.float32)
        counts = {"arrivals": jnp.sum(arrived_raw.astype(i32)),
                  "rejected": rejected, "blocked": blocked,
                  "dropped": dropped, "shed": shed, "admitted": admitted,
                  "routed_v": routed}
        return queue2, counts, age, elig, vhat

    def _route_salt(self, seed: int) -> int:
        return (self.config.route_salt ^ (seed * 0x85EBCA6B)) & 0xFFFFFFFF

    def _variant_x(self, v, elig_v, vhat_v, n_v, age, tb_t, t0):
        """Raw per-variant dispatch proposal (possibly >1 edge per port)."""
        A, c, port, server, cost, mu, sigma, port_ok, cum_w = self._consts()
        spec = self.config.variants[v]
        if spec.kind == "esdp":
            ups, sig, _, s_lim = stats_mod.scale_statistics(
                vhat_v, n_v, (t0 + 1).astype(jnp.float32), self.m,
                g_fn=self.g_fn)
            x, _ = self._solvers[v](ups, sig, self.tables, self.s_cap,
                                    s_lim, allowed=elig_v, u_max=self.u_max)
            return x
        if spec.kind == "hswf":
            score = vhat_v + tb_t * 1e-4
        elif spec.kind == "lcf":
            score = -cost + tb_t * 1e-4
        else:  # lwtf: oldest head job first (queue age replaces the
            # lockstep loop's waiting counters)
            score = age[port].astype(jnp.float32) * 1e3 + vhat_v + tb_t * 1e-4
        return greedy_pack(score, elig_v, A, c)

    def _slot_dispatch(self, queue2, load, x_raw, elig, vhat, age):
        """Trim to one head job per port, capacity-check in priority
        order (utility desc, oldest job, least-loaded server), pop
        served heads."""
        A, c, port, server, cost, mu, sigma, port_ok, cum_w = self._consts()
        P, E = self.inst.n_ports, self.inst.n_edges
        V = len(self.config.variants)
        i32 = jnp.int32

        residual = c
        xs = []
        for v in range(V):
            cand = (x_raw[v] > 0) & elig[v]
            # priority rank: utility desc → oldest head job → least-loaded
            # server → edge index (jnp.lexsort: last key is primary)
            order = jnp.lexsort((jnp.arange(E), load[server],
                                 -age[port].astype(jnp.float32), -vhat[v]))
            rank = jnp.zeros(E, i32).at[order].set(jnp.arange(E, dtype=i32))
            best = jnp.full(P, E, i32).at[port].min(
                jnp.where(cand, rank, E))
            x1 = (cand & (rank == best[port])).astype(i32)

            def cap_body(j, st):
                res, xo = st
                e = order[j]
                take = (x1[e] > 0) & jnp.all(res >= A[:, e])
                xo = xo.at[e].set(take.astype(i32))
                res = res - jnp.where(take, A[:, e], 0)
                return res, xo

            residual, x_v = jax.lax.fori_loop(
                0, E, cap_body, (residual, jnp.zeros(E, i32)))
            xs.append(x_v)

        xv = jnp.stack(xs)  # (V, E), one unit per served port overall
        x = jnp.sum(xv, axis=0)
        served = jnp.zeros(P, i32).at[port].add(x) > 0
        popped = jnp.concatenate(
            [queue2[:, 1:], jnp.full((P, 1), _EMPTY, i32)], axis=1)
        queue3 = jnp.where(served[:, None], popped, queue2)
        load2 = load + jnp.zeros_like(load).at[server].add(x)
        qlen = jnp.sum((queue3 >= 0).astype(i32))
        return xv, x, served, queue3, load2, qlen

    def _slot_account(self, n, sumz, xv, elig, noise_t, speed_t):
        """Realized welfare, per-variant regret, bandit update, share."""
        A, c, port, server, cost, mu, sigma, port_ok, cum_w = self._consts()
        V = len(self.config.variants)
        mean = mu * speed_t[server] - cost
        z = jnp.clip(mean + sigma * noise_t, 0.0, 1.0)
        v_true = jnp.clip(mean, 0.0, 1.0).astype(jnp.float32)
        x = jnp.sum(xv, axis=0)

        sw_v = jnp.sum(xv * z, axis=1).astype(jnp.float32)
        reg = []
        for v in range(V):
            x_star, _ = oracle_knapsack(v_true, self.tables, elig[v])
            reg.append(jnp.sum(v_true * x_star) - jnp.sum(v_true * xv[v]))
        regret_v = jnp.stack(reg).astype(jnp.float32)
        if V == 1:
            regret = regret_v[0]
        else:
            x_all, _ = oracle_knapsack(v_true, self.tables,
                                       jnp.any(elig, axis=0))
            regret = (jnp.sum(v_true * x_all)
                      - jnp.sum(v_true * x)).astype(jnp.float32)

        n2 = n + xv
        sumz2 = sumz + (xv * z).astype(jnp.float32)
        tot = jnp.sum(x)
        share = jnp.zeros(self.inst.n_servers, jnp.float32).at[server].add(
            x / jnp.maximum(tot, 1))
        return n2, sumz2, jnp.sum(sw_v), sw_v, regret, regret_v, share

    # -- stream mode ----------------------------------------------------
    def _scan_body(self, carry, xs_t, salt):
        V = len(self.config.variants)
        suspicious = jnp.zeros(self.inst.n_servers, bool)
        queue2, counts, age, elig, vhat = self._slot_pre(
            carry["queue"], carry["n"], carry["sumz"], xs_t["arrived"],
            xs_t["alive"], suspicious, xs_t["t"], salt)
        x_raw = jnp.stack([
            self._variant_x(v, elig[v], vhat[v], carry["n"][v], age,
                            xs_t["tb"], xs_t["t"])
            for v in range(V)])
        xv, x, served, queue3, load2, qlen = self._slot_dispatch(
            queue2, carry["load"], x_raw, elig, vhat, age)
        n2, sumz2, sw, sw_v, regret, regret_v, share = self._slot_account(
            carry["n"], carry["sumz"], xv, elig, xs_t["noise"],
            xs_t["speed"])
        carry2 = {"queue": queue3, "n": n2, "sumz": sumz2, "load": load2}
        ys = dict(counts, sw=sw, sw_v=sw_v, regret=regret,
                  regret_v=regret_v, share=share, qlen=qlen,
                  dispatched=jnp.sum(served.astype(jnp.int32)),
                  dispatched_v=jnp.sum(xv, axis=1))
        return carry2, ys

    def _stream_fn(self):
        fn = self._jit_cache.get("stream")
        if fn is None:
            def run_scan(carry0, xs, salt):
                return jax.lax.scan(
                    lambda c, x: self._scan_body(c, x, salt), carry0, xs)
            fn = jax.jit(run_scan)
            self._jit_cache["stream"] = fn
        return fn

    def make_stream_jaxpr(self, T: int):
        """The traced (unjitted) stream jaxpr at horizon ``T`` — the
        launch-count test inspects it: one ``scan`` eqn regardless of T."""
        save = self.T
        try:
            self.T = int(T)
            xs = {"arrived": jax.ShapeDtypeStruct(
                      (T, self.inst.n_ports), jnp.bool_),
                  "noise": jax.ShapeDtypeStruct(
                      (T, self.inst.n_edges), jnp.float32),
                  "tb": jax.ShapeDtypeStruct(
                      (T, self.inst.n_edges), jnp.float32),
                  "speed": jax.ShapeDtypeStruct(
                      (T, self.inst.n_servers), jnp.float32),
                  "alive": jax.ShapeDtypeStruct(
                      (T, self.inst.n_servers), jnp.bool_),
                  "t": jax.ShapeDtypeStruct((T,), jnp.int32)}

            def run_scan(carry0, xs, salt):
                return jax.lax.scan(
                    lambda c, x: self._scan_body(c, x, salt), carry0, xs)

            return jax.make_jaxpr(run_scan)(
                self._carry0(), xs, jnp.uint32(0))
        finally:
            self.T = save

    def _outputs(self, ys, carry, mode, solve_stats=None, failures=None):
        ys = {k: np.asarray(v) for k, v in ys.items()}
        led = {k: ys[k] for k in ("arrivals", "rejected", "blocked",
                                  "dropped", "shed", "admitted",
                                  "dispatched")}
        led["queue_len"] = ys["qlen"]
        led["final_queue"] = int(ys["qlen"][-1])
        for k in ("arrivals", "rejected", "blocked", "dropped", "shed",
                  "admitted", "dispatched"):
            led[f"total_{k}"] = int(led[k].sum())
        return EngineOutput(
            sw=ys["sw"], regret=ys["regret"], dispatch_share=ys["share"],
            asw=float(ys["sw"].sum()),
            variants=tuple(v.name for v in self.config.variants),
            sw_variant=ys["sw_v"], regret_variant=ys["regret_v"],
            dispatched_variant=ys["dispatched_v"],
            routed_variant=ys["routed_v"],
            n=np.asarray(carry["n"]), sumz=np.asarray(carry["sumz"]),
            ledger=led, queue_len=ys["qlen"], mode=mode,
            solve_stats=solve_stats, failures=failures)

    def _wrapper_stats(self) -> "dict | None":
        out = {}
        for spec, solver in zip(self.config.variants, self._solvers):
            if solver is None or isinstance(solver, Solver):
                continue
            if hasattr(solver, "stats_dict"):
                out[spec.name] = solver.stats_dict()
            elif isinstance(getattr(solver, "stats", None), dict):
                out[spec.name] = copy.deepcopy(solver.stats)
        return out or None

    def run(
        self, mode: str = "auto", seed: "int | None" = None, streams=None
    ) -> EngineOutput:
        """One trace.  ``mode="stream"`` is the single jitted scan;
        ``"lockstep"`` drives the same slot functions host-side (solver
        wrappers act, the failure runtime settles); ``"auto"`` picks
        lockstep iff a failure model is attached."""
        if mode == "auto":
            mode = "lockstep" if self.failures is not None else "stream"
        if mode not in ("stream", "lockstep"):
            raise ValueError(f"unknown mode {mode!r}")
        seed = self.seed if seed is None else int(seed)
        if streams is None:
            streams = self._streams(seed)
        salt = self._route_salt(seed)
        if mode == "stream":
            if self.failures is not None:
                raise ValueError("failure settlement is host-side: use "
                                 'mode="lockstep" (or "auto")')
            carry, ys = self._stream_fn()(self._carry0(), self._xs(streams),
                                          jnp.uint32(salt))
            return self._outputs(ys, carry, "stream",
                                 solve_stats=self._wrapper_stats())
        return self._run_lockstep(streams, salt)

    def run_batch(self, seeds, mode: str = "stream") -> "list[EngineOutput]":
        """One trace per seed, fleet-batched: ONE vmapped jitted scan, so
        batch-aware solver backends collapse each slot's fleet of solves
        into a single batched kernel launch (the PR 6 dispatch path).
        Stream-only; every seed shares the schedule, as in
        ``ClusterSim.run_batch``."""
        if mode != "stream":
            raise NotImplementedError("run_batch is the vmapped stream "
                                      "path; loop run() for lockstep")
        if self.failures is not None:
            raise NotImplementedError("failure settlement is host-side "
                                      "and single-seed; loop run()")
        seeds = [int(s) for s in seeds]
        streams = [self._streams(s) for s in seeds]
        xs = {
            "arrived": jnp.asarray(np.stack([s[0] for s in streams])),
            "noise": jnp.asarray(np.stack([s[1] for s in streams])),
            "tb": jnp.asarray(np.stack([s[2] for s in streams])),
            "speed": jnp.asarray(self.speed),
            "alive": jnp.asarray(self.alive),
            "t": jnp.arange(self.T, dtype=jnp.int32),
        }
        fn = self._jit_cache.get("stream_batch")
        if fn is None:
            def run_scan(carry0, xs, salt):
                return jax.lax.scan(
                    lambda c, x: self._scan_body(c, x, salt), carry0, xs)
            fn = jax.jit(jax.vmap(
                run_scan,
                in_axes=(0, {"arrived": 0, "noise": 0, "tb": 0,
                             "speed": None, "alive": None, "t": None}, 0)))
            self._jit_cache["stream_batch"] = fn
        B = len(seeds)
        carry0 = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (B,) + a.shape), self._carry0())
        salts = jnp.asarray([self._route_salt(s) for s in seeds], jnp.uint32)
        carry, ys = fn(carry0, xs, salts)
        return [self._outputs(
                    jax.tree_util.tree_map(lambda a: a[b], ys),
                    jax.tree_util.tree_map(lambda a: a[b], carry),
                    "stream")
                for b in range(B)]

    # -- lockstep mode --------------------------------------------------
    def _lockstep_jits(self):
        jits = self._jit_cache.get("lockstep")
        if jits is None:
            jits = {
                "pre": jax.jit(self._slot_pre),
                "dispatch": jax.jit(self._slot_dispatch),
                "account": jax.jit(self._slot_account),
                "stats": jax.jit(lambda vh, nn, tt: stats_mod.scale_statistics(
                    vh, nn, tt, self.m, g_fn=self.g_fn)),
                "oracle": jax.jit(lambda v, al: oracle_knapsack(
                    v, self.tables, al)[0]),
                "greedy": {},
                "solve": {},
            }
            self._jit_cache["lockstep"] = jits
        return jits

    def _lockstep_solve(self, jits, v, elig_v, vhat_v, n_v, age, tb_t, t0):
        spec, solver = self.config.variants[v], self._solvers[v]
        if spec.kind != "esdp":
            fn = jits["greedy"].get(v)
            if fn is None:
                fn = jax.jit(lambda e, vh, a, tb, t: self._variant_x(
                    v, e, vh, None, a, tb, t))
                jits["greedy"][v] = fn
            return fn(elig_v, vhat_v, age, tb_t, t0)
        ups, sig, _, s_lim = jits["stats"](
            vhat_v, n_v, jnp.float32(int(t0) + 1))
        if isinstance(solver, Solver):
            fn = jits["solve"].get(v)
            if fn is None:
                fn = jax.jit(lambda u, s, lim, al: solver(
                    u, s, self.tables, self.s_cap, lim, allowed=al,
                    u_max=self.u_max)[0])
                jits["solve"][v] = fn
            return fn(ups, sig, s_lim, elig_v)
        # host-side wrapper (CachedSolver / FallbackSolver / warm): hand
        # it concrete arrays so it can cache, skip, or walk its chain
        x, _ = solver(np.asarray(ups), np.asarray(sig), self.tables,
                      self.s_cap, int(s_lim), allowed=np.asarray(elig_v),
                      u_max=self.u_max)
        return jnp.asarray(x)

    def _run_lockstep(self, streams, salt: int) -> EngineOutput:
        inst, V, T = self.inst, len(self.config.variants), self.T
        arrivals, noise, tb = streams
        jits = self._lockstep_jits()
        carry = self._carry0()
        fr = None
        vled = None
        if self.failures is not None:
            from .dispatcher import FailureRuntime
            alive = self.alive
            fr = FailureRuntime(self.failures, inst, T,
                                lambda t: alive[t], self.seed)
            vled = [{k: np.zeros(T, np.float64) for k in
                     ("dispatched", "completed", "lost", "salvaged",
                      "ckpt_cost")} for _ in range(V)]
        server = inst.edges[:, 1]
        ys = {k: [] for k in ("arrivals", "rejected", "blocked", "dropped",
                              "shed", "admitted", "dispatched", "qlen",
                              "sw", "sw_v", "regret", "regret_v", "share",
                              "dispatched_v", "routed_v")}
        suspicious = np.zeros(inst.n_servers, bool)
        for t0 in range(T):
            queue2, counts, age, elig, vhat = jits["pre"](
                carry["queue"], carry["n"], carry["sumz"],
                jnp.asarray(arrivals[t0]), jnp.asarray(self.alive[t0]),
                jnp.asarray(suspicious), jnp.int32(t0), jnp.uint32(salt))
            x_raw = jnp.stack([
                self._lockstep_solve(jits, v, elig[v], vhat[v],
                                     carry["n"][v], age,
                                     jnp.asarray(tb[t0]), t0)
                for v in range(V)])
            xv, x, served, queue3, load2, qlen = jits["dispatch"](
                queue2, carry["load"], x_raw, elig, vhat, age)
            if fr is None:
                n2, sumz2, sw, sw_v, regret, regret_v, share = (
                    jits["account"](carry["n"], carry["sumz"], xv, elig,
                                    jnp.asarray(noise[t0]),
                                    jnp.asarray(self.speed[t0])))
                carry = {"queue": queue3, "n": n2, "sumz": sumz2,
                         "load": load2}
            else:
                (sw, sw_v, regret, regret_v, share, carry, suspicious) = (
                    self._settle_failures(fr, vled, t0, carry, queue3,
                                          load2, xv, elig, noise[t0], jits))
            ys["sw"].append(float(sw))
            ys["sw_v"].append(np.asarray(sw_v))
            ys["regret"].append(float(regret))
            ys["regret_v"].append(np.asarray(regret_v))
            ys["share"].append(np.asarray(share))
            ys["qlen"].append(int(qlen))
            ys["dispatched"].append(int(np.asarray(served).sum()))
            ys["dispatched_v"].append(np.asarray(xv).sum(axis=1))
            for k, cnt in counts.items():
                ys[k].append(np.asarray(cnt) if k == "routed_v"
                             else int(cnt))
        ys = {k: (np.asarray(v, np.float32)
                  if k in ("sw", "regret") else np.asarray(v))
              for k, v in ys.items()}
        for k in ("arrivals", "rejected", "blocked", "dropped", "shed",
                  "admitted", "dispatched", "qlen"):
            ys[k] = ys[k].astype(np.int32)
        failures = None
        if fr is not None:
            failures = fr.summary()
            failures["per_variant"] = {
                self.config.variants[v].name: {
                    **{k: a.astype(np.float32) for k, a in vled[v].items()},
                    **{f"total_{k}": float(a.sum())
                       for k, a in vled[v].items()},
                } for v in range(V)}
        return self._outputs(ys, carry, "lockstep",
                             solve_stats=self._wrapper_stats(),
                             failures=failures)

    def _settle_failures(
        self, fr, vled, t0, carry, queue3, load2, xv, elig, noise_t, jits
    ):
        """Host-side crash settlement (PR 8 runtime), per variant: each
        variant's dispatched units settle into its OWN conserving ledger
        (dispatched = completed + lost + salvaged per slot per variant),
        and its bandit sees the realized (crash-discounted) signal."""
        inst, V = self.inst, len(self.config.variants)
        server = inst.edges[:, 1]
        xv_np = np.asarray(xv)
        x_np = xv_np.sum(axis=0)
        elig_np = np.asarray(elig)
        alive_row = self.alive[t0]
        speed_t = self.speed[t0]
        mean = inst.mu * speed_t[server] - inst.cost
        z = np.clip(mean + inst.sigma * np.asarray(noise_t), 0.0, 1.0)
        v_true = np.clip(mean, 0.0, 1.0).astype(np.float32)

        crashed = fr.crashed_servers(t0, np.asarray(alive_row, bool))
        reps = fr.place_replicas(t0, x_np, elig_np.any(axis=0))
        sw_v, regret_v = np.zeros(V, np.float32), np.zeros(V, np.float32)
        n2 = np.asarray(carry["n"]).copy()
        sumz2 = np.asarray(carry["sumz"]).copy()
        for v in range(V):
            sw_t, realized = fr.settle(t0, xv_np[v], z, crashed, reps,
                                       ledger=vled[v])
            sw_v[v] = sw_t
            n2[v] += xv_np[v]
            sumz2[v] += realized.astype(np.float32)
            x_star = np.asarray(jits["oracle"](jnp.asarray(v_true),
                                               jnp.asarray(elig_np[v])))
            regret_v[v] = ((v_true * x_star).sum()
                           - (v_true * xv_np[v]).sum())
        for k in fr.ledger:
            fr.ledger[k][t0] = sum(vled[v][k][t0] for v in range(V))
        fr.observe(t0, crashed)
        x_star = np.asarray(jits["oracle"](jnp.asarray(v_true),
                                           jnp.asarray(elig_np.any(axis=0))))
        regret = (v_true * x_star).sum() - (v_true * x_np).sum()
        tot = x_np.sum()
        share = np.zeros(inst.n_servers, np.float32)
        np.add.at(share, server, x_np / max(tot, 1))
        carry2 = {"queue": queue3, "n": jnp.asarray(n2),
                  "sumz": jnp.asarray(sumz2), "load": load2}
        return (float(sw_v.sum()), sw_v, float(regret), regret_v, share,
                carry2, fr.suspicious.copy())


# ----------------------------------------------------------------------
def lockstep_run(sim, policy: str = "esdp", tiebreak: float = 1e-4):
    """The pre-engine ``ClusterSim.run`` loop, preserved bit-for-bit.

    ``ClusterSim.run`` delegates here: the paper-faithful lockstep
    semantics (every arrival dispatchable the slot it lands, f64 bandit
    accumulators, host RNG tie-breaks, failure settlement) are frozen as
    the reference the streaming engine is benchmarked against —
    ``tests/test_engine.py`` pins its outputs across the registered
    regimes.  With ``sim.malleable`` set, the slot flow gains the
    malleable phases (grow → solve → admit/shrink/preempt → advance) and
    the bandit is fed realized per-job gains at completion; with it None
    the original rigid path runs unchanged.
    """
    from .dispatcher import FailureRuntime, MalleableRuntime, SimOutput

    if policy not in LOCKSTEP_POLICIES:
        raise ValueError(
            f"unknown policy {policy!r}; valid lockstep policies: "
            f"{', '.join(LOCKSTEP_POLICIES)}")

    inst, tables = sim.inst, sim.tables
    E, R = inst.n_edges, inst.n_servers
    port = inst.port_of_edge
    server = inst.edges[:, 1]
    arrivals, noise = sim._streams()
    rng = np.random.default_rng(sim.seed + 1)

    n = np.zeros(E, np.int64)
    sumz = np.zeros(E, np.float64)
    waiting = np.zeros(inst.n_ports, np.int64)

    sw = np.zeros(sim.T, np.float32)
    regret = np.zeros(sim.T, np.float32)
    share = np.zeros((sim.T, R), np.float32)

    if sim.incremental is None and isinstance(sim.solver, Solver):
        jit_dp = jax.jit(
            lambda u, s, lim, al: sim.solver(
                u, s, tables, sim.s_cap, lim, allowed=al,
                u_max=sim.u_max)[0])

        def solve_x(u, s, lim, al):
            return np.asarray(jit_dp(u, s, lim, jnp.asarray(al)))
    else:
        # host-side wrapper paths need concrete inputs — the
        # CachedSolver/WarmPallasSolver/FallbackSolver jit their own
        # launch internals and skip/degrade them per call
        inc = sim._warm if sim.incremental == "warm" else sim.solver

        def solve_x(u, s, lim, al):
            return np.asarray(inc(u, s, tables, sim.s_cap, int(lim),
                                  allowed=al, u_max=sim.u_max)[0])

    jit_oracle = jax.jit(
        lambda v, al: oracle_knapsack(v, tables, al)[0])
    jit_greedy = jax.jit(
        lambda sc, el: greedy_pack(sc, el, jnp.asarray(inst.A),
                                   jnp.asarray(inst.c)))

    fr = (FailureRuntime(sim.failures, inst, sim.T, sim.alive_fn, sim.seed)
          if sim.failures is not None else None)
    mr = (MalleableRuntime(sim.malleable, inst, sim.T)
          if getattr(sim, "malleable", None) is not None else None)

    for t0 in range(sim.T):
        t = t0 + 1  # 1-based for the bandit schedules
        alive_srv = np.asarray(sim.alive_fn(t0), bool)  # 0-based
        alive = alive_srv[server]
        arrived = arrivals[t0][port]
        allowed = arrived & alive
        if fr is not None:
            allowed = fr.eligibility(allowed, server)
        if mr is not None:
            mr.grow(t0)
        vhat = np.where(n > 0, sumz / np.maximum(n, 1), 0.0).astype(
            np.float32)

        if policy == "esdp":
            ups, sig, _, s_lim = stats_mod.scale_statistics(
                jnp.asarray(vhat), jnp.asarray(n.astype(np.int32)),
                jnp.float32(t), sim.m, g_fn=sim.g_fn)
            x = solve_x(ups, sig, s_lim, allowed)
        else:
            tb = rng.random(E).astype(np.float32) * tiebreak
            if policy == "hswf":
                score = vhat + tb
            elif policy == "lcf":
                score = -inst.cost + tb
            else:  # lwtf
                score = waiting[port] * 1e3 + vhat + tb
            x = np.asarray(jit_greedy(jnp.asarray(score),
                                      jnp.asarray(allowed)))

        x = x * allowed
        z = sim._z(t0, noise[t0])
        settled = None
        if mr is not None:
            x = mr.admit(t0, x, vhat)
            sw[t0], settled = mr.advance(t0, z)
        elif fr is None:
            sw[t0] = float((x * z).sum())
            bandit_z = x * z
        else:
            crashed = fr.crashed_servers(t0, alive_srv)
            reps = fr.place_replicas(t0, x, allowed)
            sw[t0], bandit_z = fr.settle(t0, x, z, crashed, reps)
            fr.observe(t0, crashed)
        v_true = sim._v_true(t0)
        x_star = np.asarray(jit_oracle(jnp.asarray(v_true),
                                       jnp.asarray(allowed)))
        regret[t0] = float((v_true * x_star).sum() - (v_true * x).sum())

        if mr is not None:
            # the bandit learns realized per-job totals at settlement
            # (completion or shutdown) — mid-flight jobs are not yet signal
            for e0, gain in settled:
                n[e0] += 1
                sumz[e0] += max(gain, 0.0)
        else:
            n += x
            sumz += bandit_z
        served = np.zeros(inst.n_ports, bool)
        np.maximum.at(served, port, x > 0)
        waiting = np.where(served, 0, waiting + arrivals[t0])
        if x.sum() > 0:
            np.add.at(share[t0], server, x / x.sum())

    return SimOutput(sw=sw, regret=regret, dispatch_share=share,
                     asw=float(sw.sum()),
                     solve_stats=(sim._solve_stats()
                                  if policy == "esdp" else None),
                     failures=fr.summary() if fr is not None else None,
                     malleable=mr.summary() if mr is not None else None)

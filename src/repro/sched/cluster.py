"""Cluster model: TPU slices as the paper's servers, training/serving jobs
as multi-server job types (ports), device inventories as the K device types.

A job gang-requests chips + hosts + interconnect-domain units across a
slice — dispatching its components is All-or-Nothing (the paper's Gang
property): either the whole mesh slice is granted or the job cannot start.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.graph import Instance, clipped_normal_mean

__all__ = ["Slice", "JobType", "build_instance", "validate_jobs"]

# device types (K = 3): accelerator chips, host CPUs, ICI domains
K_CHIPS, K_HOSTS, K_ICI = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class Slice:
    name: str
    accel: str  # "v5e" | "v5p" | "trn2" — service locality
    chips: int  # e.g. 256 = one pod slice
    hosts: int
    ici_domains: int
    # a divisible slice can grant a malleable job its shrunk gang (a
    # sub-mesh); an indivisible one (e.g. a wafer-scale part) is
    # all-or-nothing and gets only full-gang edges
    divisible: bool = True


@dataclasses.dataclass(frozen=True)
class JobType:
    name: str  # e.g. "qwen2.5-32b:train_4k"
    arch: str
    shape: str
    accel_ok: tuple[str, ...]  # service-locality set
    chips: int  # gang requirement
    hosts: int
    ici_domains: int
    value_rate: float  # $-value per unit normalized throughput
    arrival_p: float = 0.9
    # malleable jobs (elona-dup-style malleable MPI scheduling) can run on
    # a shrunk gang mid-execution: ``build_instance`` emits a second edge
    # per feasible (job, divisible slice) pair at the min-gang shape, and
    # ``sched.dispatcher.MalleableRuntime`` shrinks/regrows running jobs
    # between the two configs.  min_* of 0 default to the full gang.
    malleable: bool = False
    min_chips: int = 0
    min_hosts: int = 0
    min_ici_domains: int = 0

    def min_gang(self) -> tuple[int, int, int]:
        """The shrunk-config gang (falling back to the full gang)."""
        return (self.min_chips or self.chips,
                self.min_hosts or self.hosts,
                self.min_ici_domains or self.ici_domains)


def validate_jobs(slices: list[Slice], jobs: list[JobType]) -> dict:
    """Fail-fast admission preflight: job types that can NEVER run here.

    The validate-then-queue side of the streaming engine
    (``sched.engine``): an arrival whose job type appears in this map is
    dead-lettered immediately instead of camping in the queue.  Returns
    ``{job name: human-readable reason}`` for every job type with no
    solely-servable slice — wrong accelerator family everywhere, or a
    gang (chips/hosts/ICI domains) larger than every matching slice.
    Job types absent from the map have at least one feasible edge.
    """
    reasons: dict[str, str] = {}
    for job in jobs:
        matching = [s for s in slices if s.accel in job.accel_ok]
        if not matching:
            accels = sorted({s.accel for s in slices})
            reasons[job.name] = (
                f"no slice with accelerator in {job.accel_ok} "
                f"(fleet has {accels})")
            continue
        if not any(s.chips >= job.chips and s.hosts >= job.hosts
                   and s.ici_domains >= job.ici_domains for s in matching):
            reasons[job.name] = (
                f"gang {job.chips}c/{job.hosts}h/{job.ici_domains}i "
                "exceeds every matching slice "
                f"(largest: {max(s.chips for s in matching)}c)")
    return reasons


def build_instance(
    slices: list[Slice],
    jobs: list[JobType],
    mean_rates: np.ndarray,
    *,
    alpha: float = 0.5,
    seed: int = 0,
) -> tuple[Instance, np.ndarray]:
    """Map (jobs × slices) onto the paper's bipartite Instance.

    mean_rates[l, r]: expected normalized throughput of job l on slice r
    (from the roofline model — sched/ratemodel.py); <= 0 means no edge
    (service locality violated or capacity insufficient).

    Malleable jobs (``JobType.malleable``) additionally get a *shrunk*
    edge per feasible (job, divisible slice) pair — same (port, server),
    min-gang requirement column, throughput scaled by the chip fraction
    (linear scaling; roofline-aware sublinear scaling is a refinement the
    rate model can supply via ``mean_rates``).  ``MalleableRuntime``
    groups such same-(port, server) edges into a config family and moves
    running jobs between them.

    Returns (instance, edge_rate) where edge_rate aligns with instance.edges.
    """
    L, R = len(jobs), len(slices)
    edges, A_cols, mu, rate = [], [], [], []
    for li, job in enumerate(jobs):
        for r, sl in enumerate(slices):
            if sl.accel not in job.accel_ok:
                continue
            if (sl.chips < job.chips or sl.hosts < job.hosts
                    or sl.ici_domains < job.ici_domains):
                continue  # not solely-servable (Sec 2.1)
            if mean_rates[li, r] <= 0:
                continue
            edges.append((li, r))
            A_cols.append([job.chips, job.hosts, job.ici_domains])
            mu.append(job.value_rate * mean_rates[li, r])
            rate.append(mean_rates[li, r])
            mg = job.min_gang()
            full = (job.chips, job.hosts, job.ici_domains)
            if (job.malleable and sl.divisible
                    and all(a <= b for a, b in zip(mg, full)) and mg != full):
                frac = mg[0] / job.chips
                edges.append((li, r))
                A_cols.append(list(mg))
                mu.append(job.value_rate * mean_rates[li, r] * frac)
                rate.append(mean_rates[li, r] * frac)
    edges = np.asarray(edges, np.int32)
    A = np.asarray(A_cols, np.int64).T.astype(np.int32)  # (K, E)

    # cluster-wide capacities (constraint (1)): totals over the fleet
    c = np.asarray([sum(s.chips for s in slices),
                    sum(s.hosts for s in slices),
                    sum(s.ici_domains for s in slices)], np.int64)
    # normalize requirement units so the DP capacity state space stays small:
    # express chips/hosts/ici in slice-granularity units
    unit = np.maximum(A.min(axis=1), 1)
    A_u = (A + unit[:, None] - 1) // unit[:, None]
    c_u = np.minimum(c // unit, 12).astype(np.int32)

    mu = np.asarray(mu, np.float32)
    mu = 0.1 + 0.9 * mu / max(float(mu.max()), 1e-9)  # into [0.1, 1]
    sigma = mu / 2.0
    cost = np.full(len(edges), 0.15, np.float32)  # supply cost
    v = np.asarray([clipped_normal_mean(float(m - co), float(s))
                    for m, s, co in zip(mu, sigma, cost)], np.float32)

    inst = Instance(
        n_ports=L, n_servers=R, edges=edges,
        A=A_u.astype(np.int32), c=c_u, cost=cost, mu=mu, sigma=sigma, v=v,
        rho=np.asarray([j.arrival_p for j in jobs], np.float32),
        alpha=alpha)
    return inst, np.asarray(rate, np.float32)

"""ESDP-backed gang dispatcher over the cluster, with time-varying service
rates (stragglers) and elastic events (slice loss/join).

The generative machinery — degradation schedules (multi-tenant noise,
chronic stragglers, transient brownouts: the paper's "fluctuated processing
speeds") and aliveness schedules (elastic scale-down/up) — lives in the
shared ``Scenario`` protocol of ``core.env`` with named regimes registered
in ``repro.experiments.scenarios``.  ``ClusterSim`` accepts either a
``scenario=`` (unrolled host-side through the SAME keying the jitted
environment uses) or raw ``speed_fn``/``alive_fn`` callbacks for ad-hoc
schedules.  Dispatch-share accounting lets tests assert the bandit actually
routes AROUND a degraded slice (straggler mitigation at the cluster level —
in-job mitigation lives in runtime/fault.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import build_tables, stats as stats_mod
from ..core.baselines import greedy_pack
from ..core.dp import oracle_knapsack
from ..core.env import Scenario
from ..core.graph import Instance
from ..core.solvers import Solver, get_solver

__all__ = ["ClusterSim", "SimOutput"]


@dataclasses.dataclass(frozen=True)
class SimOutput:
    sw: np.ndarray  # (T,)
    regret: np.ndarray  # (T,)
    dispatch_share: np.ndarray  # (T, R) fraction of dispatches per slice
    asw: float
    # incremental-solve counters (cache hit rate / warm skip rate) when the
    # sim ran with incremental= set; None otherwise
    solve_stats: "dict | None" = None

    @property
    def cum_regret(self):
        return np.cumsum(self.regret)


class ClusterSim:
    """Paired simulation of ESDP vs greedy policies on one cluster instance."""

    def __init__(
        self,
        instance: Instance,
        T: int,
        speed_fn: Optional[Callable[[int], np.ndarray]] = None,
        alive_fn: Optional[Callable[[int], np.ndarray]] = None,
        g_fn=stats_mod.g_logt_only,
        seed: int = 0,
        scenario: Optional[Scenario] = None,
        solver: "str | Solver | None" = None,
        incremental: "str | None" = None,
        solve_cache=None,
        warm_checkpoint_every: int = 8,
    ):
        """``incremental`` turns on cross-slot re-solve reuse for the ESDP
        policy (bit-identical in the default exact modes):

          ``"cache"`` — wrap the backend in a ``CachedSolver``
            (``core.solvers``): per-slot solves with statistics already
            seen skip the launch entirely.  Works with every backend (and
            with ``run_batch``, per-seed keys).  ``solve_cache`` optionally
            supplies a preconfigured ``core.incremental.SolveCache`` (e.g.
            quantized/bounded-staleness).
          ``"warm"`` — the host-driven segmented Pallas warm path
            (``kernels.budgeted_dp.ops.WarmPallasSolver``): re-fold only
            the edges whose statistics changed since the previous slot,
            checkpointing every ``warm_checkpoint_every`` fold steps.
            Requires a Pallas backend and the single-seed ``run()``.
        """
        self.inst = instance
        self.T = T
        self.tables = build_tables(instance.A, instance.c)
        self.g_fn = g_fn
        self.seed = seed
        self.solver = get_solver(solver)  # Algorithm-2 backend (core.solvers)
        if incremental not in (None, "cache", "warm"):
            raise ValueError(
                f"unknown incremental mode {incremental!r}; choose from "
                "(None, 'cache', 'warm')")
        self.incremental = incremental
        self._warm = None
        R = instance.n_servers
        self.arr_scale = np.ones((T, instance.n_ports), np.float32)
        if scenario is not None:
            if speed_fn is not None or alive_fn is not None:
                raise ValueError("pass either scenario= or "
                                 "speed_fn/alive_fn, not both")
            from ..experiments.scenarios import unroll_scenario
            arr_scale, speeds, alive = unroll_scenario(
                scenario, T, R, seed, n_ports=instance.n_ports)
            self.arr_scale = arr_scale
            speed_fn = lambda t: speeds[t]  # noqa: E731 — row t ↔ slot t+1
            alive_fn = lambda t: alive[t]  # noqa: E731
        self.speed_fn = speed_fn or (lambda t: np.ones(R, np.float32))
        self.alive_fn = alive_fn or (lambda t: np.ones(R, bool))
        self.m = instance.m
        self.s_cap = stats_mod.s_cap_for_horizon(T, self.m)
        self.u_max = stats_mod.u_max_for_horizon(T, self.m)
        if incremental == "cache":
            from ..core.solvers import CachedSolver
            self.solver = CachedSolver(self.solver, cache=solve_cache)
        elif incremental == "warm":
            if self.solver.name not in ("pallas", "pallas_interpret"):
                raise ValueError(
                    'incremental="warm" drives the Pallas carried-plane '
                    f"path; got backend {self.solver.name!r}. Use "
                    'incremental="cache" (any backend) or the in-scan '
                    'cache="warm" policy mode in core.esdp instead.')
            from ..kernels.budgeted_dp.ops import WarmPallasSolver
            self._warm = WarmPallasSolver(
                self.tables, self.s_cap, u_max=self.u_max,
                checkpoint_every=warm_checkpoint_every,
                interpret=self.solver.interpret)

    def _solve_stats(self) -> "dict | None":
        if self.incremental == "cache":
            return self.solver.stats.as_dict()
        if self.incremental == "warm":
            return dict(self._warm.stats, edge_skip_rate=self._warm.skip_rate)
        return None

    # ------------------------------------------------------------------
    def _streams(self, seed: int | None = None):
        """Arrival/noise streams for one seed (default: the sim's own).

        ``run_batch`` draws one stream per fleet seed through this hook;
        a given seed yields the identical stream either way, which is
        what makes ``run_batch([s])`` reproduce ``run()`` of a sim built
        with ``seed=s``."""
        rng = np.random.default_rng(self.seed if seed is None else seed)
        inst = self.inst
        rho_t = np.clip(inst.rho[None, :] * self.arr_scale, 0.0, 1.0)
        arrivals = rng.random((self.T, inst.n_ports)) < rho_t
        noise = rng.normal(0.0, 1.0, (self.T, inst.n_edges)).astype(np.float32)
        return arrivals, noise

    def _z(self, t, noise_t):
        """Realized net valuations under the speed schedule."""
        inst = self.inst
        speed = self.speed_fn(t)[inst.edges[:, 1]]
        mean = inst.mu * speed - inst.cost
        return np.clip(mean + inst.sigma * noise_t, 0.0, 1.0)

    def _v_true(self, t):
        inst = self.inst
        speed = self.speed_fn(t)[inst.edges[:, 1]]
        # oracle knows the instantaneous mean (clipped-normal expectation
        # approximated by the clipped mean — exact enough for regret trends)
        return np.clip(inst.mu * speed - inst.cost, 0.0, 1.0).astype(np.float32)

    # ------------------------------------------------------------------
    def run(self, policy: str = "esdp", tiebreak: float = 1e-4) -> SimOutput:
        inst, tables = self.inst, self.tables
        E, R = inst.n_edges, inst.n_servers
        port = inst.port_of_edge
        server = inst.edges[:, 1]
        arrivals, noise = self._streams()
        rng = np.random.default_rng(self.seed + 1)

        n = np.zeros(E, np.int64)
        sumz = np.zeros(E, np.float64)
        waiting = np.zeros(inst.n_ports, np.int64)

        sw = np.zeros(self.T, np.float32)
        regret = np.zeros(self.T, np.float32)
        share = np.zeros((self.T, R), np.float32)

        if self.incremental is None:
            jit_dp = jax.jit(
                lambda u, s, lim, al: self.solver(
                    u, s, tables, self.s_cap, lim, allowed=al,
                    u_max=self.u_max)[0])

            def solve_x(u, s, lim, al):
                return np.asarray(jit_dp(u, s, lim, jnp.asarray(al)))
        else:
            # host-side incremental paths need concrete inputs — the
            # CachedSolver/WarmPallasSolver jit their own launch internals
            # and skip them entirely on hits / unchanged fold prefixes
            inc = self.solver if self.incremental == "cache" else self._warm

            def solve_x(u, s, lim, al):
                return np.asarray(inc(u, s, tables, self.s_cap, int(lim),
                                      allowed=al, u_max=self.u_max)[0])

        jit_oracle = jax.jit(
            lambda v, al: oracle_knapsack(v, tables, al)[0])
        jit_greedy = jax.jit(
            lambda sc, el: greedy_pack(sc, el, jnp.asarray(inst.A),
                                       jnp.asarray(inst.c)))

        for t0 in range(self.T):
            t = t0 + 1  # 1-based for the bandit schedules
            alive = self.alive_fn(t0)[server]  # schedules are 0-based
            arrived = arrivals[t0][port]
            allowed = arrived & alive
            vhat = np.where(n > 0, sumz / np.maximum(n, 1), 0.0).astype(
                np.float32)

            if policy == "esdp":
                ups, sig, _, s_lim = stats_mod.scale_statistics(
                    jnp.asarray(vhat), jnp.asarray(n.astype(np.int32)),
                    jnp.float32(t), self.m, g_fn=self.g_fn)
                x = solve_x(ups, sig, s_lim, allowed)
            else:
                tb = rng.random(E).astype(np.float32) * tiebreak
                if policy == "hswf":
                    score = vhat + tb
                elif policy == "lcf":
                    score = -inst.cost + tb
                else:  # lwtf
                    score = waiting[port] * 1e3 + vhat + tb
                x = np.asarray(jit_greedy(jnp.asarray(score),
                                          jnp.asarray(allowed)))

            x = x * allowed
            z = self._z(t0, noise[t0])
            sw[t0] = float((x * z).sum())
            v_true = self._v_true(t0)
            x_star = np.asarray(jit_oracle(jnp.asarray(v_true),
                                           jnp.asarray(allowed)))
            regret[t0] = float((v_true * x_star).sum() - (v_true * x).sum())

            n += x
            sumz += x * z
            served = np.zeros(inst.n_ports, bool)
            np.maximum.at(served, port, x > 0)
            waiting = np.where(served, 0, waiting + arrivals[t0])
            if x.sum() > 0:
                np.add.at(share[t0], server, x / x.sum())

        return SimOutput(sw=sw, regret=regret, dispatch_share=share,
                         asw=float(sw.sum()),
                         solve_stats=(self._solve_stats()
                                      if policy == "esdp" else None))

    # ------------------------------------------------------------------
    def run_batch(
        self, seeds, policy: str = "esdp", tiebreak: float = 1e-4
    ) -> "list[SimOutput]":
        """One paired simulation per seed, fleet-batched per slot.

        Every seed replays the SAME cluster schedule (speed/aliveness
        callbacks, and a scenario's arrival scaling — unrolled once with
        the sim's construction seed) against its OWN arrival/noise
        streams and bandit state, exactly as ``ClusterSim(...,
        seed=s).run(policy)`` would — ``run_batch([s])`` reproduces that
        run bit for bit.  The per-slot Algorithm-2 solves of all seeds
        dispatch as ONE kernel launch per slot: the vmapped solver hits
        the batch-aware backends' custom batching rule
        (``Solver.accepts_batch``), which shares the DP-table operands
        across the fleet instead of replicating the launch per seed.

        Returns one :class:`SimOutput` per seed, in seed order.
        """
        if self.incremental == "warm":
            raise NotImplementedError(
                'incremental="warm" carries one value-plane chain and so '
                "runs single-seed only (run()); use incremental=\"cache\" "
                "for fleet batches — its keys are per instance row")
        inst, tables = self.inst, self.tables
        E, R = inst.n_edges, inst.n_servers
        port = inst.port_of_edge
        server = inst.edges[:, 1]
        seeds = [int(s) for s in seeds]
        B = len(seeds)
        streams = [self._streams(s) for s in seeds]
        arrivals = np.stack([a for a, _ in streams])  # (B, T, P)
        noise = np.stack([z for _, z in streams])  # (B, T, E)
        rngs = [np.random.default_rng(s + 1) for s in seeds]
        b_ids = np.arange(B)[:, None]

        n = np.zeros((B, E), np.int64)
        sumz = np.zeros((B, E), np.float64)
        waiting = np.zeros((B, inst.n_ports), np.int64)

        sw = np.zeros((B, self.T), np.float32)
        regret = np.zeros((B, self.T), np.float32)
        share = np.zeros((B, self.T, R), np.float32)

        jit_stats = jax.jit(jax.vmap(
            lambda v, k, t: stats_mod.scale_statistics(
                v, k, t, self.m, g_fn=self.g_fn),
            in_axes=(0, 0, None)))
        if self.incremental is None:
            jit_dp = jax.jit(jax.vmap(
                lambda u, s, lim, al: self.solver(
                    u, s, tables, self.s_cap, lim, allowed=al,
                    u_max=self.u_max)[0]))

            def solve_x(u, s, lim, al):
                return np.asarray(jit_dp(u, s, lim, jnp.asarray(al)))
        else:
            # CachedSolver's concrete batched path: per-row keys, one
            # batched launch on any miss, no launch at all on a full hit
            def solve_x(u, s, lim, al):
                return np.asarray(self.solver(
                    np.asarray(u), np.asarray(s), tables, self.s_cap,
                    np.asarray(lim), allowed=al, u_max=self.u_max)[0])
        jit_oracle = jax.jit(jax.vmap(
            lambda v, al: oracle_knapsack(v, tables, al)[0],
            in_axes=(None, 0)))
        jit_greedy = jax.jit(jax.vmap(
            lambda sc, el: greedy_pack(sc, el, jnp.asarray(inst.A),
                                       jnp.asarray(inst.c))))

        for t0 in range(self.T):
            t = t0 + 1  # 1-based for the bandit schedules
            alive = self.alive_fn(t0)[server]  # shared schedule
            arrived = arrivals[:, t0][:, port]  # (B, E)
            allowed = arrived & alive[None, :]
            vhat = np.where(n > 0, sumz / np.maximum(n, 1), 0.0).astype(
                np.float32)

            if policy == "esdp":
                ups, sig, _, s_lim = jit_stats(
                    jnp.asarray(vhat), jnp.asarray(n.astype(np.int32)),
                    jnp.float32(t))
                x = solve_x(ups, sig, s_lim, allowed)
            else:
                tb = np.stack([r.random(E) for r in rngs]).astype(
                    np.float32) * tiebreak
                if policy == "hswf":
                    score = vhat + tb
                elif policy == "lcf":
                    score = -inst.cost[None, :] + tb
                else:  # lwtf
                    score = waiting[:, port] * 1e3 + vhat + tb
                x = np.asarray(jit_greedy(jnp.asarray(score),
                                          jnp.asarray(allowed)))

            x = x * allowed
            z = self._z(t0, noise[:, t0])  # broadcasts to (B, E)
            sw[:, t0] = (x * z).sum(axis=1)
            v_true = self._v_true(t0)
            x_star = np.asarray(jit_oracle(jnp.asarray(v_true),
                                           jnp.asarray(allowed)))
            regret[:, t0] = ((v_true[None, :] * x_star).sum(axis=1)
                             - (v_true[None, :] * x).sum(axis=1))

            n += x
            sumz += x * z
            served = np.zeros((B, inst.n_ports), bool)
            np.maximum.at(served, (b_ids, port[None, :]), x > 0)
            waiting = np.where(served, 0, waiting + arrivals[:, t0])
            tot = x.sum(axis=1)
            for b in np.flatnonzero(tot > 0):
                np.add.at(share[b, t0], server, x[b] / tot[b])

        stats = self._solve_stats() if policy == "esdp" else None
        return [SimOutput(sw=sw[b], regret=regret[b],
                          dispatch_share=share[b],
                          asw=float(sw[b].sum()),
                          solve_stats=stats) for b in range(B)]

"""ESDP-backed gang dispatcher over the cluster, with time-varying service
rates (stragglers), elastic events (slice loss/join), and server failures
(crash/repair with lost-work accounting).

The generative machinery — degradation schedules (multi-tenant noise,
chronic stragglers, transient brownouts: the paper's "fluctuated processing
speeds") and aliveness schedules (elastic scale-down/up, Markov
crash/repair) — lives in the shared ``Scenario`` protocol of ``core.env``
with named regimes registered in ``repro.experiments.scenarios``.
``ClusterSim`` accepts either a ``scenario=`` (unrolled host-side through
the SAME keying the jitted environment uses) or raw
``speed_fn``/``alive_fn`` callbacks for ad-hoc schedules.  Dispatch-share
accounting lets tests assert the bandit actually routes AROUND a degraded
slice (straggler mitigation at the cluster level — in-job mitigation lives
in runtime/fault.py).

Failure-aware mode (``failures=FailureModel(...)``): a job dispatched onto
a server that crashes in-slot loses its accumulated service — unless it
was dispatched redundantly (r-way, consuming r× capacity) or salvaged by
opportunistic checkpointing with an explicit per-checkpoint cost (both
knobs per the speedup-function analysis of arXiv:1707.01655).  The crash
process is ``runtime.fault.FailureInjector`` (counter-based, replayable)
coupled with the aliveness trace's up→down transitions
(``core.env.crash_events`` semantics); detection-driven eligibility uses
``runtime.fault.CrashRateTracker`` — the StragglerTracker pattern applied
to crash events.  Lost/salvaged/restart accounting surfaces in
``SimOutput.failures``.  See ``docs/robustness.md``.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import build_tables, stats as stats_mod
from ..core.baselines import greedy_pack
from ..core.dp import oracle_knapsack
from ..core.env import Scenario
from ..core.graph import Instance
from ..core.solvers import Solver, get_solver
from ..runtime.fault import CrashRateTracker, FailureInjector

__all__ = ["ClusterSim", "SimOutput", "FailureModel", "FailureRuntime",
           "MalleableModel", "MalleableRuntime"]


@dataclasses.dataclass(frozen=True)
class SimOutput:
    sw: np.ndarray  # (T,)
    regret: np.ndarray  # (T,)
    dispatch_share: np.ndarray  # (T, R) fraction of dispatches per slice
    asw: float
    # incremental-solve counters (cache hit rate / warm skip rate) and/or
    # fallback-chain degradation events when the sim ran with incremental=
    # or a wrapped solver; None otherwise
    solve_stats: "dict | None" = None
    # lost/salvaged/restart ledger when the sim ran failure-aware
    # (failures=FailureModel(...)); None otherwise.  Per-slot arrays
    # dispatched/completed/lost/salvaged/ckpt_cost (value units, satisfying
    # dispatched = completed + lost + salvaged exactly), crash/replica
    # counts, and scalar totals.
    failures: "dict | None" = None
    # work-units ledger when the sim ran with malleable jobs
    # (malleable=MalleableModel(...)); None otherwise.  Per-slot arrays
    # dispatched/done/lost (work units, satisfying dispatched = done + lost
    # + residual exactly), reconfiguration/shutdown costs and counts, and
    # scalar totals (see MalleableRuntime.summary).
    malleable: "dict | None" = None

    @property
    def cum_regret(self):
        return np.cumsum(self.regret)


@dataclasses.dataclass(frozen=True)
class FailureModel:
    """Knobs of the failure-aware runtime (see ``docs/robustness.md``).

    Crash channels (all counter-based off the sim seed, so runs replay):
      * the aliveness schedule's up→down transitions — a server alive at
        dispatch time but dead next slot died mid-slot (the
        ``server_failures`` scenario emits exactly this coupling);
      * ``p_crash``: extra iid in-slot crashes per (server, slot) — the
        server loses the slot's work but stays in the schedule (crashes
        and recovers within the slot);
      * ``n_racks``/``p_rack``: correlated in-slot crashes — servers
        partition into ``n_racks`` contiguous groups and each group fails
        as a unit with ``p_rack`` per slot.

    Mitigations (arXiv:1707.01655's redundancy-vs-checkpointing axis):
      * ``redundancy`` — r-way dispatch: each job unit greedily places up
        to r−1 replicas on same-port edges with distinct servers within
        residual capacity (replicas consume capacity, produce no utility,
        and save the job if any copy's server survives);
      * ``checkpoints``/``checkpoint_cost`` — opportunistic checkpointing:
        n checkpoints per slot at fractions i/(n+1), each costing
        ``checkpoint_cost`` utility when written; a crash at in-slot
        fraction U salvages ⌊U·(n+1)⌋/(n+1) of the job's value;
      * ``detect`` — CrashRateTracker-driven eligibility: servers whose
        crash-rate EMA is elevated are masked out of dispatch for a
        probation window (~4 slots at the tracker defaults).
    """
    p_crash: float = 0.0
    n_racks: int = 0
    p_rack: float = 0.0
    redundancy: int = 1
    checkpoints: int = 0
    checkpoint_cost: float = 0.0
    detect: bool = False

    def __post_init__(self):
        if self.redundancy < 1:
            raise ValueError("redundancy is the total copy count (>= 1)")
        if self.checkpoints < 0 or self.checkpoint_cost < 0:
            raise ValueError("checkpoints/checkpoint_cost must be >= 0")


class FailureRuntime:
    """Host-side crash/repair bookkeeping for one ``ClusterSim`` run.

    Owns the in-slot crash process (a counter-based
    :class:`repro.runtime.fault.FailureInjector` — pure in (seed, slot,
    channel), so reruns and tests replay the identical failure stream),
    replica placement, salvage/cost settlement, detection state, and the
    per-slot ledger.  Built fresh inside every ``run()`` call: the runtime
    is mutable, the sim object stays reusable.
    """

    # injector draw channels (salt residues mod 3 keep them independent)
    _CRASH, _RACK, _FRAC = 0, 1, 2

    def __init__(
        self,
        model: FailureModel,
        instance: Instance,
        T: int,
        alive_fn: Callable[[int], np.ndarray],
        seed: int,
    ):
        self.model = model
        self.inst = instance
        self.T = T
        self.alive_fn = alive_fn
        self.inj = FailureInjector(p_fail=model.p_crash, seed=seed)
        R = instance.n_servers
        self.trackers = [CrashRateTracker() for _ in range(R)]
        self.suspicious = np.zeros(R, bool)
        self.restarts = 0
        self.ledger = {k: np.zeros(T, np.float64) for k in
                       ("dispatched", "completed", "lost", "salvaged",
                        "ckpt_cost")}
        self.crashes = np.zeros(T, np.int32)
        self.replicas = np.zeros(T, np.int32)

    def eligibility(self, allowed: np.ndarray, server: np.ndarray) -> np.ndarray:
        """Mask suspicious servers' edges out of dispatch (detection)."""
        if not self.model.detect:
            return allowed
        return allowed & ~self.suspicious[server]

    def crashed_servers(self, t0: int, alive_now: np.ndarray) -> np.ndarray:
        """(R,) bool: which servers crash DURING slot t0 (all channels)."""
        m = self.model
        R = self.inst.n_servers
        crashed = np.zeros(R, bool)
        if t0 + 1 < self.T:  # schedule transition: up now, down next slot
            nxt = np.asarray(self.alive_fn(t0 + 1), bool)
            crashed |= alive_now & ~nxt
        if m.p_crash > 0.0:
            u = np.array([self.inj.draw(t0, r * 3 + self._CRASH)
                          for r in range(R)])
            crashed |= alive_now & (u < m.p_crash)
        if m.n_racks > 0 and m.p_rack > 0.0:
            rack_of = (np.arange(R) * m.n_racks) // R
            u = np.array([self.inj.draw(t0, g * 3 + self._RACK)
                          for g in range(m.n_racks)])
            crashed |= alive_now & (u < m.p_rack)[rack_of]
        return crashed

    def place_replicas(self, t0: int, x: np.ndarray, eligible: np.ndarray):
        """Greedy r-way replica placement within residual capacity.

        For each dispatched job unit (edge e, unit i), walk the other
        eligible same-port edges in index order and claim up to
        ``redundancy − 1`` replicas on DISTINCT servers, each consuming
        its edge's full capacity column from the residual c − A·x.
        Returns ``{(e, i): [replica server ids]}``; placement is
        best-effort — a saturated cluster simply gets fewer replicas.
        """
        m, inst = self.model, self.inst
        reps: dict = {}
        if m.redundancy <= 1 or not x.any():
            return reps
        A = np.asarray(inst.A)
        residual = np.asarray(inst.c) - A @ x
        port, server = inst.port_of_edge, inst.edges[:, 1]
        placed_total = 0
        for e in np.flatnonzero(x):
            cands = np.flatnonzero((port == port[e]) & (server != server[e])
                                   & eligible)
            for i in range(int(x[e])):
                placed: list[int] = []
                used = {int(server[e])}
                for e2 in cands:
                    if len(placed) >= m.redundancy - 1:
                        break
                    if int(server[e2]) in used:
                        continue
                    if np.all(A[:, e2] <= residual):
                        residual = residual - A[:, e2]
                        placed.append(int(server[e2]))
                        used.add(int(server[e2]))
                if placed:
                    reps[(int(e), i)] = placed
                    placed_total += len(placed)
        self.replicas[t0] = placed_total
        return reps

    def settle(self, t0, x, z, crashed, reps, ledger=None):
        """Charge the slot's crashes; return (sw_t, per-edge bandit signal).

        Per job unit of value z: survived (own server or any replica's
        server up) → completed; crashed with checkpointing → the fraction
        checkpointed before the crash instant is salvaged, the rest lost;
        crashed bare → lost.  ``completed + lost + salvaged = dispatched``
        holds exactly (checkpoint costs are charged separately, including
        for completed jobs — opportunistic checkpoints are written whether
        or not the slot ends in a crash).  Social welfare for the slot is
        completed + salvaged − checkpoint costs; the bandit signal is the
        per-edge realized utility clipped at 0 (the learned v̂ then absorbs
        crash risk and checkpoint overhead, steering dispatch away from
        crashy servers).

        ``ledger`` targets an alternative (same-shape) ledger dict — the
        streaming engine settles each A/B variant's units into its OWN
        conserving ledger; default is the runtime's combined one.
        """
        m, inst = self.model, self.inst
        server = inst.edges[:, 1]
        nck = m.checkpoints
        led = self.ledger if ledger is None else ledger
        realized = np.zeros(x.shape[0], np.float64)
        for e in np.flatnonzero(x):
            ze = float(z[e])
            sv = int(server[e])
            # the server dies ONCE, at one in-slot instant: every unit on
            # it sees the same crash fraction U (counter-based, per slot)
            U = self.inj.draw(t0, sv * 3 + self._FRAC)
            for i in range(int(x[e])):
                led["dispatched"][t0] += ze
                survived = (not crashed[sv]) or any(
                    not crashed[r] for r in reps.get((int(e), i), ()))
                if survived:
                    led["completed"][t0] += ze
                    cost = nck * m.checkpoint_cost
                    gain = ze - cost
                else:
                    self.restarts += 1
                    if nck > 0:
                        written = int(U * (nck + 1))
                        salv = written / (nck + 1) * ze
                        cost = written * m.checkpoint_cost
                        led["salvaged"][t0] += salv
                        led["lost"][t0] += ze - salv
                        gain = salv - cost
                    else:
                        cost = 0.0
                        led["lost"][t0] += ze
                        gain = 0.0
                led["ckpt_cost"][t0] += cost
                realized[e] += max(gain, 0.0)
        sw_t = (led["completed"][t0] + led["salvaged"][t0]
                - led["ckpt_cost"][t0])
        return sw_t, realized

    def observe(self, t0: int, crashed: np.ndarray) -> None:
        """Feed the slot's crash indicators to the per-server trackers."""
        self.crashes[t0] = int(crashed.sum())
        for r, tr in enumerate(self.trackers):
            tr.observe(bool(crashed[r]))
        if self.model.detect:
            self.suspicious = np.array([tr.suspicious
                                        for tr in self.trackers])

    def summary(self) -> dict:
        led = {k: v.astype(np.float32) for k, v in self.ledger.items()}
        return dict(
            led,
            crashes=self.crashes.copy(),
            replicas=self.replicas.copy(),
            restarts=self.restarts,
            total_dispatched=float(self.ledger["dispatched"].sum()),
            total_completed=float(self.ledger["completed"].sum()),
            total_lost=float(self.ledger["lost"].sum()),
            total_salvaged=float(self.ledger["salvaged"].sum()),
            total_ckpt_cost=float(self.ledger["ckpt_cost"].sum()),
            model=dataclasses.asdict(self.model),
        )


@dataclasses.dataclass(frozen=True)
class MalleableModel:
    """Knobs of the malleable-jobs runtime (elona-dup-style malleable MPI
    scheduling; see ``docs/scenarios.md``).

    Jobs carry ``duration`` work units (slots at the full-gang rate) instead
    of completing in-slot.  A running job occupies its current config edge's
    capacity column until done; when a new dispatch does not fit the
    residual capacity, running jobs are *shrunk* one config level
    (``sched.cluster.build_instance`` emits the shrunk same-(port, server)
    edges for malleable job types), and — with ``grow_back`` — regrown
    toward their dispatched config when capacity frees.  Every shrink or
    grow is one reconfiguration charging ``reconfig_cost`` utility exactly
    once; with ``preempt`` a still-blocked dispatch may shut a low-value
    running job down entirely, charging ``shutdown_cost`` and losing the
    job's remaining work units into the ledger.
    """
    duration: int = 4
    reconfig_cost: float = 0.02
    shutdown_cost: float = 0.05
    grow_back: bool = True
    preempt: bool = False

    def __post_init__(self):
        if self.duration < 1:
            raise ValueError("duration is the job's work units (>= 1)")
        if self.reconfig_cost < 0 or self.shutdown_cost < 0:
            raise ValueError("reconfig_cost/shutdown_cost must be >= 0")


class MalleableRuntime:
    """Host-side shrink/grow bookkeeping for one ``ClusterSim`` run.

    Edges sharing a (port, server) pair form a *config family* ordered by
    gang size — the full config plus the shrunk configs ``build_instance``
    emitted for malleable job types.  A running job tracks its dispatched
    config ``e0`` and current config ``ecur``; per slot it advances
    ``rate[ecur] = Σ_k A[k, ecur] / Σ_k A[k, full]`` work units and accrues
    value ``z[ecur] · w / duration`` (an always-full job realizes exactly
    one z draw's worth in total — ``duration=1`` on a family-free instance
    reproduces the rigid loop bit-for-bit).  The work-units ledger conserves
    exactly, the PR 8 failure-ledger way::

        Σ dispatched = Σ done + Σ lost + residual  (work units, float64)

    with ``lost`` the remaining units of shutdown jobs and ``residual`` the
    units still in flight at the horizon.  Reconfiguration/shutdown costs
    are charged to the slot's welfare AND to the affected job's bandit gain
    exactly once per transition (``transitions`` counts them — the
    hypothesis suite pins ``reconfig_cost_total == transitions ·
    model.reconfig_cost``).
    """

    def __init__(self, model: MalleableModel, instance: Instance, T: int):
        self.model = model
        self.inst = instance
        self.T = T
        A = np.asarray(instance.A, np.int64)
        self.A = A
        self.c = np.asarray(instance.c, np.int64)
        port, server = instance.port_of_edge, instance.edges[:, 1]
        E = instance.n_edges
        gang = A.sum(axis=0)
        families: dict = {}
        for e in range(E):
            families.setdefault((int(port[e]), int(server[e])), []).append(e)
        self.full_of = np.arange(E)
        self.shrunk_of = np.full(E, -1)  # next-smaller config, -1 at bottom
        self.parent_of = np.full(E, -1)  # next-larger config, -1 at full
        for es in families.values():
            es.sort(key=lambda e: (-gang[e], e))
            for e in es:
                self.full_of[e] = es[0]
            for up, dn in zip(es, es[1:]):
                self.shrunk_of[up] = dn
                self.parent_of[dn] = up
        self.rate = gang / np.maximum(gang[self.full_of], 1)
        self.jobs: list[dict] = []  # start-ordered: {e0, ecur, rem, gain}
        self._settled: list[tuple[int, float]] = []  # (e0, gain) this slot
        self.ledger = {k: np.zeros(T, np.float64) for k in
                       ("dispatched", "done", "lost",
                        "reconfig_cost", "shutdown_cost")}
        self.counts = {k: np.zeros(T, np.int32) for k in
                       ("started", "completed", "shrinks", "grows",
                        "shutdowns", "blocked", "running")}
        self.occupancy = np.zeros((T, self.c.shape[0]), np.int64)
        self.transitions = 0

    def occupied(self) -> np.ndarray:
        occ = np.zeros_like(self.c)
        for j in self.jobs:
            occ += self.A[:, j["ecur"]]
        return occ

    def residual(self) -> np.ndarray:
        return self.c - self.occupied()

    def _reconfig(self, t0: int, job: dict, to: int, grow: bool) -> None:
        job["ecur"] = to
        cost = self.model.reconfig_cost
        self.ledger["reconfig_cost"][t0] += cost
        job["gain"] -= cost
        self.counts["grows" if grow else "shrinks"][t0] += 1
        self.transitions += 1

    def grow(self, t0: int) -> None:
        """Regrow shrunk jobs toward their dispatched config (FIFO), one
        config level per fit check — each level is one charged transition."""
        if not self.model.grow_back:
            return
        for j in self.jobs:
            while j["ecur"] != j["e0"]:
                up = self.parent_of[j["ecur"]]
                if up < 0:
                    break
                need = self.A[:, up] - self.A[:, j["ecur"]]
                if np.all(need <= self.residual()):
                    self._reconfig(t0, j, int(up), grow=True)
                else:
                    break

    def _shrink_for_room(self, t0: int, need: np.ndarray) -> bool:
        """Shrink running jobs (FIFO, one level each) until ``need`` fits
        the residual; returns whether it fits."""
        while True:
            if np.all(need <= self.residual()):
                return True
            victim = next((j for j in self.jobs
                           if self.shrunk_of[j["ecur"]] >= 0), None)
            if victim is None:
                return False
            self._reconfig(t0, victim, int(self.shrunk_of[victim["ecur"]]),
                           grow=False)

    def _preempt_for_room(
        self, t0: int, need: np.ndarray, value: float, vhat: np.ndarray
    ) -> bool:
        """Shut down running jobs whose estimated remaining value is below
        the newcomer's until ``need`` fits; returns whether it fits."""
        W = float(self.model.duration)
        while not np.all(need <= self.residual()):
            live = [(vhat[j["e0"]] * j["rem"] / W, i)
                    for i, j in enumerate(self.jobs)]
            if not live:
                return False
            remval, i = min(live)
            if remval >= value:
                return False
            job = self.jobs.pop(i)
            job["gain"] -= self.model.shutdown_cost
            self.ledger["shutdown_cost"][t0] += self.model.shutdown_cost
            self.ledger["lost"][t0] += job["rem"]
            self.counts["shutdowns"][t0] += 1
            self._settled.append((job["e0"], job["gain"]))
        return True

    def admit(self, t0: int, x: np.ndarray, vhat: np.ndarray) -> np.ndarray:
        """Fit the slot's desired dispatch into the residual capacity.

        Units are tried in descending estimated value; a unit that does not
        fit triggers shrink (then, with ``preempt``, shutdown) of running
        jobs; units that still do not fit are blocked (never started, never
        ledgered as dispatched).  Returns the admitted dispatch vector."""
        x = np.asarray(x, np.int64)
        admitted = np.zeros_like(x)
        units = [e for e in np.flatnonzero(x) for _ in range(int(x[e]))]
        units.sort(key=lambda e: (-float(vhat[e]), e))
        W = float(self.model.duration)
        for e in units:
            need = self.A[:, e]
            ok = np.all(need <= self.residual())
            if not ok:
                ok = self._shrink_for_room(t0, need)
            if not ok and self.model.preempt:
                ok = self._preempt_for_room(t0, need, float(vhat[e]), vhat)
            if not ok:
                self.counts["blocked"][t0] += 1
                continue
            self.jobs.append({"e0": int(e), "ecur": int(e),
                              "rem": W, "gain": 0.0})
            self.ledger["dispatched"][t0] += W
            self.counts["started"][t0] += 1
            admitted[e] += 1
        return admitted

    def advance(self, t0: int, z: np.ndarray):
        """Advance every running job one slot against the slot's realized
        valuations; returns (slot welfare, settled (e0, gain) pairs)."""
        self.occupancy[t0] = self.occupied()
        W = float(self.model.duration)
        accrual = 0.0
        still: list[dict] = []
        for j in self.jobs:
            w = min(self.rate[j["ecur"]], j["rem"])
            val = float(z[j["ecur"]]) * w / W
            j["gain"] += val
            j["rem"] -= w
            accrual += val
            self.ledger["done"][t0] += w
            if j["rem"] <= 1e-9:
                self.ledger["done"][t0] += j["rem"]  # absorb float residue
                j["rem"] = 0.0
                self.counts["completed"][t0] += 1
                self._settled.append((j["e0"], j["gain"]))
            else:
                still.append(j)
        self.jobs = still
        self.counts["running"][t0] = len(still)
        sw_t = (accrual - self.ledger["reconfig_cost"][t0]
                - self.ledger["shutdown_cost"][t0])
        settled, self._settled = self._settled, []
        return sw_t, settled

    @property
    def residual_units(self) -> float:
        return float(sum(j["rem"] for j in self.jobs))

    def summary(self) -> dict:
        led = {k: v.astype(np.float32) for k, v in self.ledger.items()}
        return dict(
            led,
            **{k: v.copy() for k, v in self.counts.items()},
            occupancy=self.occupancy.copy(),
            transitions=self.transitions,
            residual_units=self.residual_units,
            **{f"total_{k}": float(v.sum()) for k, v in self.ledger.items()},
            model=dataclasses.asdict(self.model),
        )


class ClusterSim:
    """Paired simulation of ESDP vs greedy policies on one cluster instance."""

    def __init__(
        self,
        instance: Instance,
        T: int,
        speed_fn: Optional[Callable[[int], np.ndarray]] = None,
        alive_fn: Optional[Callable[[int], np.ndarray]] = None,
        g_fn=stats_mod.g_logt_only,
        seed: int = 0,
        scenario: Optional[Scenario] = None,
        solver: "str | Solver | None" = None,
        incremental: "str | None" = None,
        solve_cache=None,
        warm_checkpoint_every: int = 8,
        failures: "FailureModel | None" = None,
        fallback: bool = False,
        malleable: "MalleableModel | None" = None,
    ):
        """``incremental`` turns on cross-slot re-solve reuse for the ESDP
        policy (bit-identical in the default exact modes):

          ``"cache"`` — wrap the backend in a ``CachedSolver``
            (``core.solvers``): per-slot solves with statistics already
            seen skip the launch entirely.  Works with every backend (and
            with ``run_batch``, per-seed keys).  ``solve_cache`` optionally
            supplies a preconfigured ``core.incremental.SolveCache`` (e.g.
            quantized/bounded-staleness).
          ``"warm"`` — the host-driven segmented Pallas warm path
            (``kernels.budgeted_dp.ops.WarmPallasSolver``): re-fold only
            the edges whose statistics changed since the previous slot,
            checkpointing every ``warm_checkpoint_every`` fold steps.
            Requires a Pallas backend and the single-seed ``run()``.

        ``failures=FailureModel(...)`` turns on the failure-aware runtime
        (crash settlement, redundancy, checkpointing, detection — see
        :class:`FailureModel`); single-seed ``run()`` only.
        ``malleable=MalleableModel(...)`` turns on the malleable-jobs
        runtime (multi-slot jobs, shrink/grow between config-family edges,
        reconfiguration/shutdown costs — see :class:`MalleableModel`);
        single-seed ``run()`` only, mutually exclusive with ``failures``
        (both settle work host-side and their interplay is undefined).
        ``fallback=True`` wraps the backend in a
        ``core.solvers.FallbackSolver`` degradation chain (host-side
        per-slot solves, exact results whichever link serves); mutually
        exclusive with ``incremental`` — wrap explicitly to compose.
        """
        self.inst = instance
        self.T = T
        self.tables = build_tables(instance.A, instance.c)
        self.g_fn = g_fn
        self.seed = seed
        self.solver = get_solver(solver)  # Algorithm-2 backend (core.solvers)
        if incremental not in (None, "cache", "warm"):
            raise ValueError(
                f"unknown incremental mode {incremental!r}; choose from "
                "(None, 'cache', 'warm')")
        self.incremental = incremental
        self._warm = None
        R = instance.n_servers
        self.arr_scale = np.ones((T, instance.n_ports), np.float32)
        if scenario is not None:
            if speed_fn is not None or alive_fn is not None:
                raise ValueError("pass either scenario= or "
                                 "speed_fn/alive_fn, not both")
            from ..experiments.scenarios import unroll_scenario
            arr_scale, speeds, alive = unroll_scenario(
                scenario, T, R, seed, n_ports=instance.n_ports)
            self.arr_scale = arr_scale
            speed_fn = lambda t: speeds[t]  # noqa: E731 — row t ↔ slot t+1
            alive_fn = lambda t: alive[t]  # noqa: E731
        self.speed_fn = speed_fn or (lambda t: np.ones(R, np.float32))
        self.alive_fn = alive_fn or (lambda t: np.ones(R, bool))
        self.m = instance.m
        self.s_cap = stats_mod.s_cap_for_horizon(T, self.m)
        self.u_max = stats_mod.u_max_for_horizon(T, self.m)
        self.failures = failures
        if failures is not None and malleable is not None:
            raise ValueError(
                "failures= and malleable= are mutually exclusive: both "
                "settle in-flight work host-side per slot")
        self.malleable = malleable
        if fallback:
            if incremental is not None:
                raise ValueError(
                    "fallback=True and incremental= both wrap the backend "
                    "host-side; compose explicitly (pass a preassembled "
                    "wrapper via solver=) instead of stacking them here")
            from ..core.solvers import FallbackSolver
            self.solver = FallbackSolver(self.solver)
        if incremental == "cache":
            from ..core.solvers import CachedSolver
            self.solver = CachedSolver(self.solver, cache=solve_cache)
        elif incremental == "warm":
            if self.solver.name not in ("pallas", "pallas_interpret"):
                raise ValueError(
                    'incremental="warm" drives the Pallas carried-plane '
                    f"path; got backend {self.solver.name!r}. Use "
                    'incremental="cache" (any backend) or the in-scan '
                    'cache="warm" policy mode in core.esdp instead.')
            from ..kernels.budgeted_dp.ops import WarmPallasSolver
            self._warm = WarmPallasSolver(
                self.tables, self.s_cap, u_max=self.u_max,
                checkpoint_every=warm_checkpoint_every,
                interpret=self.solver.interpret)

    def _solve_stats(self) -> "dict | None":
        if self.incremental == "cache":
            return self.solver.stats.as_dict()
        if self.incremental == "warm":
            return dict(self._warm.stats, edge_skip_rate=self._warm.skip_rate)
        stats = getattr(self.solver, "stats", None)
        if isinstance(stats, dict):
            # FallbackSolver-style structured counters: deep-copy so later
            # solves never mutate an already-returned record
            return copy.deepcopy(stats)
        return None

    # ------------------------------------------------------------------
    def _streams(self, seed: int | None = None):
        """Arrival/noise streams for one seed (default: the sim's own).

        ``run_batch`` draws one stream per fleet seed through this hook;
        a given seed yields the identical stream either way, which is
        what makes ``run_batch([s])`` reproduce ``run()`` of a sim built
        with ``seed=s``."""
        rng = np.random.default_rng(self.seed if seed is None else seed)
        inst = self.inst
        rho_t = np.clip(inst.rho[None, :] * self.arr_scale, 0.0, 1.0)
        arrivals = rng.random((self.T, inst.n_ports)) < rho_t
        noise = rng.normal(0.0, 1.0, (self.T, inst.n_edges)).astype(np.float32)
        return arrivals, noise

    def _z(self, t, noise_t):
        """Realized net valuations under the speed schedule."""
        inst = self.inst
        speed = self.speed_fn(t)[inst.edges[:, 1]]
        mean = inst.mu * speed - inst.cost
        return np.clip(mean + inst.sigma * noise_t, 0.0, 1.0)

    def _v_true(self, t):
        inst = self.inst
        speed = self.speed_fn(t)[inst.edges[:, 1]]
        # oracle knows the instantaneous mean (clipped-normal expectation
        # approximated by the clipped mean — exact enough for regret trends)
        return np.clip(inst.mu * speed - inst.cost, 0.0, 1.0).astype(np.float32)

    # ------------------------------------------------------------------
    def run(self, policy: str = "esdp", tiebreak: float = 1e-4) -> SimOutput:
        """The lockstep reference loop (thin adapter).

        The loop body lives in ``sched.engine.lockstep_run``, preserved
        bit-for-bit from the pre-engine implementation (same seeds ⇒ same
        ``SimOutput`` arrays — pinned by ``tests/test_engine.py`` on all
        six registered regimes).  The streaming admission/queue/dispatch
        loop is :meth:`engine` / :class:`repro.sched.engine.DispatchEngine`.
        """
        from .engine import lockstep_run

        return lockstep_run(self, policy, tiebreak)

    # ------------------------------------------------------------------
    def engine(self, config=None):
        """A :class:`repro.sched.engine.DispatchEngine` sharing this sim's
        instance, horizon, schedule (already unrolled), seed, bandit
        scaling, and failure model — the streaming counterpart of
        :meth:`run` (admission control, bounded queue with backpressure,
        weighted A/B policy variants; see ``docs/engine.md``)."""
        from .engine import DispatchEngine

        return DispatchEngine(
            self.inst, self.T, config,
            speed_fn=self.speed_fn, alive_fn=self.alive_fn,
            arr_scale=self.arr_scale, g_fn=self.g_fn, seed=self.seed,
            failures=self.failures)

    # ------------------------------------------------------------------
    def run_batch(
        self, seeds, policy: str = "esdp", tiebreak: float = 1e-4
    ) -> "list[SimOutput]":
        """One paired simulation per seed, fleet-batched per slot.

        Every seed replays the SAME cluster schedule (speed/aliveness
        callbacks, and a scenario's arrival scaling — unrolled once with
        the sim's construction seed) against its OWN arrival/noise
        streams and bandit state, exactly as ``ClusterSim(...,
        seed=s).run(policy)`` would — ``run_batch([s])`` reproduces that
        run bit for bit.  The per-slot Algorithm-2 solves of all seeds
        dispatch as ONE kernel launch per slot: the vmapped solver hits
        the batch-aware backends' custom batching rule
        (``Solver.accepts_batch``), which shares the DP-table operands
        across the fleet instead of replicating the launch per seed.

        Returns one :class:`SimOutput` per seed, in seed order.
        """
        if self.incremental == "warm":
            raise NotImplementedError(
                'incremental="warm" carries one value-plane chain and so '
                "runs single-seed only (run()); use incremental=\"cache\" "
                "for fleet batches — its keys are per instance row")
        if self.failures is not None:
            raise NotImplementedError(
                "the failure-aware runtime settles crashes per seed "
                "host-side and so runs single-seed only (run()); loop "
                "run() over seeds for a failure-aware fleet")
        if self.malleable is not None:
            raise NotImplementedError(
                "the malleable-jobs runtime tracks per-seed in-flight "
                "jobs host-side and so runs single-seed only (run()); "
                "loop run() over seeds for a malleable fleet")
        from .engine import LOCKSTEP_POLICIES
        if policy not in LOCKSTEP_POLICIES:
            raise ValueError(
                f"unknown policy {policy!r}; valid lockstep policies: "
                f"{', '.join(LOCKSTEP_POLICIES)}")
        inst, tables = self.inst, self.tables
        E, R = inst.n_edges, inst.n_servers
        port = inst.port_of_edge
        server = inst.edges[:, 1]
        seeds = [int(s) for s in seeds]
        B = len(seeds)
        streams = [self._streams(s) for s in seeds]
        arrivals = np.stack([a for a, _ in streams])  # (B, T, P)
        noise = np.stack([z for _, z in streams])  # (B, T, E)
        rngs = [np.random.default_rng(s + 1) for s in seeds]
        b_ids = np.arange(B)[:, None]

        n = np.zeros((B, E), np.int64)
        sumz = np.zeros((B, E), np.float64)
        waiting = np.zeros((B, inst.n_ports), np.int64)

        sw = np.zeros((B, self.T), np.float32)
        regret = np.zeros((B, self.T), np.float32)
        share = np.zeros((B, self.T, R), np.float32)

        jit_stats = jax.jit(jax.vmap(
            lambda v, k, t: stats_mod.scale_statistics(
                v, k, t, self.m, g_fn=self.g_fn),
            in_axes=(0, 0, None)))
        if self.incremental is None and isinstance(self.solver, Solver):
            jit_dp = jax.jit(jax.vmap(
                lambda u, s, lim, al: self.solver(
                    u, s, tables, self.s_cap, lim, allowed=al,
                    u_max=self.u_max)[0]))

            def solve_x(u, s, lim, al):
                return np.asarray(jit_dp(u, s, lim, jnp.asarray(al)))
        else:
            # host-side wrappers' concrete batched paths: CachedSolver
            # keys per row (one batched launch on any miss, none on a
            # full hit); FallbackSolver walks its chain once per slot
            # with per-row plane validation
            def solve_x(u, s, lim, al):
                return np.asarray(self.solver(
                    np.asarray(u), np.asarray(s), tables, self.s_cap,
                    np.asarray(lim), allowed=al, u_max=self.u_max)[0])
        jit_oracle = jax.jit(jax.vmap(
            lambda v, al: oracle_knapsack(v, tables, al)[0],
            in_axes=(None, 0)))
        jit_greedy = jax.jit(jax.vmap(
            lambda sc, el: greedy_pack(sc, el, jnp.asarray(inst.A),
                                       jnp.asarray(inst.c))))

        for t0 in range(self.T):
            t = t0 + 1  # 1-based for the bandit schedules
            alive = self.alive_fn(t0)[server]  # shared schedule
            arrived = arrivals[:, t0][:, port]  # (B, E)
            allowed = arrived & alive[None, :]
            vhat = np.where(n > 0, sumz / np.maximum(n, 1), 0.0).astype(
                np.float32)

            if policy == "esdp":
                ups, sig, _, s_lim = jit_stats(
                    jnp.asarray(vhat), jnp.asarray(n.astype(np.int32)),
                    jnp.float32(t))
                x = solve_x(ups, sig, s_lim, allowed)
            else:
                tb = np.stack([r.random(E) for r in rngs]).astype(
                    np.float32) * tiebreak
                if policy == "hswf":
                    score = vhat + tb
                elif policy == "lcf":
                    score = -inst.cost[None, :] + tb
                else:  # lwtf
                    score = waiting[:, port] * 1e3 + vhat + tb
                x = np.asarray(jit_greedy(jnp.asarray(score),
                                          jnp.asarray(allowed)))

            x = x * allowed
            z = self._z(t0, noise[:, t0])  # broadcasts to (B, E)
            sw[:, t0] = (x * z).sum(axis=1)
            v_true = self._v_true(t0)
            x_star = np.asarray(jit_oracle(jnp.asarray(v_true),
                                           jnp.asarray(allowed)))
            regret[:, t0] = ((v_true[None, :] * x_star).sum(axis=1)
                             - (v_true[None, :] * x).sum(axis=1))

            n += x
            sumz += x * z
            served = np.zeros((B, inst.n_ports), bool)
            np.maximum.at(served, (b_ids, port[None, :]), x > 0)
            waiting = np.where(served, 0, waiting + arrivals[:, t0])
            tot = x.sum(axis=1)
            for b in np.flatnonzero(tot > 0):
                np.add.at(share[b, t0], server, x[b] / tot[b])

        stats = self._solve_stats() if policy == "esdp" else None
        if stats is not None:
            # the counters aggregate the WHOLE fleet's solves (per-slot
            # batched launches are shared across seeds) — label them so
            # they cannot masquerade as per-seed numbers, and hand every
            # output its OWN copy (a shared dict object would alias
            # mutation across seeds)
            stats["scope"] = "fleet"
        return [SimOutput(sw=sw[b], regret=regret[b],
                          dispatch_share=share[b],
                          asw=float(sw[b].sum()),
                          solve_stats=(copy.deepcopy(stats)
                                       if stats is not None else None))
                for b in range(B)]

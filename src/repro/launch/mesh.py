"""Production meshes. Functions, not module constants — importing this file
never touches jax device state."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_shape"]


def _make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    # jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist on
    # newer jax; older releases default every axis to Auto anyway.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips as (16 data, 16 model). Multi-pod: 2×256 with a
    leading 'pod' axis (DP across pods; PP over 'pod' in the pp demo)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh_shape(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (elastic re-scale paths)."""
    return _make_mesh(shape, axes)

"""Training driver: fault-tolerant loop over the jitted train step.

CPU-scale usage (examples, tests):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-32b \
        --reduced --steps 200 --batch 8 --seq 128 --fail-p 0.02

On a real cluster the same driver runs with the production mesh and the
FULL config; the dry-run (launch/dryrun.py) proves that combination lowers
and fits, so this file stays mesh-agnostic: pass --mesh data,model sizes
that multiply to the local device count.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import get_config
from ..data import SyntheticLM, make_batch_iterator
from ..models import build_model
from ..optim import AdamW, linear_warmup_cosine
from ..runtime import init_train_state, make_rules, make_train_step
from ..runtime.fault import FailureInjector, TrainSupervisor
from .mesh import make_mesh_shape


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", type=float, default=0.0,
                    help="top-k gradient compression ratio (0 = off)")
    ap.add_argument("--fail-p", type=float, default=0.0)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--mesh", default="", help="e.g. '1,1' => data,model")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)

    rules = None
    if args.mesh:
        sizes = tuple(int(x) for x in args.mesh.split(","))
        mesh = make_mesh_shape(sizes, ("data", "model")[:len(sizes)])
        rules = make_rules(mesh, "train")

    opt = AdamW(lr=linear_warmup_cosine(args.lr, 10, args.steps))
    step_fn = make_train_step(
        model, opt, rules=rules, remat=args.remat,
        microbatches=args.microbatches,
        compress_ratio=args.compress or None)
    step_fn = jax.jit(step_fn, donate_argnums=(0,))

    state = init_train_state(model, jax.random.PRNGKey(args.seed), opt,
                             compress=args.compress > 0)

    ds = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                     global_batch=args.batch, seed=args.seed)
    ckpt = CheckpointManager(args.ckpt_dir)
    injector = FailureInjector(p_fail=args.fail_p, seed=args.seed,
                               scheduled=tuple(args.fail_at))
    sup = TrainSupervisor(step_fn, ckpt, injector,
                          save_every=args.save_every)

    losses = []

    def on_metrics(step, metrics):
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 10 == 0:
            print(f"step {step:5d} loss {loss:7.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f}", flush=True)

    t0 = time.time()
    state, final_step = sup.run(
        state,
        make_iterator=lambda s: make_batch_iterator(ds, start_step=s),
        total_steps=args.steps, on_metrics=on_metrics)
    wall = time.time() - t0

    summary = {
        "arch": cfg.name, "steps": final_step, "wall_s": round(wall, 1),
        "first_loss": losses[0] if losses else None,
        "last_loss": float(np.mean(losses[-10:])) if losses else None,
        "restarts": sup.restarts, "lost_steps": sup.lost_steps,
        "straggler_slow_steps": sup.straggler.slow_steps,
    }
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()

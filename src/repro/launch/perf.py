"""§Perf hillclimb runner: re-lowers chosen cells under candidate changes
and prints before/after roofline terms.

    python -m repro.launch.perf --cell gemma-7b:train_4k:single

Each candidate is (tag, sharding-rule overrides, remat, config overrides).
Results are written as tagged JSONs next to the baselines so EXPERIMENTS.md
§Perf can cite exact numbers.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys

RESULTS = pathlib.Path("results/dryrun")

# candidate changes per hillclimb cell: (tag, dryrun extra args)
CANDIDATES: dict[str, list[tuple[str, list[str]]]] = {
    # collective-bound dense train cell: TP psums dominate ⇒ FSDP pivot
    "gemma-7b:train_4k": [
        ("fsdp", ["--overrides", json.dumps(
            {"heads": [], "kv_heads": [], "mlp": [], "vocab": [],
             "embed": ["data", "model"]})]),
        ("fsdp_dots", ["--overrides", json.dumps(
            {"heads": [], "kv_heads": [], "mlp": [], "vocab": [],
             "embed": ["data", "model"]}), "--remat", "dots"]),
        ("dots", ["--remat", "dots"]),
    ],
    # collective-bound MoE train cell: keep EP, drop dense TP
    "dbrx-132b:train_4k": [
        ("fsdp_ep", ["--overrides", json.dumps(
            {"heads": [], "kv_heads": [], "mlp": [], "vocab": [],
             "embed": ["data", "model"], "expert": ["model"]})]),
        ("fsdp_ep_dots", ["--overrides", json.dumps(
            {"heads": [], "kv_heads": [], "mlp": [], "vocab": [],
             "embed": ["data", "model"], "expert": ["model"]}),
         "--remat", "dots"]),
    ],
    # deepseek: EP stays on model, dense TP dropped; remat policy second
    "deepseek-v3-671b:train_4k": [
        ("fsdp_ep", ["--overrides", json.dumps(
            {"heads": [], "kv_heads": [], "mlp": [], "vocab": [],
             "embed": ["data", "model"], "expert": ["model"]})]),
        ("fsdp_ep_dots", ["--overrides", json.dumps(
            {"heads": [], "kv_heads": [], "mlp": [], "vocab": [],
             "embed": ["data", "model"], "expert": ["model"]}),
         "--remat", "dots"]),
    ],
    # worst-fraction cell: SSD resharding + f32 intermediates
    "mamba2-2.7b:prefill_32k": [
        ("fsdp", ["--overrides", json.dumps(
            {"heads": [], "kv_heads": [], "mlp": [], "vocab": [],
             "embed": ["data", "model"]})]),
        ("fsdp_q64", ["--overrides", json.dumps(
            {"heads": [], "kv_heads": [], "mlp": [], "vocab": [],
             "embed": ["data", "model"]}),
         "--config-overrides", json.dumps({"ssm_chunk": 64})]),
        ("fsdp_q256", ["--overrides", json.dumps(
            {"heads": [], "kv_heads": [], "mlp": [], "vocab": [],
             "embed": ["data", "model"]}),
         "--config-overrides", json.dumps({"ssm_chunk": 256})]),
    ],
    # memory-bound hybrid train cell: SSD chunk trade-off
    "zamba2-7b:train_4k": [
        ("ssmq64", ["--config-overrides", json.dumps({"ssm_chunk": 64})]),
    ],
    # memory-bound decode cell: cache traffic
    "qwen2.5-32b:decode_32k": [
        ("cacheseq_dm", ["--overrides", json.dumps(
            {"cache_seq": ["model"], "batch": ["pod", "data"]})]),
    ],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", default=[],
                    help="arch:shape[:mesh] (default mesh=single)")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    cells = args.cell or (list(CANDIDATES) if args.all else [])
    for cell in cells:
        parts = cell.split(":")
        arch, shape = parts[0], parts[1]
        mesh = parts[2] if len(parts) > 2 else "single"
        base = RESULTS / f"{arch}_{shape}_{mesh}.json"
        if base.exists():
            b = json.loads(base.read_text())
            if "roofline" in b:
                t = b["roofline"]
                print(f"BASE {arch}:{shape}:{mesh} "
                      f"comp={t['compute_s']:.3f} mem={t['memory_s']:.3f} "
                      f"coll={t['collective_s']:.3f} "
                      f"frac={t['roofline_fraction']:.3f}", flush=True)
        for tag, extra in CANDIDATES.get(f"{arch}:{shape}", []):
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--tag", tag] + extra
            print(">>", tag, flush=True)
            subprocess.run(cmd)


if __name__ == "__main__":
    main()

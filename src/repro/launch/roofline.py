"""Roofline-term derivation from compiled dry-run artifacts.

Three terms (seconds, per chip — cost_analysis is post-SPMD per-device):
    compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
    memory     = HLO_bytes / HBM_bw                (819 GB/s)
    collective = wire_bytes / link_bw              (~50 GB/s ICI)

wire_bytes comes from parsing the compiled HLO: for each collective op we
take the per-device result shape and convert to ring-algorithm wire traffic:
    all-gather        : out_bytes · (N-1)/N        (receives all other shards)
    reduce-scatter    : out_bytes · (N-1)          (N-1 chunk passes)
    all-reduce        : out_bytes · 2(N-1)/N       (RS + AG at full size)
    all-to-all        : out_bytes · (N-1)/N
    collective-permute: out_bytes
Replica groups are parsed from both iota ([G,N]<=[T]) and explicit ({{..}})
forms to recover the group size N.

MODEL_FLOPS uses the 6·N_active·D (train) / 2·N_active·D (inference)
convention with N_active counted from the spec tree (routed expert tensors
scaled by top_k/E; embedding gather excluded, tied head counted once).
"""
from __future__ import annotations

import re

import numpy as np

__all__ = ["HW", "parse_collective_bytes", "active_param_count",
           "roofline_terms", "model_flops"]

HW = {
    "peak_flops": 197e12,  # bf16 / chip
    "hbm_bw": 819e9,  # B/s
    "link_bw": 50e9,  # B/s per ICI link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|([a-z0-9]+)\[([\d,]*)\][^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )

_TUPLE_RE = re.compile(r"=\s*\(([^)]*)\)\s*"
                       r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                       r"collective-permute)(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _line_out_bytes(line: str) -> int:
    """Bytes of the op result (first shape(s) after '=')."""
    eq = line.find("=")
    if eq < 0:
        return 0
    # result type is between '=' and the op name
    m = re.match(r"\s*(\(?[^(]*?\)?)\s*(?:all-gather|all-reduce|"
                 r"reduce-scatter|all-to-all|collective-permute)", line[eq + 1:])
    if not m:
        return 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(m.group(1)):
        if dt in _DTYPE_BYTES:
            total += _shape_bytes(dt, dims)
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        g, n = int(m.group(1)), int(m.group(2))
        # iota form [G,N]<=[T]: either G groups of N or transposed; the
        # second dim is the per-group size in HLO's row-major convention
        return max(n, 1)
    m = _EXPL_GROUPS_RE.search(line)
    if m:
        first = [s for s in m.group(1).split(",") if s.strip() != ""]
        return max(len(first), 1)
    return total_devices


_WIRE_FACTOR = {
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: float(n - 1),
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


def parse_collective_bytes(hlo_text: str, total_devices: int) -> dict:
    """Per-device wire bytes by collective kind + op counts."""
    out_bytes = {k: 0.0 for k in _WIRE_FACTOR}
    counts = {k: 0 for k in _WIRE_FACTOR}
    for line in hlo_text.splitlines():
        for kind in _WIRE_FACTOR:
            # match op occurrence as an instruction (not operand reference)
            if f" {kind}(" in line or f" {kind}-start(" in line:
                b = _line_out_bytes(line)
                if b == 0:
                    continue
                n = _group_size(line, total_devices)
                out_bytes[kind] += b * _WIRE_FACTOR[kind](n)
                counts[kind] += 1
                break
    total = sum(out_bytes.values())
    return {"by_kind": out_bytes, "counts": counts, "total_wire_bytes": total}


# ---------------------------------------------------------------------------
# MODEL_FLOPS
# ---------------------------------------------------------------------------

def _spec_leaves(tree, prefix=()):
    if isinstance(tree, dict) and tree.get("__leaf__", False):
        yield prefix, tree
        return
    for k, v in tree.items():
        yield from _spec_leaves(v, prefix + (k,))


def active_param_count(model) -> tuple[int, int]:
    """(total_params, active_params): routed experts scaled by top_k/E,
    embedding gather excluded (tied head counted once as the head matmul)."""
    cfg = model.config
    total = 0
    active = 0
    for path, leaf in _spec_leaves(model.spec.tree):
        n = int(np.prod(leaf["shape"]))
        total += n
        name = "/".join(path)
        if name == "embed":
            if cfg.tie_embeddings:
                active += n  # used as the output head matmul
            continue
        if name == "pos_embed":
            continue
        if "expert" in leaf["axes"]:  # routed expert tensor (E, d, f)
            active += int(n * cfg.top_k / cfg.n_experts)
            continue
        active += n
    return total, active


def model_flops(model, shape) -> float:
    """6·N_active·D for train, 2·N_active·D for inference shapes (global)."""
    _, active = active_param_count(model)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    tokens = shape.global_batch * 1  # decode: one token per row
    return 2.0 * active * tokens


def roofline_terms(
    cost: dict, coll: dict, n_devices: int, model=None, shape=None
) -> dict:
    flops = float(cost.get("flops", 0.0))
    bytes_ = float(cost.get("bytes accessed", 0.0))
    wire = float(coll["total_wire_bytes"])
    terms = {
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "wire_bytes_per_device": wire,
        "compute_s": flops / HW["peak_flops"],
        "memory_s": bytes_ / HW["hbm_bw"],
        "collective_s": wire / HW["link_bw"],
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    if model is not None and shape is not None:
        mf = model_flops(model, shape)
        terms["model_flops_global"] = mf
        terms["model_flops_per_device"] = mf / n_devices
        terms["useful_flops_ratio"] = (
            mf / n_devices / flops if flops > 0 else 0.0)
        step_s = max(terms["compute_s"], terms["memory_s"],
                     terms["collective_s"])
        terms["roofline_fraction"] = (
            (mf / n_devices / HW["peak_flops"]) / step_s if step_s > 0 else 0.0)
    return terms

"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

Everything is weak-type-correct and shardable; nothing allocates. The
returned (abstract_batch, batch_axes) pair feeds Rules.tree_shardings for
in_shardings of the lowered step.

Conventions:
  train   : tokens (B, S_text+1) — loss shifts internally
  prefill : tokens (B, S_text)
  decode  : token (B, 1) + pos (B,) + cache sized seq_len
  vlm     : n_vision_tokens of the seq budget are patch embeddings
            (precomputed by the stub frontend), positions are M-RoPE (3,B,S)
  encdec  : enc_embeds (B, enc_len, d) from the stub conv frontend
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import Shape
from ..models import build_model
from ..models.layers import DTYPES

__all__ = ["input_specs", "batch_axes"]

I32 = jnp.int32


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def input_specs(cfg, shape: Shape, model=None):
    """Returns (abstract_batch, axes_tree) for the step this shape lowers."""
    B, S = shape.global_batch, shape.seq_len
    cdt = DTYPES[cfg.compute_dtype]
    kind = shape.kind

    if kind in ("train", "prefill"):
        extra = 1 if kind == "train" else 0
        batch, axes = {}, {}
        if cfg.family == "vlm":
            nv = cfg.n_vision_tokens
            s_text = S - nv
            batch["tokens"] = _sd((B, s_text + extra), I32)
            axes["tokens"] = ("batch", None)
            batch["patch_embeds"] = _sd((B, nv, cfg.d_model), cdt)
            axes["patch_embeds"] = ("batch", None, None)
            batch["positions"] = _sd((3, B, S), I32)
            axes["positions"] = (None, "batch", None)
        elif cfg.family == "encdec":
            batch["tokens"] = _sd((B, S + extra), I32)
            axes["tokens"] = ("batch", None)
            batch["enc_embeds"] = _sd((B, cfg.enc_len, cfg.d_model), cdt)
            axes["enc_embeds"] = ("batch", None, None)
        else:
            batch["tokens"] = _sd((B, S + extra), I32)
            axes["tokens"] = ("batch", None)
        return batch, axes

    assert kind == "decode"
    if model is None:
        model = build_model(cfg)
    cache, cache_axes = model.cache_spec(B, S)
    batch = {"token": _sd((B, 1), I32), "pos": _sd((B,), I32),
             "cache": cache}
    axes = {"token": ("batch", None), "pos": ("batch",),
            "cache": cache_axes}
    if cfg.family == "vlm":
        batch["positions"] = _sd((3, B, 1), I32)
        axes["positions"] = (None, "batch", None)
    return batch, axes


def batch_axes(cfg, shape: Shape):
    return input_specs(cfg, shape)[1]

"""Depth-affine cost extrapolation for scanned stacks.

XLA's ``compiled.cost_analysis()`` counts a ``while``-loop body ONCE, not
× trip-count (verified empirically: a 10-step scan of a matmul reports one
matmul of FLOPs). Every stack here is a ``lax.scan`` over layers, so raw
dry-run costs wildly undercount. Because scanned layers are homogeneous,
total cost is *exactly affine* in the number of scanned units:

        cost(L) = a + b·L

We therefore compile 2–3 reduced-DEPTH, full-WIDTH variants (abstract only —
cheap), solve for (a, b), and extrapolate to the full depth. All *inner*
scans (attention KV chunks, SSD chunks, xent seq chunks) are forced to a
single trip in these cost compiles (chunk = seq_len ⇒ scan length 1 ⇒
counted-once is exact), so no nested undercounting remains. The same
extrapolation applies to the HLO-parsed collective wire bytes.

Family systems:
  dense/moe/vlm     : vary n_layers ∈ {2,4}       → a + b·L
  deepseek          : vary (dense, moe) scans     → a + b_d·Ld + b_m·Lm
  whisper           : enc & dec vary jointly       → a + (b_e+b_d)·L
  ssm (train/prefill): vary (L, ssd chunk count)  → a + L·(base + quad/nc)
      SSD's intra-chunk term is quadratic in the chunk size Q = S/nc, so —
      unlike attention chunking, which only re-tiles the same total work —
      chunk count changes the ALGORITHM's cost: per-layer cost is affine in
      1/nc. nc is probed at {1, 2} and extrapolated to the real config.
  zamba2 (hybrid)   : a + G·(c + P·(mb + mq/nc)) + 3·(mb + mq/nc) — four
      unknowns, four compiles (ΔG, ΔP, Δnc).
"""
from __future__ import annotations

import math
from typing import Callable

__all__ = ["cost_variants", "solve_costs", "COST_KEYS"]

COST_KEYS = ("flops", "bytes accessed", "transcendentals")


def _single_chunk(cfg, seq_len: int):
    """Cost-compile mode: unroll layer scans (exact counting) and force every
    inner chunk scan to one trip (XLA inlines trip-1 while loops, verified)."""
    s = max(seq_len, 1)
    return cfg.replace(attn_chunk=s, ssm_chunk=s, xent_chunk=0,
                       unroll_scans=True)


def _nc_full(cfg, seq_len: int) -> int:
    return max(1, math.ceil(seq_len / cfg.ssm_chunk))


def cost_variants(cfg, seq_len: int, kind: str = "train"):
    """Returns (variant_cfgs, solve_fn). solve_fn(values: list[dict]) -> dict
    of extrapolated cost values for the FULL config; values[i] aligns with
    variant_cfgs[i] and maps key -> float."""
    base = _single_chunk(cfg, seq_len)
    ssd_active = cfg.family in ("ssm", "hybrid") and kind in ("train",
                                                              "prefill")

    if cfg.family == "hybrid" and ssd_active:
        per_full = cfg.hybrid_every
        G_full = cfg.n_layers // per_full
        P_full = per_full - 1
        tail = cfg.n_layers - G_full * per_full
        ncf = _nc_full(cfg, seq_len)
        half = max(seq_len // 2, 1)
        A = base.replace(hybrid_every=4, n_layers=2 * 4 + tail)  # G2 P3 nc1
        B = base.replace(hybrid_every=4, n_layers=3 * 4 + tail)  # G3 P3 nc1
        C = base.replace(hybrid_every=6, n_layers=2 * 6 + tail)  # G2 P5 nc1
        D = A.replace(ssm_chunk=half)  # G2 P3 nc2

        def solve(vals):
            out = {}
            for k in vals[0]:
                vA, vB, vC, vD = (v[k] for v in vals)
                # mamba layers in A: 2·3 + tail(3) = 9 ⇒ vA−vD = 9·mq/2
                mq = 2 * (vA - vD) / (2 * 3 + tail)
                mbq = (vC - vA) / 4  # mb + mq (ΔP=2, G2)
                mb = mbq - mq
                c = (vB - vA) - 3 * mbq  # ΔG=1 at P3 nc1
                a_fixed = vA - 2 * (c + 3 * mbq) - tail * mbq
                per_m = mb + mq / ncf
                out[k] = (a_fixed + tail * per_m
                          + G_full * (c + P_full * per_m))
            return out

        return [A, B, C, D], solve

    if cfg.family == "hybrid":  # decode shapes: no ssd chunk scan
        per_full = cfg.hybrid_every
        G_full = cfg.n_layers // per_full
        P_full = per_full - 1
        tail = cfg.n_layers - G_full * per_full
        A = base.replace(hybrid_every=4, n_layers=2 * 4 + tail)
        B = base.replace(hybrid_every=4, n_layers=3 * 4 + tail)
        C = base.replace(hybrid_every=6, n_layers=2 * 6 + tail)

        def solve(vals):
            out = {}
            for k in vals[0]:
                vA, vB, vC = (v[k] for v in vals)
                d = (vC - vA) / 4
                c = (vB - vA) - 3 * d
                a = vA - 2 * (c + 3 * d) - tail * d
                out[k] = a + G_full * (c + P_full * d) + tail * d
            return out

        return [A, B, C], solve

    if cfg.family == "ssm" and ssd_active:
        L_full = cfg.n_layers
        ncf = _nc_full(cfg, seq_len)
        half = max(seq_len // 2, 1)
        A = base.replace(n_layers=2)  # L2 nc1
        B = base.replace(n_layers=2, ssm_chunk=half)  # L2 nc2
        C = base.replace(n_layers=4)  # L4 nc1

        def solve(vals):
            out = {}
            for k in vals[0]:
                vA, vB, vC = (v[k] for v in vals)
                quad = vA - vB  # L2·quad/2 gap
                per1 = (vC - vA) / 2.0  # base + quad at nc1
                bse = per1 - quad
                a = vA - 2 * per1
                out[k] = a + L_full * (bse + quad / ncf)
            return out

        return [A, B, C], solve

    if cfg.family == "encdec":
        L_full = cfg.n_layers
        A = base.replace(n_layers=2, n_enc_layers=2)
        B = base.replace(n_layers=4, n_enc_layers=4)

        def solve(vals):
            out = {}
            for k in vals[0]:
                b = (vals[1][k] - vals[0][k]) / 2.0
                a = vals[0][k] - 2 * b
                out[k] = a + L_full * b
            return out

        return [A, B], solve

    if cfg.n_experts > 0 and cfg.moe_layer_start > 0:
        # deepseek: v = a + b_d·Ld + b_m·Lm
        Ld_full, Lm_full = cfg.moe_layer_start, cfg.n_layers - cfg.moe_layer_start
        A = base.replace(n_layers=3, moe_layer_start=1)  # Ld1 Lm2
        B = base.replace(n_layers=4, moe_layer_start=2)  # Ld2 Lm2
        C = base.replace(n_layers=5, moe_layer_start=1)  # Ld1 Lm4

        def solve(vals):
            out = {}
            for k in vals[0]:
                vA, vB, vC = (v[k] for v in vals)
                bd = vB - vA
                bm = (vC - vA) / 2.0
                a = vA - bd - 2 * bm
                out[k] = a + Ld_full * bd + Lm_full * bm
            return out

        return [A, B, C], solve

    # uniform stacks (dense / moe-uniform / vlm / ssm)
    L_full = cfg.n_layers
    A = base.replace(n_layers=2)
    B = base.replace(n_layers=4)
    if cfg.n_experts > 0:
        A = A.replace(moe_layer_start=0)
        B = B.replace(moe_layer_start=0)

    def solve(vals):
        out = {}
        for k in vals[0]:
            b = (vals[1][k] - vals[0][k]) / 2.0
            a = vals[0][k] - 2 * b
            out[k] = a + L_full * b
        return out

    return [A, B], solve


def solve_costs(variant_values: list[dict], solve: Callable) -> dict:
    """Guard against tiny negative extrapolations from parser noise."""
    out = solve(variant_values)
    return {k: max(v, 0.0) for k, v in out.items()}

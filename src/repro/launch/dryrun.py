import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

_DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

    python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all          # subprocess per cell, resumable

Each cell writes results/dryrun/{arch}_{shape}_{mesh}[_tag].json with
memory_analysis, cost_analysis, collective wire bytes, and roofline terms.
Sharding failures / OOM-at-compile are bugs — they land in the JSON as
"error" and fail the sweep summary.
"""

import argparse
import json
import pathlib
import subprocess
import sys
import time

import jax

from ..configs import ARCHS, SHAPES, get_config, shape_applicable
from ..models import build_model
from ..optim import AdamW, OptState
from ..runtime import TrainState, init_train_state, make_rules, make_train_step
from .cost_model import COST_KEYS, cost_variants, solve_costs
from .mesh import make_production_mesh
from .roofline import parse_collective_bytes, roofline_terms
from .specs import input_specs

RESULTS = pathlib.Path("results/dryrun")


def _preset_for(shape) -> str:
    if shape.name == "long_500k":
        return "long"
    if shape.kind == "decode":
        return "decode"
    return "train"


def _compile_cell(cfg, shape, mesh, rules, remat: str, microbatches: int):
    """Lower + compile one (config, shape) on a mesh. Returns compiled."""
    model = build_model(cfg)
    batch_abs, batch_axes = input_specs(cfg, shape, model)
    batch_shardings = rules.tree_shardings(batch_abs, batch_axes)

    if shape.kind == "train":
        opt = AdamW(lr=3e-4)
        step = make_train_step(model, opt, rules=rules, remat=remat,
                               microbatches=microbatches)
        state_abs = jax.eval_shape(
            lambda k: init_train_state(model, k, opt), jax.random.PRNGKey(0))
        p_sh = rules.tree_shardings(model.abstract(), model.axes())
        state_sh = TrainState(
            params=p_sh,
            opt=OptState(step=rules.named((), ()), m=p_sh, v=p_sh),
            err=None)
        jf = jax.jit(step, in_shardings=(state_sh, batch_shardings),
                     donate_argnums=(0,))
        return jf.lower(state_abs, batch_abs).compile(), model
    p_abs = model.abstract()
    p_sh = rules.tree_shardings(p_abs, model.axes())
    if shape.kind == "prefill":
        def fn(p, b):
            return model.prefill(p, b, rules=rules)
        jf = jax.jit(fn, in_shardings=(p_sh, batch_shardings))
    else:
        def fn(p, b):
            return model.decode(p, b, rules=rules)
        jf = jax.jit(fn, in_shardings=(p_sh, batch_shardings),
                     donate_argnums=(1,))
    return jf.lower(p_abs, batch_abs).compile(), model


def _extract_costs(compiled, n_dev) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax wraps it per-partition
        cost = cost[0] if cost else {}
    coll = parse_collective_bytes(compiled.as_text(), n_dev)
    vals = {k: float(cost.get(k, 0.0)) for k in COST_KEYS}
    for kind, b in coll["by_kind"].items():
        vals[f"wire:{kind}"] = b
    vals["wire:total"] = coll["total_wire_bytes"]
    for kind, c in coll["counts"].items():
        vals[f"count:{kind}"] = float(c)
    return vals


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    remat: str = "full",
    microbatches: int = 1,
    overrides: dict | None = None,
    return_artifacts: bool = False,
    config_overrides: dict | None = None,
):
    """Lower + compile one cell; returns the result record (and artifacts).

    Two kinds of compiles happen:
      1. the FULL-depth compile (scanned stacks) → memory_analysis + proof
         that the production sharding lowers and fits;
      2. 2–3 reduced-depth UNROLLED cost compiles → exact FLOPs / bytes /
         collective wire bytes via affine depth extrapolation
         (launch/cost_model.py — XLA counts while bodies once).
    """
    cfg = get_config(arch)
    if config_overrides:
        cfg = cfg.replace(**config_overrides)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": True, "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(len(mesh.devices.reshape(-1)))
    rules = make_rules(mesh, _preset_for(shape), overrides)

    t0 = time.time()
    compiled, model = _compile_cell(cfg, shape, mesh, rules, remat,
                                    microbatches)
    t_full = time.time() - t0
    ma = compiled.memory_analysis()

    # cost compiles (reduced depth, unrolled, single-chunk)
    t0 = time.time()
    variants, solve = cost_variants(cfg, shape.seq_len, shape.kind)
    vals = []
    for vcfg in variants:
        vc, _ = _compile_cell(vcfg, shape, mesh, rules, remat, 1)
        vals.append(_extract_costs(vc, n_dev))
    corrected = solve_costs(vals, solve)
    t_cost = time.time() - t0

    cost = {"flops": corrected["flops"],
            "bytes accessed": corrected["bytes accessed"],
            "transcendentals": corrected.get("transcendentals", 0.0)}
    coll = {"by_kind": {k.split(":", 1)[1]: v for k, v in corrected.items()
                        if k.startswith("wire:") and k != "wire:total"},
            "counts": {k.split(":", 1)[1]: v for k, v in corrected.items()
                       if k.startswith("count:")},
            "total_wire_bytes": corrected["wire:total"]}
    terms = roofline_terms(cost, coll, n_dev, model, shape)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev,
        "remat": remat, "microbatches": microbatches,
        "overrides": overrides or {},
        "config_overrides": config_overrides or {},
        "compile_s": round(t_full, 1), "cost_compiles_s": round(t_cost, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_est_bytes": (ma.argument_size_in_bytes
                               + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes
                               - ma.alias_size_in_bytes),
        },
        "cost": cost,
        "collectives": coll,
        "roofline": terms,
    }
    if return_artifacts:
        return rec, compiled, model
    return rec


def run_one(args) -> int:
    RESULTS.mkdir(parents=True, exist_ok=True)
    tag = f"_{args.tag}" if args.tag else ""
    out = RESULTS / f"{args.arch}_{args.shape}_{args.mesh}{tag}.json"
    try:
        rec = lower_cell(args.arch, args.shape, args.mesh == "multi",
                         remat=args.remat, microbatches=args.microbatches,
                         overrides=json.loads(args.overrides)
                         if args.overrides else None,
                         config_overrides=json.loads(args.config_overrides)
                         if args.config_overrides else None)
    except Exception as e:  # noqa: BLE001 — recorded, sweep summary fails
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "error": f"{type(e).__name__}: {e}"}
    out.write_text(json.dumps(rec, indent=1, default=str))
    if rec.get("error"):
        print(f"FAIL {out.name}: {rec['error'][:300]}")
        return 1
    if rec.get("skipped"):
        print(f"SKIP {out.name}: {rec['reason']}")
        return 0
    r = rec["roofline"]
    print(f"OK   {out.name} compile={rec['compile_s']}s "
          f"mem={rec['memory']['peak_est_bytes']/2**30:.2f}GiB/dev "
          f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
          f"coll={r['collective_s']:.4f}s -> {r['bottleneck']}")
    return 0


def run_all(args) -> int:
    RESULTS.mkdir(parents=True, exist_ok=True)
    cells = [(a, s, m)
             for a in ARCHS for s in SHAPES for m in ("single", "multi")]
    fails = 0
    for arch, shape, mesh_kind in cells:
        out = RESULTS / f"{arch}_{shape}_{mesh_kind}.json"
        if out.exists() and not args.force:
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
               "--remat", args.remat]
        print(">>", " ".join(cmd[3:]), flush=True)
        try:
            proc = subprocess.run(cmd, timeout=args.cell_timeout)
            rc = proc.returncode
        except subprocess.TimeoutExpired:
            out.write_text(json.dumps(
                {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "error": f"compile timeout > {args.cell_timeout}s"}))
            print(f"FAIL {out.name}: timeout", flush=True)
            rc = 1
        fails += int(rc != 0)
    print(f"sweep done, {fails} failures")
    return int(fails > 0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--overrides", default="",
                    help="JSON dict of sharding-rule overrides")
    ap.add_argument("--config-overrides", default="",
                    help="JSON dict of ModelConfig field overrides")
    ap.add_argument("--tag", default="", help="suffix for the result file")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--cell-timeout", type=int, default=3600)
    args = ap.parse_args()
    if args.all:
        sys.exit(run_all(args))
    assert args.arch and args.shape, "--arch/--shape required without --all"
    sys.exit(run_one(args))


if __name__ == "__main__":
    main()

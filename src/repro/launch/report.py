"""Render EXPERIMENTS.md §Roofline table + §Perf comparisons from
results/dryrun/*.json.

    python -m repro.launch.report            # print tables
    python -m repro.launch.report --inject   # splice into EXPERIMENTS.md
"""
from __future__ import annotations

import argparse
import json
import pathlib

RESULTS = pathlib.Path("results/dryrun")
EXP = pathlib.Path("EXPERIMENTS.md")


def _fmt(v, n=3):
    return f"{v:.{n}f}" if isinstance(v, (int, float)) else str(v)


def roofline_markdown() -> str:
    rows = ["| arch | shape | mesh | compute s | memory s | coll s | "
            "bottleneck | useful | frac | mem GiB/dev | note |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for p in sorted(RESULTS.glob("*.json")):
        if p.stem.count("_") > 2 and not p.stem.endswith(("single", "multi")):
            continue  # tagged perf variants: §Perf table
        r = json.loads(p.read_text())
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                        "| — | — | — | — | — | SKIP: sub-quadratic-only |")
            continue
        if r.get("error"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                        "| — | — | — | — | — | — | — "
                        f"| ERROR: {r['error'][:60]} |")
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {_fmt(t['compute_s'], 4)} | {_fmt(t['memory_s'], 4)} "
            f"| {_fmt(t['collective_s'], 4)} | {t['bottleneck']} "
            f"| {_fmt(t['useful_flops_ratio'], 2)} "
            f"| {_fmt(t['roofline_fraction'], 3)} "
            f"| {r['memory']['peak_est_bytes'] / 2**30:.1f} | |")
    return "\n".join(rows)


def perf_markdown() -> str:
    groups: dict[str, list] = {}
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("skipped") or r.get("error") or "roofline" not in r:
            continue
        key = f"{r['arch']}:{r['shape']}:{r['mesh']}"
        tag = p.stem.replace(
            f"{r['arch']}_{r['shape']}_{r['mesh']}", "").lstrip("_") or "baseline"
        groups.setdefault(key, []).append((tag, r))
    rows = ["| cell | variant | compute s | memory s | coll s | bottleneck "
            "| frac | mem GiB | Δfrac |", "|---|---|---|---|---|---|---|---|---|"]
    for key, variants in groups.items():
        if len(variants) < 2:
            continue
        base = dict(variants)["baseline"]["roofline"]["roofline_fraction"] \
            if "baseline" in dict(variants) else None
        for tag, r in sorted(variants, key=lambda kv: kv[0] != "baseline"):
            t = r["roofline"]
            delta = ("—" if base is None or tag == "baseline"
                     else f"{t['roofline_fraction'] / base:.2f}×")
            rows.append(
                f"| {key} | {tag} | {_fmt(t['compute_s'], 3)} "
                f"| {_fmt(t['memory_s'], 3)} | {_fmt(t['collective_s'], 3)} "
                f"| {t['bottleneck']} | {_fmt(t['roofline_fraction'], 3)} "
                f"| {r['memory']['peak_est_bytes'] / 2**30:.1f} | {delta} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--inject", action="store_true")
    args = ap.parse_args()
    roof = roofline_markdown()
    perf = perf_markdown()
    if args.inject and EXP.exists():
        txt = EXP.read_text()
        txt = txt.replace("<!-- ROOFLINE_TABLE -->",
                          "<!-- ROOFLINE_TABLE -->\n\n" + roof, 1) \
            if "<!-- ROOFLINE_TABLE -->\n\n|" not in txt else txt
        txt = txt.replace("<!-- PERF_LOG -->",
                          "<!-- PERF_LOG -->\n\n" + perf, 1) \
            if "<!-- PERF_LOG -->\n\n|" not in txt else txt
        EXP.write_text(txt)
        print("injected into EXPERIMENTS.md")
    else:
        print(roof)
        print()
        print(perf)


if __name__ == "__main__":
    main()

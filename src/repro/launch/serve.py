"""Serving driver: batched prefill + greedy decode (CPU-scale demo of the
decode path that decode_32k / long_500k lower at production scale)."""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import build_model
from ..runtime import greedy_generate


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, nv, cfg.d_model))
        stot = nv + args.prompt_len
        pos = jax.numpy.broadcast_to(
            jax.numpy.arange(stot)[None], (args.batch, stot))
        batch["positions"] = jax.numpy.broadcast_to(pos[None], (3,) + pos.shape)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (args.batch, cfg.enc_len, cfg.d_model))

    extra = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    s_max = args.prompt_len + extra + args.gen + 1
    t0 = time.time()
    out = greedy_generate(model, params, batch, steps=args.gen, s_max=s_max)
    wall = time.time() - t0
    toks = int(np.prod(out.shape))
    summary = {"arch": cfg.name, "generated": toks,
               "tokens_per_s": round(toks / wall, 1),
               "wall_s": round(wall, 2),
               "out_shape": list(out.shape)}
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()

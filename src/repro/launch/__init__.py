"""Launchers: production meshes, dry-run, training and serving drivers."""
from .mesh import make_mesh_shape, make_production_mesh

__all__ = ["make_mesh_shape", "make_production_mesh"]

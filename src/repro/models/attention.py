"""Attention: GQA/MQA/MHA (+bias, sliding-window, M-RoPE), cross-attn, MLA.

All weights are flattened 2D (in, out) so sharding rules stay uniform.
``rules`` is a callable (x, logical_axes_tuple) -> x inserting sharding
constraints; the default identity is used on CPU smoke tests.

Full-sequence attention always goes through ``chunked_attention`` — an
online-softmax scan over KV chunks (flash-attention recurrence in pure
JAX). That keeps the compiled temp footprint at O(S·chunk) instead of
O(S²) for the 32k prefill shapes and mirrors `kernels/flash_attention`,
which is the TPU execution target for the same math.

Caches:
  GQA : k/v (B, S_max, KV, hd) per layer (stacked (L, ...) by the stack).
  MLA : compressed c_kv (B, S_max, kv_lora) + k_rope (B, S_max, rope_hd) —
        decode runs in the *absorbed* form entirely in compressed space
        (the DeepSeek-V3 trick; never expands the 32k cache to 128 heads).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import SpecTree, apply_rope, rms_norm

__all__ = [
    "chunked_attention",
    "attn_specs", "attn_train", "attn_decode",
    "mla_specs", "mla_train", "mla_decode",
    "cross_attn_specs", "cross_attn", "cross_kv",
]

def _ID(x, axes):
    return x
_NEG = -1e30


def chunked_attention(
    q, k, v, *, scale: float, causal: bool = True, window=None, chunk: int = 1024
):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, H, hd); k: (B, Sk, KH, hd); v: (B, Sk, KH, vh) with H = KH·g.
    ``window`` may be None (no sliding window), a static int, or a traced
    scalar (per-layer windows inside a layer scan; ≤0 means "no window").
    Returns (B, Sq, H, vh). f32 softmax state regardless of input dtype.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KH, _ = k.shape
    vh = v.shape[-1]
    g = H // KH
    chunk = min(chunk, Sk)
    pad = (-Sk) % chunk
    if pad:  # padded keys are masked out below (kj < Sk)
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Sk + pad) // chunk

    qg = q.reshape(B, Sq, KH, g, hd)
    kc = k.reshape(B, n_chunks, chunk, KH, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KH, vh).transpose(1, 0, 2, 3, 4)

    qi = jnp.arange(Sq)[:, None] + (Sk - Sq)  # absolute q positions
    m0 = jnp.full((B, KH, g, Sq), _NEG, jnp.float32)
    l0 = jnp.zeros((B, KH, g, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KH, g, vh), jnp.float32)

    def body(carry, inp):
        m, lsum, acc = carry
        c_idx, kb, vb = inp  # kb (B, chunk, KH, hd)
        kj = c_idx * chunk + jnp.arange(chunk)[None, :]
        mask = kj < Sk  # exclude pad keys
        if causal:
            mask &= kj <= qi
        if window is not None:
            w = jnp.asarray(window)
            mask &= (qi - kj < w) | (w <= 0)
        logits = jnp.einsum("bqkgh,bckh->bkgqc", qg, kb).astype(jnp.float32)
        logits = jnp.where(mask[None, None, None], logits * scale, _NEG)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        lsum = lsum * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgqc,bckv->bqkgv", p.astype(vb.dtype), vb)
        acc = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, lsum, acc), None

    xs = (jnp.arange(n_chunks), kc, vc)
    (m, lsum, acc), _ = jax.lax.scan(body, (m0, l0, acc0), xs)
    denom = jnp.maximum(lsum, 1e-30).transpose(0, 3, 1, 2)[..., None]
    out = (acc / denom).reshape(B, Sq, H, vh)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def attn_specs(spec: SpecTree, path: str, cfg):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    spec.param(path + "/wq", (d, H * hd), ("embed", "heads"))
    spec.param(path + "/wk", (d, KV * hd), ("embed", "heads"))
    spec.param(path + "/wv", (d, KV * hd), ("embed", "heads"))
    spec.param(path + "/wo", (H * hd, d), ("heads", "embed"))
    if cfg.qkv_bias:
        spec.param(path + "/bq", (H * hd,), ("heads",), init="zeros")
        spec.param(path + "/bk", (KV * hd,), ("heads",), init="zeros")
        spec.param(path + "/bv", (KV * hd,), ("heads",), init="zeros")


def _qkv(p, cfg, x):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = x @ p["wq"], x @ p["wk"], x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, KV, hd),
            v.reshape(B, S, KV, hd))


def attn_train(
    p, cfg, x, positions, *, window=None, theta=None, chunk: int = 1024, rules=_ID
):
    """Full-sequence attention (training / prefill). Returns (out, (k, v))."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    theta = cfg.rope_theta if theta is None else theta
    q, k, v = _qkv(p, cfg, x)
    if cfg.use_rope:
        q = apply_rope(q, positions, theta, cfg.mrope_sections)
        k = apply_rope(k, positions, theta, cfg.mrope_sections)
    q = rules(q, ("batch", "seq", "heads", None))
    k = rules(k, ("batch", "seq", "kv_heads", None))
    v = rules(v, ("batch", "seq", "kv_heads", None))

    ctx = chunked_attention(q, k, v, scale=1.0 / math.sqrt(hd),
                            causal=True, window=window, chunk=chunk)
    ctx = rules(ctx.reshape(B, S, H * hd), ("batch", "seq", "heads"))
    return ctx @ p["wo"], (k, v)


def _scatter_kv(cache, new, pos):
    """cache (B, S_max, ...) ← new (B, 1, ...) at per-row pos (B,)."""
    B = cache.shape[0]
    return cache.at[jnp.arange(B), pos].set(new[:, 0].astype(cache.dtype))


def attn_decode(
    p, cfg, x, pos, kv_cache, *, window=None, theta=None, rope_positions=None, rules=_ID
):
    """One-token decode. x: (B, 1, d); pos: (B,) absolute positions (cache
    write index + mask); rope_positions overrides the rotary stream (M-RoPE
    decode passes (3, B, 1)); kv_cache: (k, v) each (B, S_max, KV, hd)."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    theta = cfg.rope_theta if theta is None else theta
    k_cache, v_cache = kv_cache
    S_max = k_cache.shape[1]

    q, k_new, v_new = _qkv(p, cfg, x)
    pos_b = pos[:, None] if rope_positions is None else rope_positions
    if cfg.use_rope:
        q = apply_rope(q, pos_b, theta, cfg.mrope_sections)
        k_new = apply_rope(k_new, pos_b, theta, cfg.mrope_sections)

    k_cache = rules(_scatter_kv(k_cache, k_new, pos),
                    ("batch", "cache_seq", "kv_heads", None))
    v_cache = rules(_scatter_kv(v_cache, v_new, pos),
                    ("batch", "cache_seq", "kv_heads", None))

    g = H // KV
    qg = q.reshape(B, 1, KV, g, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg,
                        k_cache.astype(q.dtype)) / math.sqrt(hd)
    idx = jnp.arange(S_max)[None, None, None, None, :]
    m = idx <= pos[:, None, None, None, None]
    if window is not None:
        w = jnp.asarray(window)
        m &= (pos[:, None, None, None, None] - idx < w) | (w <= 0)
    attn = jax.nn.softmax(
        jnp.where(m, logits.astype(jnp.float32), _NEG), axis=-1)
    ctx = jnp.einsum("bkgqs,bskh->bqkgh", attn.astype(v_cache.dtype), v_cache)
    out = ctx.reshape(B, 1, H * hd).astype(x.dtype) @ p["wo"]
    return out, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_specs(spec: SpecTree, path: str, cfg):
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    spec.param(path + "/wq", (d, H * hd), ("embed", "heads"))
    spec.param(path + "/wk", (d, H * hd), ("embed", "heads"))
    spec.param(path + "/wv", (d, H * hd), ("embed", "heads"))
    spec.param(path + "/wo", (H * hd, d), ("heads", "embed"))


def cross_kv(p, cfg, enc_out):
    B, Se, _ = enc_out.shape
    H, hd = cfg.n_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(B, Se, H, hd)
    v = (enc_out @ p["wv"]).reshape(B, Se, H, hd)
    return k, v


def cross_attn(p, cfg, x, enc_kv, chunk: int = 1024, rules=_ID):
    """x: (B, Sq, d); enc_kv: (k, v) each (B, Se, H, hd) precomputed."""
    B, Sq, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    k, v = enc_kv
    q = (x @ p["wq"]).reshape(B, Sq, H, hd)
    ctx = chunked_attention(q, k, v, scale=1.0 / math.sqrt(hd),
                            causal=False, chunk=chunk)
    return ctx.reshape(B, Sq, H * hd) @ p["wo"]


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_specs(spec: SpecTree, path: str, cfg):
    d, H = cfg.d_model, cfg.n_heads
    nh, rh, vh = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    spec.param(path + "/wq_a", (d, cfg.q_lora_rank), ("embed", None))
    spec.param(path + "/q_norm", (cfg.q_lora_rank,), (None,), init="ones")
    spec.param(path + "/wq_b", (cfg.q_lora_rank, H * (nh + rh)),
               (None, "heads"))
    spec.param(path + "/wkv_a", (d, cfg.kv_lora_rank + rh), ("embed", None))
    spec.param(path + "/kv_norm", (cfg.kv_lora_rank,), (None,), init="ones")
    spec.param(path + "/wk_b", (cfg.kv_lora_rank, H * nh), (None, "heads"))
    spec.param(path + "/wv_b", (cfg.kv_lora_rank, H * vh), (None, "heads"))
    spec.param(path + "/wo", (H * vh, d), ("heads", "embed"))


def _mla_q(p, cfg, x, positions):
    B, S, _ = x.shape
    H, nh, rh = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    ql = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps, False)
    q = (ql @ p["wq_b"]).reshape(B, S, H, nh + rh)
    q_nope, q_rope = q[..., :nh], q[..., nh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, cfg, x, positions):
    kv = x @ p["wkv_a"]
    c_kv, k_rope = kv[..., :cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps, False)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_train(p, cfg, x, positions, chunk: int = 1024, rules=_ID):
    """Naive-expansion MLA for train/prefill. Returns (out, (c_kv, k_rope))."""
    B, S, _ = x.shape
    H, nh, rh, vh = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_ckv(p, cfg, x, positions)

    k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, nh)
    v = (c_kv @ p["wv_b"]).reshape(B, S, H, vh)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rh))],
        axis=-1)
    q = rules(q, ("batch", "seq", "heads", None))
    k = rules(k, ("batch", "seq", "heads", None))
    v = rules(v, ("batch", "seq", "heads", None))

    ctx = chunked_attention(q, k, v, scale=1.0 / math.sqrt(nh + rh),
                            causal=True, chunk=chunk)
    out = rules(ctx.reshape(B, S, H * vh), ("batch", "seq", "heads")) @ p["wo"]
    return out, (c_kv, k_rope)


def mla_decode(p, cfg, x, pos, cache, rules=_ID):
    """Absorbed-form decode: attention entirely in compressed (kv_lora) space.

    cache: (c_kv (B, S_max, kv_lora), k_rope (B, S_max, rh)).
    """
    B = x.shape[0]
    H, nh, rh, vh = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    R = cfg.kv_lora_rank
    c_cache, r_cache = cache
    S_max = c_cache.shape[1]

    q_nope, q_rope = _mla_q(p, cfg, x, pos[:, None])
    c_new, r_new = _mla_ckv(p, cfg, x, pos[:, None])

    c_cache = rules(_scatter_kv(c_cache, c_new, pos),
                    ("batch", "cache_seq", None))
    r_cache = _scatter_kv(r_cache, r_new, pos)

    # absorb W_k^b into q:  q_eff[h] = q_nope[h] @ W_k^b[h]^T  ∈ R^R
    wk = p["wk_b"].reshape(R, H, nh)
    q_eff = jnp.einsum("bqhn,rhn->bqhr", q_nope, wk)  # (B,1,H,R)

    scale = 1.0 / math.sqrt(nh + rh)
    logits = (jnp.einsum("bqhr,bsr->bhqs", q_eff, c_cache.astype(q_eff.dtype))
              + jnp.einsum("bqhp,bsp->bhqs", q_rope,
                           r_cache.astype(q_rope.dtype))) * scale
    idx = jnp.arange(S_max)[None, None, None, :]
    attn = jax.nn.softmax(
        jnp.where(idx <= pos[:, None, None, None],
                  logits.astype(jnp.float32), _NEG), axis=-1)

    ctx = jnp.einsum("bhqs,bsr->bqhr", attn.astype(c_cache.dtype), c_cache)
    wv = p["wv_b"].reshape(R, H, vh)
    o = jnp.einsum("bqhr,rhv->bqhv", ctx.astype(x.dtype), wv)
    return o.reshape(B, 1, H * vh) @ p["wo"], (c_cache, r_cache)

"""Parameter-spec system and basic layers (norm, rope, MLP, embeddings).

Params are nested dicts of arrays. Every leaf is declared through a
``SpecTree`` so three things derive from one source of truth:
  * ``init_params``      — materialized random init (reduced/smoke configs),
  * ``abstract_params``  — ShapeDtypeStructs (dry-run; no allocation),
  * ``param_axes``       — logical-axis names per dim, consumed by
                           runtime/sharding.py to build NamedShardings.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "SpecTree", "init_params", "abstract_params", "param_axes",
    "rms_norm", "layer_norm", "rope_freqs", "apply_rope", "mlp_apply",
    "mlp_specs", "norm_specs", "DTYPES",
]

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
          "float16": jnp.float16}


class SpecTree:
    """Collects parameter declarations as a nested dict of leaf specs."""

    def __init__(self, dtype: str = "float32"):
        self.tree: dict[str, Any] = {}
        self.dtype = dtype

    def param(
        self,
        path: str,
        shape: tuple[int, ...],
        axes: tuple,
        init: str = "fan_in",
        scale: float | None = None,
    ):
        """Declare a leaf at 'a/b/c'. axes has one logical name (or None)
        per dim. init ∈ {fan_in, zeros, ones, normal}."""
        assert len(shape) == len(axes), (path, shape, axes)
        node = self.tree
        parts = path.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        assert parts[-1] not in node, f"duplicate param {path}"
        node[parts[-1]] = {"shape": tuple(int(s) for s in shape), "axes": axes,
                           "init": init, "scale": scale, "dtype": self.dtype,
                           "__leaf__": True}

    def subtree(self, path: str, other: "SpecTree"):
        """Mount another SpecTree under a path prefix."""
        node = self.tree
        for p in path.split("/"):
            node = node.setdefault(p, {})
        node.update(other.tree)


def _is_leaf(n) -> bool:
    return isinstance(n, dict) and n.get("__leaf__", False)


def _map_specs(tree, fn):
    if _is_leaf(tree):
        return fn(tree)
    return {k: _map_specs(v, fn) for k, v in tree.items()}


def _leaves(tree, prefix=()):
    if _is_leaf(tree):
        yield prefix, tree
        return
    for k, v in tree.items():
        yield from _leaves(v, prefix + (k,))


def init_params(spec: SpecTree, key) -> dict:
    """Materialize with deterministic per-leaf keys (order-independent)."""
    leaves = sorted(_leaves(spec.tree), key=lambda kv: kv[0])
    keys = jax.random.split(key, max(len(leaves), 1))
    out = {}
    for (path, leaf), k in zip(leaves, keys):
        shape, dtype = leaf["shape"], DTYPES[leaf["dtype"]]
        kind = leaf["init"]
        if kind == "zeros":
            arr = jnp.zeros(shape, dtype)
        elif kind == "ones":
            arr = jnp.ones(shape, dtype)
        elif kind == "normal":
            arr = (jax.random.normal(k, shape, jnp.float32)
                   * (leaf["scale"] or 0.02)).astype(dtype)
        else:  # fan_in
            fan = shape[-2] if len(shape) >= 2 else shape[-1]
            std = leaf["scale"] or (1.0 / math.sqrt(max(fan, 1)))
            arr = (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = arr
    return out


def abstract_params(spec: SpecTree) -> dict:
    return _map_specs(
        spec.tree,
        lambda leaf: jax.ShapeDtypeStruct(leaf["shape"], DTYPES[leaf["dtype"]]))


def param_axes(spec: SpecTree) -> dict:
    return _map_specs(spec.tree, lambda leaf: leaf["axes"])


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_specs(spec: SpecTree, path: str, d: int, plus_one: bool):
    spec.param(path + "/w", (d,), (None,),
               init="zeros" if plus_one else "ones")


def rms_norm(x, w, eps: float, plus_one: bool):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    scale = (1.0 + w.astype(jnp.float32)) if plus_one else w.astype(jnp.float32)
    return (x * scale).astype(dt)


def layer_norm(x, w, b, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta) -> jnp.ndarray:
    """theta may be a static float or a traced scalar (per-layer gemma3)."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return jnp.asarray(theta, jnp.float32) ** (-exponents)


def apply_rope(x, positions, theta, mrope_sections: tuple[int, int, int] | None = None):
    """x: (B, S, H, hd); positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (qwen2-vl): the hd/2 frequency pairs are split into (t, h, w)
    sections; each section rotates by its own position stream. Text-only
    inputs pass identical streams, reducing to standard RoPE.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    if mrope_sections is None:
        if positions.ndim == 3:
            positions = positions[0]
        angles = positions[..., None].astype(jnp.float32) * freqs  # (B,S,hd/2)
    else:
        assert positions.ndim == 3, "M-RoPE needs (3, B, S) positions"
        sec = mrope_sections
        assert sum(sec) == hd // 2, (sec, hd)
        parts = []
        start = 0
        for i, n in enumerate(sec):
            f = freqs[start:start + n]
            parts.append(positions[i][..., None].astype(jnp.float32) * f)
            start += n
        angles = jnp.concatenate(parts, axis=-1)  # (B,S,hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain GELU)
# ---------------------------------------------------------------------------

def mlp_specs(spec: SpecTree, path: str, d: int, d_ff: int, activation: str):
    if activation in ("swiglu", "geglu"):
        spec.param(path + "/w_gate", (d, d_ff), ("embed", "mlp"))
        spec.param(path + "/w_up", (d, d_ff), ("embed", "mlp"))
    else:
        spec.param(path + "/w_up", (d, d_ff), ("embed", "mlp"))
    spec.param(path + "/w_down", (d_ff, d), ("mlp", "embed"))


def mlp_apply(p, x, activation: str):
    if activation == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif activation == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    return h @ p["w_down"]

"""Pure-JAX model substrate for the assigned architectures."""
from .transformer import Model, build_model
from .layers import SpecTree, abstract_params, init_params, param_axes

__all__ = ["Model", "build_model", "SpecTree", "abstract_params",
           "init_params", "param_axes"]

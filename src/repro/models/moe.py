"""Mixture-of-Experts with grouped, gather-based, capacity-limited dispatch.

Design notes (TPU adaptation, measured on the 512-device dry-run):
  * A one-hot dispatch einsum (naive GShard) makes XLA count dense
    all-expert FLOPs — wrecks MODEL_FLOPS/HLO_FLOPS.
  * A GLOBAL-index gather (jnp.take over all T tokens) makes GSPMD
    all-gather the full (T, d) token tensor per layer — measured 24 GiB
    all-gather + 24 GiB all-reduce per MoE layer on dbrx.
  * The fix is GShard's *group* dimension: tokens reshape to (G, T/G, d)
    with G aligned to the data shards; expert-choice top-C runs within each
    group, so dispatch gathers/scatters are shard-LOCAL and the only
    cross-device traffic is the canonical (G → E) all-to-all on the
    (G, E, C, d) dispatched block — exactly production MoE behaviour.

Router math in f32. DeepSeek-V3's sigmoid bias-free balancing is simplified
to softmax top-k + renormalization + the switch aux loss (documented
deviation — the assignment pins the architecture shape, not router math).
Tokens overflowing an expert's per-group capacity are dropped (standard
capacity-factor semantics) and still flow through the shared expert.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import SpecTree, mlp_apply, mlp_specs

__all__ = ["moe_specs", "moe_apply"]

def _ID(x, axes):
    return x


def moe_specs(spec: SpecTree, path: str, cfg):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    spec.param(path + "/router", (d, E), ("embed", "expert"))
    spec.param(path + "/w_gate", (E, d, f), ("expert", "embed", "mlp"))
    spec.param(path + "/w_up", (E, d, f), ("expert", "embed", "mlp"))
    spec.param(path + "/w_down", (E, f, d), ("expert", "mlp", "embed"))
    if cfg.n_shared_experts > 0:
        mlp_specs(spec, path + "/shared", d,
                  cfg.n_shared_experts * f, "swiglu")


def _n_groups(T: int, want: int = 32) -> int:
    g = min(want, T)
    while T % g:
        g -= 1
    return max(g, 1)


def moe_apply(p, cfg, x, rules=_ID):
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = _n_groups(T)
    Tg = T // G

    # flatten into dispatch groups with a PURE batch sharding (reshaping a
    # (batch→data, seq→model)-sharded residual would force a repartition)
    x = rules(x, ("batch", None, None))
    xg = rules(x.reshape(G, Tg, d), ("moe_group", None, None))

    # GSPMD drops shardings through sort/top_k — every router tensor is
    # pinned to the group axis or its f32 backward replicates (G, Tg, d).
    gte = ("moe_group", None, "expert")
    logits = rules(jnp.einsum("gtd,de->gte", xg,
                              p["router"]).astype(jnp.float32), gte)
    probs = rules(jax.nn.softmax(logits, axis=-1), gte)  # (G, Tg, E)
    top_w, top_i = jax.lax.top_k(probs, k)  # (G, Tg, k)
    top_w = rules(top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9),
                  ("moe_group", None, None))
    top_i = rules(top_i, ("moe_group", None, None))

    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)  # (G, Tg, k, E)
    w_te = rules(jnp.einsum("gtke,gtk->gte", onehot, top_w), gte)

    # per-(group, expert) top-C tokens ("expert choice" within the top-k mask)
    C = max(1, int(math.ceil(Tg * k / E * cfg.capacity_factor)))
    C = min(C, Tg)
    gate, idx = jax.lax.top_k(w_te.transpose(0, 2, 1), C)  # (G, E, C)
    gate = rules(gate, ("moe_group", "expert", None))
    idx = rules(idx, ("moe_group", "expert", None))

    # dispatch: gather SHARD-LOCALLY (expert dim local per group shard),
    # THEN reshard expert→model — GSPMD emits the canonical G→E all-to-all.
    # Scattering/gathering while E is model-sharded instead makes GSPMD
    # all-reduce the full f32 (G,Tg,d) per layer (measured 24 GiB/op).
    idx_local = rules(idx, ("moe_group", None, None))
    xe = jnp.take_along_axis(xg[:, None, :, :], idx_local[..., None], axis=2)
    xe = rules(xe, ("moe_group", None, None, None))  # local gather
    xe = rules(xe, ("moe_group", "expert", None, None))  # all-to-all

    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
         * jnp.einsum("gecd,edf->gecf", xe, p["w_up"]))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ye = ye * gate[..., None].astype(ye.dtype)  # dropped ⇒ gate 0
    ye = rules(ye, ("moe_group", "expert", None, None))

    # combine (§Perf P5): the scatter SUMS over experts, so two layouts:
    #   scatter_ar — scatter expert-sharded partials, all-reduce (G,Tg,d)
    #                over the expert axis (wire ≈ 2·Tg·d; wins at E/k≫2)
    #   gather     — reshard ye expert-unsharded first, scatter locally
    #                (wire ≈ k·Tg·d; wins for small E/k — GSPMD also
    #                partitions this scatter more reliably)
    if cfg.moe_combine != "scatter_ar":
        ye = rules(ye, ("moe_group", None, None, None))
    out = jnp.zeros((G, Tg, d), ye.dtype).at[
        jnp.arange(G)[:, None, None], idx_local].add(ye)
    out = rules(out, ("moe_group", None, None))
    outf = out.reshape(T, d)

    if cfg.n_shared_experts > 0:
        outf = outf + mlp_apply(p["shared"], xg.reshape(T, d), "swiglu")

    # switch-style load-balancing aux: E · Σ_e fraction_e · router_prob_e
    frac = jnp.mean(w_te > 0, axis=(0, 1))
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * pmean)
    return outf.reshape(B, S, d), aux

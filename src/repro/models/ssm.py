"""Mamba2 (SSD — state-space duality) block: chunked train/prefill scan and
O(1)-state decode.

Shapes follow the Mamba2 convention: d_inner = expand·d_model, H heads of
size P = ssm_head_dim, state size N = ssm_state, n_groups = 1 (B/C shared
across heads). The chunked algorithm (chunk Q):

  intra-chunk (quadratic within Q):
      Y_intra[i] = Σ_{j≤i} (C_i·B_j) · exp(cum_i − cum_j) · dt_j · x_j
  chunk states: S_c = Σ_j exp(cum_last − cum_j) · dt_j · B_j ⊗ x_j
  inter-chunk recurrence (lax.scan over chunks):
      S←exp(cum_last)·S_prev + S_c;  Y_inter[i] = exp(cum_i) · C_i · S_prev

This mirrors `kernels/ssd` (the TPU Pallas target, validated against the
pure-jnp math here). Decode keeps (ssm_state (B,H,P,N), conv_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import SpecTree, rms_norm

__all__ = ["ssm_specs", "mamba_train", "mamba_decode", "ssd_chunked",
           "conv_dim"]

def _ID(x, axes):
    return x


def conv_dim(cfg) -> int:
    """channels that pass through the causal depthwise conv: x ++ B ++ C."""
    return cfg.d_inner + 2 * cfg.ssm_state  # n_groups = 1


def ssm_specs(spec: SpecTree, path: str, cfg):
    d, di, H, N = cfg.d_model, cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_state
    spec.param(path + "/wz", (d, di), ("embed", "heads"))
    spec.param(path + "/wxbc", (d, conv_dim(cfg)), ("embed", "heads"))
    spec.param(path + "/wdt", (d, H), ("embed", None))
    spec.param(path + "/dt_bias", (H,), (None,), init="zeros")
    spec.param(path + "/A_log", (H,), (None,), init="zeros")
    spec.param(path + "/D", (H,), (None,), init="ones")
    spec.param(path + "/conv_w", (cfg.conv_width, conv_dim(cfg)),
               (None, "heads"), init="normal", scale=0.1)
    spec.param(path + "/conv_b", (conv_dim(cfg),), ("heads",), init="zeros")
    spec.param(path + "/gate_norm", (di,), ("heads",), init="ones")
    spec.param(path + "/wo", (di, d), ("heads", "embed"))


def _causal_conv(xbc, w, b):
    """Depthwise causal conv. xbc: (B, S, Ch); w: (W, Ch)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, A, B_, C_, chunk: int, unroll: bool = False):
    """Chunked SSD scan.

    x: (B,S,H,P) values; dt: (B,S,H) post-softplus; A: (H,) negative;
    B_, C_: (B,S,N). Returns (y: (B,S,H,P), final_state: (B,H,N,P))
    (no D skip / gate). ``unroll`` unrolls the inter-chunk scan (cost
    compiles — launch/cost_model.py).
    """
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, S)
    # pad to a chunk multiple: dt=0 steps are exact no-ops (no decay, no
    # state update, zero output weight), so padding preserves the final state
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    xr = x.reshape(Bb, nc, Q, H, P)
    dtr = dt.reshape(Bb, nc, Q, H)
    Br = B_.reshape(Bb, nc, Q, N)
    Cr = C_.reshape(Bb, nc, Q, N)

    dA = dtr * A[None, None, None, :]  # (B,nc,Q,H) ≤ 0
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumsum

    # ---- intra-chunk (quadratic in Q) ----
    # decay(i,j) = exp(cum_i - cum_j) for i ≥ j else 0
    ii = jnp.arange(Q)[:, None]
    jj = jnp.arange(Q)[None, :]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q_i,Q_j,H)
    decay = jnp.where((ii >= jj)[None, None, :, :, None],
                      jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cr, Br)  # (B,nc,Q,Q)
    M = cb[..., None] * decay * dtr[:, :, None, :, :]  # (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xr)

    # ---- chunk summaries ----
    last = cum[:, :, -1:, :]  # (B,nc,1,H)
    wj = jnp.exp(last - cum) * dtr  # (B,nc,Q,H)
    S_c = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", wj, Br, xr)  # (B,nc,H,N,P)

    # ---- inter-chunk recurrence ----
    def body(S_prev, inp):
        S_chunk, decay_last = inp  # (B,H,N,P), (B,H)
        S_new = S_prev * jnp.exp(decay_last)[:, :, None, None] + S_chunk
        return S_new, S_prev

    S0 = jnp.zeros((Bb, H, N, P), x.dtype)
    xs = (S_c.transpose(1, 0, 2, 3, 4), last[:, :, 0, :].transpose(1, 0, 2))
    S_final, S_prevs = jax.lax.scan(body, S0, xs,
                                    unroll=True if unroll else 1)  # (nc,...)
    S_prevs = S_prevs.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcqn,bchnp->bcqhp",
                         Cr, S_prevs) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bb, Sp, H, P)[:, :S]
    return y, S_final


def mamba_train(
    p, cfg, x, chunk: int | None = None, return_state: bool = False, rules=_ID
):
    """Full-sequence Mamba2 block. x: (B,S,d) -> (y, final_state).

    final_state (when requested) is a dict {"ssm": (B,H,P,N), "conv":
    (B, W-1, conv_dim)} — exactly the decode-step carry, so prefill can hand
    off to `mamba_decode`.
    """
    B, S, d = x.shape
    di, H, P, N = cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    chunk = chunk or cfg.ssm_chunk

    z = x @ p["wz"]  # (B,S,di)
    xbc_raw = x @ p["wxbc"]
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs, B_, C_ = jnp.split(xbc, [di, di + N], axis=-1)
    xs = rules(xs, ("batch", "seq", "heads"))
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs.reshape(B, S, H, P)
    y, S_final = ssd_chunked(xh.astype(jnp.float32), dt, A,
                             B_.astype(jnp.float32), C_.astype(jnp.float32),
                             chunk, unroll=cfg.unroll_scans)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps, False)
    out = y @ p["wo"]
    if not return_state:
        return out, None
    W = cfg.conv_width
    state = {
        "ssm": S_final.transpose(0, 1, 3, 2).astype(x.dtype),  # (B,H,P,N)
        "conv": xbc_raw[:, S - (W - 1):, :].astype(x.dtype),
    }
    return out, state


def mamba_decode(p, cfg, x, state, rules=_ID):
    """One-token step. x: (B,1,d); state: {"ssm": (B,H,P,N),
    "conv": (B, W-1, conv_dim)}. Returns (y, new_state)."""
    B = x.shape[0]
    di, H, P, N = cfg.d_inner, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    W = cfg.conv_width

    z = x @ p["wz"]
    xbc_new = (x @ p["wxbc"])[:, 0, :]  # (B, Ch)
    conv_in = jnp.concatenate([state["conv"], xbc_new[:, None, :]], axis=1)
    w = p["conv_w"]
    out = sum(conv_in[:, i, :] * w[i] for i in range(W)) + p["conv_b"]
    xbc = jax.nn.silu(out)  # (B, Ch)
    new_conv = conv_in[:, 1:, :]

    xs, B_, C_ = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(
        (x[:, 0, :] @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None, :])  # (B,H)

    xh = xs.reshape(B, H, P).astype(jnp.float32)
    ssm = state["ssm"].astype(jnp.float32)
    upd = ((dt[:, :, None] * xh)[:, :, :, None]
           * B_[:, None, None, :].astype(jnp.float32))
    ssm_new = ssm * dA[:, :, None, None] + upd  # (B,H,P,N)
    y = jnp.einsum("bhpn,bn->bhp", ssm_new, C_.astype(jnp.float32))
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)

    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps, False)
    return y @ p["wo"], {"ssm": ssm_new.astype(state["ssm"].dtype),
                         "conv": new_conv}

"""Model stacks for all assigned families + the unified Model facade.

Every homogeneous run of layers is a ``lax.scan`` over stacked parameters
(leading "layers" dim), keeping HLO size O(1) in depth — essential for the
512-device dry-run compiles. Heterogeneous patterns decompose into scans:

  dense/moe/vlm : one scan over L blocks (deepseek: 3 dense + 58 moe scans)
  gemma3        : one scan with per-layer (window, theta) arrays as scan xs
  ssm           : one scan over L mamba blocks
  hybrid zamba2 : outer scan over 13 groups of [5 stacked mamba + one
                  SHARED attention block (params outside the scan — weight
                  sharing is zamba2's hallmark)] + a 3-layer mamba tail
  encdec whisper: encoder scan + decoder scan (self + cross attention)

``rules(x, logical_axes)`` inserts sharding constraints; identity on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

import math

from .attention import (_qkv, attn_decode, attn_specs, attn_train,
                        chunked_attention, cross_attn, cross_attn_specs,
                        cross_kv, mla_decode, mla_specs, mla_train)
from .layers import (DTYPES, SpecTree, abstract_params, init_params,
                     layer_norm, mlp_apply, mlp_specs, norm_specs,
                     param_axes, rms_norm)
from .moe import moe_apply, moe_specs
from .ssm import conv_dim, mamba_decode, mamba_train, ssm_specs

def _ID(x, axes):
    return x


def _cfg_scan(cfg, body, init, xs):
    """lax.scan that fully unrolls under cfg.unroll_scans (cost compiles)."""
    return jax.lax.scan(body, init, xs,
                        unroll=True if cfg.unroll_scans else 1)

REMAT_POLICIES = {
    "full": None,  # save nothing
    "dots": "dots_with_no_batch_dims_saveable",
    "none": "everything_saveable",
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    policy = getattr(jax.checkpoint_policies, REMAT_POLICIES[remat])
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# spec stacking
# ---------------------------------------------------------------------------

def stack_specs(spec: SpecTree, path: str, n: int, build: Callable[[SpecTree], None]):
    """Build a one-layer spec and lift every leaf to (n, ...) + 'layers' axis."""
    sub = SpecTree(spec.dtype)
    build(sub)

    def lift(node):
        if isinstance(node, dict) and node.get("__leaf__", False):
            out = dict(node)
            out["shape"] = (n,) + node["shape"]
            out["axes"] = ("layers",) + tuple(node["axes"])
            return out
        return {k: lift(v) for k, v in node.items()}

    lifted = lift(sub.tree)
    host = spec.tree
    for p in path.split("/"):
        host = host.setdefault(p, {})
    host.update(lifted)


# ---------------------------------------------------------------------------
# per-family block bodies
# ---------------------------------------------------------------------------

def _norm(p, cfg, x):
    return rms_norm(x, p["w"], cfg.norm_eps, cfg.norm_plus_one)


def _dense_block_specs(cfg, moe: bool):
    def build(s):
        norm_specs(s, "ln1", cfg.d_model, cfg.norm_plus_one)
        if cfg.mla:
            mla_specs(s, "attn", cfg)
        else:
            attn_specs(s, "attn", cfg)
        norm_specs(s, "ln2", cfg.d_model, cfg.norm_plus_one)
        if moe:
            moe_specs(s, "moe", cfg)
        else:
            mlp_specs(s, "mlp", cfg.d_model, cfg.d_ff, cfg.activation)
    return build


def _dense_block_train(p, cfg, h, positions, window, theta, moe: bool, rules):
    x = _norm(p["ln1"], cfg, h)
    if cfg.mla:
        a, kv = mla_train(p["attn"], cfg, x, positions,
                          chunk=cfg.attn_chunk, rules=rules)
    else:
        a, kv = attn_train(p["attn"], cfg, x, positions, window=window,
                           theta=theta, chunk=cfg.attn_chunk, rules=rules)
    h = h + a
    x = _norm(p["ln2"], cfg, h)
    if moe:
        f, aux = moe_apply(p["moe"], cfg, x, rules=rules)
    else:
        f, aux = mlp_apply(p["mlp"], x, cfg.activation), jnp.float32(0)
    h = rules(h + f, ("batch", "seq_sp", None))
    return h, kv, aux


def _dense_block_decode(
    p, cfg, h, pos, cache, window, theta, moe: bool, rules, rope_positions=None
):
    x = _norm(p["ln1"], cfg, h)
    if cfg.mla:
        a, cache = mla_decode(p["attn"], cfg, x, pos, cache, rules=rules)
    else:
        a, cache = attn_decode(p["attn"], cfg, x, pos, cache, window=window,
                               theta=theta, rope_positions=rope_positions,
                               rules=rules)
    h = h + a
    x = _norm(p["ln2"], cfg, h)
    if moe:
        f, _ = moe_apply(p["moe"], cfg, x, rules=rules)
    else:
        f = mlp_apply(p["mlp"], x, cfg.activation)
    return h + f, cache


def _layer_pattern(cfg, n_layers: int):
    """(window, theta) arrays for gemma3-style local:global patterns."""
    if cfg.global_every <= 0:
        return None, None
    is_global = (np.arange(n_layers) % cfg.global_every) == (cfg.global_every - 1)
    window = np.where(is_global, 0, cfg.window).astype(np.int32)
    theta = np.where(is_global, 1_000_000.0, cfg.rope_theta).astype(np.float32)
    return jnp.asarray(window), jnp.asarray(theta)


# ---------------------------------------------------------------------------
# the Model facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class Model:
    config: Any
    spec: SpecTree
    loss: Callable  # (params, batch, rules=, remat=) -> (loss, metrics)
    prefill: Callable  # (params, batch, rules=) -> (last_logits, cache)
    decode: Callable  # (params, batch, rules=) -> (logits, cache)
    cache_spec: Callable  # (batch_size, s_max) -> (ShapeDtypeStruct tree, axes tree)

    def init(self, key):
        return init_params(self.spec, key)

    def abstract(self):
        return abstract_params(self.spec)

    def axes(self):
        return param_axes(self.spec)


def build_model(cfg) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return _build_decoder_lm(cfg)
    if cfg.family == "ssm":
        return _build_ssm_lm(cfg)
    if cfg.family == "hybrid":
        return _build_hybrid_lm(cfg)
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# decoder-only LM (dense / moe / vlm)
# ---------------------------------------------------------------------------

def _lm_head_specs(spec: SpecTree, cfg):
    spec.param("embed", (cfg.vocab, cfg.d_model), ("vocab", "embed"),
               init="normal")
    norm_specs(spec, "final_norm", cfg.d_model, cfg.norm_plus_one)
    if not cfg.tie_embeddings:
        spec.param("head", (cfg.d_model, cfg.vocab), ("embed", "vocab"))


def _logits(params, cfg, h, rules):
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (h @ head).astype(jnp.float32)
    return rules(logits, ("batch", "seq_sp", "vocab"))


def _xent(logits, labels, mask=None):
    """mean token cross-entropy in f32. labels: (B, S) int32."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean(), nll.size
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0), mask.sum()


def _ce_from_hidden(params, cfg, h, labels, rules):
    """Cross-entropy with seq-chunked logits (never materializes the full
    (B, S, vocab) tensor — decisive for the 256k-vocab archs). The chunk
    body is checkpointed so backward recomputes its logits."""
    B, S, _ = h.shape
    chunk = cfg.xent_chunk
    if chunk <= 0 or S <= chunk:
        logits = _logits(params, cfg, h, rules)
        return _xent(logits, labels)

    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    valid = (jnp.arange(S + pad) < S)
    nc = (S + pad) // chunk
    hc = h.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)
    vc = valid.reshape(nc, chunk)

    @jax.checkpoint
    def body(carry, inp):
        hs, ls, vs = inp
        logits = _logits(params, cfg, hs, rules)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        nll = jnp.where(vs[None, :], lse - gold, 0.0)
        return carry + nll.sum(), None

    total, _ = _cfg_scan(cfg, body, jnp.float32(0), (hc, lc, vc))
    n = jnp.float32(B * S)
    return total / n, n


def _split_layers(cfg):
    """deepseek: first `moe_layer_start` layers dense, remainder MoE."""
    if cfg.n_experts > 0:
        n_dense = cfg.moe_layer_start
        return n_dense, cfg.n_layers - n_dense
    return cfg.n_layers, 0


def _build_decoder_lm(cfg):
    n_dense, n_moe = _split_layers(cfg)
    spec = SpecTree(cfg.param_dtype)
    _lm_head_specs(spec, cfg)
    if n_dense:
        stack_specs(spec, "blocks", n_dense, _dense_block_specs(cfg, moe=False))
    if n_moe:
        stack_specs(spec, "moe_blocks", n_moe, _dense_block_specs(cfg, moe=True))
    if cfg.mtp:
        spec.param("mtp/proj", (2 * cfg.d_model, cfg.d_model),
                   ("embed", "embed2"))
        norm_specs(spec, "mtp/norm_h", cfg.d_model, cfg.norm_plus_one)
        norm_specs(spec, "mtp/norm_e", cfg.d_model, cfg.norm_plus_one)
        _dense_block_specs(cfg, moe=False)(_mtp_sub := SpecTree(cfg.param_dtype))
        spec.subtree("mtp/block", _mtp_sub)

    wpat, tpat = _layer_pattern(cfg, n_dense)  # moe archs here are uniform

    def embed_input(params, batch, S_expected):
        """tokens (+ optional patch embeds for vlm) -> (h, positions, text_mask)."""
        cdt = DTYPES[cfg.compute_dtype]
        tok_emb = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        if cfg.embed_scale:
            tok_emb = tok_emb * jnp.sqrt(jnp.float32(cfg.d_model)).astype(cdt)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            h = jnp.concatenate(
                [batch["patch_embeds"].astype(cdt), tok_emb], axis=1)
        else:
            h = tok_emb
        B, S, _ = h.shape
        if "positions" in batch:
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        return h, positions

    def run_stack(params, h, positions, rules, remat, collect_cache=False):
        auxes = []
        caches = {}

        def scan_blocks(name, stacked, moe, wpat_, tpat_):
            def body(carry, xs):
                h = carry
                if wpat_ is not None:
                    lp, w, th = xs
                else:
                    lp, w, th = xs, None, None
                h, kv, aux = _dense_block_train(
                    lp, cfg, h, positions, w, th, moe, rules)
                return h, (kv, aux) if collect_cache else (None, aux)

            body = _maybe_remat(body, remat)
            xs = (stacked, wpat_, tpat_) if wpat_ is not None else stacked
            h2, (kv, aux) = _cfg_scan(cfg, body, h, xs)
            return h2, kv, aux

        if n_dense:
            h, kv, aux = scan_blocks("blocks", params["blocks"], False, wpat, tpat)
            auxes.append(aux.sum())
            if collect_cache:
                caches["dense"] = kv
        if n_moe:
            h, kv, aux = scan_blocks("moe_blocks", params["moe_blocks"], True,
                                     None, None)
            auxes.append(aux.sum())
            if collect_cache:
                caches["moe"] = kv
        h = _norm(params["final_norm"], cfg, h)
        return h, sum(auxes), caches

    def loss(params, batch, rules=_ID, remat="full"):
        tokens = batch["tokens"]  # (B, S_text+1)
        inputs = {**batch, "tokens": tokens[:, :-1]}
        labels = tokens[:, 1:]
        h, positions = embed_input(params, inputs, None)
        h, aux, _ = run_stack(params, h, positions, rules, remat)
        n_vis = h.shape[1] - labels.shape[1]
        ce, ntok = _ce_from_hidden(params, cfg, h[:, n_vis:], labels, rules)
        total = ce + 0.01 * aux
        metrics = {"ce": ce, "aux": aux, "ntok": ntok}
        if cfg.mtp:
            mtp_loss = _mtp_loss(params, cfg, h[:, n_vis:], tokens, rules)
            total = total + 0.3 * mtp_loss
            metrics["mtp"] = mtp_loss
        return total, metrics

    def _mtp_loss(params, cfg_, h, tokens, rules):
        # h at position i encodes prefix ..t_i; combine with emb(t_{i+1})
        # to predict t_{i+2} (one-depth MTP, DeepSeek-V3 style).
        cdt = DTYPES[cfg_.compute_dtype]
        emb_next = jnp.take(params["embed"], tokens[:, 1:-1], axis=0).astype(cdt)
        hh = _norm(params["mtp"]["norm_h"], cfg_, h[:, :-1])
        ee = _norm(params["mtp"]["norm_e"], cfg_, emb_next)
        hm = jnp.concatenate([hh, ee], axis=-1) @ params["mtp"]["proj"]
        B, S, _ = hm.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        hm = _dense_block_train(
            params["mtp"]["block"], cfg_, hm, positions, None, None, False,
            rules)[0]
        mtp, _ = _ce_from_hidden(params, cfg_, hm, tokens[:, 2:], rules)
        return mtp

    def prefill(params, batch, rules=_ID):
        h, positions = embed_input(params, batch, None)
        h, _, caches = run_stack(params, h, positions, rules, "none",
                                 collect_cache=True)
        logits = _logits(params, cfg, h[:, -1:], rules)[:, 0]
        return logits, caches

    def decode(params, batch, rules=_ID):
        cache, pos = batch["cache"], batch["pos"]
        rope_positions = batch.get("positions")  # (3, B, 1) for M-RoPE
        cdt = DTYPES[cfg.compute_dtype]
        h = jnp.take(params["embed"], batch["token"], axis=0).astype(cdt)
        if cfg.embed_scale:
            h = h * jnp.sqrt(jnp.float32(cfg.d_model)).astype(cdt)

        def scan_blocks(stacked, layer_cache, moe, wpat_, tpat_):
            def body(h, xs):
                if wpat_ is not None:
                    lp, lc, w, th = xs
                else:
                    lp, lc = xs[0], xs[1]
                    w, th = None, None
                h, lc = _dense_block_decode(lp, cfg, h, pos, lc, w, th, moe,
                                            rules, rope_positions=rope_positions)
                return h, lc

            xs = ((stacked, layer_cache, wpat_, tpat_) if wpat_ is not None
                  else (stacked, layer_cache))
            return _cfg_scan(cfg, body, h, xs)

        new_cache = {}
        if n_dense:
            h, kv = scan_blocks(params["blocks"], cache["dense"], False,
                                wpat, tpat)
            new_cache["dense"] = kv
        if n_moe:
            h, kv = scan_blocks(params["moe_blocks"], cache["moe"], True,
                                None, None)
            new_cache["moe"] = kv
        h = _norm(params["final_norm"], cfg, h)
        logits = _logits(params, cfg, h, rules)[:, 0]
        return logits, new_cache

    def cache_spec(B, s_max):
        cdt = DTYPES[cfg.compute_dtype]
        def kv(n):
            if cfg.mla:
                c = jax.ShapeDtypeStruct((n, B, s_max, cfg.kv_lora_rank), cdt)
                r = jax.ShapeDtypeStruct((n, B, s_max, cfg.rope_head_dim), cdt)
                return ((c, r),
                        (("layers", "batch", "cache_seq", None),
                         ("layers", "batch", "cache_seq", None)))
            k = jax.ShapeDtypeStruct(
                (n, B, s_max, cfg.n_kv_heads, cfg.head_dim), cdt)
            ax = ("layers", "batch", "cache_seq", "kv_heads", None)
            return (k, k), (ax, ax)

        tree, axes = {}, {}
        if n_dense:
            tree["dense"], axes["dense"] = kv(n_dense)
        if n_moe:
            tree["moe"], axes["moe"] = kv(n_moe)
        return tree, axes

    return Model(cfg, spec, loss, prefill, decode, cache_spec)


# ---------------------------------------------------------------------------
# attention-free SSM LM (mamba2)
# ---------------------------------------------------------------------------

def _ssm_block_specs(cfg):
    def build(s):
        norm_specs(s, "ln", cfg.d_model, cfg.norm_plus_one)
        ssm_specs(s, "mixer", cfg)
    return build


def _build_ssm_lm(cfg):
    spec = SpecTree(cfg.param_dtype)
    _lm_head_specs(spec, cfg)
    stack_specs(spec, "blocks", cfg.n_layers, _ssm_block_specs(cfg))

    def run(params, h, rules, remat):
        def body(h, lp):
            y, _ = mamba_train(lp["mixer"], cfg, _norm(lp["ln"], cfg, h),
                               rules=rules)
            return rules(h + y, ("batch", "seq_sp", None)), None
        body = _maybe_remat(body, remat)
        h, _ = _cfg_scan(cfg, body, h, params["blocks"])
        return _norm(params["final_norm"], cfg, h)

    def loss(params, batch, rules=_ID, remat="full"):
        tokens = batch["tokens"]
        cdt = DTYPES[cfg.compute_dtype]
        h = jnp.take(params["embed"], tokens[:, :-1], axis=0).astype(cdt)
        h = run(params, h, rules, remat)
        ce, ntok = _ce_from_hidden(params, cfg, h, tokens[:, 1:], rules)
        return ce, {"ce": ce, "ntok": ntok}

    def prefill(params, batch, rules=_ID):
        """Chunked-scan prefill; the 'cache' is the final recurrent state."""
        tokens = batch["tokens"]
        cdt = DTYPES[cfg.compute_dtype]
        h = jnp.take(params["embed"], tokens, axis=0).astype(cdt)

        def body(h, lp):
            y, st = mamba_train(lp["mixer"], cfg, _norm(lp["ln"], cfg, h),
                                return_state=True, rules=rules)
            return rules(h + y, ("batch", "seq_sp", None)), (st["ssm"],
                                                             st["conv"])

        h, (ssm, conv) = _cfg_scan(cfg, body, h, params["blocks"])
        h = _norm(params["final_norm"], cfg, h)
        logits = _logits(params, cfg, h[:, -1:], rules)[:, 0]
        return logits, {"ssm": ssm, "conv": conv}

    def decode(params, batch, rules=_ID):
        cache, pos = batch["cache"], batch["pos"]
        cdt = DTYPES[cfg.compute_dtype]
        h = jnp.take(params["embed"], batch["token"], axis=0).astype(cdt)

        def body(h, xs):
            lp, lssm, lconv = xs
            y, st = mamba_decode(lp["mixer"], cfg, _norm(lp["ln"], cfg, h),
                                 {"ssm": lssm, "conv": lconv}, rules=rules)
            return h + y, (st["ssm"], st["conv"])

        h, (ssm, conv) = _cfg_scan(cfg,
            body, h, (params["blocks"], cache["ssm"], cache["conv"]))
        h = _norm(params["final_norm"], cfg, h)
        logits = _logits(params, cfg, h, rules)[:, 0]
        return logits, {"ssm": ssm, "conv": conv}

    def cache_spec(B, s_max):
        cdt = DTYPES[cfg.compute_dtype]
        L, H, P, N = cfg.n_layers, cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        tree = {
            "ssm": jax.ShapeDtypeStruct((L, B, H, P, N), cdt),
            "conv": jax.ShapeDtypeStruct((L, B, cfg.conv_width - 1,
                                          conv_dim(cfg)), cdt),
        }
        axes = {
            "ssm": ("layers", "batch", "heads", None, None),
            "conv": ("layers", "batch", None, "heads"),
        }
        return tree, axes

    return Model(cfg, spec, loss, prefill, decode, cache_spec)


# ---------------------------------------------------------------------------
# hybrid (zamba2): groups of mamba blocks + one shared attention block
# ---------------------------------------------------------------------------

def _hybrid_layout(cfg):
    """81 layers = n_groups · (hybrid_every-1 mamba + 1 shared attn) + tail."""
    per = cfg.hybrid_every  # e.g. 6 ⇒ 5 mamba + 1 attn
    n_groups = cfg.n_layers // per
    tail = cfg.n_layers - n_groups * per
    return n_groups, per - 1, tail


def _build_hybrid_lm(cfg):
    n_groups, mamba_per, tail = _hybrid_layout(cfg)
    spec = SpecTree(cfg.param_dtype)
    _lm_head_specs(spec, cfg)

    def group_build(s):
        stack_specs(s, "mamba", mamba_per, _ssm_block_specs(cfg))
    # groups: (n_groups, mamba_per, ...) double-stacked mamba params
    stack_specs(spec, "groups", n_groups, group_build)
    # ONE shared attention block (zamba2 weight sharing)
    shared = SpecTree(cfg.param_dtype)
    _dense_block_specs(cfg, moe=False)(shared)
    spec.subtree("shared_attn", shared)
    if tail:
        stack_specs(spec, "tail", tail, _ssm_block_specs(cfg))

    def mamba_scan(stacked, h, rules, remat):
        def body(h, lp):
            y, _ = mamba_train(lp["mixer"], cfg, _norm(lp["ln"], cfg, h),
                               rules=rules)
            return rules(h + y, ("batch", "seq_sp", None)), None
        body = _maybe_remat(body, remat)
        h, _ = _cfg_scan(cfg, body, h, stacked)
        return h

    def run(params, h, positions, rules, remat, collect=False):
        kvs = None

        def group_body(h, gp):
            h = mamba_scan(gp["mamba"], h, rules, remat)
            h, kv, _ = _dense_block_train(
                params["shared_attn"], cfg, h, positions, None, None, False,
                rules)
            return h, kv if collect else None

        h, kvs = _cfg_scan(cfg, group_body, h, params["groups"])
        if tail:
            h = mamba_scan(params["tail"], h, rules, remat)
        return _norm(params["final_norm"], cfg, h), kvs

    def loss(params, batch, rules=_ID, remat="full"):
        tokens = batch["tokens"]
        cdt = DTYPES[cfg.compute_dtype]
        h = jnp.take(params["embed"], tokens[:, :-1], axis=0).astype(cdt)
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        h, _ = run(params, h, positions, rules, remat)
        ce, ntok = _ce_from_hidden(params, cfg, h, tokens[:, 1:], rules)
        return ce, {"ce": ce, "ntok": ntok}

    def mamba_scan_state(stacked, h, rules):
        def body(h, lp):
            y, st = mamba_train(lp["mixer"], cfg, _norm(lp["ln"], cfg, h),
                                return_state=True, rules=rules)
            return rules(h + y, ("batch", "seq_sp", None)), (st["ssm"],
                                                             st["conv"])
        return _cfg_scan(cfg, body, h, stacked)

    def prefill(params, batch, rules=_ID):
        tokens = batch["tokens"]
        cdt = DTYPES[cfg.compute_dtype]
        h = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
        B, S, _ = h.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def group_body(h, gp):
            h, (ssm, conv) = mamba_scan_state(gp["mamba"], h, rules)
            h, kv, _ = _dense_block_train(
                params["shared_attn"], cfg, h, positions, None, None, False,
                rules)
            return h, (ssm, conv, kv)

        h, (g_ssm, g_conv, g_kv) = _cfg_scan(cfg, group_body, h,
                                                params["groups"])
        new = {"g_ssm": g_ssm, "g_conv": g_conv,
               "k": g_kv[0], "v": g_kv[1]}
        if tail:
            h, (tssm, tconv) = mamba_scan_state(params["tail"], h, rules)
            new["t_ssm"], new["t_conv"] = tssm, tconv
        h = _norm(params["final_norm"], cfg, h)
        logits = _logits(params, cfg, h[:, -1:], rules)[:, 0]
        return logits, new

    def decode(params, batch, rules=_ID):
        cache, pos = batch["cache"], batch["pos"]
        cdt = DTYPES[cfg.compute_dtype]
        h = jnp.take(params["embed"], batch["token"], axis=0).astype(cdt)

        def mamba_step(h, xs):
            lp, lssm, lconv = xs
            y, st = mamba_decode(lp["mixer"], cfg, _norm(lp["ln"], cfg, h),
                                 {"ssm": lssm, "conv": lconv}, rules=rules)
            return h + y, (st["ssm"], st["conv"])

        def group_body(h, xs):
            gp, gssm, gconv, gkv = xs
            h, (ssm, conv) = _cfg_scan(cfg, mamba_step, h,
                                          (gp["mamba"], gssm, gconv))
            h, kv = _dense_block_decode(
                params["shared_attn"], cfg, h, pos, gkv, None, None, False,
                rules)
            return h, (ssm, conv, kv)

        h, (g_ssm, g_conv, g_kv) = _cfg_scan(cfg,
            group_body, h,
            (params["groups"], cache["g_ssm"], cache["g_conv"],
             (cache["k"], cache["v"])))
        new = {"g_ssm": g_ssm, "g_conv": g_conv,
               "k": g_kv[0], "v": g_kv[1]}
        if tail:
            h, (tssm, tconv) = _cfg_scan(cfg,
                mamba_step, h,
                (params["tail"], cache["t_ssm"], cache["t_conv"]))
            new["t_ssm"], new["t_conv"] = tssm, tconv
        h = _norm(params["final_norm"], cfg, h)
        logits = _logits(params, cfg, h, rules)[:, 0]
        return logits, new

    def cache_spec(B, s_max):
        cdt = DTYPES[cfg.compute_dtype]
        H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        G, M = n_groups, mamba_per
        tree = {
            "g_ssm": jax.ShapeDtypeStruct((G, M, B, H, P, N), cdt),
            "g_conv": jax.ShapeDtypeStruct((G, M, B, cfg.conv_width - 1,
                                            conv_dim(cfg)), cdt),
            "k": jax.ShapeDtypeStruct(
                (G, B, s_max, cfg.n_kv_heads, cfg.head_dim), cdt),
            "v": jax.ShapeDtypeStruct(
                (G, B, s_max, cfg.n_kv_heads, cfg.head_dim), cdt),
        }
        axes = {
            "g_ssm": ("layers", None, "batch", "heads", None, None),
            "g_conv": ("layers", None, "batch", None, "heads"),
            "k": ("layers", "batch", "cache_seq", "kv_heads", None),
            "v": ("layers", "batch", "cache_seq", "kv_heads", None),
        }
        if tail:
            tree["t_ssm"] = jax.ShapeDtypeStruct((tail, B, H, P, N), cdt)
            tree["t_conv"] = jax.ShapeDtypeStruct(
                (tail, B, cfg.conv_width - 1, conv_dim(cfg)), cdt)
            axes["t_ssm"] = ("layers", "batch", "heads", None, None)
            axes["t_conv"] = ("layers", "batch", None, "heads")
        return tree, axes

    return Model(cfg, spec, loss, prefill, decode, cache_spec)


# ---------------------------------------------------------------------------
# enc-dec (whisper): conv frontend is a STUB — input_specs provide frame
# embeddings (B, enc_len, d); sinusoidal positions on the encoder, learned
# positional table on the decoder.
# ---------------------------------------------------------------------------

def _sinusoid(S, d):
    pos = np.arange(S)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / (10_000 ** (2 * i / d))
    out = np.concatenate([np.sin(ang), np.cos(ang)], axis=1)
    return jnp.asarray(out, jnp.float32)


def _ln(p, cfg, x):
    return layer_norm(x, p["w"], p["b"], cfg.norm_eps)


def _ln_specs(s, path, d):
    s.param(path + "/w", (d,), (None,), init="ones")
    s.param(path + "/b", (d,), (None,), init="zeros")


def _build_encdec(cfg):
    spec = SpecTree(cfg.param_dtype)
    spec.param("embed", (cfg.vocab, cfg.d_model), ("vocab", "embed"),
               init="normal")
    spec.param("pos_embed", (cfg.max_positions, cfg.d_model),
               (None, "embed"), init="normal")
    _ln_specs(spec, "enc_final_ln", cfg.d_model)
    _ln_specs(spec, "dec_final_ln", cfg.d_model)

    def enc_build(s):
        _ln_specs(s, "ln1", cfg.d_model)
        attn_specs(s, "attn", cfg)
        _ln_specs(s, "ln2", cfg.d_model)
        mlp_specs(s, "mlp", cfg.d_model, cfg.d_ff, "gelu")

    def dec_build(s):
        _ln_specs(s, "ln1", cfg.d_model)
        attn_specs(s, "attn", cfg)
        _ln_specs(s, "ln2", cfg.d_model)
        cross_attn_specs(s, "xattn", cfg)
        _ln_specs(s, "ln3", cfg.d_model)
        mlp_specs(s, "mlp", cfg.d_model, cfg.d_ff, "gelu")

    stack_specs(spec, "enc", cfg.n_enc_layers, enc_build)
    stack_specs(spec, "dec", cfg.n_layers, dec_build)

    def encode(params, enc_embeds, rules, remat):
        cdt = DTYPES[cfg.compute_dtype]
        Se = enc_embeds.shape[1]
        h = enc_embeds.astype(cdt) + _sinusoid(Se, cfg.d_model).astype(cdt)

        def body(h, lp):
            # whisper encoder: bidirectional self-attention, no RoPE
            x = _ln(lp["ln1"], cfg, h)
            q, k, v = _qkv(lp["attn"], cfg, x)
            ctx = chunked_attention(q, k, v,
                                    scale=1.0 / math.sqrt(cfg.head_dim),
                                    causal=False, chunk=cfg.attn_chunk)
            B, S, _ = x.shape
            a = ctx.reshape(B, S, cfg.n_heads * cfg.head_dim) @ lp["attn"]["wo"]
            h = h + a
            f = mlp_apply(lp["mlp"], _ln(lp["ln2"], cfg, h), "gelu")
            return rules(h + f, ("batch", "seq_sp", None)), None

        body = _maybe_remat(body, remat)
        h, _ = _cfg_scan(cfg, body, h, params["enc"])
        return _ln(params["enc_final_ln"], cfg, h)

    def dec_block_train(lp, h, enc_out, positions, rules):
        a, kv = attn_train(lp["attn"], cfg, _ln(lp["ln1"], cfg, h), positions,
                           chunk=cfg.attn_chunk, rules=rules)
        h = h + a
        ckv = cross_kv(lp["xattn"], cfg, enc_out)
        h = h + cross_attn(lp["xattn"], cfg, _ln(lp["ln2"], cfg, h), ckv,
                           chunk=cfg.attn_chunk, rules=rules)
        f = mlp_apply(lp["mlp"], _ln(lp["ln3"], cfg, h), "gelu")
        return rules(h + f, ("batch", "seq_sp", None)), kv, ckv

    def loss(params, batch, rules=_ID, remat="full"):
        tokens = batch["tokens"]
        cdt = DTYPES[cfg.compute_dtype]
        enc_out = encode(params, batch["enc_embeds"], rules, remat)
        inp = tokens[:, :-1]
        B, S = inp.shape
        h = (jnp.take(params["embed"], inp, axis=0)
             + params["pos_embed"][None, :S]).astype(cdt)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(h, lp):
            h, _, _ = dec_block_train(lp, h, enc_out, positions, rules)
            return h, None

        body = _maybe_remat(body, remat)
        h, _ = _cfg_scan(cfg, body, h, params["dec"])
        h = _ln(params["dec_final_ln"], cfg, h)
        ce, ntok = _ce_from_hidden(params, cfg, h, tokens[:, 1:], rules)
        return ce, {"ce": ce, "ntok": ntok}

    def prefill(params, batch, rules=_ID):
        tokens = batch["tokens"]
        cdt = DTYPES[cfg.compute_dtype]
        enc_out = encode(params, batch["enc_embeds"], rules, "none")
        B, S = tokens.shape
        h = (jnp.take(params["embed"], tokens, axis=0)
             + params["pos_embed"][None, :S]).astype(cdt)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(h, lp):
            h, kv, ckv = dec_block_train(lp, h, enc_out, positions, rules)
            return h, (kv, ckv)

        h, (kv, ckv) = _cfg_scan(cfg, body, h, params["dec"])
        h = _ln(params["dec_final_ln"], cfg, h)
        logits = (h[:, -1] @ params["embed"].T).astype(jnp.float32)
        return logits, {"k": kv[0], "v": kv[1], "ck": ckv[0], "cv": ckv[1]}

    def decode(params, batch, rules=_ID):
        cache, pos = batch["cache"], batch["pos"]
        cdt = DTYPES[cfg.compute_dtype]
        tok = batch["token"]
        B = tok.shape[0]
        pe = jnp.take(params["pos_embed"], pos, axis=0)[:, None, :]
        h = (jnp.take(params["embed"], tok, axis=0) + pe).astype(cdt)

        def body(h, xs):
            lp, lk, lv, lck, lcv = xs
            a, kv = attn_decode(lp["attn"], cfg, _ln(lp["ln1"], cfg, h), pos,
                                (lk, lv), rules=rules)
            h = h + a
            h = h + cross_attn(lp["xattn"], cfg, _ln(lp["ln2"], cfg, h),
                               (lck, lcv), rules=rules)
            f = mlp_apply(lp["mlp"], _ln(lp["ln3"], cfg, h), "gelu")
            return h + f, kv

        h, kv = _cfg_scan(cfg,
            body, h, (params["dec"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]))
        h = _ln(params["dec_final_ln"], cfg, h)
        logits = (h[:, 0] @ params["embed"].T).astype(jnp.float32)
        return logits, {"k": kv[0], "v": kv[1],
                        "ck": cache["ck"], "cv": cache["cv"]}

    def cache_spec(B, s_max):
        cdt = DTYPES[cfg.compute_dtype]
        L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
        Se = cfg.enc_len
        tree = {
            "k": jax.ShapeDtypeStruct((L, B, s_max, cfg.n_kv_heads, hd), cdt),
            "v": jax.ShapeDtypeStruct((L, B, s_max, cfg.n_kv_heads, hd), cdt),
            "ck": jax.ShapeDtypeStruct((L, B, Se, H, hd), cdt),
            "cv": jax.ShapeDtypeStruct((L, B, Se, H, hd), cdt),
        }
        ax = ("layers", "batch", "cache_seq", "kv_heads", None)
        axes = {"k": ax, "v": ax,
                "ck": ("layers", "batch", None, "heads", None),
                "cv": ("layers", "batch", None, "heads", None)}
        return tree, axes

    return Model(cfg, spec, loss, prefill, decode, cache_spec)

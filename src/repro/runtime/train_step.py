"""The jittable train step: loss → grad → AdamW, with remat policy,
microbatch gradient accumulation, and optional gradient compression.

Everything is expressed in global-array pjit style: the step function is
pure; shardings are applied by the caller (launch/train.py, launch/dryrun.py)
through in_shardings/out_shardings built from Rules.

Distributed-optimization levers (each a §Perf knob):
  * remat ∈ {full, dots, none}            — recompute vs HBM
  * microbatches > 1                      — accumulate grads in f32; on real
    hardware the per-microbatch reduce overlaps the next microbatch compute
  * compress_ratio < 1                    — top-k grad compression + error
    feedback carried in TrainState
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..optim import AdamW, OptState, topk_compress_with_feedback

__all__ = ["TrainState", "make_train_step", "init_train_state"]


@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: OptState
    err: Any  # compression error-feedback tree (or None)


jax.tree_util.register_dataclass(
    TrainState, data_fields=["params", "opt", "err"], meta_fields=[])


def init_train_state(
    model, key, optimizer: AdamW, compress: bool = False
) -> TrainState:
    params = model.init(key)
    err = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
           if compress else None)
    return TrainState(params=params, opt=optimizer.init(params), err=err)


def make_train_step(
    model,
    optimizer: AdamW,
    *,
    rules=None,
    remat: str = "full",
    microbatches: int = 1,
    compress_ratio: Optional[float] = None,
):
    """Returns step(state, batch) -> (state, metrics)."""
    rules = rules if rules is not None else (lambda x, a: x)
    param_axes = model.axes()

    def constrain_grads(grads):
        """Pin gradient shardings to the parameter shardings. Without this
        GSPMD all-reduces FULL gradients across the data axis instead of
        reduce-scattering to the FSDP shard (ZeRO) — measured 324 GB/device
        of all-reduce on gemma-7b before this constraint."""
        return jax.tree.map(lambda g, ax: rules(g, ax), grads, param_axes)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch, rules=rules, remat=remat)
        return loss, metrics

    _vg = jax.value_and_grad(loss_fn, has_aux=True)

    def grad_fn(params, batch):
        (loss, metrics), grads = _vg(params, batch)
        return (loss, metrics), constrain_grads(grads)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def split(x):
            b = x.shape[0] if x.ndim >= 1 else None
            # batch-dim leaves only; positions for vlm are (3, B, S)
            if x.ndim >= 3 and x.shape[0] == 3 and x.shape[1] % microbatches == 0:
                return x.reshape(3, microbatches, -1, *x.shape[2:]).swapaxes(0, 1)
            assert b is not None and b % microbatches == 0, x.shape
            return x.reshape(microbatches, -1, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, loss_sum = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_sum + loss), None

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (acc, loss_sum), _ = jax.lax.scan(body, (acc0, jnp.float32(0)), micro)
        grads = jax.tree.map(lambda a: a / microbatches, acc)
        # metrics from the mean loss only (cheap)
        return loss_sum / microbatches, {"ce": loss_sum / microbatches}, grads

    def step(state: TrainState, batch):
        loss, metrics, grads = compute_grads(state.params, batch)
        err = state.err
        if compress_ratio is not None:
            grads, err = topk_compress_with_feedback(grads, err,
                                                     compress_ratio)
        params, opt, gnorm = optimizer.update(grads, state.opt, state.params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return TrainState(params=params, opt=opt, err=err), metrics

    return step

"""Distributed runtime: sharding rules, train/serve steps, fault handling."""
from .sharding import PRESETS, Rules, make_rules
from .train_step import TrainState, init_train_state, make_train_step
from .serve_step import greedy_generate, make_decode_step, make_prefill_step

__all__ = ["PRESETS", "Rules", "make_rules",
           "TrainState", "init_train_state", "make_train_step",
           "greedy_generate", "make_decode_step", "make_prefill_step"]

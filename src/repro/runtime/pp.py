"""Pipeline parallelism: GPipe-style microbatch pipeline over a mesh axis
(the multi-pod mesh's "pod" axis), built on shard_map + lax.ppermute.

Schedule: T = M + S − 1 ticks. At tick t, stage 0 ingests microbatch t (if
t < M); every stage applies its layer block; activations hop one stage via
collective_permute. The last stage banks the finished microbatch t−(S−1).
Bubble fraction = (S−1)/T — reported by `bubble_fraction` so launch configs
can size M (the standard GPipe trade-off).

This is the communication pattern the multi-pod dry-run validates over the
"pod" axis (launch/dryrun.py --pp-demo): inter-pod traffic becomes
point-to-point activation hops instead of all-reduce — the right shape for
low-bandwidth pod interconnect.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["gpipe", "bubble_fraction"]


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe(stage_fn, stage_params, micro_inputs, *, mesh, axis: str):
    """Run micro_inputs through n_stages sequential stages, pipelined.

    stage_fn(params_one_stage, x) -> y  (same shape as x)
    stage_params: pytree stacked along a leading stage dim (= mesh.shape[axis])
    micro_inputs: (M, mb, ...) microbatches, replicated across `axis`.
    Returns (M, mb, ...) outputs (replicated).
    """
    S = int(mesh.shape[axis])
    M = int(micro_inputs.shape[0])
    perm = [(i, (i + 1) % S) for i in range(S)]

    def local(params_local, xs):
        idx = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params_local)
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            inject = xs[jnp.minimum(t, M - 1)]
            cur = jnp.where(idx == 0, inject, buf)
            y = stage_fn(p, cur)
            out_t = t - (S - 1)
            take = (idx == S - 1) & (out_t >= 0)
            outs = jax.lax.cond(
                take,
                lambda o: o.at[jnp.maximum(out_t, 0)].set(y),
                lambda o: o, outs)
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, M + S - 1, tick, (buf, outs))
        # broadcast the last stage's bank to every shard
        outs = jnp.where(idx == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    other = tuple(a for a in mesh.axis_names if a != axis)
    pspec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(pspec_params, P()),
                   out_specs=P(), check_rep=False)
    return fn(stage_params, micro_inputs)

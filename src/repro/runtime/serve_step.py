"""Serving steps: batched prefill and single-token decode over a KV cache.

The decode step is exactly what ``decode_32k`` / ``long_500k`` lower in the
dry-run: one new token against a seq_len-sized cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["make_prefill_step", "make_decode_step", "greedy_generate"]


def make_prefill_step(model, rules=None):
    rules = rules if rules is not None else (lambda x, a: x)

    def prefill(params, batch):
        return model.prefill(params, batch, rules=rules)

    return prefill


def make_decode_step(model, rules=None):
    rules = rules if rules is not None else (lambda x, a: x)

    def decode(params, batch):
        logits, cache = model.decode(params, batch, rules=rules)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache

    return decode


def greedy_generate(model, params, batch, steps: int, s_max: int, rules=None):
    """Prefill then greedy-decode ``steps`` tokens (CPU-scale examples).

    batch["tokens"]: (B, S0). Caches are padded to s_max before decoding.
    """
    rules = rules if rules is not None else (lambda x, a: x)
    B, S0 = batch["tokens"].shape
    logits, cache = model.prefill(params, batch, rules=rules)

    _, axes = model.cache_spec(B, s_max)

    def pad(leaf, ax):
        if ax is None or "cache_seq" not in ax:
            return leaf
        i = ax.index("cache_seq")
        pads = [(0, 0)] * leaf.ndim
        pads[i] = (0, s_max - leaf.shape[i])
        return jnp.pad(leaf, pads)

    cache = jax.tree.map(pad, cache, axes)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    pos0 = S0 + (model.config.n_vision_tokens
                 if model.config.family == "vlm" else 0)

    decode = jax.jit(lambda p, b: model.decode(p, b, rules=rules))
    for i in range(steps - 1):
        dec_batch = {"token": tok, "pos": jnp.full((B,), pos0 + i, jnp.int32),
                     "cache": cache}
        if model.config.family == "vlm":
            dec_batch["positions"] = jnp.full((3, B, 1), pos0 + i, jnp.int32)
        logits, cache = decode(params, dec_batch)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)

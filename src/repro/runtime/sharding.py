"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

A Rules object maps logical axis names → mesh axes. ``spec(shape, axes)``
builds a PartitionSpec, dropping any assignment whose mesh-axis product does
not divide the dimension (or whose mesh axis is already consumed by an
earlier dim) — that is the fallback chain promised in DESIGN.md §5 (e.g.
kv_heads=8 on a model=16 axis falls back to replication while the flattened
weight column dim still shards).

Presets:
  train/prefill : DP over (pod,data), FSDP params over data, TP over model,
                  SP residuals (seq→model)
  decode        : batch over (pod,data), KV-cache seq over model
  long          : batch=1 ⇒ cache/state sharded over everything available
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Rules", "make_rules", "PRESETS"]

# logical name -> tuple of mesh axes (in priority order)
PRESETS: dict[str, dict[str, tuple[str, ...]]] = {
    "train": {
        "batch": ("pod", "data"),
        "seq": (),  # attention runs with full seq per shard
        "seq_sp": ("model",),  # SP: residual stream seq-sharded
        "embed": ("data",),  # FSDP
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "expert": ("model",),
        "layers": (),
        "cache_seq": (),
        "moe_group": ("pod", "data"),
    },
    "decode": {
        "batch": ("pod", "data"),
        "seq": (),
        "seq_sp": (),
        "embed": ("data",),
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "expert": ("model",),
        "layers": (),
        "cache_seq": ("model",),
        "moe_group": ("pod", "data"),
    },
    "long": {
        "batch": (),
        "seq": (),
        "seq_sp": ("model",),
        "embed": ("data",),
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "expert": ("model",),
        "layers": (),
        "cache_seq": ("pod", "data"),
        "moe_group": ("model",),
    },
    # FSDP-pivot (§Perf): no tensor parallelism — params fully sharded over
    # BOTH mesh axes (ZeRO-3), residuals sequence-sharded over model. Right
    # regime for ≲70B dense models where TP activation psums dominate the
    # collective roofline term (measured: gemma-7b TP psums = 324 GB/device).
    "fsdp": {
        "batch": ("pod", "data"),
        "seq": (),
        "seq_sp": ("model",),
        "embed": ("data", "model"),
        "vocab": (),
        "heads": (),
        "kv_heads": (),
        "mlp": (),
        "expert": ("model",),
        "layers": (),
        "cache_seq": (),
        "moe_group": ("pod", "data"),
    },
}


@dataclasses.dataclass(frozen=True, eq=False)
class Rules:
    mesh: Optional[Mesh]
    table: dict[str, tuple[str, ...]]

    def _axis_size(self, name: str) -> int:
        return int(self.mesh.shape[name])

    def spec(self, shape: tuple[int, ...], axes) -> P:
        """PartitionSpec for a concrete shape; divisibility-aware."""
        if self.mesh is None:
            return P()
        used: set[str] = set()
        parts: list[Any] = []
        for dim, name in zip(shape, axes):
            assign: tuple[str, ...] = ()
            if name is not None:
                want = tuple(a for a in self.table.get(name, ())
                             if a in self.mesh.axis_names and a not in used)
                prod = int(np.prod([self._axis_size(a) for a in want])) if want else 1
                if want and dim % prod == 0 and prod > 1:
                    assign = want
            used.update(assign)
            parts.append(assign if len(assign) > 1 else
                         (assign[0] if assign else None))
        return P(*parts)

    def __call__(self, x, axes):
        """Insert a sharding constraint (no-op without a mesh)."""
        if self.mesh is None:
            return x
        spec = self.spec(x.shape, axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def named(self, shape: tuple[int, ...], axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, axes))

    def tree_shardings(self, abstract_tree, axes_tree):
        """NamedSharding tree for params / caches from their axes tree.

        abstract_tree's leaves (ShapeDtypeStructs) align with whole axes
        tuples in axes_tree via flatten_up_to semantics of jax.tree.map.
        """
        return jax.tree.map(lambda ab, axes: self.named(ab.shape, axes),
                            abstract_tree, axes_tree)


def make_rules(
    mesh: Optional[Mesh], preset: str = "train", overrides: Optional[dict] = None
) -> Rules:
    table = dict(PRESETS[preset])
    if overrides:
        table.update(overrides)
    return Rules(mesh=mesh, table=table)

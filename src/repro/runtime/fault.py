"""Fault-tolerance machinery: failure injection, detection, restart policy,
straggler tracking.

On real hardware, failures surface as collective timeouts / ICI errors; here
the FailureInjector models them as a seeded random process so the restart
logic is exercised deterministically in tests. The TrainSupervisor owns the
loop: step → (maybe) failure → restore-from-checkpoint → continue, counting
lost steps. StragglerTracker implements the per-step detection that feeds
the ESDP dispatcher (repro/sched): slices whose observed rate drops are
learned to be slow and routed around — the paper's fluctuating-service-rate
premise, closed-loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

__all__ = ["FailureInjector", "StragglerTracker", "TrainSupervisor"]


@dataclasses.dataclass
class FailureInjector:
    """Bernoulli(p) node failure per step + optional deterministic schedule.

    A scheduled failure fires ONCE — node failures are transient; replaying
    through the same step after restore must not re-kill the job (otherwise
    recovery live-locks — caught by test_supervisor_restart_exact).
    """
    p_fail: float = 0.0
    seed: int = 0
    scheduled: tuple[int, ...] = ()

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._fired: set[int] = set()

    def check(self, step: int) -> bool:
        if step in self.scheduled and step not in self._fired:
            self._fired.add(step)
            return True
        return self._rng.random() < self.p_fail


@dataclasses.dataclass
class StragglerTracker:
    """EMA of per-step wall time; flags steps slower than k× the EMA."""
    alpha: float = 0.1
    k: float = 2.0
    _ema: Optional[float] = None
    slow_steps: int = 0

    def observe(self, dt: float) -> bool:
        if self._ema is None:
            self._ema = dt
            return False
        slow = dt > self.k * self._ema
        self._ema = (1 - self.alpha) * self._ema + self.alpha * dt
        self.slow_steps += int(slow)
        return slow

    @property
    def rate_estimate(self) -> float:
        return 1.0 / self._ema if self._ema else 0.0


class TrainSupervisor:
    """Checkpoint/restart loop around a jitted step function.

    step_fn(state, batch) -> (state, metrics); batches come from a
    restart-exact iterator (data/pipeline.py), so recovery replays the
    exact stream from the restored step.
    """

    def __init__(
        self,
        step_fn: Callable,
        ckpt,
        injector: FailureInjector,
        save_every: int = 50,
        async_save: bool = True,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.injector = injector
        self.save_every = save_every
        self.async_save = async_save
        self.straggler = StragglerTracker()
        self.restarts = 0
        self.lost_steps = 0

    def run(
        self,
        state,
        make_iterator,
        total_steps: int,
        start_step: int = 0,
        on_metrics: Optional[Callable] = None,
    ):
        step = start_step
        it = make_iterator(step)
        while step < total_steps:
            t0 = time.time()
            if self.injector.check(step):
                # simulate node loss: restore latest checkpoint, rebuild
                # the data iterator at the restored step (restart-exact)
                self.restarts += 1
                restored = self.ckpt.latest_step()
                if restored is None:
                    restored = start_step
                    state_r = state  # no checkpoint yet: lose nothing but time
                else:
                    state_r, restored = self.ckpt.restore(like=state,
                                                          step=restored)
                self.lost_steps += max(step - restored, 0)
                step = restored
                it = make_iterator(step)
                state = state_r
                continue
            _, batch = next(it)
            state, metrics = self.step_fn(state, batch)
            self.straggler.observe(time.time() - t0)
            if on_metrics is not None:
                on_metrics(step, metrics)
            step += 1
            if step % self.save_every == 0:
                self.ckpt.save(step, state, async_=self.async_save)
        self.ckpt.wait()
        return state, step

"""Fault-tolerance machinery: failure injection, detection, restart policy,
straggler tracking.

On real hardware, failures surface as collective timeouts / ICI errors; here
the FailureInjector models them as a seeded random process so the restart
logic is exercised deterministically in tests. The TrainSupervisor owns the
loop: step → (maybe) failure → restore-from-checkpoint → continue, counting
lost steps. StragglerTracker implements the per-step detection that feeds
the ESDP dispatcher (repro/sched): slices whose observed rate drops are
learned to be slow and routed around — the paper's fluctuating-service-rate
premise, closed-loop.

Two consumers beyond the training loop (see docs/robustness.md):

  * the failure-aware cluster runtime (``sched.dispatcher.FailureRuntime``)
    drives its per-server crash process with :class:`FailureInjector` and
    its detection-driven eligibility with :class:`CrashRateTracker` — the
    StragglerTracker pattern applied to crash events;
  * the graceful-degradation solver chain (``core.solvers.FallbackSolver``)
    exercises its retry path in CI through the deterministic fault hook
    (:func:`planned_fault` / :class:`InjectedFault`), toggled by the
    ``REPRO_DP_FAULT_RATE`` env var — no real hardware fault needed.
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Callable, Optional

import numpy as np

__all__ = [
    "FailureInjector", "StragglerTracker", "CrashRateTracker",
    "TrainSupervisor", "InjectedFault", "planned_fault",
    "fault_rate_from_env", "FAULT_RATE_ENV", "FAULT_SEED_ENV",
]


@dataclasses.dataclass
class FailureInjector:
    """Bernoulli(p) node failure per step + optional deterministic schedule.

    A scheduled failure fires ONCE — node failures are transient; replaying
    through the same step after restore must not re-kill the job (otherwise
    recovery live-locks — caught by test_supervisor_restart_exact).

    The Bernoulli draw is COUNTER-BASED: step t's outcome is a pure function
    of ``(seed, t)``, never of how many times ``check`` was called before.
    A restore-replay through the same steps therefore sees the identical
    failure stream (a stateful generator would silently re-randomize it —
    caught by test_injector_replay_deterministic).
    """
    p_fail: float = 0.0
    seed: int = 0
    scheduled: tuple[int, ...] = ()

    def __post_init__(self):
        self._fired: set[int] = set()

    def check(self, step: int) -> bool:
        if step in self.scheduled and step not in self._fired:
            self._fired.add(step)
            return True
        if self.p_fail <= 0.0:
            return False
        return self.draw(step) < self.p_fail

    def draw(self, step: int, salt: int = 0) -> float:
        """The uniform [0, 1) variate behind step ``step`` (pure in
        ``(seed, step, salt)``).  Consumers needing extra independent
        per-step randomness — e.g. the in-slot crash fraction of the
        failure-aware dispatcher — draw with a distinct ``salt``."""
        return float(
            np.random.default_rng((self.seed, int(step), salt)).random())


@dataclasses.dataclass
class StragglerTracker:
    """EMA of per-step wall time; flags steps slower than k× the EMA."""
    alpha: float = 0.1
    k: float = 2.0
    _ema: Optional[float] = None
    slow_steps: int = 0

    def observe(self, dt: float) -> bool:
        if self._ema is None:
            self._ema = dt
            return False
        slow = dt > self.k * self._ema
        self._ema = (1 - self.alpha) * self._ema + self.alpha * dt
        self.slow_steps += int(slow)
        return slow

    @property
    def rate_estimate(self) -> float:
        return 1.0 / self._ema if self._ema else 0.0


@dataclasses.dataclass
class CrashRateTracker:
    """EMA of a per-step crash indicator; flags elevated crash rates.

    :class:`StragglerTracker`'s detection pattern applied to failures: the
    failure-aware dispatcher keeps one tracker per server, feeds it the
    server's crash indicator each slot, and masks the edges of servers
    whose estimated rate exceeds ``threshold`` out of eligibility — a
    freshly-repaired crasher sits out a probation window (~4 slots at the
    defaults) instead of immediately receiving work again.
    """
    alpha: float = 0.2
    threshold: float = 0.1
    rate: float = 0.0
    crashes: int = 0

    def observe(self, crashed: bool) -> bool:
        self.rate = (1 - self.alpha) * self.rate + self.alpha * float(crashed)
        self.crashes += int(crashed)
        return self.suspicious

    @property
    def suspicious(self) -> bool:
        return self.rate > self.threshold


# ---------------------------------------------------------------------------
# deterministic solver-fault injection (the CI hook of the fallback chain)
# ---------------------------------------------------------------------------

FAULT_RATE_ENV = "REPRO_DP_FAULT_RATE"
FAULT_SEED_ENV = "REPRO_DP_FAULT_SEED"


class InjectedFault(RuntimeError):
    """Synthetic backend-launch failure raised by the fault hook."""


def fault_rate_from_env() -> float:
    """The injection rate requested by ``$REPRO_DP_FAULT_RATE`` (0.0 when
    unset).  An unparsable value warns and disables injection — a stale
    shell var must never corrupt a production run."""
    raw = os.environ.get(FAULT_RATE_ENV)
    if not raw:
        return 0.0
    try:
        rate = float(raw)
    except ValueError:
        warnings.warn(
            f"ignoring unparsable {FAULT_RATE_ENV}={raw!r}; fault "
            "injection disabled", RuntimeWarning, stacklevel=2)
        return 0.0
    if not 0.0 <= rate <= 1.0:
        warnings.warn(
            f"ignoring out-of-range {FAULT_RATE_ENV}={raw!r} (want "
            "[0, 1]); fault injection disabled", RuntimeWarning,
            stacklevel=2)
        return 0.0
    return rate


def planned_fault(
    call_index: int, rate: float, seed: int = 0, attempt: int = 0
) -> "str | None":
    """The fault (if any) planned for one solver attempt.

    Pure in ``(seed, call_index, attempt)`` — the same run always injects
    the same faults at the same call indices, so a CI leg exercising the
    fallback chain is reproducible.  Returns ``None`` (no fault),
    ``"launch"`` (the attempt should raise :class:`InjectedFault` instead
    of launching) or ``"corrupt"`` (the attempt's value plane should be
    poisoned so output validation has something to catch), split evenly.
    """
    if rate <= 0.0:
        return None
    rng = np.random.default_rng((seed, int(call_index), int(attempt), 0xFA))
    if rng.random() >= rate:
        return None
    return "launch" if rng.random() < 0.5 else "corrupt"


class TrainSupervisor:
    """Checkpoint/restart loop around a jitted step function.

    step_fn(state, batch) -> (state, metrics); batches come from a
    restart-exact iterator (data/pipeline.py), so recovery replays the
    exact stream from the restored step.
    """

    def __init__(
        self,
        step_fn: Callable,
        ckpt,
        injector: FailureInjector,
        save_every: int = 50,
        async_save: bool = True,
    ):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.injector = injector
        self.save_every = save_every
        self.async_save = async_save
        self.straggler = StragglerTracker()
        self.restarts = 0
        self.lost_steps = 0

    def run(
        self,
        state,
        make_iterator,
        total_steps: int,
        start_step: int = 0,
        on_metrics: Optional[Callable] = None,
    ):
        step = start_step
        it = make_iterator(step)
        while step < total_steps:
            t0 = time.time()
            if self.injector.check(step):
                # simulate node loss: restore latest checkpoint, rebuild
                # the data iterator at the restored step (restart-exact).
                # An async save may still be in flight — join it first, or
                # latest_step() misses the newest checkpoint and the
                # restart replays more steps than it lost.
                self.restarts += 1
                self.ckpt.wait()
                restored = self.ckpt.latest_step()
                if restored is None:
                    restored = start_step
                    state_r = state  # no checkpoint yet: lose nothing but time
                else:
                    state_r, restored = self.ckpt.restore(like=state,
                                                          step=restored)
                self.lost_steps += max(step - restored, 0)
                step = restored
                it = make_iterator(step)
                state = state_r
                continue
            _, batch = next(it)
            state, metrics = self.step_fn(state, batch)
            self.straggler.observe(time.time() - t0)
            if on_metrics is not None:
                on_metrics(step, metrics)
            step += 1
            if step % self.save_every == 0:
                self.ckpt.save(step, state, async_=self.async_save)
        self.ckpt.wait()
        return state, step

"""Quickstart: the paper in 60 seconds.

Generates the paper's default bipartite instance (Table 2), runs ESDP
against the three baselines for 2000 slots, and prints the accumulative
social welfare + regret — the headline numbers of Fig. 2.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (build_tables, generate_instance, make_esdp_policy,
                        make_hswf_policy, make_lcf_policy, make_lwtf_policy,
                        simulate)
from repro.core.stats import g_logt_only


def main():
    inst = generate_instance(seed=0)          # |L|=8, |R|=40, Table-2 defaults
    tables = build_tables(inst.A, inst.c)
    T = 2000
    print(f"instance: |L|={inst.n_ports} |R|={inst.n_servers} "
          f"|E|={inst.n_edges} K={inst.n_device_types} c={inst.c.tolist()}")

    policies = {
        "ESDP (paper default g)": make_esdp_policy(inst, T, tables=tables),
        "ESDP (g=ln t, Fig-8 winner)": make_esdp_policy(
            inst, T, g_fn=g_logt_only, tables=tables),
        "HSWF": make_hswf_policy(inst, tiebreak=0.0),
        "LCF": make_lcf_policy(inst, tiebreak=0.0),
        "LWTF": make_lwtf_policy(inst, tiebreak=0.0),
    }
    results = {}
    for name, pol in policies.items():
        r = simulate(inst, pol, T, seed=42, tables=tables)
        results[name] = r
        print(f"{name:30s} ASW={r.asw[-1]:8.1f}  "
              f"cumRegret={r.cum_regret[-1]:8.1f}  "
              f"avg‖x‖={r.n_dispatched.mean():.2f}")

    best = results["ESDP (g=ln t, Fig-8 winner)"].asw[-1]
    for b in ("HSWF", "LCF", "LWTF"):
        print(f"ESDP improvement vs {b}: "
              f"{(best / results[b].asw[-1] - 1) * 100:+.0f}%")


if __name__ == "__main__":
    main()

"""The paper's technique doing its production job: ESDP gang-dispatches the
assigned (arch × shape) workloads onto a heterogeneous TPU fleet whose
service rates come from the compiled dry-run rooflines, fluctuate, and
degrade mid-run (straggler brownout) — ESDP learns and routes around it.

    PYTHONPATH=src python examples/dispatch_cluster.py
"""
import numpy as np

from repro.sched import ClusterSim, JobType, Slice, build_instance, rate_matrix


def main():
    slices = [
        Slice("pod-a", "v5e", 256, 32, 4),
        Slice("pod-b", "v5e", 256, 32, 4),
        Slice("pod-c", "v5e", 512, 64, 8),
        Slice("pod-d", "v5p", 256, 32, 4),
    ]
    jobs = [
        JobType("qwen2.5:train", "qwen2.5-32b", "train_4k", ("v5e", "v5p"),
                256, 32, 4, value_rate=1.0),
        JobType("deepseek:decode", "deepseek-v3-671b", "decode_32k",
                ("v5e", "v5p"), 256, 32, 4, value_rate=1.5),
        JobType("mamba2:long", "mamba2-2.7b", "long_500k", ("v5e",),
                256, 32, 4, value_rate=0.8),
        JobType("gemma3:prefill", "gemma3-27b", "prefill_32k", ("v5e",),
                256, 32, 4, value_rate=0.9),
        JobType("whisper:train", "whisper-medium", "train_4k", ("v5p",),
                256, 32, 4, value_rate=0.4),
    ]
    rates = rate_matrix(jobs, slices)
    inst, _ = build_instance(slices, jobs, rates, seed=0)
    print(f"cluster instance: {inst.n_ports} job types × "
          f"{inst.n_servers} slices, {inst.n_edges} channels")

    T = 800
    R = len(slices)

    def brownout(t0):   # pod-b at 40% speed in the middle third
        s = np.ones(R, np.float32)
        if T // 3 < t0 < 2 * T // 3:
            s[1] = 0.4
        return s

    for pol in ("esdp", "hswf", "lcf", "lwtf"):
        out = ClusterSim(inst, T, speed_fn=brownout, seed=7).run(
            pol, tiebreak=0.0)
        print(f"{pol:5s} ASW={out.asw:8.1f} cumRegret={out.cum_regret[-1]:8.1f}")

    out = ClusterSim(inst, T, speed_fn=brownout, seed=7).run("esdp")
    mid = slice(T // 3, 2 * T // 3)
    print("pod-b dispatch share: before brownout "
          f"{out.dispatch_share[:T // 3, 1].mean():.3f}, during "
          f"{out.dispatch_share[mid, 1].mean():.3f}, after "
          f"{out.dispatch_share[2 * T // 3:, 1].mean():.3f}")


if __name__ == "__main__":
    main()

"""Scenario sweep in a few lines: ESDP vs HSWF across fluctuation regimes.

Demonstrates the two levels of batching in ``repro.experiments``:

  1. a declarative SweepSpec — every (policy × scenario) cell is ONE jitted
     ``jax.vmap`` over the seed batch (no per-seed Python loop), and
  2. a scenario-parameter grid — severity values folded into a single
     compilation via ``lax.map`` on top of the vmapped seeds.

    PYTHONPATH=src python examples/scenario_sweep.py
"""
import numpy as np

from repro.core import build_tables, generate_instance
from repro.core.baselines import hswf_factory
from repro.core.esdp import esdp_factory
from repro.core.stats import g_logt_only
from repro.experiments import (SweepSpec, run_spec, scenario_names,
                               sweep_scenario_param, write_csv)

T = 1000
SEEDS = (0, 1, 2)


def main():
    # -- 1. registry sweep: every named regime, one spec each ---------------
    # paper-literal HSWF (tiebreak=0), as in the paper's Fig.-2 comparison
    policies = {"esdp": esdp_factory(g_fn=g_logt_only),
                "hswf": hswf_factory(tiebreak=0.0)}
    print(f"{'scenario':20s} {'esdp ASW':>12s} {'hswf ASW':>12s} {'winner':>8s}")
    rows = []
    for scen in scenario_names():
        spec = SweepSpec(name=f"sweep/{scen}", T=T, seeds=SEEDS,
                         policies=policies, scenario=scen,
                         instance_kwargs={"seed": 0})
        res = {r.policy: r for r in run_spec(spec)}
        rows += list(res.values())
        e, h = res["esdp"], res["hswf"]
        print(f"{scen:20s} {e.asw_mean:8.1f}±{e.asw_ci95:3.0f} "
              f"{h.asw_mean:8.1f}±{h.asw_ci95:3.0f} "
              f"{'esdp' if e.asw_mean > h.asw_mean else 'hswf':>8s}")
    path = write_csv(rows, "results/scenario_sweep.csv")
    print(f"\nwrote {path}")

    # -- 2. severity grid: one compiled lax.map × vmap call -----------------
    inst = generate_instance(seed=0)
    tables = build_tables(inst.A, inst.c)
    speeds = (0.2, 0.4, 0.6, 0.8, 1.0)
    grid = sweep_scenario_param(
        inst, esdp_factory(g_fn=g_logt_only), T, SEEDS,
        "chronic_straggler", "straggler_speed", speeds, tables=tables)
    print("\nstraggler severity sweep (single jitted lax.map × vmap call):")
    asw = grid.asw[..., -1]                  # (G, S)
    for v, mean, sd in zip(speeds, asw.mean(axis=1), asw.std(axis=1)):
        print(f"  straggler_speed={v:.1f}  ASW={mean:7.1f} ± {sd:4.1f}")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-parameter qwen2.5-family model for a few
hundred steps on the synthetic pipeline, with checkpointing and a scheduled
mid-run failure + restart — the fault-tolerance path, end to end.

    PYTHONPATH=src python examples/train_tiny_lm.py
"""
import jax

from repro.configs import get_config
from repro.launch.train import main as train_main


def main():
    cfg = get_config("qwen2.5-32b", reduced=True).replace(
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab=8192)
    # ~100M params: verify
    from repro.models import build_model
    import numpy as np
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(
        build_model(cfg).abstract()))
    print(f"model: {n / 1e6:.1f}M params")

    # drive through the production train driver (with a failure at step 120)
    import repro.configs as configs
    configs._MODULES["tiny-100m"] = type(
        "M", (), {"FULL": cfg, "REDUCED": cfg})
    summary = train_main([
        "--arch", "tiny-100m", "--steps", "300", "--batch", "16",
        "--seq", "256", "--lr", "1e-3", "--fail-at", "120",
        "--save-every", "50", "--ckpt-dir", "/tmp/tiny100m_ckpt",
    ])
    assert summary["last_loss"] < summary["first_loss"] * 0.7, summary
    print("loss dropped:", summary["first_loss"], "->", summary["last_loss"],
          f"(restarts={summary['restarts']}, lost={summary['lost_steps']})")


if __name__ == "__main__":
    main()

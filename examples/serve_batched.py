"""Serve a small model with batched requests: prefill + greedy decode over
KV caches, across three different architecture families (GQA cache, MLA
compressed cache, SSM recurrent state).

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main as serve_main


def main():
    for arch in ("qwen2.5-32b", "deepseek-v3-671b", "mamba2-2.7b"):
        print(f"--- {arch} (reduced config) ---")
        serve_main(["--arch", arch, "--batch", "4",
                    "--prompt-len", "48", "--gen", "16"])


if __name__ == "__main__":
    main()

"""Paper Figs. 2–4: accumulative social welfare vs the baselines.

Each figure is ONE declarative :class:`SweepSpec` over the sweep engine —
the engine vmaps every (policy × grid-point) over the seed batch in a single
jitted call (no per-seed Python loops).
"""
from __future__ import annotations

import numpy as np

from repro.core.stats import g_logt_only
from repro.experiments import GridPoint, SweepSpec, default_policies, run_spec

T_DEFAULT = 2000
SEEDS = (41, 42, 43)

FIG2_SPECS = {
    tag: SweepSpec(
        name=f"fig2/{tag}", T=T_DEFAULT, seeds=SEEDS,
        policies=default_policies(g_fn=g),
        instance_kwargs={"seed": 0},
    )
    for tag, g in (("default_g", None), ("logt_g", g_logt_only))
}

FIG3_SPEC = SweepSpec(
    name="fig3", T=T_DEFAULT, seeds=SEEDS,
    policies=default_policies(g_fn=g_logt_only, tiebreak=0.0),
    instance_kwargs={"seed": 0},
    grid=tuple(GridPoint(f"T{T}", T=T) for T in (250, 500, 1000, 2000)),
)

FIG4_SPEC = SweepSpec(
    name="fig4", T=T_DEFAULT, seeds=(42,),
    policies=default_policies(g_fn=g_logt_only, names=("esdp",)),
    instance_kwargs={"seed": 0},
)


def fig2_asw_vs_time(rows, smoke=False):
    """ASW at t ∈ {500, 1000, 2000} for each policy (default params;
    both the paper's default g(t) and its Fig-8 winner ln(t+1))."""
    for tag, spec in FIG2_SPECS.items():
        spec = spec.smoke() if smoke else spec
        res = {r.policy: r for r in run_spec(spec)}
        marks = [min(t, spec.T) for t in (500, 1000, 2000)]
        for name, r in res.items():
            c = r.result.asw.mean(axis=0)
            rows.append((f"fig2/{tag}/{name}",
                         f"asw@{marks[0]}={c[marks[0] - 1]:.1f}",
                         f"asw@{marks[1]}={c[marks[1] - 1]:.1f};"
                         f"asw@{marks[2]}={c[marks[2] - 1]:.1f}"))
        e = res["esdp"].asw_mean
        for b in ("hswf", "lcf", "lwtf"):
            rows.append((f"fig2/{tag}/improvement_vs_{b}",
                         f"{(e / res[b].asw_mean - 1) * 100:.1f}%",
                         f"esdp={e:.1f};{b}={res[b].asw_mean:.1f}"))


def fig3_asw_ratio(rows, smoke=False):
    """Ratio ESDP/baseline vs horizon length (paper-literal baselines)."""
    spec = FIG3_SPEC.smoke() if smoke else FIG3_SPEC
    by_point: dict[str, dict] = {}
    for r in run_spec(spec):
        by_point.setdefault(r.point, {})[r.policy] = r.asw_mean
    for point, res in by_point.items():
        e = res["esdp"]
        rows.append((f"fig3/{point}",
                     f"vs_hswf={e / res['hswf']:.2f}",
                     f"vs_lcf={e / res['lcf']:.2f};"
                     f"vs_lwtf={e / res['lwtf']:.2f}"))


def fig4_avg_asw(rows, smoke=False):
    """Average per-slot welfare over the horizon — ESDP's curve steepens
    then flattens toward the oracle bound."""
    spec = FIG4_SPEC.smoke(seeds=(42,)) if smoke else FIG4_SPEC
    (r,) = run_spec(spec)
    t_axis = np.arange(1, spec.T + 1)
    avg = r.result.asw[0] / t_axis
    oracle_avg = np.cumsum(r.result.sw_oracle[0]) / t_axis
    for T in (250, 500, 1000, 2000):
        T = min(T, spec.T)
        rows.append((f"fig4/avg_asw@{T}", f"{avg[T - 1]:.3f}",
                     f"oracle={oracle_avg[T - 1]:.3f};"
                     f"frac={avg[T - 1] / oracle_avg[T - 1]:.3f}"))

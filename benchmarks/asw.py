"""Paper Figs. 2–4: accumulative social welfare vs the baselines."""
from __future__ import annotations

import numpy as np

from repro.core import (build_tables, generate_instance, make_esdp_policy,
                        make_hswf_policy, make_lcf_policy, make_lwtf_policy,
                        simulate)
from repro.core.stats import g_logt_only

T_DEFAULT = 2000
SEEDS = (41, 42, 43)


def _run_all(T=T_DEFAULT, g_fn=None, tiebreak=1e-4, seed_inst=0):
    inst = generate_instance(seed=seed_inst)
    tables = build_tables(inst.A, inst.c)
    kw = {"g_fn": g_fn} if g_fn else {}
    out = {}
    mk = {
        "esdp": lambda: make_esdp_policy(inst, T, tables=tables, **kw),
        "hswf": lambda: make_hswf_policy(inst, tiebreak=tiebreak),
        "lcf": lambda: make_lcf_policy(inst, tiebreak=tiebreak),
        "lwtf": lambda: make_lwtf_policy(inst, tiebreak=tiebreak),
    }
    for name, f in mk.items():
        runs = [simulate(inst, f(), T, seed=s, tables=tables) for s in SEEDS]
        out[name] = {
            "asw": np.mean([r.asw[-1] for r in runs]),
            "asw_curve": np.mean([r.asw for r in runs], axis=0),
            "regret": np.mean([r.cum_regret[-1] for r in runs]),
        }
    return out


def fig2_asw_vs_time(rows):
    """ASW at t ∈ {500, 1000, 2000} for each policy (default params;
    both the paper's default g(t) and its Fig-8 winner ln(t+1))."""
    for tag, g in (("default_g", None), ("logt_g", g_logt_only)):
        res = _run_all(g_fn=g)
        for name, d in res.items():
            c = d["asw_curve"]
            rows.append((f"fig2/{tag}/{name}",
                         f"asw@500={c[499]:.1f}",
                         f"asw@1000={c[999]:.1f};asw@2000={c[1999]:.1f}"))
        e = res["esdp"]["asw"]
        for b in ("hswf", "lcf", "lwtf"):
            rows.append((f"fig2/{tag}/improvement_vs_{b}",
                         f"{(e / res[b]['asw'] - 1) * 100:.1f}%",
                         f"esdp={e:.1f};{b}={res[b]['asw']:.1f}"))


def fig3_asw_ratio(rows):
    """Ratio ESDP/baseline vs horizon length (paper-literal baselines)."""
    for T in (250, 500, 1000, 2000):
        res = _run_all(T=T, g_fn=g_logt_only, tiebreak=0.0)
        e = res["esdp"]["asw"]
        rows.append((f"fig3/T{T}",
                     f"vs_hswf={e / res['hswf']['asw']:.2f}",
                     f"vs_lcf={e / res['lcf']['asw']:.2f};"
                     f"vs_lwtf={e / res['lwtf']['asw']:.2f}"))


def fig4_avg_asw(rows):
    """Average per-slot welfare over the horizon — ESDP's curve steepens
    then flattens toward the oracle bound."""
    inst = generate_instance(seed=0)
    tables = build_tables(inst.A, inst.c)
    pol = make_esdp_policy(inst, T_DEFAULT, g_fn=g_logt_only, tables=tables)
    r = simulate(inst, pol, T_DEFAULT, seed=42, tables=tables)
    avg = r.asw / np.arange(1, T_DEFAULT + 1)
    oracle_avg = np.cumsum(r.sw_oracle) / np.arange(1, T_DEFAULT + 1)
    for T in (250, 500, 1000, 2000):
        rows.append((f"fig4/avg_asw@{T}", f"{avg[T - 1]:.3f}",
                     f"oracle={oracle_avg[T - 1]:.3f};"
                     f"frac={avg[T - 1] / oracle_avg[T - 1]:.3f}"))

"""Roofline summary from the dry-run sweep (EXPERIMENTS.md §Roofline feed)."""
from __future__ import annotations

import json
import pathlib

RESULTS = pathlib.Path("results/dryrun")


def roofline_table(rows, smoke=False):
    if not RESULTS.exists():
        rows.append(("roofline/missing", "0", "run repro.launch.dryrun --all"))
        return
    recs = []
    for p in sorted(RESULTS.glob("*_single.json")):
        r = json.loads(p.read_text())
        if r.get("skipped") or r.get("error"):
            continue
        recs.append(r)
    for r in recs:
        t = r["roofline"]
        rows.append((
            f"roofline/{r['arch']}/{r['shape']}",
            f"{t['roofline_fraction']:.3f}",
            f"bottleneck={t['bottleneck']};compute={t['compute_s']:.3g};"
            f"memory={t['memory_s']:.3g};coll={t['collective_s']:.3g};"
            f"useful={t['useful_flops_ratio']:.2f};"
            f"mem_GiB={r['memory']['peak_est_bytes'] / 2**30:.1f}"))
    if recs:
        worst = min(recs, key=lambda r: r["roofline"]["roofline_fraction"])
        rows.append(("roofline/worst_cell",
                     f"{worst['roofline']['roofline_fraction']:.3f}",
                     f"{worst['arch']}/{worst['shape']}"))

"""Benchmark harness — one function per paper table/figure.

Prints ``name,value,derived`` CSV rows. Select subsets:
    python -m benchmarks.run                 # everything
    python -m benchmarks.run fig2 fig8       # substring filter
    python -m benchmarks.run fig2 --smoke    # CI-sized horizons/seeds

Every figure is a declarative sweep spec over ``repro.experiments`` — see
the per-module ``*_SPEC`` constants.
"""
from __future__ import annotations

import sys

from . import asw, overhead, roofline_bench, scenarios_bench, sensitivity

ALL = [
    asw.fig2_asw_vs_time,
    asw.fig3_asw_ratio,
    asw.fig4_avg_asw,
    overhead.fig5_overhead,
    sensitivity.fig6_solution_space,
    sensitivity.fig7_delta,
    sensitivity.fig8_g,
    sensitivity.fig9_rho,
    sensitivity.fig10_edges,
    scenarios_bench.scenario_table,
    roofline_bench.roofline_table,
]


def main() -> None:
    smoke = "--smoke" in sys.argv
    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    rows: list[tuple] = []
    print("name,value,derived")
    for fn in ALL:
        if filters and not any(f in fn.__name__ for f in filters):
            continue
        start = len(rows)
        fn(rows, smoke=smoke)
        for r in rows[start:]:
            print(",".join(str(x) for x in r), flush=True)


if __name__ == "__main__":
    main()

"""Beyond the paper: ESDP vs its strongest baseline under every registered
fluctuation regime (DVFS, MMPP bursts, stragglers, brownouts, outages).

One declarative spec per scenario — the scenario registry makes "does ESDP
still win under regime X?" a 5-line question (see docs/scenarios.md).

Run as a module for the timed benchmark (the nightly perf-trend artifact)::

    python -m benchmarks.scenarios_bench                 # full regimes
    python -m benchmarks.scenarios_bench --smoke
    python -m benchmarks.scenarios_bench --baseline results/BENCH_scenarios.json

Writes ``results/BENCH_scenarios.json``: per-scenario end-to-end sweep
wall-clock (trace + compile recorded separately from the steady-state
re-run) plus the ASW/regret summary.  ``--baseline`` applies the same
guard as ``dp_bench``: exits non-zero on a ``--max-regression``-fold
slowdown, warn-not-fail when the host fingerprint (CPU model + jax
version) differs from the committed file.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from repro.core.baselines import hswf_factory
from repro.core.esdp import esdp_factory
from repro.core.stats import g_logt_only
from repro.experiments import SweepSpec, run_spec, scenario_names

from .dp_bench import host_fingerprint

T = 800
SEEDS = (21, 22)


def _spec(scenario: str) -> SweepSpec:
    return SweepSpec(
        name=f"scenarios/{scenario}", T=T, seeds=SEEDS,
        policies={"esdp": esdp_factory(g_fn=g_logt_only),
                  "hswf": hswf_factory()},
        scenario=scenario,
        instance_kwargs={"seed": 0},
    )


def scenario_table(rows, smoke=False):
    names = scenario_names() if not smoke else ("iid", "markov_dvfs")
    for scen in names:
        spec = _spec(scen)
        if smoke:
            spec = spec.smoke()
        res = {r.policy: r for r in run_spec(spec)}
        e, h = res["esdp"], res["hswf"]
        rows.append((f"scenarios/{scen}",
                     f"esdp={e.asw_mean:.1f}",
                     f"hswf={h.asw_mean:.1f};"
                     f"oracle={e.oracle_asw_mean:.1f};"
                     f"esdp_regret={e.regret_mean:.1f}"))


def bench(smoke: bool) -> dict:
    """Time every registered regime's full sweep (both policies, all
    seeds).  The first run of a spec pays trace + compile; the second run
    hits jit caches — recording both separates compile drift from
    steady-state throughput drift in the nightly trend."""
    import jax

    names = ("iid", "markov_dvfs") if smoke else scenario_names()
    records = []
    for scen in names:
        spec = _spec(scen)
        if smoke:
            spec = spec.smoke()
        t0 = time.perf_counter()
        res = {r.policy: r for r in run_spec(spec)}
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = {r.policy: r for r in run_spec(spec)}
        warm_s = time.perf_counter() - t0
        e, h = res["esdp"], res["hswf"]
        records.append({
            "scenario": scen, "T": spec.T, "seeds": len(spec.seeds),
            "cold_s": cold_s, "warm_s": warm_s,
            "esdp_asw": e.asw_mean, "hswf_asw": h.asw_mean,
            "esdp_regret": e.regret_mean,
        })
        print(f"scenarios/{scen}: cold={cold_s:.2f}s warm={warm_s:.2f}s "
              f"esdp={e.asw_mean:.1f} hswf={h.asw_mean:.1f}", flush=True)
    return {"platform": jax.default_backend(), "jax": jax.__version__,
            "host": host_fingerprint(), "smoke": smoke, "grid": records}


def check_baseline(result: dict, base: dict, max_regression: float) -> list[str]:
    """Warm (steady-state) per-scenario wall-clock vs the committed file;
    only (scenario, T, seeds)-matched rows compare."""
    base_s = {(r["scenario"], r["T"], r["seeds"]): r["warm_s"]
              for r in base.get("grid", [])}
    failures = []
    for r in result["grid"]:
        key = (r["scenario"], r["T"], r["seeds"])
        if key not in base_s:
            continue
        if r["warm_s"] > max_regression * base_s[key]:
            failures.append(
                f"scenarios/{r['scenario']}: warm {r['warm_s']:.2f}s vs "
                f"baseline {base_s[key]:.2f}s (> {max_regression:.1f}x)")
    return failures


def main() -> None:
    from .dp_bench import apply_baseline_guard

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized regimes")
    ap.add_argument("--out", default="results/BENCH_scenarios.json")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_scenarios.json to guard against")
    ap.add_argument("--max-regression", type=float, default=2.0)
    args = ap.parse_args()
    base = None
    if args.baseline:
        bpath = pathlib.Path(args.baseline)
        if not bpath.exists():
            sys.exit(f"baseline {bpath} not found — refresh it with: "
                     "PYTHONPATH=src python -m benchmarks.scenarios_bench "
                     f"--out {bpath}")
        base = json.loads(bpath.read_text())
    out = bench(args.smoke)
    path = pathlib.Path(args.out)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=2))
    print(f"wrote {path}")
    if base is not None:
        apply_baseline_guard(out, base, args.baseline, args.max_regression,
                             check_baseline(out, base, args.max_regression))


if __name__ == "__main__":
    main()

"""Beyond the paper: ESDP vs its strongest baseline under every registered
fluctuation regime (DVFS, MMPP bursts, stragglers, brownouts, outages).

One declarative spec per scenario — the scenario registry makes "does ESDP
still win under regime X?" a 5-line question (see docs/scenarios.md).
"""
from __future__ import annotations

from repro.core.baselines import hswf_factory
from repro.core.esdp import esdp_factory
from repro.core.stats import g_logt_only
from repro.experiments import SweepSpec, run_spec, scenario_names

T = 800
SEEDS = (21, 22)


def _spec(scenario: str) -> SweepSpec:
    return SweepSpec(
        name=f"scenarios/{scenario}", T=T, seeds=SEEDS,
        policies={"esdp": esdp_factory(g_fn=g_logt_only),
                  "hswf": hswf_factory()},
        scenario=scenario,
        instance_kwargs={"seed": 0},
    )


def scenario_table(rows, smoke=False):
    names = scenario_names() if not smoke else ("iid", "markov_dvfs")
    for scen in names:
        spec = _spec(scen)
        if smoke:
            spec = spec.smoke()
        res = {r.policy: r for r in run_spec(spec)}
        e, h = res["esdp"], res["hswf"]
        rows.append((f"scenarios/{scen}",
                     f"esdp={e.asw_mean:.1f}",
                     f"hswf={h.asw_mean:.1f};"
                     f"oracle={e.oracle_asw_mean:.1f};"
                     f"esdp_regret={e.regret_mean:.1f}"))

"""Beyond the paper: ESDP vs the baseline field under every registered
fluctuation regime (DVFS, MMPP bursts, stragglers, brownouts, power-coupled
speeds, outages, server crash/repair) — the field now includes the two
Markovian-service-rate baselines (``msr_greedy`` / ``msr_index``,
arXiv:2412.08915) alongside HSWF, plus a malleable-jobs leg (rigid vs
shrink vs shrink+preempt — ``docs/scenarios.md``).

One declarative spec per scenario — the scenario registry makes "does ESDP
still win under regime X?" a 5-line question (see docs/scenarios.md).

Run as a module for the timed benchmark (the nightly perf-trend artifact)::

    python -m benchmarks.scenarios_bench                 # full regimes
    python -m benchmarks.scenarios_bench --smoke
    python -m benchmarks.scenarios_bench --baseline results/BENCH_scenarios.json
    python -m benchmarks.scenarios_bench --fault-smoke   # CI degradation leg
    python -m benchmarks.scenarios_bench --engine-smoke \
        --baseline results/BENCH_engine.json             # CI throughput gate
    python -m benchmarks.scenarios_bench --engine-full \
        --out results/BENCH_engine.json                  # refresh baseline

Writes ``results/BENCH_scenarios.json``: per-scenario end-to-end sweep
wall-clock (trace + compile recorded separately from the steady-state
re-run) plus the ASW/regret summary, the failure-aware mitigation legs
(utility recovered by redundancy / opportunistic checkpointing vs naive on
the crashy ``server_failures`` regime — docs/robustness.md), and the
fault-injection bit-exactness record.  ``--baseline`` applies the same
guard as ``dp_bench``: exits non-zero on a ``--max-regression``-fold
slowdown, warn-not-fail when the host fingerprint (CPU model + jax
version) differs from the committed file.  ``--fault-smoke`` runs ONLY
the degradation-chain check (honouring ``$REPRO_DP_FAULT_RATE``) and
exits non-zero unless the faulted run is bit-identical to the fault-free
one with at least one fault actually injected — the CI leg.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

from repro.core.baselines import (hswf_factory, msr_greedy_factory,
                                  msr_index_factory)
from repro.core.esdp import esdp_factory
from repro.core.stats import g_logt_only
from repro.experiments import SweepSpec, run_spec, scenario_names

from .dp_bench import host_fingerprint

T = 800
SEEDS = (21, 22)

# the crashy regime the mitigation legs share: frequent crashes, a lemon
# subset crashing 3x as often, and spare capacity so replicas fit
FAILURE_REGIME = dict(p_crash=0.12, p_repair=0.6, lemon_frac=0.34,
                      lemon_mult=3.0, arr_scale=0.6)


def _spec(scenario: str) -> SweepSpec:
    return SweepSpec(
        name=f"scenarios/{scenario}", T=T, seeds=SEEDS,
        policies={"esdp": esdp_factory(g_fn=g_logt_only),
                  "hswf": hswf_factory(),
                  "msr_greedy": msr_greedy_factory(),
                  "msr_index": msr_index_factory()},
        scenario=scenario,
        instance_kwargs={"seed": 0},
    )


def scenario_table(rows, smoke=False):
    names = scenario_names() if not smoke else ("iid", "markov_dvfs")
    for scen in names:
        spec = _spec(scen)
        if smoke:
            spec = spec.smoke()
        res = {r.policy: r for r in run_spec(spec)}
        e = res["esdp"]
        rows.append((f"scenarios/{scen}",
                     f"esdp={e.asw_mean:.1f}",
                     f"hswf={res['hswf'].asw_mean:.1f};"
                     f"msr_greedy={res['msr_greedy'].asw_mean:.1f};"
                     f"msr_index={res['msr_index'].asw_mean:.1f};"
                     f"oracle={e.oracle_asw_mean:.1f};"
                     f"esdp_regret={e.regret_mean:.1f}"))


def bench(smoke: bool) -> dict:
    """Time every registered regime's full sweep (both policies, all
    seeds).  The first run of a spec pays trace + compile; the second run
    hits jit caches — recording both separates compile drift from
    steady-state throughput drift in the nightly trend."""
    import jax

    names = ("iid", "markov_dvfs") if smoke else scenario_names()
    records = []
    for scen in names:
        spec = _spec(scen)
        if smoke:
            spec = spec.smoke()
        t0 = time.perf_counter()
        res = {r.policy: r for r in run_spec(spec)}
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = {r.policy: r for r in run_spec(spec)}
        warm_s = time.perf_counter() - t0
        e, h = res["esdp"], res["hswf"]
        records.append({
            "scenario": scen, "T": spec.T, "seeds": len(spec.seeds),
            "cold_s": cold_s, "warm_s": warm_s,
            "esdp_asw": e.asw_mean, "hswf_asw": h.asw_mean,
            "msr_greedy_asw": res["msr_greedy"].asw_mean,
            "msr_index_asw": res["msr_index"].asw_mean,
            "esdp_regret": e.regret_mean,
        })
        print(f"scenarios/{scen}: cold={cold_s:.2f}s warm={warm_s:.2f}s "
              f"esdp={e.asw_mean:.1f} hswf={h.asw_mean:.1f} "
              f"msr_greedy={res['msr_greedy'].asw_mean:.1f} "
              f"msr_index={res['msr_index'].asw_mean:.1f}", flush=True)
    return {"platform": jax.default_backend(), "jax": jax.__version__,
            "host": host_fingerprint(), "smoke": smoke, "grid": records}


def _failure_cluster():
    """The tiny roofline-grounded cluster the failure legs run on."""
    from repro.sched import JobType, Slice, build_instance, rate_matrix

    slices = [Slice("pod-a", "v5e", 256, 32, 4),
              Slice("pod-b", "v5e", 256, 32, 4),
              Slice("pod-c", "v5p", 256, 32, 4)]
    jobs = [JobType("train", "qwen2.5-32b", "train_4k", ("v5e", "v5p"),
                    256, 32, 4, value_rate=1.0),
            JobType("decode", "deepseek-v3-671b", "decode_32k", ("v5e",),
                    256, 32, 4, value_rate=1.2)]
    inst, _ = build_instance(slices, jobs, rate_matrix(jobs, slices), seed=0)
    return inst


def failure_bench(smoke: bool) -> list[dict]:
    """Mitigation legs on the crashy regime: how much of the utility lost
    to in-slot crashes does each failure-aware mode recover vs dispatching
    naively?  (ClusterSim host loop — the failure runtime settles crashes
    per slot, so these legs time the failure-aware path itself.)"""
    from repro.experiments import get_scenario
    from repro.sched import ClusterSim, FailureModel

    T = 200 if smoke else 600
    scn = get_scenario("server_failures", **FAILURE_REGIME)
    inst = _failure_cluster()
    legs = {
        "naive": FailureModel(),
        "redundant": FailureModel(redundancy=2),
        "checkpoint": FailureModel(checkpoints=3, checkpoint_cost=0.003),
        "detect": FailureModel(detect=True),
    }
    records = []
    for leg, model in legs.items():
        t0 = time.perf_counter()
        out = ClusterSim(inst, T, scenario=scn, seed=4,
                         failures=model).run("esdp")
        led = out.failures
        records.append({
            "leg": leg, "T": T, "wall_s": time.perf_counter() - t0,
            "asw": out.asw, "lost": led["total_lost"],
            "salvaged": led["total_salvaged"],
            "ckpt_cost": led["total_ckpt_cost"],
            "restarts": led["restarts"],
            "replicas": int(led["replicas"].sum()),
        })
        print(f"failures/{leg}: asw={out.asw:.1f} "
              f"lost={led['total_lost']:.1f} "
              f"salvaged={led['total_salvaged']:.1f} "
              f"restarts={led['restarts']}", flush=True)
    return records


def malleable_bench(smoke: bool) -> list[dict]:
    """Malleable-jobs legs: the same cluster with shrinkable gangs, rigid
    vs shrink(+grow) vs shrink+preempt (docs/scenarios.md).  Records ASW,
    the conserving work-units ledger totals, and transition counts — the
    headline is how much utility mid-flight reconfiguration buys once its
    explicit costs are ledgered."""
    from repro.sched import (ClusterSim, JobType, MalleableModel, Slice,
                             build_instance, rate_matrix)

    slices = [Slice("pod-a", "v5e", 256, 32, 4),
              Slice("pod-b", "v5e", 256, 32, 4),
              Slice("pod-c", "v5p", 256, 32, 4)]

    def _jobs(malleable):
        return [JobType("train", "qwen2.5-32b", "train_4k", ("v5e", "v5p"),
                        256, 32, 4, value_rate=1.0, malleable=malleable,
                        min_chips=128, min_hosts=16, min_ici_domains=2),
                JobType("decode", "deepseek-v3-671b", "decode_32k", ("v5e",),
                        256, 32, 4, value_rate=1.2, malleable=malleable,
                        min_chips=64, min_hosts=8, min_ici_domains=1)]

    def _inst(malleable):
        jobs = _jobs(malleable)
        return build_instance(slices, jobs, rate_matrix(jobs, slices),
                              seed=0)[0]

    T = 150 if smoke else 500
    # rigid runs the same multi-slot jobs on an instance WITHOUT shrunk
    # config edges (nothing to shrink to) — what reconfiguration buys
    legs = {
        "rigid": (_inst(False), MalleableModel(duration=4)),
        "shrink": (_inst(True), MalleableModel(duration=4)),
        "shrink_preempt": (_inst(True), MalleableModel(duration=4,
                                                       preempt=True)),
    }
    records = []
    for leg, (inst, model) in legs.items():
        t0 = time.perf_counter()
        out = ClusterSim(inst, T, seed=4, malleable=model).run("esdp")
        mal = out.malleable
        records.append({
            "leg": leg, "T": T, "wall_s": time.perf_counter() - t0,
            "asw": out.asw,
            "dispatched_units": mal["total_dispatched"],
            "done_units": mal["total_done"],
            "lost_units": mal["total_lost"],
            "reconfig_cost": mal["total_reconfig_cost"],
            "shutdown_cost": mal["total_shutdown_cost"],
            "transitions": mal["transitions"],
            "shutdowns": int(mal["shutdowns"].sum()),
            "blocked": int(mal["blocked"].sum()),
        })
        print(f"malleable/{leg}: asw={out.asw:.1f} "
              f"transitions={mal['transitions']} "
              f"blocked={int(mal['blocked'].sum())} "
              f"lost={mal['total_lost']:.1f}", flush=True)
    return records


def engine_bench(smoke: bool) -> dict:
    """Arrivals/sec of the streaming dispatch engine vs the lockstep host
    loop it replaced, on the roofline cluster.

    Legs (all seed-pinned):
      * ``lockstep``   — ``ClusterSim.run("esdp")``, the pre-engine per-slot
        host loop, at a modest horizon (it is ~100x slower per arrival);
      * ``engine``     — single-variant stream mode: the whole trace is ONE
        jitted ``lax.scan`` call;
      * ``engine_ab``  — stream mode with a 90/10 ESDP/HSWF A/B split.

    Before any timing, stream mode must be bit-identical to lockstep mode
    at a small horizon — a throughput number for a wrong engine is
    meaningless.  Full (non-smoke) mode adds the ~100k-arrival horizon the
    acceptance bar targets (engine >= 5x lockstep arrivals/sec) and stamps
    ``speedup`` / ``speedup_ok`` from that leg.
    """
    import jax
    import numpy as np

    from repro.sched import (ClusterSim, DispatchEngine, EngineConfig,
                             VariantSpec)

    inst = _failure_cluster()
    seed = 9

    # -- equivalence gate: stream == lockstep, bit for bit, or no timing --
    eng = DispatchEngine(inst, 200, seed=seed)
    o_s, o_l = eng.run(mode="stream"), eng.run(mode="lockstep")
    for f in ("sw", "regret", "n", "sumz", "queue_len"):
        if not np.array_equal(np.asarray(getattr(o_s, f)),
                              np.asarray(getattr(o_l, f))):
            raise AssertionError(
                f"engine stream/lockstep diverged on {f!r} — refusing to "
                "record a throughput number for a wrong engine")

    ab = EngineConfig(variants=(VariantSpec("esdp", weight=0.9),
                                VariantSpec("challenger", kind="hswf",
                                            weight=0.1)))
    T_lock = 200 if smoke else 400
    horizons = (3_000,) if smoke else (3_000, 56_000)
    records = []

    def record(leg, T, arrivals, wall_s, mode):
        records.append({
            "leg": leg, "T": T, "arrivals": int(arrivals),
            "wall_s": wall_s, "arrivals_per_s": arrivals / wall_s,
            "mode": mode,
        })
        print(f"engine/{leg}: T={T} arrivals={arrivals} "
              f"wall={wall_s:.2f}s -> {arrivals / wall_s:,.0f} arr/s",
              flush=True)

    # lockstep leg: second run so jit caches are warm and only the host
    # loop itself is on the clock
    arr_lock = int(DispatchEngine(inst, T_lock, seed=seed)
                   ._streams(seed)[0].sum())
    ClusterSim(inst, T_lock, seed=seed).run("esdp")
    t0 = time.perf_counter()
    ClusterSim(inst, T_lock, seed=seed).run("esdp")
    record("lockstep", T_lock, arr_lock, time.perf_counter() - t0,
           "host-loop")

    for T in horizons:
        for leg, cfg in (("engine", None), ("engine_ab", ab)):
            eng = DispatchEngine(inst, T, cfg, seed=seed)
            out = eng.run(mode="stream")  # pays trace + compile
            t0 = time.perf_counter()
            out = eng.run(mode="stream")
            wall = time.perf_counter() - t0
            record(leg, T, out.ledger["total_arrivals"], wall, "stream")

    res = {"platform": jax.default_backend(), "jax": jax.__version__,
           "host": host_fingerprint(), "smoke": smoke, "grid": records}
    lock_rate = records[0]["arrivals_per_s"]
    big = max((r for r in records if r["leg"] == "engine"),
              key=lambda r: r["T"])
    res["speedup"] = big["arrivals_per_s"] / lock_rate
    res["speedup_ok"] = bool(res["speedup"] >= 5.0)
    print(f"engine speedup vs lockstep ({big['arrivals']} arrivals): "
          f"{res['speedup']:.0f}x (>=5x: {res['speedup_ok']})", flush=True)
    return res


def check_engine_baseline(result: dict, base: dict, max_regression: float) -> list[str]:
    """Arrivals/sec per (leg, T) vs the committed file — a leg that got
    ``max_regression``-fold slower (or a speedup that fell below the 5x
    acceptance bar) fails the gate."""
    base_r = {(r["leg"], r["T"]): r["arrivals_per_s"]
              for r in base.get("grid", [])}
    failures = []
    for r in result["grid"]:
        key = (r["leg"], r["T"])
        if key not in base_r or r["leg"] == "lockstep":
            continue  # lockstep is the denominator, not the gated path
        if r["arrivals_per_s"] * max_regression < base_r[key]:
            failures.append(
                f"engine/{r['leg']} T={r['T']}: "
                f"{r['arrivals_per_s']:,.0f} arr/s vs baseline "
                f"{base_r[key]:,.0f} (> {max_regression:.1f}x slower)")
    if not result.get("speedup_ok", True):
        failures.append(
            f"engine speedup {result['speedup']:.1f}x fell below the 5x "
            "acceptance bar vs the lockstep host loop")
    return failures


def fault_injection_check(rate: "float | None" = None) -> dict:
    """The graceful-degradation acceptance bar: a full ESDP ClusterSim run
    with solver faults injected (``rate``, else ``$REPRO_DP_FAULT_RATE``)
    completes BIT-IDENTICAL to the fault-free run — every fallback link is
    exact, so degradation costs speed, never answers."""
    import numpy as np

    from repro.core.solvers import FallbackSolver
    from repro.sched import ClusterSim

    inst = _failure_cluster()
    T = 120
    plain = ClusterSim(inst, T, seed=7).run("esdp")
    fb = FallbackSolver(chain=("pallas_interpret", "reference"),
                        fault_rate=rate)
    out = ClusterSim(inst, T, seed=7, solver=fb).run("esdp")
    identical = bool(np.array_equal(plain.sw, out.sw)
                     and np.array_equal(plain.regret, out.regret))
    rec = {"T": T, "rate": fb.fault_rate, "identical": identical,
           "served_by": dict(fb.stats["served_by"]),
           **{k: v for k, v in fb.stats.items() if isinstance(v, int)}}
    print(f"fault-injection: rate={rec['rate']} "
          f"faults={rec['faults_injected']} "
          f"degraded={rec['degraded_calls']} identical={identical}",
          flush=True)

    # streaming-engine leg: lockstep mode driving the faulted degradation
    # chain must stay bit-identical to plain stream mode (every fallback
    # link is exact), with at least one degradation event actually fired
    from repro.sched import DispatchEngine, EngineConfig, VariantSpec

    eng_plain = DispatchEngine(inst, T, seed=7).run(mode="stream")
    fb_eng = FallbackSolver(chain=("pallas_interpret", "reference"),
                            fault_rate=rate)
    cfg = EngineConfig(variants=(VariantSpec("esdp", solver=fb_eng),))
    eng_fault = DispatchEngine(inst, T, cfg, seed=7).run(mode="lockstep")
    eng_identical = bool(
        np.array_equal(np.asarray(eng_plain.sw), np.asarray(eng_fault.sw))
        and np.array_equal(np.asarray(eng_plain.regret),
                           np.asarray(eng_fault.regret)))
    rec["engine"] = {
        "identical": eng_identical,
        "served_by": dict(fb_eng.stats["served_by"]),
        **{k: v for k, v in fb_eng.stats.items() if isinstance(v, int)}}
    print(f"fault-injection/engine: "
          f"faults={rec['engine']['faults_injected']} "
          f"degraded={rec['engine']['degraded_calls']} "
          f"identical={eng_identical}", flush=True)
    return rec


def check_baseline(result: dict, base: dict, max_regression: float) -> list[str]:
    """Warm (steady-state) per-scenario wall-clock vs the committed file;
    only (scenario, T, seeds)-matched rows compare."""
    base_s = {(r["scenario"], r["T"], r["seeds"]): r["warm_s"]
              for r in base.get("grid", [])}
    failures = []
    for r in result["grid"]:
        key = (r["scenario"], r["T"], r["seeds"])
        if key not in base_s:
            continue
        if r["warm_s"] > max_regression * base_s[key]:
            failures.append(
                f"scenarios/{r['scenario']}: warm {r['warm_s']:.2f}s vs "
                f"baseline {base_s[key]:.2f}s (> {max_regression:.1f}x)")
    return failures


def main() -> None:
    from .dp_bench import apply_baseline_guard

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized regimes")
    ap.add_argument("--out", default="results/BENCH_scenarios.json")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_scenarios.json to guard against")
    ap.add_argument("--max-regression", type=float, default=2.0)
    ap.add_argument("--fault-smoke", action="store_true",
                    help="run ONLY the degradation-chain bit-exactness "
                         "check (rate from $REPRO_DP_FAULT_RATE); non-zero "
                         "exit on mismatch or zero injected faults")
    ap.add_argument("--engine-smoke", action="store_true",
                    help="run ONLY the streaming-engine arrivals/sec legs "
                         "at CI size (the engine-throughput gate)")
    ap.add_argument("--engine-full", action="store_true",
                    help="run ONLY the engine legs at full size, including "
                         "the ~100k-arrival horizon the 5x acceptance bar "
                         "targets — refreshes results/BENCH_engine.json")
    args = ap.parse_args()
    if args.fault_smoke:
        rec = fault_injection_check()
        if rec["rate"] <= 0.0:
            sys.exit("fault-smoke needs a positive rate — set "
                     "REPRO_DP_FAULT_RATE (e.g. 0.05)")
        if not rec["identical"]:
            sys.exit("FAULT SMOKE FAILED: faulted run diverged from the "
                     "fault-free run — a fallback link is not exact")
        if rec["faults_injected"] == 0:
            sys.exit("FAULT SMOKE FAILED: no faults injected at rate "
                     f"{rec['rate']} over {rec['T']} solves — the hook "
                     "is not firing")
        if not rec["engine"]["identical"]:
            sys.exit("FAULT SMOKE FAILED: the streaming engine's faulted "
                     "lockstep run diverged from plain stream mode")
        if rec["engine"]["degraded_calls"] == 0:
            sys.exit("FAULT SMOKE FAILED: the engine leg fired no "
                     "degradation events — the chain never acted")
        return
    base = None
    if args.baseline:
        bpath = pathlib.Path(args.baseline)
        if not bpath.exists():
            sys.exit(f"baseline {bpath} not found — refresh it with: "
                     "PYTHONPATH=src python -m benchmarks.scenarios_bench "
                     f"--out {bpath}")
        base = json.loads(bpath.read_text())
    if args.engine_smoke or args.engine_full:
        out = engine_bench(smoke=not args.engine_full)
        path = pathlib.Path(args.out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(out, indent=2))
        print(f"wrote {path}")
        if not out["speedup_ok"]:
            sys.exit(f"ENGINE BENCH FAILED: speedup {out['speedup']:.1f}x "
                     "< 5x vs the lockstep host loop")
        if base is not None:
            apply_baseline_guard(
                out, base, args.baseline, args.max_regression,
                check_engine_baseline(out, base, args.max_regression))
        return
    out = bench(args.smoke)
    out["failures"] = failure_bench(args.smoke)
    out["malleable"] = malleable_bench(args.smoke)
    out["fault_injection"] = fault_injection_check(rate=0.05)
    path = pathlib.Path(args.out)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=2))
    print(f"wrote {path}")
    if base is not None:
        apply_baseline_guard(out, base, args.baseline, args.max_regression,
                             check_baseline(out, base, args.max_regression))


if __name__ == "__main__":
    main()

"""Timing harness for the Algorithm-2 solver backends.

Times reference vs pallas-interpret vs pallas-compiled across an (E, C, S)
grid of synthetic P4 instances and writes ``results/BENCH_dp.json``::

    python -m benchmarks.dp_bench            # full grid
    python -m benchmarks.dp_bench --smoke    # CI-sized grid
    python -m benchmarks.dp_bench --runs 20 --out results/BENCH_dp.json

The compiled-pallas leg only runs on a real TPU; elsewhere it is recorded
as skipped (the interpreter leg still exercises the kernel's program).
Per-point records include the one-off table/operand preparation cost so the
amortization argument (prepare once per instance, solve every slot) is
visible in the numbers.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dp import build_tables
from repro.core.solvers import get_solver
from repro.kernels.budgeted_dp.ops import prepare_tables

# (E, K, c_hi, u_hi): edges, device types, per-type capacity, Υ̂ range.
# C = Π(c_k+1) and S = Σ Υ̂ + 1 are reported per point.
GRID = [
    (12, 2, 2, 4),
    (24, 2, 3, 6),
    (40, 3, 2, 6),
    (64, 3, 3, 8),
]
SMOKE_GRID = [(12, 2, 2, 4), (24, 2, 3, 6)]


def _make_problem(E: int, K: int, c_hi: int, u_hi: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    A = rng.integers(1, 3, (K, E))
    c = rng.integers(1, c_hi + 1, K)
    A = np.minimum(A, c[:, None])
    ups = rng.integers(0, u_hi + 1, E).astype(np.int32)
    sig = rng.integers(1, 5000, E).astype(np.int32)
    return A, c, ups, sig


def _time_solver(solver, ups, sig, tables, s_cap, runs: int):
    # jit the whole contract call so both backends are measured compiled
    # (the reference scan would otherwise run eagerly op-by-op)
    fn = jax.jit(lambda u, s, lim: solver(u, s, tables, s_cap, lim, None))

    def call():
        x, info = fn(jnp.asarray(ups), jnp.asarray(sig), jnp.int32(s_cap))
        jax.block_until_ready((x, info["s_star"]))
        return x

    t0 = time.perf_counter()
    call()                                   # warmup: trace + compile
    warmup_ms = (time.perf_counter() - t0) * 1e3
    samples = []
    for _ in range(runs):
        t0 = time.perf_counter()
        call()
        samples.append((time.perf_counter() - t0) * 1e3)
    return {
        "warmup_ms": warmup_ms,
        "mean_ms": statistics.fmean(samples),
        "min_ms": min(samples),
        "runs": runs,
    }


def bench(grid, runs: int) -> dict:
    platform = jax.default_backend()
    backends = ["reference", "pallas_interpret", "pallas"]
    records = []
    for (E, K, c_hi, u_hi) in grid:
        A, c, ups, sig = _make_problem(E, K, c_hi, u_hi)
        t0 = time.perf_counter()
        tables = build_tables(A, c)
        build_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        prepare_tables(tables)               # one-off, cached on the tables
        prepare_ms = (time.perf_counter() - t0) * 1e3
        s_cap = int(ups.sum())
        point = {"E": E, "K": K, "n_states": tables.n_states,
                 "S": s_cap + 1, "build_tables_ms": build_ms,
                 "prepare_operands_ms": prepare_ms, "backends": {}}
        for name in backends:
            if name == "pallas" and platform != "tpu":
                point["backends"][name] = {
                    "skipped": f"compiled pallas needs TPU (platform="
                               f"{platform}); interpret leg covers the "
                               f"kernel program"}
                continue
            solver = get_solver(name)
            point["backends"][name] = _time_solver(
                solver, ups, sig, tables, s_cap, runs)
        records.append(point)
        print(f"E={E} C={tables.n_states} S={s_cap + 1}: " + "  ".join(
            f"{n}={r['mean_ms']:.2f}ms" if "mean_ms" in r else f"{n}=skip"
            for n, r in point["backends"].items()), flush=True)
    return {"platform": platform, "jax": jax.__version__, "grid": records}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized grid")
    ap.add_argument("--runs", type=int, default=10)
    ap.add_argument("--out", default="results/BENCH_dp.json")
    args = ap.parse_args()
    out = bench(SMOKE_GRID if args.smoke else GRID,
                max(1, args.runs if not args.smoke else min(args.runs, 3)))
    path = pathlib.Path(args.out)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=2))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()

"""Timing harness for the Algorithm-2 solver backends.

Times reference vs pallas-interpret vs pallas-compiled across named
(E, C, S) configs of synthetic P4 instances — including large capacity
spaces (C = 512 / 1024 / 4096) that the old (E, C, C) one-hot transition
operand could never hold in VMEM (4·E·C² = 16 MB at E=16, C=512) but the
offset-encoded kernel handles — and writes ``results/BENCH_dp.json``::

    python -m benchmarks.dp_bench            # full grid
    python -m benchmarks.dp_bench --smoke    # CI-sized grid
    python -m benchmarks.dp_bench --smoke --baseline results/BENCH_dp.json
    python -m benchmarks.dp_bench --runs 20 --out results/BENCH_dp.json

``--baseline`` compares the fresh per-config/backend mean timings against a
committed BENCH_dp.json (matched on (E, C, S, backend) so files from before
the config-naming change still compare) and exits non-zero on a
``--max-regression``-fold slowdown — the CI perf-regression guard.

The compiled-pallas leg only runs on a real TPU; elsewhere it is recorded
as skipped (the interpreter leg still exercises the kernel's program).  The
largest config additionally times the C-blocked grid path (forced tiles) as
backend ``pallas_interpret_blocked``.  Per-point records include the one-off
table/operand preparation cost plus a kernel-vs-wrapper split:
``forward_ms`` times the DP forward kernel alone, so the share spent in the
eq.-17 selection + backtrack wrapper is visible in the numbers.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dp import build_tables
from repro.core.solvers import get_solver
from repro.kernels.budgeted_dp.kernel import NEG, dp_forward_pallas
from repro.kernels.budgeted_dp.ops import prepare_tables

# Named configs: explicit capacity vector c (C = Π(c_k+1)) and Υ̂ range.
# The first four mirror the legacy (E, K, c_hi, u_hi) random draws so their
# (E, C, S) keys line up with pre-offset baselines; the large-C configs are
# the regime the offset encoding unlocks.
CONFIGS = [
    {"name": "E12_C6", "E": 12, "c_rand": (2, 2), "u_hi": 4},
    {"name": "E24_C6", "E": 24, "c_rand": (2, 3), "u_hi": 6},
    {"name": "E40_K3", "E": 40, "c_rand": (3, 2), "u_hi": 6},
    {"name": "E64_K3", "E": 64, "c_rand": (3, 3), "u_hi": 8},
    {"name": "E16_C512", "E": 16, "c": (7, 7, 7), "u_hi": 3},
    {"name": "E16_C1024", "E": 16, "c": (3, 15, 15), "u_hi": 3},
    {"name": "E16_C4096", "E": 16, "c": (7, 7, 7, 7), "u_hi": 2,
     "blocked_c": 1024},   # off_max ≈ 585 (stride of the 4th resource is
                           # 512), so the halo needs ≥ 1024-wide tiles
]
SMOKE_NAMES = ("E12_C6", "E24_C6", "E16_C512")


def _make_problem(cfg: dict, seed: int = 0):
    rng = np.random.default_rng(seed)
    E = cfg["E"]
    if "c" in cfg:
        c = np.asarray(cfg["c"], np.int64)
        K = c.shape[0]
        A = rng.integers(0, 2, (K, E))
        A[:, A.sum(axis=0) == 0] = 1         # no all-zero demand columns
    else:
        K, c_hi = cfg["c_rand"]
        A = rng.integers(1, 3, (K, E))
        c = rng.integers(1, c_hi + 1, K)
        A = np.minimum(A, c[:, None])
    ups = rng.integers(0, cfg["u_hi"] + 1, E).astype(np.int32)
    sig = rng.integers(1, 5000, E).astype(np.int32)
    return A, c, ups, sig


def _timed(call, runs: int) -> dict:
    t0 = time.perf_counter()
    call()                                   # warmup: trace + compile
    warmup_ms = (time.perf_counter() - t0) * 1e3
    samples = []
    for _ in range(runs):
        t0 = time.perf_counter()
        call()
        samples.append((time.perf_counter() - t0) * 1e3)
    return {
        "warmup_ms": warmup_ms,
        "mean_ms": statistics.fmean(samples),
        "min_ms": min(samples),
        "runs": runs,
    }


def _time_solver(solver, ups, sig, tables, s_cap, runs: int, u_max: int):
    # jit the whole contract call so both backends are measured compiled
    # (the reference scan would otherwise run eagerly op-by-op); u_max is
    # the same tight bound _time_forward uses, so the kernel-vs-wrapper
    # split compares kernels with identical scratch sizes
    fn = jax.jit(lambda u, s, lim: solver(u, s, tables, s_cap, lim, None,
                                          u_max=u_max))

    def call():
        x, info = fn(jnp.asarray(ups), jnp.asarray(sig), jnp.int32(s_cap))
        jax.block_until_ready((x, info["s_star"]))
        return x

    return _timed(call, runs)


def _time_forward(ups, sig, tables, s_cap, runs: int, interpret: bool,
                  u_max: int, block_c: int | None = None):
    """The DP forward kernel alone — the kernel side of the
    kernel-vs-wrapper split (mean_ms − forward_ms ≈ s*-rule + backtrack)."""
    feas, offs = prepare_tables(tables)
    S, C = s_cap + 1, tables.n_states
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)
    fn = jax.jit(lambda u, s: dp_forward_pallas(
        u, s, jnp.asarray(feas), jnp.asarray(offs), v0, n_edges=offs.shape[0],
        u_max=u_max, off_max=int(offs.max()),
        interpret=interpret, block_c=block_c))

    def call():
        jax.block_until_ready(fn(jnp.asarray(ups), jnp.asarray(sig)))

    return _timed(call, runs)


def bench(configs, runs: int) -> dict:
    platform = jax.default_backend()
    backends = ["reference", "pallas_interpret", "pallas"]
    records = []
    for cfg in configs:
        A, c, ups, sig = _make_problem(cfg)
        t0 = time.perf_counter()
        tables = build_tables(A, c)
        build_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        prepare_tables(tables)               # offsets + feasibility plane
        prepare_ms = (time.perf_counter() - t0) * 1e3
        s_cap = int(ups.sum())
        u_max = int(ups.max() + 1)
        point = {"config": cfg["name"], "E": cfg["E"], "K": len(c),
                 "n_states": tables.n_states, "S": s_cap + 1,
                 "build_tables_ms": build_ms,
                 "prepare_operands_ms": prepare_ms, "backends": {}}
        for name in backends:
            if name == "pallas" and platform != "tpu":
                point["backends"][name] = {
                    "skipped": f"compiled pallas needs TPU (platform="
                               f"{platform}); interpret leg covers the "
                               f"kernel program"}
                continue
            solver = get_solver(name)
            rec = _time_solver(solver, ups, sig, tables, s_cap, runs, u_max)
            if name != "reference":
                interpret = (name == "pallas_interpret" or platform != "tpu")
                fwd = _time_forward(ups, sig, tables, s_cap, runs, interpret,
                                    u_max)
                rec["forward_ms"] = fwd["mean_ms"]
                rec["wrapper_ms"] = max(rec["mean_ms"] - fwd["mean_ms"], 0.0)
            point["backends"][name] = rec
        if cfg.get("blocked_c"):
            fwd = _time_forward(ups, sig, tables, s_cap, runs,
                                platform != "tpu", u_max,
                                block_c=cfg["blocked_c"])
            point["backends"]["pallas_interpret_blocked" if platform != "tpu"
                              else "pallas_blocked"] = {
                "forward_ms": fwd["mean_ms"], "warmup_ms": fwd["warmup_ms"],
                "runs": runs, "block_c": cfg["blocked_c"]}
        records.append(point)
        print(f"{cfg['name']}: E={cfg['E']} C={tables.n_states} "
              f"S={s_cap + 1}: " + "  ".join(
                  f"{n}={r['mean_ms']:.2f}ms" if "mean_ms" in r
                  else (f"{n}[fwd]={r['forward_ms']:.2f}ms"
                        if "forward_ms" in r else f"{n}=skip")
                  for n, r in point["backends"].items()), flush=True)
    return {"platform": platform, "jax": jax.__version__, "grid": records}


def check_baseline(result: dict, base: dict,
                   max_regression: float) -> list[str]:
    """Compare per-config/backend mean timings against a committed baseline.

    Keyed on (E, n_states, S, backend) so baselines written before configs
    had names (including the one-hot-era files) still compare.  Only pairs
    present in both files are checked; returns the list of violations.
    """
    base_ms = {}
    for point in base.get("grid", []):
        for backend, rec in point["backends"].items():
            if "mean_ms" in rec:
                base_ms[(point["E"], point["n_states"], point["S"],
                         backend)] = rec["mean_ms"]
    failures = []
    for point in result["grid"]:
        for backend, rec in point["backends"].items():
            key = (point["E"], point["n_states"], point["S"], backend)
            if "mean_ms" not in rec or key not in base_ms:
                continue
            if rec["mean_ms"] > max_regression * base_ms[key]:
                failures.append(
                    f"{point.get('config', key)}/{backend}: "
                    f"{rec['mean_ms']:.2f}ms vs baseline "
                    f"{base_ms[key]:.2f}ms (> {max_regression:.1f}x)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized grid")
    ap.add_argument("--runs", type=int, default=10)
    ap.add_argument("--out", default="results/BENCH_dp.json")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_dp.json to guard against")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail when mean_ms exceeds baseline by this factor")
    args = ap.parse_args()
    configs = ([c for c in CONFIGS if c["name"] in SMOKE_NAMES]
               if args.smoke else CONFIGS)
    # read the baseline up front: --out may legitimately overwrite it
    base = None
    if args.baseline:
        bpath = pathlib.Path(args.baseline)
        if not bpath.exists():
            sys.exit(f"baseline {bpath} not found — refresh it with: "
                     f"PYTHONPATH=src python -m benchmarks.dp_bench "
                     f"--runs 30 --out {bpath}")
        base = json.loads(bpath.read_text())
    out = bench(configs,
                max(1, args.runs if not args.smoke else min(args.runs, 3)))
    path = pathlib.Path(args.out)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=2))
    print(f"wrote {path}")
    if base is not None:
        failures = check_baseline(out, base, args.max_regression)
        if failures:
            print("PERF REGRESSION vs " + args.baseline)
            for f in failures:
                print("  " + f)
            sys.exit(1)
        print(f"no >{args.max_regression:.1f}x regression vs {args.baseline}")


if __name__ == "__main__":
    main()

"""Timing harness for the Algorithm-2 solver backends.

Times reference vs pallas-interpret vs pallas-compiled across named
(E, C, S) configs of synthetic P4 instances — large capacity spaces
(C = 512 / 1024 / 4096) that the old (E, C, C) one-hot transition operand
could never hold in VMEM, and long budget axes (S = 4096 / 8192) that even
the offset-encoded whole-plane kernel cannot hold (``unblocked_vmem_bytes``
over the budget) and that run through the 2-D S-tiled pipeline — and
writes ``results/BENCH_dp.json``::

    python -m benchmarks.dp_bench            # full grid
    python -m benchmarks.dp_bench --smoke    # CI-sized grid
    python -m benchmarks.dp_bench --smoke --baseline results/BENCH_dp.json
    python -m benchmarks.dp_bench --runs 20 --out results/BENCH_dp.json

``--baseline`` compares the fresh per-config/backend mean timings against a
committed BENCH_dp.json (matched on (E, C, S, backend) so files from before
the config-naming change still compare) and exits non-zero on a
``--max-regression``-fold slowdown — the CI perf-regression guard.  The
baseline records a host fingerprint (CPU model + jax version); when the
fresh run's fingerprint differs, absolute wall-clock is not comparable and
the guard WARNS instead of failing (refresh the committed file from the CI
machine class to re-arm it).

The compiled-pallas leg only runs on a real TPU; elsewhere it is recorded
as skipped (the interpreter leg still exercises the kernel's program).
Configs with a forced ``block`` additionally time the blocked grid paths
as backend ``pallas_interpret_blocked``; every blocked/fused leg is first
checked BIT-EXACT against the reference backend on x / s* / value_row
(the acceptance contract), and its record carries the tiling plus
``unblocked_vmem_bytes`` so "impossible unblocked" is visible in the
artifact.  Per-point records include the one-off table/operand
preparation cost plus a kernel-vs-wrapper split: ``forward_ms`` times the
DP forward kernel alone, so the share spent in the eq.-17 selection +
backtrack wrapper is visible in the numbers.

Every pallas leg also records ``hbm_bytes_streamed`` — the MODELED HBM
traffic of its tiling (``kernel.modeled_hbm_bytes``; wall-clock on
interpret-CPU does not see HBM, so the model is what the nightly perf
trend tracks).  Blocked configs time BOTH the edge-fused pipeline (the
auto tiling since PR 5) and a forced per-edge-scan leg
(``pallas_interpret_scan``, same plane tiling with ``block_e=None``), and
record ``hbm_reduction_vs_scan`` — the modeled traffic ratio the fusion
buys (the PR-5 acceptance bound is ≥ 4× on E16_C512_S4096).

Configs with a ``batch`` tuple additionally time the FLEET-BATCHED legs
at each batch size B (``--smoke`` keeps only B=8): B heterogeneous solves
(per-instance Υ̂/Σ̂²/allowed/s_limit) against
``solve_budgeted_dp_batched`` — ONE launch, tables shared — next to two
single-instance baselines on identical inputs: ``*_vmapped_B{B}``
(conventional ``jax.vmap`` of the per-instance solve: still one launch,
but the feasibility plane replicates to (B, E, C)) and
``*_launch_loop_B{B}`` (``lax.map``: one launch per instance,
sequential).  Every leg is bit-exact-gated against a per-instance
reference loop before it is timed, and the batched record carries
``solves_per_sec``, ``speedup_vs_vmapped`` / ``speedup_vs_launch_loop``
(wall-clock — NOTE that on interpret-CPU all three lower to the same
vectorized XLA loops, so wall-clock parity is expected there; the
launch-grid advantage is the HBM model and launch count, measured on
real TPUs), and ``hbm_reduction_vs_vmapped`` — the modeled shared-vs-
replicated traffic ratio (``kernel.batched_modeled_hbm_bytes``).

Configs with ``incremental: True`` additionally time the CROSS-SLOT
INCREMENTAL legs (``incr_*`` backends) over a recorded post-exploration
drift trace: per-slot statistics come from the real sampling model
(``stats.scale_statistics`` at a large t₀, with (v̂, n) evolving only on
the edges each slot's solve dispatches), so the trace's repeat/drift
structure is the one the scheduler actually sees after exploration — the
⌈·⌉ in Υ̂ = ⌈ξv̂⌉ and Σ̂² = ⌈ξ²g/2n⌉ freezes the integer statistics for
long stretches once n is large.  Legs: a cold per-slot host loop
(``incr_reference`` / ``incr_pallas_interpret``), the exact-key solve
cache (``incr_reference_cached``, bit-exact-gated, cleared at the start
of every timed replay so hits come from WITHIN-trace structure only), a
quantized bounded-staleness cache (``incr_reference_cached_q`` — NOT
exact; records ``utility_gap_mean``/``utility_gap_max``, the relative
eq.-17 score loss of its solutions under the true statistics), the
warm-started reference path (``incr_reference_warm``) and the segmented
carried-plane Pallas driver (``incr_pallas_interpret_warm``).  Each
record carries ``cache_hit_rate`` / ``edge_skip_rate``, ``per_slot_ms``,
and ``speedup_vs_cold`` (the acceptance bound: the exact incremental
legs are ≥ 2× over their cold loop on the full-size trace).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform as platform_mod
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import stats as stats_mod
from repro.core.dp import build_tables, solve_budgeted_dp
from repro.core.incremental import solve_budgeted_dp_warm, warm_carry_init
from repro.core.solvers import CachedSolver, get_solver
from repro.kernels.budgeted_dp.ops import WarmPallasSolver
from repro.kernels.budgeted_dp.kernel import (
    NEG, VMEM_BUDGET_BYTES, batched_modeled_hbm_bytes, choose_tiling,
    dp_forward_pallas, modeled_hbm_bytes, unblocked_vmem_bytes)
from repro.kernels.budgeted_dp.ops import (_solve, prepare_tables,
                                           solve_budgeted_dp_batched,
                                           solve_budgeted_dp_pallas)

# Named configs: explicit capacity vector c (C = Π(c_k+1)) and Υ̂ range.
# The first four mirror the legacy (E, K, c_hi, u_hi) random draws so their
# (E, C, S) keys line up with pre-offset baselines; the large-C configs are
# the regime the offset encoding unlocks; the long-S configs (``s_cap``
# overrides the Υ̂-derived budget axis) are the long-horizon regime the
# S-tiled pipeline unlocks — their plane is impossible unblocked
# (``unblocked_vmem_bytes`` > budget, asserted at run time).
CONFIGS = [
    {"name": "E12_C6", "E": 12, "c_rand": (2, 2), "u_hi": 4},
    {"name": "E24_C6", "E": 24, "c_rand": (2, 3), "u_hi": 6},
    {"name": "E40_K3", "E": 40, "c_rand": (3, 2), "u_hi": 6},
    {"name": "E64_K3", "E": 64, "c_rand": (3, 3), "u_hi": 8},
    {"name": "E16_C512", "E": 16, "c": (7, 7, 7), "u_hi": 3,
     "batch": (8, 64), "incremental": True},
    {"name": "E16_C1024", "E": 16, "c": (3, 15, 15), "u_hi": 3},
    {"name": "E16_C4096", "E": 16, "c": (7, 7, 7, 7), "u_hi": 2,
     "block": (8, None, 1024)},  # off_max ≈ 585 (stride of the 4th resource
                                 # is 512), so the halo needs ≥ 1024 tiles;
                                 # fused in chunks of 8 edges
    {"name": "E16_C512_S4096", "E": 16, "c": (7, 7, 7), "u_hi": 3,
     "s_cap": 4095, "verify": True},
    {"name": "E16_C512_S8192", "E": 16, "c": (7, 7, 7), "u_hi": 3,
     "s_cap": 8191, "verify": True},
]
SMOKE_NAMES = ("E12_C6", "E24_C6", "E16_C512", "E16_C512_S4096")


def _make_problem(cfg: dict, seed: int = 0):
    rng = np.random.default_rng(seed)
    E = cfg["E"]
    if "c" in cfg:
        c = np.asarray(cfg["c"], np.int64)
        K = c.shape[0]
        A = rng.integers(0, 2, (K, E))
        A[:, A.sum(axis=0) == 0] = 1  # no all-zero demand columns
    else:
        K, c_hi = cfg["c_rand"]
        A = rng.integers(1, 3, (K, E))
        c = rng.integers(1, c_hi + 1, K)
        A = np.minimum(A, c[:, None])
    ups = rng.integers(0, cfg["u_hi"] + 1, E).astype(np.int32)
    sig = rng.integers(1, 5000, E).astype(np.int32)
    return A, c, ups, sig


def host_fingerprint() -> dict:
    """CPU model + jax version: the facts that make absolute wall-clock
    comparable between a fresh run and a committed baseline."""
    cpu = platform_mod.processor() or platform_mod.machine()
    try:
        with open("/proc/cpuinfo") as fh:
            for line in fh:
                if line.lower().startswith("model name"):
                    cpu = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    return {"cpu": cpu, "jax": jax.__version__}


def _timed(call, runs: int) -> dict:
    t0 = time.perf_counter()
    call()  # warmup: trace + compile
    warmup_ms = (time.perf_counter() - t0) * 1e3
    samples = []
    for _ in range(runs):
        t0 = time.perf_counter()
        call()
        samples.append((time.perf_counter() - t0) * 1e3)
    return {
        "warmup_ms": warmup_ms,
        "mean_ms": statistics.fmean(samples),
        "min_ms": min(samples),
        "runs": runs,
    }


def _time_solver(solver, ups, sig, tables, s_cap, runs: int, u_max: int):
    # jit the whole contract call so both backends are measured compiled
    # (the reference scan would otherwise run eagerly op-by-op); u_max is
    # the same tight bound _time_forward uses, so the kernel-vs-wrapper
    # split compares kernels with identical scratch sizes
    fn = jax.jit(lambda u, s, lim: solver(u, s, tables, s_cap, lim, None,
                                          u_max=u_max))

    def call():
        x, info = fn(jnp.asarray(ups), jnp.asarray(sig), jnp.int32(s_cap))
        jax.block_until_ready((x, info["s_star"]))
        return x

    return _timed(call, runs)


def _time_forward(
    ups,
    sig,
    tables,
    s_cap,
    runs: int,
    interpret: bool,
    u_max: int,
    block_c: int | None = None,
    block_s: int | None = None,
    block_e: int | None = None,
):
    """The DP forward kernel alone — the kernel side of the
    kernel-vs-wrapper split (mean_ms − forward_ms ≈ s*-rule + backtrack)."""
    feas, offs = prepare_tables(tables)
    S, C = s_cap + 1, tables.n_states
    v0 = jnp.full((S, C), NEG, jnp.float32).at[0, :].set(0.0)
    fn = jax.jit(lambda u, s: dp_forward_pallas(
        u, s, jnp.asarray(feas), jnp.asarray(offs), v0, n_edges=offs.shape[0],
        u_max=u_max, off_max=int(offs.max()),
        interpret=interpret, block_c=block_c, block_s=block_s,
        block_e=block_e))

    def call():
        jax.block_until_ready(fn(jnp.asarray(ups), jnp.asarray(sig)))

    return _timed(call, runs)


def _hbm_model(
    tables, s_cap: int, E: int, u_max: int, block_e, block_s, block_c
) -> int:
    """Modeled HBM bytes streamed by one forward solve under a tiling."""
    _, offs = prepare_tables(tables)
    return modeled_hbm_bytes(s_cap + 1, tables.n_states, E, u_max,
                             int(offs.max()), block_e, block_s, block_c)


def _verify_blocked_bitexact(
    ups,
    sig,
    tables,
    s_cap,
    u_max: int,
    block_s,
    block_c,
    interpret: bool,
    block_e=None,
    ref=None,
) -> None:
    """Acceptance contract for the blocked/tiled/fused legs: x, s*, and
    the feasibility-normalized value row are bit-exact vs the reference
    backend.  Raises on any mismatch — a wrong kernel must fail the
    benchmark, not record a fast wrong number.  ``ref`` is an optional
    precomputed reference solution — configs gating several legs solve
    the (slow, exact) reference once and share it."""
    x_ref, info_ref = ref if ref is not None else solve_budgeted_dp(
        jnp.asarray(ups, jnp.int32), jnp.asarray(sig, jnp.int32), tables,
        s_cap, jnp.int32(s_cap))
    x_t, info_t = solve_budgeted_dp_pallas(
        ups, sig, tables, s_cap, s_cap, u_max=u_max, interpret=interpret,
        block_c=block_c, block_s=block_s, block_e=block_e)
    np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x_t))
    assert int(info_ref["s_star"]) == int(info_t["s_star"])
    row_ref = np.asarray(info_ref["value_row"]).astype(np.int64)
    row_t = np.asarray(info_t["value_row"])
    np.testing.assert_array_equal(row_ref >= 0, row_t >= 0)
    np.testing.assert_array_equal(row_ref[row_ref >= 0],
                                  row_t[row_t >= 0].astype(np.int64))


def _bench_batched(
    point: dict,
    cfg: dict,
    tables,
    s_cap: int,
    u_max: int,
    runs: int,
    platform: str,
    B: int,
) -> None:
    """The fleet-batched legs for one batch size B: batched megakernel vs
    conventionally-vmapped vs launch-loop baselines, all on the SAME
    heterogeneous fleet, all bit-exact-gated before timing."""
    rng = np.random.default_rng(100 + B)
    E = cfg["E"]
    S, C = s_cap + 1, tables.n_states
    ups = rng.integers(0, cfg["u_hi"] + 1, (B, E)).astype(np.int32)
    sig = rng.integers(1, 5000, (B, E)).astype(np.int32)
    alw = rng.integers(0, 2, (B, E)).astype(np.int32)
    slim = rng.integers(0, s_cap + 1, B).astype(np.int32)
    interpret = platform != "tpu"
    tag = "pallas_interpret" if interpret else "pallas"
    feas, offs = prepare_tables(tables)
    off_max = int(offs.max())
    bb, be, bs, bc = choose_tiling(S, C, E, u_max, off_max, batch=B)

    def batched_call(u, s, l, a):
        x, info = solve_budgeted_dp_batched(u, s, tables, s_cap, l,
                                            u_max=u_max, allowed=a,
                                            interpret=interpret)
        return x, info["s_star"], info["value_row"]

    fn_batched = jax.jit(batched_call)
    # conventional vmap of the per-instance solve: ONE launch too, but the
    # eligibility fold materializes B copies of the feasibility plane —
    # the replicated-operand lowering the custom batching rule replaces
    single_kw = dict(s_cap=s_cap, u_max=u_max, off_max=off_max,
                     full_state=tables.full_state, interpret=interpret,
                     block_c=None, block_s=None, block_e=None)
    feas_j, offs_j = jnp.asarray(feas), jnp.asarray(offs)

    def one(u, s, l, a):
        return _solve(u, s, feas_j * a.astype(jnp.float32)[:, None],
                      offs_j, l, **single_kw)

    fn_vmapped = jax.jit(jax.vmap(one))
    fn_loop = jax.jit(lambda U, Sg, L, Al: jax.lax.map(
        lambda t: one(*t), (U, Sg, L, Al)))

    args = (jnp.asarray(ups), jnp.asarray(sig), jnp.asarray(slim),
            jnp.asarray(alw))
    # bit-exact gate: every leg vs a per-instance reference loop
    got = {"batched": fn_batched(*args), "vmapped": fn_vmapped(*args),
           "launch_loop": fn_loop(*args)}
    for b in range(B):
        x_ref, info_ref = solve_budgeted_dp(
            jnp.asarray(ups[b]), jnp.asarray(sig[b]), tables, s_cap,
            int(slim[b]), allowed=jnp.asarray(alw[b]))
        for leg, (x, s_star, _) in got.items():
            np.testing.assert_array_equal(
                np.asarray(x[b]), np.asarray(x_ref),
                err_msg=f"{leg} B={B} instance {b}")
            assert int(s_star[b]) == int(info_ref["s_star"]), (leg, B, b)

    one_hbm = modeled_hbm_bytes(S, C, E, u_max, off_max, None, None, None)
    batched_hbm = batched_modeled_hbm_bytes(S, C, E, u_max, off_max, B,
                                            be, bs, bc)
    recs = {}
    for leg, fn in (("batched", fn_batched), ("vmapped", fn_vmapped),
                    ("launch_loop", fn_loop)):
        rec = _timed(lambda fn=fn: jax.block_until_ready(fn(*args)), runs)
        rec["batch"] = B
        rec["solves_per_sec"] = B / (rec["mean_ms"] / 1e3)
        rec["hbm_bytes_streamed"] = (batched_hbm if leg == "batched"
                                     else B * one_hbm)
        recs[leg] = rec
    recs["batched"]["bitexact_vs_reference"] = True
    recs["batched"]["tiling"] = {"block_b": bb, "block_e": be,
                                 "block_s": bs, "block_c": bc}
    recs["batched"]["speedup_vs_vmapped"] = (
        recs["vmapped"]["mean_ms"] / recs["batched"]["mean_ms"])
    recs["batched"]["speedup_vs_launch_loop"] = (
        recs["launch_loop"]["mean_ms"] / recs["batched"]["mean_ms"])
    recs["batched"]["hbm_reduction_vs_vmapped"] = B * one_hbm / batched_hbm
    for leg, rec in recs.items():
        point["backends"][f"{tag}_{leg}_B{B}"] = rec


def _record_drift_trace(
    E: int, tables, s_cap: int, slots: int, seed: int = 7, t0: int = 200_000
):
    """A recorded post-exploration slot trace with HONEST drift structure.

    Statistics come from the paper's sampling model, not a synthetic
    mutation schedule: at slot i the scaled (Υ̂, Σ̂², s_limit) are
    ``stats.scale_statistics(v̂, n, t₀+i, m)``, and (v̂, n) then evolve
    ONLY on the edges the (exact, reference) solve dispatches — a running
    mean over fresh speed samples and a visit-count increment.  With n in
    the hundreds and t₀ ≫ 1 the ceilings freeze the integer statistics
    for long stretches, which is precisely the repeat structure the
    incremental layers exploit.  Eligibility is near-saturated (a single
    random dropout on ~10% of slots) — the heavy-load regime.

    Returns (trace, cold_out, m, u_max): per-slot concrete inputs, the
    cold reference outputs (the bit-exact gate for every incremental
    leg), the server count m sized so ξ(t_end)·m fits the config's
    budget axis, and the tight Υ̂ bound for the Pallas legs.
    """
    rng = np.random.default_rng(seed)
    t_end = float(t0 + slots)
    m = 0
    while int(stats_mod.xi_of(jnp.float32(t_end), m + 1)) * (m + 1) <= s_cap:
        m += 1
    if m == 0:
        return None, None, 0, 0
    u_max = int(stats_mod.xi_of(jnp.float32(t_end), m)) + 1

    mu = rng.uniform(0.2, 1.0, E)
    vhat = np.clip(mu + rng.normal(0, 0.02, E), 0.0, 1.0)
    n = rng.integers(200, 800, E).astype(np.int64)

    ref = get_solver("reference")
    fn = jax.jit(lambda u, s, lim, a: ref(u, s, tables, s_cap, lim,
                                          allowed=a))
    trace, cold_out = [], []
    for i in range(slots):
        ups, sig, _, s_limit = stats_mod.scale_statistics(
            jnp.asarray(vhat, jnp.float32), jnp.asarray(n, jnp.int32),
            jnp.float32(t0 + i), m)
        ups, sig = np.asarray(ups, np.int32), np.asarray(sig, np.int32)
        lim = min(int(s_limit), s_cap)
        alw = np.ones(E, bool)
        if rng.random() < 0.1:
            alw[rng.integers(0, E)] = False
        x, info = fn(jnp.asarray(ups), jnp.asarray(sig), jnp.int32(lim),
                     jnp.asarray(alw))
        x = np.asarray(x)
        trace.append((ups, sig, alw, lim))
        cold_out.append((x, int(info["s_star"]),
                         np.asarray(info["value_row"])))
        for e in np.flatnonzero(x):  # (v̂, n) drift on dispatch only
            v = float(np.clip(rng.normal(mu[e], 0.05), 0.0, 1.0))
            vhat[e] = (vhat[e] * n[e] + v) / (n[e] + 1)
            n[e] += 1
    return trace, cold_out, m, u_max


def _eq17_score(x, ups, sig, s_limit) -> float:
    """The eq.-17 objective a concrete solution realizes under the TRUE
    statistics — the utility meter for the approximate cache leg."""
    s = min(int(ups @ x), int(s_limit))
    return s + float(np.sqrt(max(int(sig @ x), 0)))


def _bench_incremental(
    point: dict, cfg: dict, tables, s_cap: int, runs: int, platform: str, slots: int
) -> None:
    """The cross-slot incremental legs over one recorded drift trace."""
    E = cfg["E"]
    trace, cold_out, m, u_max = _record_drift_trace(E, tables, s_cap, slots)
    if trace is None:
        point["incremental"] = {"skipped": "budget axis too small for the "
                                           "sampling model (m=0)"}
        return
    point["incremental"] = {"slots": slots, "m": m, "t0": 200_000,
                            "u_max": u_max}
    interpret = platform != "tpu"
    pal_tag = "pallas_interpret" if interpret else "pallas"
    ref, pal = get_solver("reference"), get_solver(
        "pallas_interpret" if interpret else "pallas")

    def gate(outs, leg):
        """Bit-exact acceptance vs the recorded cold reference outputs."""
        for i, ((x, s_star, row), (xc, sc, rowc)) in enumerate(
                zip(outs, cold_out)):
            np.testing.assert_array_equal(np.asarray(x), xc,
                                          err_msg=f"{leg} slot {i}")
            assert int(s_star) == sc, (leg, i)
            np.testing.assert_array_equal(np.asarray(row), rowc,
                                          err_msg=f"{leg} slot {i}")

    def loop_solver(solver):
        fn = jax.jit(lambda u, s, lim, a: solver(u, s, tables, s_cap, lim,
                                                 allowed=a, u_max=u_max))

        def run():
            out = []
            for u, s, a, lim in trace:
                x, info = fn(jnp.asarray(u), jnp.asarray(s), jnp.int32(lim),
                             jnp.asarray(a))
                jax.block_until_ready(x)
                out.append((x, info["s_star"], info["value_row"]))
            return out

        return run

    recs = {}

    # cold per-slot host loops: the speedup denominators
    run_ref_cold = loop_solver(ref)
    recs["incr_reference"] = _timed(run_ref_cold, runs)
    run_pal_cold = loop_solver(pal)
    gate(run_pal_cold(), f"incr_{pal_tag}")
    recs[f"incr_{pal_tag}"] = _timed(run_pal_cold, runs)
    recs[f"incr_{pal_tag}"]["bitexact_vs_cold"] = True

    # exact-key solve cache: cleared per replay — hits are within-trace
    cached = CachedSolver(ref)

    def run_cached():
        cached.cache.clear()
        return [cached(u, s, tables, s_cap, int(lim), allowed=a,
                       u_max=u_max) + (None,)
                for u, s, a, lim in trace]

    gate([(x, info["s_star"], info["value_row"])
          for x, info, _ in run_cached()], "incr_reference_cached")
    hit_rate = cached.stats.hit_rate
    rec = _timed(run_cached, runs)
    rec.update(cache_hit_rate=hit_rate, exact=True, bitexact_vs_cold=True)
    recs["incr_reference_cached"] = rec

    # quantized bounded-staleness cache: NOT exact — measure the utility
    # gap of its solutions under the true per-slot statistics
    cached_q = CachedSolver(ref, q_ups=2, q_sig=64, max_stale=2 * slots)

    def run_cached_q():
        cached_q.cache.clear()
        return [cached_q(u, s, tables, s_cap, int(lim), allowed=a,
                         u_max=u_max)
                for u, s, a, lim in trace]

    gaps = []
    for (x, _), (u, s, a, lim), (xc, _, _) in zip(run_cached_q(), trace,
                                                  cold_out):
        best = _eq17_score(xc, u, s, lim)
        gaps.append((best - _eq17_score(np.asarray(x), u, s, lim))
                    / max(best, 1.0))
    rec = _timed(run_cached_q, runs)
    rec.update(cache_hit_rate=cached_q.stats.hit_rate, exact=False,
               q_ups=2, q_sig=64,
               utility_gap_mean=float(np.mean(gaps)),
               utility_gap_max=float(np.max(gaps)))
    recs["incr_reference_cached_q"] = rec

    # warm-started reference: carry re-initialized per replay
    wfn = jax.jit(lambda u, s, lim, a, cr: solve_budgeted_dp_warm(
        u, s, tables, s_cap, lim, cr, allowed=a))

    def run_warm_ref():
        cr = warm_carry_init(E, s_cap, tables.n_states)
        out, folded = [], 0
        for u, s, a, lim in trace:
            x, info, cr = wfn(jnp.asarray(u), jnp.asarray(s),
                              jnp.int32(lim), jnp.asarray(a), cr)
            jax.block_until_ready(x)
            folded += int(info["edges_folded"])
            out.append((x, info["s_star"], info["value_row"]))
        return out, folded

    out, folded = run_warm_ref()
    gate(out, "incr_reference_warm")
    rec = _timed(lambda: run_warm_ref(), runs)
    rec.update(edge_skip_rate=1.0 - folded / (len(trace) * E), exact=True,
               bitexact_vs_cold=True)
    recs["incr_reference_warm"] = rec

    # segmented carried-plane Pallas driver: reset per replay
    warm_pal = WarmPallasSolver(tables, s_cap, u_max=u_max,
                                interpret=interpret)

    def run_warm_pal():
        warm_pal.reset()
        return [warm_pal(u, s, tables, s_cap, lim, allowed=a)
                for u, s, a, lim in trace]

    gate([(x, info["s_star"], info["value_row"])
          for x, info in run_warm_pal()], f"incr_{pal_tag}_warm")
    rec = _timed(run_warm_pal, runs)
    rec.update(edge_skip_rate=warm_pal.skip_rate, exact=True,
               bitexact_vs_cold=True)
    recs[f"incr_{pal_tag}_warm"] = rec

    for leg, rec in recs.items():
        rec["slots"] = slots
        rec["per_slot_ms"] = rec["mean_ms"] / slots
        cold = ("incr_reference" if leg.startswith("incr_reference")
                else f"incr_{pal_tag}")
        if leg != cold:
            rec["speedup_vs_cold"] = (recs[cold]["mean_ms"]
                                      / rec["mean_ms"])
        point["backends"][leg] = rec


def bench(configs, runs: int, incr_slots: int = 120) -> dict:
    platform = jax.default_backend()
    backends = ["reference", "pallas_interpret", "pallas"]
    records = []
    for cfg in configs:
        A, c, ups, sig = _make_problem(cfg)
        t0 = time.perf_counter()
        tables = build_tables(A, c)
        build_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        feas, offs = prepare_tables(tables)  # offsets + feasibility plane
        prepare_ms = (time.perf_counter() - t0) * 1e3
        s_cap = int(cfg.get("s_cap", ups.sum()))
        u_max = int(ups.max() + 1)
        S, C = s_cap + 1, tables.n_states
        off_max = int(offs.max())
        unblocked = unblocked_vmem_bytes(S, C, cfg["E"], u_max, off_max)
        # the tiling the pallas backends auto-resolve for this plane: the
        # solver legs below time exactly that execution path, so the
        # long-S configs get an end-to-end mean_ms AND a kernel-vs-wrapper
        # split through the edge-fused S-tiled pipeline, not just a
        # forward number
        block_e, block_s, block_c = choose_tiling(S, C, cfg["E"], u_max,
                                                  off_max)
        auto_hbm = _hbm_model(tables, s_cap, cfg["E"], u_max,
                              block_e, block_s, block_c)
        point = {"config": cfg["name"], "E": cfg["E"], "K": len(c),
                 "n_states": C, "S": S,
                 "build_tables_ms": build_ms,
                 "prepare_operands_ms": prepare_ms,
                 "unblocked_vmem_bytes": unblocked,
                 "vmem_budget_bytes": VMEM_BUDGET_BYTES,
                 "tiling": {"block_e": block_e, "block_s": block_s,
                            "block_c": block_c},
                 "hbm_bytes_streamed": auto_hbm,
                 "backends": {}}
        # one exact reference solution per config, shared by every
        # bit-exact gate below (it is the slowest solve on the long-S
        # configs — never compute it twice)
        ref = None
        if cfg.get("verify") or cfg.get("block") or (
                block_c is not None and block_e is not None):
            ref = solve_budgeted_dp(
                jnp.asarray(ups, jnp.int32), jnp.asarray(sig, jnp.int32),
                tables, s_cap, jnp.int32(s_cap))
        if cfg.get("verify"):
            _verify_blocked_bitexact(ups, sig, tables, s_cap, u_max,
                                     block_s, block_c, platform != "tpu",
                                     block_e=block_e, ref=ref)
            point["bitexact_vs_reference"] = True
        for name in backends:
            if name == "pallas" and platform != "tpu":
                point["backends"][name] = {
                    "skipped": "compiled pallas needs TPU (platform="
                               f"{platform}); interpret leg covers the "
                               "kernel program"}
                continue
            solver = get_solver(name)
            rec = _time_solver(solver, ups, sig, tables, s_cap, runs, u_max)
            if name != "reference":
                interpret = (name == "pallas_interpret" or platform != "tpu")
                fwd = _time_forward(ups, sig, tables, s_cap, runs, interpret,
                                    u_max, block_c=block_c, block_s=block_s,
                                    block_e=block_e)
                rec["forward_ms"] = fwd["mean_ms"]
                rec["wrapper_ms"] = max(rec["mean_ms"] - fwd["mean_ms"], 0.0)
                rec["hbm_bytes_streamed"] = auto_hbm
                if block_c is not None:
                    rec["block_e"] = block_e
                    rec["block_s"], rec["block_c"] = block_s, block_c
            point["backends"][name] = rec
        if block_c is not None and block_e is not None:
            # the fused-vs-scan comparison: the SAME plane tiling forced
            # through the per-edge-scan pipeline (one pallas_call per
            # edge), bit-exact-gated, so the artifact shows what the
            # fusion buys in wall-clock AND modeled HBM traffic
            interpret = platform != "tpu"
            _verify_blocked_bitexact(ups, sig, tables, s_cap, u_max,
                                     block_s, block_c, interpret,
                                     block_e=None, ref=ref)
            fwd = _time_forward(ups, sig, tables, s_cap, runs, interpret,
                                u_max, block_c=block_c, block_s=block_s,
                                block_e=None)
            scan_hbm = _hbm_model(tables, s_cap, cfg["E"], u_max,
                                  None, block_s, block_c)
            point["backends"]["pallas_interpret_scan" if interpret
                              else "pallas_scan"] = {
                "forward_ms": fwd["mean_ms"], "warmup_ms": fwd["warmup_ms"],
                "runs": runs, "block_c": block_c, "block_s": block_s,
                "block_e": None, "hbm_bytes_streamed": scan_hbm}
            point["hbm_reduction_vs_scan"] = scan_hbm / auto_hbm
        if cfg.get("block"):
            # additionally time a FORCED tiling (e.g. the fused C-blocked
            # grid on a plane that also fits whole-plane, for comparison)
            fbe, fbs, fbc = cfg["block"]
            interpret = platform != "tpu"
            _verify_blocked_bitexact(ups, sig, tables, s_cap, u_max,
                                     fbs, fbc, interpret, block_e=fbe,
                                     ref=ref)
            fwd = _time_forward(ups, sig, tables, s_cap, runs, interpret,
                                u_max, block_c=fbc, block_s=fbs,
                                block_e=fbe)
            point["backends"]["pallas_interpret_blocked" if interpret
                              else "pallas_blocked"] = {
                "forward_ms": fwd["mean_ms"], "warmup_ms": fwd["warmup_ms"],
                "runs": runs, "block_c": fbc, "block_s": fbs,
                "block_e": fbe,
                "hbm_bytes_streamed": _hbm_model(tables, s_cap, cfg["E"],
                                                 u_max, fbe, fbs, fbc)}
        for B in cfg.get("batch", ()):
            _bench_batched(point, cfg, tables, s_cap, u_max, runs,
                           platform, B)
        if cfg.get("incremental"):
            _bench_incremental(point, cfg, tables, s_cap, runs, platform,
                               incr_slots)
        records.append(point)
        print(f"{cfg['name']}: E={cfg['E']} C={C} "
              f"S={S}: " + "  ".join(
                  f"{n}={r['mean_ms']:.2f}ms" if "mean_ms" in r
                  else (f"{n}[fwd]={r['forward_ms']:.2f}ms"
                        if "forward_ms" in r else f"{n}=skip")
                  for n, r in point["backends"].items()), flush=True)
    return {"platform": platform, "jax": jax.__version__,
            "host": host_fingerprint(), "grid": records}


def _guard_ms(rec: dict):
    """The guarded timing of one backend record: the end-to-end mean when
    present, else the forward-only mean (the blocked/tiled legs)."""
    return rec.get("mean_ms", rec.get("forward_ms"))


def check_baseline(result: dict, base: dict, max_regression: float) -> list[str]:
    """Compare per-config/backend timings against a committed baseline.

    Keyed on (E, n_states, S, backend) so baselines written before configs
    had names (including the one-hot-era files) still compare.  Only pairs
    present in both files are checked; returns the list of violations.
    """
    base_ms = {}
    for point in base.get("grid", []):
        for backend, rec in point["backends"].items():
            if _guard_ms(rec) is not None:
                base_ms[(point["E"], point["n_states"], point["S"],
                         backend)] = _guard_ms(rec)
    failures = []
    for point in result["grid"]:
        for backend, rec in point["backends"].items():
            key = (point["E"], point["n_states"], point["S"], backend)
            got = _guard_ms(rec)
            if got is None or key not in base_ms:
                continue
            if got > max_regression * base_ms[key]:
                failures.append(
                    f"{point.get('config', key)}/{backend}: "
                    f"{got:.2f}ms vs baseline "
                    f"{base_ms[key]:.2f}ms (> {max_regression:.1f}x)")
    return failures


def fingerprints_match(result: dict, base: dict) -> bool:
    """Absolute wall-clock only compares within one machine class: same CPU
    model and jax version.  Baselines from before fingerprints were
    recorded never match (they cannot be attributed to a host)."""
    fresh, committed = result.get("host"), base.get("host")
    return bool(fresh and committed and fresh == committed)


def apply_baseline_guard(
    result: dict, base: dict, baseline_path: str, max_regression: float, failures: list
) -> None:
    """Shared guard epilogue (dp_bench and scenarios_bench): fail the run
    on regressions within one machine class, warn when the host
    fingerprint differs (absolute wall-clock is not comparable across
    machines — refresh the committed baseline from the comparison machine
    class to re-arm the strict check)."""
    if failures and not fingerprints_match(result, base):
        print("WARNING: host fingerprint differs from baseline "
              f"({result.get('host')} vs {base.get('host')}); "
              "would-be regressions reported as warnings only — refresh "
              f"{baseline_path} from the comparison machine to re-arm")
        for f in failures:
            print("  WARN " + f)
    elif failures:
        print("PERF REGRESSION vs " + baseline_path)
        for f in failures:
            print("  " + f)
        sys.exit(1)
    else:
        print(f"no >{max_regression:.1f}x regression vs {baseline_path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="CI-sized grid")
    ap.add_argument("--runs", type=int, default=10)
    ap.add_argument("--out", default="results/BENCH_dp.json")
    ap.add_argument("--baseline", default=None,
                    help="committed BENCH_dp.json to guard against")
    ap.add_argument("--max-regression", type=float, default=2.0,
                    help="fail when mean_ms exceeds baseline by this factor")
    args = ap.parse_args()
    configs = ([c for c in CONFIGS if c["name"] in SMOKE_NAMES]
               if args.smoke else CONFIGS)
    if args.smoke:  # CI sizes: keep only the B=8 fleet leg
        configs = [dict(c, batch=tuple(b for b in c["batch"] if b == 8))
                   if "batch" in c else c for c in configs]
    # read the baseline up front: --out may legitimately overwrite it
    base = None
    if args.baseline:
        bpath = pathlib.Path(args.baseline)
        if not bpath.exists():
            sys.exit(f"baseline {bpath} not found — refresh it with: "
                     "PYTHONPATH=src python -m benchmarks.dp_bench "
                     f"--runs 30 --out {bpath}")
        base = json.loads(bpath.read_text())
    out = bench(configs,
                max(1, args.runs if not args.smoke else min(args.runs, 3)),
                incr_slots=32 if args.smoke else 120)
    path = pathlib.Path(args.out)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=2))
    print(f"wrote {path}")
    if base is not None:
        apply_baseline_guard(out, base, args.baseline, args.max_regression,
                             check_baseline(out, base, args.max_regression))


if __name__ == "__main__":
    main()

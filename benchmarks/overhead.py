"""Paper Fig. 5: ESDP computation overhead vs bipartite-graph scale.

Timed through the sweep engine's batched entry point: the steady-state
column is a cached-jit single-seed run, and the ``batch8`` column shows the
per-slot cost when the SAME jitted program is vmapped over 8 seeds — the
amortization that makes scenario sweeps cheap.
"""
from __future__ import annotations

import time

from repro.core import (build_tables, generate_instance, make_esdp_policy,
                        simulate_batch)


def fig5_overhead(rows, smoke=False):
    shapes = ((8, 40, 0.1), (8, 80, 0.1), (16, 80, 0.1), (16, 160, 0.1))
    if smoke:
        shapes = shapes[:1]
    for (L, R, p) in shapes:
        inst = generate_instance(seed=1, n_ports=L, n_servers=R, edge_prob=p)
        tables = build_tables(inst.A, inst.c)
        T = 200
        pol = make_esdp_policy(inst, T, tables=tables)
        t0 = time.time()
        simulate_batch(inst, pol, T, (0,), tables=tables)  # includes jit
        compile_and_run = time.time() - t0
        t0 = time.time()
        simulate_batch(inst, pol, T, (1,), tables=tables)  # cached jit
        steady = time.time() - t0
        us = steady / T * 1e6
        simulate_batch(inst, pol, T, tuple(range(2, 10)), tables=tables)
        t0 = time.time()  # batch-shape jit cached
        simulate_batch(inst, pol, T, tuple(range(10, 18)), tables=tables)
        batch_us = (time.time() - t0) / (8 * T) * 1e6
        rows.append((f"fig5/L{L}_R{R}_E{inst.n_edges}", f"{us:.0f}",
                     f"compile+run_s={compile_and_run:.1f};"
                     f"steady_per_slot_us={us:.0f};"
                     f"batch8_per_slot_us={batch_us:.0f}"))

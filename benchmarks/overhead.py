"""Paper Fig. 5: ESDP computation overhead vs bipartite-graph scale."""
from __future__ import annotations

import time

import jax

from repro.core import build_tables, generate_instance, make_esdp_policy, simulate


def fig5_overhead(rows):
    for (L, R, p) in ((8, 40, 0.1), (8, 80, 0.1), (16, 80, 0.1),
                      (16, 160, 0.1)):
        inst = generate_instance(seed=1, n_ports=L, n_servers=R, edge_prob=p)
        tables = build_tables(inst.A, inst.c)
        T = 200
        pol = make_esdp_policy(inst, T, tables=tables)
        t0 = time.time()
        simulate(inst, pol, T, seed=0, tables=tables)   # includes jit
        compile_and_run = time.time() - t0
        t0 = time.time()
        simulate(inst, pol, T, seed=1, tables=tables)   # cached jit
        steady = time.time() - t0
        us = steady / T * 1e6
        rows.append((f"fig5/L{L}_R{R}_E{inst.n_edges}", f"{us:.0f}",
                     f"compile+run_s={compile_and_run:.1f};"
                     f"steady_per_slot_us={us:.0f}"))

"""Paper Figs. 6–10: sensitivity to solution-space size, δ(t), g(t), ρ, |E|."""
from __future__ import annotations

import numpy as np

from repro.core import (build_tables, generate_instance, make_esdp_policy,
                        make_hswf_policy, simulate)
from repro.core.stats import DELTA_VARIANTS, G_VARIANTS

T = 1500
SEEDS = (11, 12)


def _asw(inst, policy_factory, **kw):
    tables = build_tables(inst.A, inst.c)
    vals = [simulate(inst, policy_factory(inst, tables), T, seed=s,
                     tables=tables).asw[-1] for s in SEEDS]
    return float(np.mean(vals))


def fig6_solution_space(rows):
    """Grow X via capacities: larger c ⇒ more feasible dispatch vectors."""
    for c_hi in (1, 2, 4, 6):
        inst = generate_instance(seed=2, c_lo=1, c_hi=c_hi)
        e = _asw(inst, lambda i, tb: make_esdp_policy(i, T, tables=tb))
        h = _asw(inst, lambda i, tb: make_hswf_policy(i))
        rows.append((f"fig6/c_hi{c_hi}", f"esdp={e:.1f}",
                     f"hswf={h:.1f};states={build_tables(inst.A, inst.c).n_states}"))


def fig7_delta(rows):
    """δ(t) variants: little ASW effect, big S(t)-size (overhead) effect."""
    inst = generate_instance(seed=0)
    from repro.core.stats import s_cap_for_horizon
    for name, fn in DELTA_VARIANTS.items():
        e = _asw(inst, lambda i, tb: make_esdp_policy(i, T, delta_fn=fn,
                                                      tables=tb))
        rows.append((f"fig7/delta_{name}", f"esdp={e:.1f}",
                     f"s_cap={s_cap_for_horizon(T, inst.m, fn)}"))


def fig8_g(rows):
    """g(t) variants: ln(t+1) should win 'overwhelmingly' (paper Fig. 8)."""
    inst = generate_instance(seed=0)
    for name, fn in G_VARIANTS.items():
        e = _asw(inst, lambda i, tb: make_esdp_policy(i, T, g_fn=fn,
                                                      tables=tb))
        rows.append((f"fig8/g_{name}", f"esdp={e:.1f}", ""))


def fig9_rho(rows):
    for rho in (0.3, 0.6, 0.9):
        inst = generate_instance(seed=4, rho=rho)
        e = _asw(inst, lambda i, tb: make_esdp_policy(i, T, tables=tb))
        h = _asw(inst, lambda i, tb: make_hswf_policy(i))
        rows.append((f"fig9/rho{rho}", f"esdp={e:.1f}", f"hswf={h:.1f}"))


def fig10_edges(rows):
    for p in (0.05, 0.1, 0.2, 0.4):
        inst = generate_instance(seed=5, edge_prob=p)
        e = _asw(inst, lambda i, tb: make_esdp_policy(i, T, tables=tb))
        h = _asw(inst, lambda i, tb: make_hswf_policy(i))
        rows.append((f"fig10/p{p}", f"esdp={e:.1f}",
                     f"hswf={h:.1f};E={inst.n_edges}"))

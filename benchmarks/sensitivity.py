"""Paper Figs. 6–10: sensitivity to solution-space size, δ(t), g(t), ρ, |E|.

Every figure is a declarative :class:`SweepSpec`; the sweep engine runs one
jitted vmapped call per (grid-point × policy) instead of the old per-seed
Python loop, so the printed means are over the same seeds as before.
"""
from __future__ import annotations

from repro.core.esdp import esdp_factory
from repro.core.baselines import hswf_factory
from repro.core.stats import DELTA_VARIANTS, G_VARIANTS, s_cap_for_horizon
from repro.experiments import GridPoint, SweepSpec, run_spec

T = 1500
SEEDS = (11, 12)

FIG6_SPEC = SweepSpec(
    name="fig6", T=T, seeds=SEEDS,
    policies={"esdp": esdp_factory(), "hswf": hswf_factory()},
    grid=tuple(GridPoint(f"c_hi{c}", instance_kwargs={"seed": 2, "c_lo": 1,
                                                      "c_hi": c})
               for c in (1, 2, 4, 6)),
)

FIG7_SPEC = SweepSpec(
    name="fig7", T=T, seeds=SEEDS,
    policies={f"delta_{name}": esdp_factory(delta_fn=fn)
              for name, fn in DELTA_VARIANTS.items()},
    instance_kwargs={"seed": 0},
)

FIG8_SPEC = SweepSpec(
    name="fig8", T=T, seeds=SEEDS,
    policies={f"g_{name}": esdp_factory(g_fn=fn)
              for name, fn in G_VARIANTS.items()},
    instance_kwargs={"seed": 0},
)

FIG9_SPEC = SweepSpec(
    name="fig9", T=T, seeds=SEEDS,
    policies={"esdp": esdp_factory(), "hswf": hswf_factory()},
    grid=tuple(GridPoint(f"rho{rho}", instance_kwargs={"seed": 4, "rho": rho})
               for rho in (0.3, 0.6, 0.9)),
)

FIG10_SPEC = SweepSpec(
    name="fig10", T=T, seeds=SEEDS,
    policies={"esdp": esdp_factory(), "hswf": hswf_factory()},
    grid=tuple(GridPoint(f"p{p}", instance_kwargs={"seed": 5, "edge_prob": p})
               for p in (0.05, 0.1, 0.2, 0.4)),
)


def _paired(spec, smoke):
    """esdp-vs-hswf rows keyed by grid point."""
    by_point: dict[str, dict] = {}
    for r in run_spec(spec.smoke() if smoke else spec):
        by_point.setdefault(r.point, {})[r.policy] = r
    return by_point


def fig6_solution_space(rows, smoke=False):
    """Grow X via capacities: larger c ⇒ more feasible dispatch vectors."""
    for point, res in _paired(FIG6_SPEC, smoke).items():
        rows.append((f"fig6/{point}", f"esdp={res['esdp'].asw_mean:.1f}",
                     f"hswf={res['hswf'].asw_mean:.1f};"
                     f"states={res['esdp'].tables.n_states}"))


def fig7_delta(rows, smoke=False):
    """δ(t) variants: little ASW effect, big S(t)-size (overhead) effect."""
    spec = FIG7_SPEC.smoke() if smoke else FIG7_SPEC
    for r in run_spec(spec):
        delta_fn = DELTA_VARIANTS[r.policy.removeprefix("delta_")]
        rows.append((f"fig7/{r.policy}", f"esdp={r.asw_mean:.1f}",
                     f"s_cap={s_cap_for_horizon(r.T, r.instance.m, delta_fn)}"))


def fig8_g(rows, smoke=False):
    """g(t) variants: ln(t+1) should win 'overwhelmingly' (paper Fig. 8)."""
    spec = FIG8_SPEC.smoke() if smoke else FIG8_SPEC
    for r in run_spec(spec):
        rows.append((f"fig8/{r.policy}", f"esdp={r.asw_mean:.1f}", ""))


def fig9_rho(rows, smoke=False):
    for point, res in _paired(FIG9_SPEC, smoke).items():
        rows.append((f"fig9/{point}", f"esdp={res['esdp'].asw_mean:.1f}",
                     f"hswf={res['hswf'].asw_mean:.1f}"))


def fig10_edges(rows, smoke=False):
    for point, res in _paired(FIG10_SPEC, smoke).items():
        rows.append((f"fig10/{point}", f"esdp={res['esdp'].asw_mean:.1f}",
                     f"hswf={res['hswf'].asw_mean:.1f};"
                     f"E={res['esdp'].instance.n_edges}"))

"""Deterministic stdlib-only formatter for the black-compatible subset.

The CI format gate (`ruff format --check`) was advisory for a long time
because the tree carried two systematic divergences from black style:
column-aligned trailing comments and aligned-under-paren ("hanging
indent") function signatures.  This tool machine-normalizes exactly
those divergences, deterministically, using only the standard library —
so the tree can be formatted (and the gate kept blocking) on machines
where ruff itself is not installable.

Two transforms, both semantics-preserving and verified per file by
``ast.dump`` equality before anything is written:

1. **Inline-comment spacing** — exactly two spaces between code and a
   trailing ``#`` comment (black's rule).  Standalone comments are
   untouched.

2. **Def-signature shape** — every multi-line ``def``/``async def``
   signature is rewritten into one of black's canonical forms, tried in
   order:

   * one line, when ``def name(p1, p2) -> ret:`` fits in 88 columns;
   * the three-line "hug" form (all params on a single line indented
     four spaces, closing paren back at def indent) when that fits;
   * exploded one-param-per-line with a magic trailing comma otherwise.

   A trailing comma already present at the top level of the parameter
   list forces the exploded form (black's magic trailing comma).
   Signatures containing comments are left alone and reported.

``ruff format`` remains the canonical formatter: where it disagrees
with this tool, run it and commit.  This tool exists so the invariant
is checkable offline and in tier-1 tests.

Usage::

    python tools/format.py [--check] [--diff] PATH [PATH ...]

``--check`` exits 1 listing files that would change (CI mode).
"""

from __future__ import annotations

import argparse
import ast
import difflib
import io
import sys
import tokenize
from pathlib import Path

LINE_LIMIT = 88

# ---------------------------------------------------------------------------
# small string-aware scanner helpers
# ---------------------------------------------------------------------------

_OPEN = {"(": ")", "[": "]", "{": "}"}
_CLOSE = {")", "]", "}"}


def _skip_string(text: str, i: int) -> int:
    """Return the index just past the string literal starting at ``i``.

    ``text[i]`` must be a quote character.  Handles triple quotes and
    backslash escapes.
    """
    q = text[i]
    if text[i : i + 3] == q * 3:
        end = text.find(q * 3, i + 3)
        return len(text) if end < 0 else end + 3
    j = i + 1
    while j < len(text):
        if text[j] == "\\":
            j += 2
        elif text[j] == q:
            return j + 1
        else:
            j += 1
    return j


def _split_top_level(params: str) -> list[str]:
    """Split a parameter-list body on commas at bracket depth zero."""
    parts, depth, start, i = [], 0, 0, 0
    while i < len(params):
        ch = params[i]
        if ch in "'\"":
            i = _skip_string(params, i)
            continue
        if ch in _OPEN:
            depth += 1
        elif ch in _CLOSE:
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(params[start:i])
            start = i + 1
        i += 1
    parts.append(params[start:])
    return parts


def _collapse_ws(text: str) -> str:
    """Collapse whitespace runs to single spaces, except inside strings."""
    out, i = [], 0
    while i < len(text):
        ch = text[i]
        if ch in "'\"":
            j = _skip_string(text, i)
            out.append(text[i:j])
            i = j
        elif ch in " \t\n\r":
            j = i
            while j < len(text) and text[j] in " \t\n\r":
                j += 1
            out.append(" ")
            i = j
        else:
            out.append(ch)
            i += 1
    return "".join(out).strip()


def _ends_in_colon(line: str) -> bool:
    """True when the code part of ``line`` (trailing comment stripped) ends ``:``."""
    i = 0
    while i < len(line):
        ch = line[i]
        if ch in "'\"":
            i = _skip_string(line, i)
        elif ch == "#":
            line = line[:i]
            break
        else:
            i += 1
    return line.rstrip().endswith(":")


# ---------------------------------------------------------------------------
# transform 2: def-signature shape
# ---------------------------------------------------------------------------


def _sig_region(src: str, def_line: int) -> tuple[int, int, int, int] | None:
    """Locate the signature starting on 1-based ``def_line``.

    Returns ``(open_idx, close_idx, colon_idx, end_line)`` as absolute
    character offsets of ``(``, its matching ``)``, the following ``:``,
    and the 1-based line the colon sits on — or None when the region
    cannot be resolved cleanly (e.g. a comment inside the signature).
    """
    line_starts = [0]
    for ln in src.splitlines(keepends=True):
        line_starts.append(line_starts[-1] + len(ln))
    base = line_starts[def_line - 1]
    open_idx = src.find("(", base)
    if open_idx < 0:
        return None
    depth, i = 0, open_idx
    while i < len(src):
        ch = src[i]
        if ch in "'\"":
            i = _skip_string(src, i)
            continue
        if ch == "#":  # comment inside the signature: bail out
            return None
        if ch in _OPEN:
            depth += 1
        elif ch in _CLOSE:
            depth -= 1
            if depth == 0:
                break
        i += 1
    else:
        return None
    close_idx = i
    # scan forward to the def-colon (may cross lines for `-> ret:`)
    j = close_idx + 1
    depth = 0
    while j < len(src):
        ch = src[j]
        if ch in "'\"":
            j = _skip_string(src, j)
            continue
        if ch == "#":
            return None
        if ch in _OPEN:
            depth += 1
        elif ch in _CLOSE:
            depth -= 1
        elif ch == ":" and depth == 0:
            break
        j += 1
    else:
        return None
    colon_idx = j
    end_line = src.count("\n", 0, colon_idx) + 1
    # inline body on the colon line is out of scope — leave the def alone
    rest = src[colon_idx + 1 : line_starts[end_line] - 1 if end_line < len(line_starts) else len(src)]
    if rest.strip():
        return None
    return open_idx, close_idx, colon_idx, end_line


def _render_def(indent: str, head: str, params: list[str], tail: str) -> str | None:
    """Render a def signature in black's canonical forms, narrowest first."""
    force_explode = bool(params) and params[-1] == ""
    clean = [p for p in params if p]
    if not force_explode:
        one = f"{indent}{head}({', '.join(clean)}){tail}"
        if len(one) <= LINE_LIMIT:
            return one
        hug_body = f"{indent}    {', '.join(clean)}"
        if len(hug_body) <= LINE_LIMIT:
            return f"{indent}{head}(\n{hug_body}\n{indent}){tail}"
    lines = [f"{indent}{head}("]
    lines += [f"{indent}    {p}," for p in clean]
    lines.append(f"{indent}){tail}")
    return "\n".join(lines)


def _format_defs(src: str) -> tuple[str, list[str]]:
    """Rewrite multi-line def signatures into black's canonical forms."""
    skipped: list[str] = []
    try:
        tree = ast.parse(src)
    except SyntaxError as exc:  # pragma: no cover - tree is expected to parse
        return src, [f"syntax error: {exc}"]
    edits: list[tuple[int, int, str]] = []  # (start_offset, end_offset, text)
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        region = _sig_region(src, node.lineno)
        if region is None:
            if not _ends_in_colon(src.splitlines()[node.lineno - 1]):
                skipped.append(f"line {node.lineno}: def {node.name} (unresolvable signature)")
            continue
        open_idx, close_idx, colon_idx, end_line = region
        if end_line == node.lineno:
            continue  # already one line
        line_start = src.rfind("\n", 0, open_idx) + 1
        indent = src[line_start : line_start + (len(src[line_start:]) - len(src[line_start:].lstrip()))]
        head = _collapse_ws(src[line_start + len(indent) : open_idx])
        params = [_collapse_ws(p) for p in _split_top_level(src[open_idx + 1 : close_idx])]
        if params == [""]:
            params = []
        tail = _collapse_ws(src[close_idx + 1 : colon_idx + 1])
        tail = f" {tail}" if tail != ":" else tail
        rendered = _render_def(indent, head, params, tail)
        if rendered is None:
            skipped.append(f"line {node.lineno}: def {node.name}")
            continue
        edits.append((line_start, colon_idx + 1, rendered))
    for start, end, text in sorted(edits, reverse=True):
        src = src[:start] + text + src[end:]
    return src, skipped


# ---------------------------------------------------------------------------
# transform 1: inline-comment spacing
# ---------------------------------------------------------------------------


def _format_comments(src: str) -> str:
    """Normalize spacing before trailing comments to exactly two spaces."""
    lines = src.splitlines(keepends=True)
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(src).readline))
    except tokenize.TokenError:  # pragma: no cover - tree is expected to parse
        return src
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        row, col = tok.start
        line = lines[row - 1]
        code = line[:col]
        if not code.strip():
            continue  # standalone comment: indent untouched
        fixed = code.rstrip() + "  " + line[col:]
        lines[row - 1] = fixed
    return "".join(lines)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def format_source(src: str) -> tuple[str, list[str]]:
    """Apply both transforms; the result must be AST-identical to the input."""
    out, skipped = _format_defs(src)
    out = _format_comments(out)
    if ast.dump(ast.parse(out)) != ast.dump(ast.parse(src)):
        raise ValueError("transform changed program semantics — refusing to write")
    return out, skipped


def _iter_files(paths: list[str]):
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        elif path.suffix == ".py":
            yield path


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="files or directories to format")
    ap.add_argument("--check", action="store_true", help="exit 1 if any file would change")
    ap.add_argument("--diff", action="store_true", help="print unified diffs instead of writing")
    args = ap.parse_args(argv)

    changed, errors = [], []
    for path in _iter_files(args.paths):
        src = path.read_text()
        try:
            out, skipped = format_source(src)
        except (ValueError, SyntaxError) as exc:
            errors.append(f"{path}: {exc}")
            continue
        for s in skipped:
            print(f"note: {path}: skipped {s}", file=sys.stderr)
        if out == src:
            continue
        changed.append(str(path))
        if args.diff:
            sys.stdout.writelines(
                difflib.unified_diff(
                    src.splitlines(keepends=True),
                    out.splitlines(keepends=True),
                    fromfile=str(path),
                    tofile=str(path),
                )
            )
        elif not args.check:
            path.write_text(out)
            print(f"reformatted {path}")
    for err in errors:
        print(f"error: {err}", file=sys.stderr)
    if errors:
        return 2
    if args.check and changed:
        print(f"{len(changed)} file(s) would be reformatted:")
        for f in changed:
            print(f"  {f}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

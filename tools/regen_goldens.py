#!/usr/bin/env python
"""Regenerate the golden-trace regression file used by
``tests/test_scenario_contracts.py``.

The goldens pin the mean utility (final average social welfare and final
cumulative regret, averaged over seeds) of every registered fluctuation
regime x a representative policy slate on one small fixed grid.  They
are a *tripwire*, not a spec: a legitimate change to a regime, a policy,
or the simulator should regenerate them with

    PYTHONPATH=src python tools/regen_goldens.py

and the diff of ``tests/goldens/scenario_goldens.json`` becomes part of
the review — silent drift in any regime/policy pair fails the golden
test instead of sailing through.

The grid is deliberately tiny (3 ports x 8 servers, T=120, 2 seeds) so
the whole matrix regenerates in well under a minute on CPU.
"""
from __future__ import annotations

import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import build_tables, generate_instance, simulate_batch
from repro.experiments import get_scenario, scenario_names
from repro.experiments.sweep import default_policies

GOLDEN_PATH = (pathlib.Path(__file__).resolve().parents[1]
               / "tests" / "goldens" / "scenario_goldens.json")

# the fixed grid — changing any of these invalidates every golden, so
# the test file asserts they match what it replays
GRID = dict(instance_kwargs=dict(seed=5, n_ports=3, n_servers=8,
                                 edge_prob=0.4),
            T=120, seeds=(0, 1),
            policies=("esdp", "hswf", "msr_greedy", "msr_index"))


def regenerate() -> dict:
    inst = generate_instance(**GRID["instance_kwargs"])
    tables = build_tables(inst.A, inst.c)
    T, seeds = GRID["T"], GRID["seeds"]
    factories = default_policies(names=GRID["policies"])
    goldens: dict = {"grid": {**GRID, "seeds": list(seeds),
                              "policies": list(GRID["policies"])},
                     "values": {}}
    for regime in scenario_names():
        scn = get_scenario(regime)
        for pname, factory in factories.items():
            policy = factory(inst, T, tables)
            res = simulate_batch(inst, policy, T, seeds, tables=tables,
                                 scenario=scn)
            goldens["values"][f"{regime}/{pname}"] = {
                "asw_final_mean": float(res.asw[:, -1].mean()),
                "regret_final_mean": float(res.regret[:, -1].mean()),
            }
    return goldens


def main() -> None:
    goldens = regenerate()
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN_PATH.write_text(json.dumps(goldens, indent=2, sort_keys=True)
                           + "\n")
    print(f"wrote {len(goldens['values'])} goldens -> {GOLDEN_PATH}")


if __name__ == "__main__":
    main()
